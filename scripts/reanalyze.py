"""Re-derive roofline fields in experiments/dryrun/*.json from the archived
per-device HLO (.hlo.gz) — analyzer iterations without recompiling.

    PYTHONPATH=src python scripts/reanalyze.py
"""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.utils import roofline as rl
from repro.utils import hlo_analyzer as H

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def main():
    for jf in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        hf = jf[:-5] + ".hlo.gz"
        if not os.path.exists(hf):
            continue
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        r = json.load(open(jf))
        tot = H.analyze(hlo)
        roof = rl.Roofline(tot.flops, tot.bytes,
                           {k: int(v) for k, v in tot.coll_bytes.items()},
                           r["chips"], r["roofline"].get("model_flops", 0.0))
        r["roofline"] = roof.as_dict()
        json.dump(r, open(jf, "w"), indent=2)
        print(f"reanalyzed {os.path.basename(jf)}: dominant={roof.dominant}")


if __name__ == "__main__":
    main()
