"""Dev scratch: exercise every smoke config end to end (not a test)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_smoke_config, CompressorConfig
from repro.models.build import build_model, syn_spec_for, syn_loss_fn
from repro.models.encdec import EncDec
from repro.core import threesfc

key = jax.random.PRNGKey(0)
B, S = 2, 32

for arch in ARCH_IDS:
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if isinstance(model, EncDec):
        frames = jax.random.normal(key, (B, cfg.num_mm_tokens, cfg.d_model))
        batch = {"frames": frames, "tokens": tokens}
    elif cfg.num_mm_tokens:
        batch = {"tokens": tokens,
                 "prefix_embeds": jax.random.normal(key, (B, cfg.num_mm_tokens, cfg.d_model))}
    else:
        batch = {"tokens": tokens}
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree_util.tree_leaves(grads)))
    assert jnp.isfinite(loss), f"{arch}: loss NaN"
    assert jnp.isfinite(gnorm), f"{arch}: grad NaN"

    # serving
    if isinstance(model, EncDec):
        logits, cache, t0 = model.prefill(params, batch["frames"], tokens, cache_len=S + 4)
    else:
        logits, cache, t0 = model.prefill(params, tokens, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size), (arch, logits.shape)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = model.decode_step(params, cache, tok, t0)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2)), f"{arch}: decode NaN"

    # 3SFC syn loss + grad-of-grad
    comp = CompressorConfig(syn_batch=1, syn_seq=4, soft_label_rank=0)
    spec = syn_spec_for(cfg, comp)
    syn = threesfc.init_syn(key, spec)
    lf = syn_loss_fn(model)
    res = threesfc.encode(lf, params, grads, syn, steps=1, lr=0.1)
    assert jnp.isfinite(res.cosine), f"{arch}: encode NaN"
    print(f"{arch:24s} params={n:>10,} loss={float(loss):8.4f} "
          f"syn_cos={float(res.cosine):+.4f}")

print("ALL OK")
