"""Static-analysis gate: IR contracts + repo lints + protocol analysis.

One driver for the three layers of ``repro.analysis`` plus (when the
binary exists) ruff with the repo's pinned ``pyproject.toml`` rule set:

* **IR contracts** — compiles every constructible
  strategy × fan-out × wire × fused × faulted round configuration at tiny
  shapes in a forced-8-device child (the ``bench_collectives`` recipe)
  and checks the five ``repro.analysis.contracts`` rules against the
  optimized HLO.
* **Repo lint** — the four AST rules of ``repro.analysis.lint`` over
  ``src/``.
* **Protocol** — the ``MSG_*`` transition-table rules and the
  shared-state locking rules of ``repro.analysis.protocol``.
* **ruff** — style/correctness lints pinned in ``pyproject.toml``; the
  CI image may not ship ruff, in which case the stanza records
  ``available: false`` and the layer is skipped (never silently green:
  the artifact says so).

Emits ``BENCH_static.json`` (repo root, diffed by
``scripts/check_bench.py``: violations must stay 0, rule and config
coverage may only grow) and exits 1 on any violation.

    python scripts/check_static.py            # full gate (~2 min)
    python scripts/check_static.py --skip-ir  # AST layers only (seconds)
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
from typing import Dict

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

IR_CHILD_TIMEOUT_S = 1200


def run_ir_layer() -> Dict:
    """The contract matrix needs >=4 XLA devices before jax initializes,
    so it runs in a child under the shared forced-8-device recipe."""
    from benchmarks.bench_collectives import multidev_env
    p = subprocess.run([sys.executable, "-m", "repro.analysis.ir"],
                       env=multidev_env(), cwd=REPO, capture_output=True,
                       text=True, timeout=IR_CHILD_TIMEOUT_S)
    if p.returncode != 0:
        sys.stderr.write(p.stdout + p.stderr)
        raise RuntimeError(f"IR contract child failed (exit {p.returncode})")
    return json.loads(p.stdout)


def run_ruff_layer() -> Dict:
    """ruff with the pyproject.toml pins — gated on the binary existing
    (the CI image does not bake it in; nothing may be pip-installed)."""
    exe = shutil.which("ruff")
    if exe is None:
        return {"available": False, "violations": []}
    p = subprocess.run(
        [exe, "check", "--output-format", "concise",
         "src", "scripts", "benchmarks", "tests"],
        cwd=REPO, capture_output=True, text=True, timeout=300)
    lines = [ln for ln in p.stdout.splitlines()
             if ln.strip() and not ln.startswith(("Found", "All checks"))]
    return {"available": True, "exit": p.returncode,
            "violations": lines if p.returncode != 0 else []}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-ir", action="store_true",
                    help="skip the compile-time contract matrix (the AST "
                         "layers run in seconds; the artifact is NOT "
                         "emitted without the IR layer)")
    args = ap.parse_args(argv)

    from repro.analysis import lint, protocol

    report: Dict = {}
    if not args.skip_ir:
        print("== IR contracts: compiling the round matrix "
              "(forced 8-device child) ==")
        report["ir"] = run_ir_layer()
        ir = report["ir"]
        print(f"  {ir['configs_evaluated']} configs, "
              f"{ir['rules_evaluated']} rule evaluations, "
              f"{ir['violations']} violation(s)")
        for cname, c in ir["contracts"].items():
            mark = "PASS" if not c["violations"] else "FAIL"
            print(f"  [{mark}] {cname}: {c['evaluated']} evaluated")
            for v in c["violations"]:
                print(f"      - {v}")

    print("== Repo lint (AST over src/) ==")
    report["lint"] = lint.run_lint()
    for rname, r in report["lint"]["rules"].items():
        mark = "PASS" if not r["violations"] else "FAIL"
        print(f"  [{mark}] {rname}: {r['evaluated']} evaluated")
        for v in r["violations"]:
            print(f"      - {v}")

    print("== Protocol analysis (transport/worker) ==")
    report["protocol"] = protocol.run_protocol()
    for rname, r in report["protocol"]["rules"].items():
        mark = "PASS" if not r["violations"] else "FAIL"
        print(f"  [{mark}] {rname}: {r['evaluated']} evaluated")
        for v in r["violations"]:
            print(f"      - {v}")

    print("== ruff (pyproject.toml pins) ==")
    report["ruff"] = run_ruff_layer()
    if not report["ruff"]["available"]:
        print("  ruff not installed in this environment — layer skipped "
              "(recorded in the artifact)")
    else:
        mark = "PASS" if not report["ruff"]["violations"] else "FAIL"
        print(f"  [{mark}] exit {report['ruff']['exit']}")
        for v in report["ruff"]["violations"][:50]:
            print(f"      - {v}")

    layers = [k for k in ("ir", "lint", "protocol") if k in report]
    report["rules_evaluated"] = sum(report[k]["rules_evaluated"]
                                    for k in layers)
    report["violations"] = (sum(report[k]["violations"] for k in layers)
                            + len(report["ruff"]["violations"]))
    report["configs_evaluated"] = (report["ir"]["configs_evaluated"]
                                   if "ir" in report else 0)
    report["pass"] = report["violations"] == 0

    if args.skip_ir:
        # a partial run must never overwrite the gated artifact with one
        # whose coverage collapsed — check_bench would flag the shrink,
        # but the committed artifact should always be the full gate
        print(f"\ncheck_static (partial, --skip-ir): "
              f"{report['rules_evaluated']} rules, "
              f"{report['violations']} violation(s); artifact not written")
    else:
        out_dir = os.path.join(REPO, "experiments", "results")
        os.makedirs(out_dir, exist_ok=True)
        for path in (os.path.join(REPO, "BENCH_static.json"),
                     os.path.join(out_dir, "static.json")):
            with open(path, "w") as f:
                json.dump(report, f, indent=2)
        print(f"\ncheck_static: {report['configs_evaluated']} IR configs, "
              f"{report['rules_evaluated']} rule evaluations, "
              f"{report['violations']} violation(s) -> BENCH_static.json")
    return 0 if report["pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
