#!/usr/bin/env python
"""Analyze a repro.obs round trace: phase latency quantiles, byte
reconciliation against the transport ledger, straggler / dead-worker
attribution, and a ``--replay`` summary shaped as input for the
trace-driven round simulator (ROADMAP million-client item).

    PYTHONPATH=src python scripts/trace_report.py <out>/trace.jsonl \
        [--ledger <ledger.json>] [--replay replay.json] [--json]

Input is the merged JSONL trace ``launch/train.py --trace`` writes (worker
spans already shifted onto the server clock). The ledger file is a
``Channel.ledger()`` dict (uplink/downlink LinkStats snapshots + overhead
counters); with it, the report checks that the bytes the trace saw are
EXACTLY the bytes the ledger billed — the reconciliation the observability
bench gates on.

How to read a straggle: the server's ``round.collect`` span ends at the
deadline with ``delivered < expected``; the missing client's ``round.outcome``
event says ``undelivered`` (not ``dead`` — its heartbeats kept arriving);
and that client's own ``worker.compute``/``worker.straggle`` spans overrun
the server's deadline window. ``attribute()`` automates exactly that
cross-check.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional

# server-side phases every executed round must show (the completeness gate)
ROUND_PHASES = ("round.encode", "round.broadcast", "round.collect",
                "round.ack", "round.aggregate")


def load_records(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _quantile(sorted_vals: List[float], q: float) -> Optional[float]:
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


def phase_quantiles(records: List[Dict[str, Any]]) -> Dict[str, Dict]:
    """Per span-name duration stats (seconds): count/p50/p95/p99/max/total."""
    durs: Dict[str, List[float]] = defaultdict(list)
    for r in records:
        if r.get("kind") == "span" and r.get("t1") is not None:
            durs[r["name"]].append((int(r["t1"]) - int(r["t0"])) / 1e9)
    out = {}
    for name, vals in sorted(durs.items()):
        vals.sort()
        out[name] = {"count": len(vals), "p50": _quantile(vals, 0.50),
                     "p95": _quantile(vals, 0.95), "p99": _quantile(vals, 0.99),
                     "max": vals[-1], "total": sum(vals)}
    return out


def rounds_in_trace(records: List[Dict[str, Any]]) -> List[int]:
    return sorted({int(r["round"]) for r in records
                   if r.get("name") == "round" and r.get("kind") == "span"})


def phase_completeness(records: List[Dict[str, Any]]) -> Dict[int, List[str]]:
    """round -> list of missing server phases (empty list == complete)."""
    seen: Dict[int, set] = defaultdict(set)
    for r in records:
        if r.get("kind") == "span" and r.get("name") in ROUND_PHASES:
            seen[int(r["round"])].add(r["name"])
    return {rnd: [p for p in ROUND_PHASES if p not in seen[rnd]]
            for rnd in rounds_in_trace(records)}


def trace_bytes(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Sum the data-frame bytes the trace saw, per direction and per round.

    Every transport ``LinkStats._record`` emits exactly one rx_frame /
    tx_frame event carrying the billed byte count (including re-sends,
    filtered and stale frames — the bytes crossed the wire), so these sums
    must equal the ledger's ``total_bytes`` exactly."""
    up_total = down_total = 0
    up_rounds: Dict[int, int] = defaultdict(int)
    down_rounds: Dict[int, int] = defaultdict(int)
    for r in records:
        if r.get("name") == "rx_frame":
            up_total += int(r["bytes"])
            up_rounds[int(r["round"])] += int(r["bytes"])
        elif r.get("name") == "tx_frame":
            down_total += int(r["bytes"])
            down_rounds[int(r["round"])] += int(r["bytes"])
    return {"uplink_bytes": up_total, "downlink_bytes": down_total,
            "uplink_per_round": dict(up_rounds),
            "downlink_per_round": dict(down_rounds)}


def reconcile(records: List[Dict[str, Any]],
              ledger: Dict[str, Any]) -> Dict[str, Any]:
    """Trace-summed frame bytes vs the ledger's billed bytes (exact)."""
    tb = trace_bytes(records)
    up_billed = int(ledger["uplink"]["total_bytes"])
    down_billed = int(ledger["downlink"]["total_bytes"])
    return {"uplink_trace": tb["uplink_bytes"], "uplink_billed": up_billed,
            "uplink_exact": tb["uplink_bytes"] == up_billed,
            "downlink_trace": tb["downlink_bytes"],
            "downlink_billed": down_billed,
            "downlink_exact": tb["downlink_bytes"] == down_billed,
            "overhead_up": int(ledger.get("overhead_up", 0)),
            "overhead_down": int(ledger.get("overhead_down", 0))}


def attribute(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Explain every non-delivery: who straggled, whose frame the wire ate,
    who was dead — from the outcome tags plus the worker-side timeline."""
    # (round, client) -> outcome from the server's round.outcome events
    outcomes: Dict[tuple, str] = {}
    deadlines: Dict[int, float] = {}
    for r in records:
        if r.get("name") == "round.outcome":
            outcomes[(int(r["round"]), int(r["client"]))] = r["outcome"]
        elif r.get("name") == "round" and r.get("kind") == "span":
            if r.get("deadline_s") is not None:
                deadlines[int(r["round"])] = float(r["deadline_s"])
    # (round, client) -> worker-side busy seconds (decode+compute+straggle)
    worker_busy: Dict[tuple, float] = defaultdict(float)
    straggled: set = set()
    for r in records:
        if r.get("kind") != "span" or r.get("t1") is None:
            continue
        if r.get("name") in ("worker.decode", "worker.compute",
                             "worker.straggle"):
            k = (int(r["round"]), int(str(r["proc"]).rsplit("-", 1)[-1]))
            worker_busy[k] += (int(r["t1"]) - int(r["t0"])) / 1e9
            if r["name"] == "worker.straggle":
                straggled.add(k)
    # frames the injection seam / wire ate or corrupted
    lost_frames: set = set()
    for r in records:
        if r.get("name") == "rx_frame" and r.get("outcome") in ("filtered",
                                                                "corrupt"):
            lost_frames.add((int(r["round"]), int(r["client"])))

    causes: List[Dict[str, Any]] = []
    stragglers: Dict[int, List[int]] = defaultdict(list)
    dead: Dict[int, List[int]] = defaultdict(list)
    dropped: Dict[int, List[int]] = defaultdict(list)
    for (rnd, cid), outcome in sorted(outcomes.items()):
        if outcome == "delivered" or outcome == "sat_out":
            continue
        if outcome == "dead":
            cause = "dead"
            dead[cid].append(rnd)
        elif (rnd, cid) in straggled or worker_busy.get(
                (rnd, cid), 0.0) > deadlines.get(rnd, float("inf")):
            cause = "straggler"
            stragglers[cid].append(rnd)
        elif (rnd, cid) in lost_frames:
            cause = "frame_lost"
            dropped[cid].append(rnd)
        else:
            cause = "unknown"
        causes.append({"round": rnd, "client": cid, "outcome": outcome,
                       "cause": cause,
                       "worker_busy_s": round(worker_busy.get((rnd, cid),
                                                              0.0), 4),
                       "deadline_s": deadlines.get(rnd)})
    return {"undelivered": causes,
            "stragglers": {c: sorted(rs) for c, rs in stragglers.items()},
            "dead_workers": {c: sorted(rs) for c, rs in dead.items()},
            "frame_lost": {c: sorted(rs) for c, rs in dropped.items()}}


def replay_summary(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Per-round client availability/latency profile — the input shape for
    the trace-driven round simulator: for each round, when each client's
    frame arrived relative to the broadcast, and how it resolved."""
    round_spans = {int(r["round"]): r for r in records
                   if r.get("name") == "round" and r.get("kind") == "span"}
    arrivals: Dict[tuple, float] = {}
    for r in records:
        if r.get("name") == "rx_frame" and r.get("outcome") == "ok":
            rnd = int(r["round"])
            base = round_spans.get(rnd)
            if base is not None:
                arrivals[(rnd, int(r["client"]))] = \
                    (int(r["t"]) - int(base["t0"])) / 1e9
    outcomes: Dict[tuple, str] = {
        (int(r["round"]), int(r["client"])): r["outcome"]
        for r in records if r.get("name") == "round.outcome"}
    tb = trace_bytes(records)
    rounds = []
    for rnd, span in sorted(round_spans.items()):
        clients = sorted({c for (rr, c) in outcomes if rr == rnd})
        rounds.append({
            "round": rnd,
            "wall_s": (int(span["t1"]) - int(span["t0"])) / 1e9
            if span.get("t1") is not None else None,
            "deadline_s": span.get("deadline_s"),
            "bytes_up": tb["uplink_per_round"].get(rnd, 0),
            "bytes_down": tb["downlink_per_round"].get(rnd, 0),
            "clients": {str(c): {
                "outcome": outcomes.get((rnd, c)),
                "arrival_s": round(arrivals[(rnd, c)], 6)
                if (rnd, c) in arrivals else None,
            } for c in clients},
        })
    return {"schema": "repro.trace-replay/v1", "rounds": rounds}


def report(records: List[Dict[str, Any]],
           ledger: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The full analysis dict (what ``--json`` prints)."""
    missing = phase_completeness(records)
    out = {
        "rounds": rounds_in_trace(records),
        "phases": phase_quantiles(records),
        "phase_complete": all(not m for m in missing.values()),
        "missing_phases": {str(r): m for r, m in missing.items() if m},
        "bytes": trace_bytes(records),
        "attribution": attribute(records),
    }
    if ledger is not None:
        out["reconciliation"] = reconcile(records, ledger)
    return out


def _fmt_s(v: Optional[float]) -> str:
    return "-" if v is None else f"{v * 1e3:9.3f}ms"


def print_report(rep: Dict[str, Any]) -> None:
    rounds = rep["rounds"]
    print(f"rounds in trace: {len(rounds)} "
          f"({rounds[0]}..{rounds[-1]})" if rounds else "rounds in trace: 0")
    print(f"phase set complete: {rep['phase_complete']}")
    for rnd, m in rep["missing_phases"].items():
        print(f"  round {rnd} missing: {', '.join(m)}")
    print("\nper-phase latency (s):")
    print(f"  {'phase':<18} {'count':>5} {'p50':>11} {'p95':>11} "
          f"{'p99':>11} {'max':>11}")
    for name, st in rep["phases"].items():
        print(f"  {name:<18} {st['count']:>5} {_fmt_s(st['p50'])} "
              f"{_fmt_s(st['p95'])} {_fmt_s(st['p99'])} {_fmt_s(st['max'])}")
    b = rep["bytes"]
    print(f"\nbytes seen by trace: uplink={b['uplink_bytes']} "
          f"downlink={b['downlink_bytes']}")
    rec = rep.get("reconciliation")
    if rec is not None:
        print(f"ledger reconciliation: uplink {rec['uplink_trace']} vs "
              f"billed {rec['uplink_billed']} "
              f"({'EXACT' if rec['uplink_exact'] else 'MISMATCH'}); "
              f"downlink {rec['downlink_trace']} vs "
              f"billed {rec['downlink_billed']} "
              f"({'EXACT' if rec['downlink_exact'] else 'MISMATCH'})")
        print(f"control-plane overhead: up={rec['overhead_up']} "
              f"down={rec['overhead_down']}")
    att = rep["attribution"]
    if att["stragglers"]:
        for cid, rs in att["stragglers"].items():
            print(f"straggler: client {cid} (rounds {rs})")
    if att["dead_workers"]:
        for cid, rs in att["dead_workers"].items():
            print(f"dead worker: client {cid} (rounds {rs})")
    if att["frame_lost"]:
        for cid, rs in att["frame_lost"].items():
            print(f"frame lost/corrupt: client {cid} (rounds {rs})")
    if not (att["stragglers"] or att["dead_workers"] or att["frame_lost"]):
        print("no undelivered frames to attribute")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="analyze a repro.obs round trace")
    ap.add_argument("trace", help="trace.jsonl from launch/train.py --trace")
    ap.add_argument("--ledger", default=None,
                    help="Channel.ledger() JSON to reconcile bytes against")
    ap.add_argument("--replay", default=None, metavar="OUT",
                    help="write the trace-driven-simulator replay summary "
                         "to this JSON file")
    ap.add_argument("--json", action="store_true",
                    help="print the full analysis as JSON instead of text")
    args = ap.parse_args(argv)

    records = load_records(args.trace)
    ledger = None
    if args.ledger:
        with open(args.ledger) as f:
            ledger = json.load(f)
    rep = report(records, ledger)
    if args.replay:
        with open(args.replay, "w") as f:
            json.dump(replay_summary(records), f, indent=1)
        rep["replay_written"] = args.replay
    if args.json:
        print(json.dumps(rep, indent=1))
    else:
        print_report(rep)
        if args.replay:
            print(f"replay summary -> {args.replay}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
