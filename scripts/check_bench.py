"""Perf-trajectory gate: freshly emitted BENCH_*.json vs the committed ones.

Run the benches first (they rewrite the repo-root ``BENCH_*.json``
artifacts), then this script; it diffs each fresh artifact against the
version committed at git HEAD and FAILS (exit 1) on a regression:

* ``BENCH_kernels.json``: any increase in HBM passes per 3SFC objective
  evaluation (``encoder_fused_kernel_passes``, the BlockSpec contract
  number — immune to CPU noise), or the single-pass gate flipping false.
* ``BENCH_collectives.json``: any increase in the fused path's per-round
  collective bytes, any drop in the naive/fused wire-bytes ratio beyond
  1% (HLO byte totals are compile-deterministic; the slack only absorbs
  jax-version drift), any collective appearing inside the per-client
  encode region, or any ``pass_*`` gate flipping false.
* ``BENCH_wire.json``: any round-trip loss (decode∘encode no longer
  bit-exact, fresh-run absolute — a lossy codec is a bug regardless of
  HEAD), any growth in a method's measured wire bytes, any header-overhead
  regression >1% (relative), or any ``pass_*`` gate flipping false.
* ``BENCH_round_engine.json``: >5% drop in the engine's driver-path
  rounds/sec relative to the same run's python-loop baseline (the
  ``driver.speedup`` ratio — absolute rounds/sec swings 2x+ with load on
  the shared CI box, but the interleaved per-pair ratio cancels box speed;
  tolerance configurable with ``--tolerance`` / ``CHECK_BENCH_TOLERANCE``),
  any new host sync or dispatch per round (structural counters, exact),
  any per-round upload bytes, or any ``pass_*`` gate flipping false.

* ``BENCH_faults.json``: the zero-fault bitwise gate false (fresh-run
  absolute — a fault pipeline that perturbs healthy rounds is a bug
  regardless of HEAD), any increase in fedavg/threesfc 30%-dropout
  rounds-to-target vs HEAD, or the dropout-convergence gate flipping
  false.
* ``BENCH_transport.json``: the byte-match, socket-bitwise, residual-
  conservation, or straggle-isolation gate false (all fresh-run absolute —
  a wire that bills more than the codec bytes, diverges from the
  in-process oracle, leaks EF mass, or lets one straggler stall the round
  is a bug regardless of HEAD), any growth in the settled per-round
  uplink bytes vs HEAD (tiny or mlp scenario), or any ``pass_*`` gate
  flipping false.

* ``BENCH_observability.json``: the tracing-overhead gate false (traced
  driver throughput below 97% of untraced — telemetry that distorts what
  it measures), the complete-trace gate false (an executed round missing
  from the merged trace, a phase missing from a round, a straggler or
  eaten frame mis-attributed), or the bytes-parity gate false (trace-
  summed frame bytes != ledger-billed bytes — all fresh-run absolute), a
  drop in the traced-throughput ratio beyond the tolerance vs HEAD, or
  any ``pass_*`` gate flipping false.

* ``BENCH_recovery.json``: the bitwise-resume, rejoin-EF-conservation, or
  previous-checkpoint-survives gate false (all fresh-run absolute — a
  resume that diverges from the uninterrupted run, a rejoiner whose
  residual leaks mass, or a crash that corrupts the last recovery point is
  a bug regardless of HEAD), the rejoin 2x-convergence gate false, any
  growth in the chaos run's rounds-to-target vs HEAD, or any ``pass_*``
  gate flipping false.

* ``BENCH_static.json``: any static-analysis violation (IR contracts,
  repo lint, protocol rules — fresh-run absolute: a violation is a bug
  regardless of HEAD, and the ``pass`` flag must hold), any shrink vs
  HEAD in the rules-evaluated count or in the IR combo-matrix coverage
  (``configs_evaluated`` — the strategy × fan-out × wire matrix may only
  grow), or ruff flipping from clean to failing while available.

Artifacts present in the working tree but not at HEAD are new benches:
reported and skipped. Exit 2 on usage/setup errors (not a git checkout,
malformed JSON).

    PYTHONPATH=src python -m benchmarks.run --only kernels,round_engine
    python scripts/check_bench.py
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class GitUnavailable(Exception):
    pass


def _check_git():
    """HEAD must resolve, else every artifact would look 'new' and the gate
    would pass vacuously — that's a setup error (exit 2), not a clean run."""
    p = subprocess.run(["git", "rev-parse", "--verify", "HEAD"], cwd=REPO,
                       capture_output=True, text=True)
    if p.returncode != 0:
        raise GitUnavailable(p.stderr.strip() or "git rev-parse HEAD failed")


def _committed(name: str):
    """The artifact as committed at HEAD, or None if it's new at HEAD
    (_check_git has already ruled out a broken checkout)."""
    p = subprocess.run(["git", "cat-file", "-e", f"HEAD:{name}"], cwd=REPO,
                       capture_output=True, text=True)
    if p.returncode != 0:
        return None
    p = subprocess.run(["git", "show", f"HEAD:{name}"], cwd=REPO,
                       capture_output=True, text=True)
    if p.returncode != 0:
        raise GitUnavailable(f"git show HEAD:{name}: {p.stderr.strip()}")
    return json.loads(p.stdout)


def _get(d, path):
    for k in path.split("."):
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def check_kernels(fresh, base, tol):
    probs = []
    f_passes = _get(fresh, "encoder_fused_kernel_passes")
    b_passes = _get(base, "encoder_fused_kernel_passes")
    if f_passes is not None and b_passes is not None and \
            f_passes > b_passes + 1e-9:
        probs.append(f"HBM passes per objective evaluation increased: "
                     f"{b_passes:.3f} -> {f_passes:.3f}")
    if _get(base, "encoder_fused_single_pass") and \
            not _get(fresh, "encoder_fused_single_pass"):
        probs.append("encoder_fused_single_pass gate flipped to false")
    if _get(base, "allclose") and not _get(fresh, "allclose"):
        probs.append("kernel-vs-oracle allclose flipped to false")
    return probs


def check_round_engine(fresh, base, tol):
    probs = []
    f_sp = _get(fresh, "driver.speedup")
    b_sp = _get(base, "driver.speedup")
    if f_sp is not None and b_sp is not None and f_sp < (1 - tol) * b_sp:
        probs.append(f"driver-path rounds/sec (vs same-run loop baseline) "
                     f"dropped >{tol:.0%}: {b_sp:.2f}x -> {f_sp:.2f}x")
    for field in ("driver.engine.host_syncs_per_round",
                  "driver.engine.dispatches_per_round",
                  "driver.engine.upload_guard_violations"):
        f_v, b_v = _get(fresh, field), _get(base, field)
        if f_v is not None and b_v is not None and f_v > b_v + 1e-9:
            probs.append(f"{field} increased: {b_v:.3f} -> {f_v:.3f}")
    for gate in ("pass", "pass_driver_speedup", "pass_syncs_per_eval_block",
                 "pass_no_per_round_upload"):
        if _get(base, gate) and not _get(fresh, gate):
            probs.append(f"{gate} gate flipped to false")
    return probs


def check_collectives(fresh, base, tol):
    probs = []
    f_b = _get(fresh, "fused.collective_bytes_per_round")
    b_b = _get(base, "fused.collective_bytes_per_round")
    if f_b is not None and b_b is not None and f_b > 1.01 * b_b:
        probs.append(f"fused-decode per-round collective bytes increased: "
                     f"{b_b:.0f} -> {f_b:.0f}")
    f_r, b_r = _get(fresh, "wire_ratio"), _get(base, "wire_ratio")
    if f_r is not None and b_r is not None and f_r < 0.99 * b_r:
        probs.append(f"naive/fused wire-bytes ratio dropped: "
                     f"{b_r:.0f}x -> {f_r:.0f}x")
    for path in ("naive.encode_region_collectives",
                 "fused.encode_region_collectives"):
        v = _get(fresh, path)
        if v:
            probs.append(f"{path}: {v} collective(s) inside the per-client "
                         f"encode region (must be 0)")
    for gate in ("pass", "pass_wire_ratio", "pass_payload_scaling",
                 "pass_encode_region_clean", "pass_bitexact",
                 "pass_threesfc_tol"):
        if _get(base, gate) and not _get(fresh, gate):
            probs.append(f"{gate} gate flipped to false")
    return probs


def check_wire(fresh, base, tol):
    probs = []
    # round-trip loss fails absolutely: a codec that stopped being
    # bit-exact is broken even if HEAD's artifact predates the gate
    for flag in ("pass_roundtrip", "pass_recon_consistency"):
        if _get(fresh, flag) is False:
            probs.append(f"{flag} is false: decode∘encode round-trip loss")
    f_m, b_m = _get(fresh, "measure.methods"), _get(base, "measure.methods")
    if isinstance(f_m, dict) and isinstance(b_m, dict):
        for k in sorted(set(f_m) & set(b_m)):
            f_b, b_b = _get(f_m[k], "measured_bytes"), _get(b_m[k], "measured_bytes")
            if f_b is not None and b_b is not None and f_b > b_b:
                probs.append(f"{k}: measured wire bytes grew {b_b} -> {f_b}")
            f_h = _get(f_m[k], "header_overhead")
            b_h = _get(b_m[k], "header_overhead")
            if f_h is not None and b_h is not None and f_h > 1.01 * b_h:
                probs.append(f"{k}: header overhead regressed >1%: "
                             f"{b_h:.4f} -> {f_h:.4f}")
    # (pass_roundtrip/pass_recon_consistency are absolute above — not
    # repeated here, so one failure reports once)
    for gate in ("pass", "pass_signsgd_bytes", "pass_threesfc_bytes",
                 "pass_round_parity", "pass_channel_accounting"):
        if _get(base, gate) and not _get(fresh, gate):
            probs.append(f"{gate} gate flipped to false")
    return probs


def check_faults(fresh, base, tol):
    probs = []
    # absolute: the zero-fault bitwise identity is a correctness property
    # of the round pipeline, not a trajectory — losing it is a bug even in
    # the commit that introduces the bench
    if _get(fresh, "pass_zero_fault_bitwise") is False:
        bw = _get(fresh, "zero_fault_bitwise") or {}
        bad = sorted(k for k, v in bw.items() if not v)
        probs.append("pass_zero_fault_bitwise is false: null fault schedule "
                     f"no longer bitwise the unfaulted round ({bad})")
    # vs HEAD: 30%-dropout rounds-to-target must not regress per method
    for m in ("fedavg", "threesfc"):
        f_r = _get(fresh, f"grid.{m}.drop30_k0.rounds_to_target")
        b_r = _get(base, f"grid.{m}.drop30_k0.rounds_to_target")
        if b_r is not None and f_r is None:
            probs.append(f"{m}: no longer reaches target under 30% dropout "
                         f"(was {b_r} rounds)")
        elif f_r is not None and b_r is not None and f_r > b_r:
            probs.append(f"{m}: 30%-dropout rounds-to-target regressed "
                         f"{b_r} -> {f_r}")
    for gate in ("pass", "pass_dropout_convergence"):
        if _get(base, gate) and not _get(fresh, gate):
            probs.append(f"{gate} gate flipped to false")
    return probs


def check_transport(fresh, base, tol):
    probs = []
    # absolute: these are correctness properties of the socket transport
    # (exact billing, oracle parity, EF conservation, deadline isolation),
    # not trajectories — they fail even in the commit introducing the bench
    for flag, why in (
            ("pass_bytes_match", "wire bills more than N*nbytes (or "
             "diverges from BENCH_wire's measured bytes)"),
            ("pass_socket_bitwise", "live socket round no longer bitwise "
             "equal to the in-process oracle on the same fault pattern"),
            ("pass_residual_conservation", "EF residual mass not conserved "
             "on a dropped frame"),
            ("pass_straggle_isolation", "a straggler's sleep leaked into "
             "the round wall clock (deadline no longer isolates)")):
        if _get(fresh, flag) is False:
            probs.append(f"{flag} is false: {why}")
    # vs HEAD: settled-round uplink bytes must not grow
    for field in ("faulted.settled_null_round_bytes",
                  "bytes_mlp.per_message_bytes",
                  "bytes_mlp.n8_round_bytes"):
        f_v, b_v = _get(fresh, field), _get(base, field)
        if f_v is not None and b_v is not None and f_v > b_v:
            probs.append(f"{field} grew: {b_v} -> {f_v}")
    if _get(base, "pass") and not _get(fresh, "pass"):
        probs.append("pass gate flipped to false")
    return probs


def check_recovery(fresh, base, tol):
    probs = []
    # absolute: recovery correctness properties — bitwise resume, EF mass
    # conservation across a worker outage, and durability of the previous
    # recovery point — fail even in the commit introducing the bench
    for flag, why in (
            ("pass_bitwise_resume", "a SIGKILLed-and-resumed run no longer "
             "replays bitwise equal to the uninterrupted oracle"),
            ("pass_rejoin_ef_conserved", "a rejoining worker's EF residual "
             "is not bitwise the banked commit (mass leaked across the "
             "outage)"),
            ("pass_rejoin_convergence", "the crash+rejoin run needs more "
             "than 2x the no-crash rounds to the target loss"),
            ("pass_prev_ckpt_survives", "a crash during a checkpoint write "
             "corrupted the previously committed recovery point")):
        if _get(fresh, flag) is False:
            probs.append(f"{flag} is false: {why}")
    # vs HEAD: the chaos run's rounds-to-target must not regress
    f_r = _get(fresh, "worker_rejoin.rounds_to_target.chaos")
    b_r = _get(base, "worker_rejoin.rounds_to_target.chaos")
    if b_r is not None and f_r is None:
        probs.append(f"chaos run no longer reaches the target loss "
                     f"(was {b_r} rounds)")
    elif f_r is not None and b_r is not None and f_r > b_r:
        probs.append(f"chaos rounds-to-target regressed {b_r} -> {f_r}")
    if _get(base, "pass") and not _get(fresh, "pass"):
        probs.append("pass gate flipped to false")
    return probs


def check_observability(fresh, base, tol):
    probs = []
    # absolute: telemetry correctness properties — cheap-when-on, complete,
    # and byte-exact — fail even in the commit introducing the bench
    for flag, why in (
            ("pass_overhead", "tracing-on driver throughput fell below 97% "
             "of tracing-off (instrumentation distorts the hot path)"),
            ("pass_complete_trace", "merged trace missing rounds/phases or "
             "mis-attributing the straggler / eaten frame"),
            ("pass_bytes_parity", "trace-summed frame bytes != ledger-billed "
             "bytes (the trace is no longer a complete record of the wire)")):
        if _get(fresh, flag) is False:
            probs.append(f"{flag} is false: {why}")
    # vs HEAD: the traced/untraced throughput ratio must not sag
    f_r = _get(fresh, "overhead.traced_throughput_ratio")
    b_r = _get(base, "overhead.traced_throughput_ratio")
    if f_r is not None and b_r is not None and f_r < (1 - tol) * b_r:
        probs.append(f"traced-throughput ratio dropped >{tol:.0%}: "
                     f"{b_r:.3f} -> {f_r:.3f}")
    if _get(base, "pass") and not _get(fresh, "pass"):
        probs.append("pass gate flipped to false")
    return probs


def check_static(fresh, base, tol):
    probs = []
    # absolute: a static-analysis violation is a bug in the commit that
    # produced it, HEAD or not
    v = _get(fresh, "violations")
    if v:
        probs.append(f"{v} static-analysis violation(s) (must be 0)")
        for layer in ("ir", "lint", "protocol"):
            rules = _get(fresh, f"{layer}.contracts") \
                or _get(fresh, f"{layer}.rules") or {}
            for rname, r in sorted(rules.items()):
                for msg in r.get("violations", []):
                    probs.append(f"  [{layer}/{rname}] {msg}")
    if _get(fresh, "pass") is False and not v:
        probs.append("pass flag is false")
    # vs HEAD: coverage may only grow — fewer rule evaluations or a
    # smaller IR combo matrix means an invariant silently stopped being
    # checked
    for field, what in (("rules_evaluated", "rule evaluations"),
                        ("configs_evaluated", "IR matrix configs")):
        f_v, b_v = _get(fresh, field), _get(base, field)
        if f_v is not None and b_v is not None and f_v < b_v:
            probs.append(f"static-analysis coverage shrank: {what} "
                         f"{b_v} -> {f_v}")
    if _get(base, "ruff.available") and _get(base, "ruff.exit") == 0 \
            and _get(fresh, "ruff.available") \
            and _get(fresh, "ruff.exit") != 0:
        probs.append("ruff flipped from clean to failing")
    return probs


CHECKS = {
    "BENCH_kernels.json": check_kernels,
    "BENCH_round_engine.json": check_round_engine,
    "BENCH_collectives.json": check_collectives,
    "BENCH_wire.json": check_wire,
    "BENCH_faults.json": check_faults,
    "BENCH_transport.json": check_transport,
    "BENCH_recovery.json": check_recovery,
    "BENCH_observability.json": check_observability,
    "BENCH_static.json": check_static,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("CHECK_BENCH_TOLERANCE",
                                                 "0.05")),
                    help="fractional rounds/sec drop allowed (default 0.05)")
    args = ap.parse_args(argv)

    artifacts = sorted(glob.glob(os.path.join(REPO, "BENCH_*.json")))
    if not artifacts:
        print("check_bench: no BENCH_*.json artifacts found", file=sys.stderr)
        return 2
    try:
        _check_git()
    except GitUnavailable as e:
        print(f"check_bench: not a usable git checkout ({e})", file=sys.stderr)
        return 2
    failures = 0
    for path in artifacts:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                fresh = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"check_bench: cannot read {name}: {e}", file=sys.stderr)
            return 2
        try:
            base = _committed(name)
        except (GitUnavailable, json.JSONDecodeError) as e:
            print(f"check_bench: cannot read committed {name}: {e}",
                  file=sys.stderr)
            return 2
        checker = CHECKS.get(name)
        if checker is None:
            print(f"  {name}: no regression rules registered — skipped")
            continue
        # new-at-HEAD artifacts still get the checker's *absolute* rules
        # (every base-relative probe is None-guarded); otherwise a lossy
        # codec could land in the very commit that introduces its bench
        probs = checker(fresh, base, args.tolerance)
        label = "new artifact (absolute checks only)" if base is None else "ok"
        if probs:
            failures += len(probs)
            print(f"  {name}: REGRESSION")
            for p in probs:
                print(f"    - {p}")
        else:
            print(f"  {name}: {label}")
    if failures:
        print(f"check_bench: {failures} regression(s) vs HEAD", file=sys.stderr)
        return 1
    print("check_bench: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
