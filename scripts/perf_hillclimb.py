"""§Perf hillclimb driver: runs tagged dry-run variants for the three
selected pairs and prints before/after roofline terms.

Each variant runs in a SUBPROCESS (XLA device-count flags + the activation-
sharding global are per-process). Results land in experiments/dryrun/ with
the variant tag; collate with scripts/perf_report.py.

    PYTHONPATH=src python scripts/perf_hillclimb.py [h1|h2|h3|all]
"""
import json
import subprocess
import sys
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (arch, shape, tag, variant_json, mesh)
RUNS = {
    # H1 — llama4-scout train_4k: worst memory/dev (baseline peak 185 GiB vs
    # 16 GiB HBM). Levers: bf16 params+EF (2x on the two biggest residents),
    # then a 4x64 mesh reshape (4 clients/pod x 64-way model parallel:
    # per-device param/EF footprint /4, experts shard over ff-dim).
    "h1": [
        ("llama4-scout-17b-a16e", "train_4k", "bf16",
         {"param_dtype": "bfloat16", "ef_dtype": "bfloat16"}, ""),
        ("llama4-scout-17b-a16e", "train_4k", "bf16-mesh4x64",
         {"param_dtype": "bfloat16", "ef_dtype": "bfloat16"}, "4,64"),
    ],
    # H2 — llama4-scout prefill_32k: most collective-bound pair (6.96 s).
    # Lever: explicit head-axis sharding constraints through attention/MoE
    # (kills the involuntary full-rematerialization copies GSPMD inserts).
    "h2": [
        ("llama4-scout-17b-a16e", "prefill_32k", "actshard",
         {"act_shard": True}, ""),
        ("internvl2-1b", "prefill_32k", "actshard",
         {"act_shard": True}, ""),
    ],
    # H3 — tinyllama train_4k: the paper-representative pair. Lever: fused
    # server decode — all-gather tiny (D_syn, s) payloads instead of
    # all-reducing the full per-client gradient reconstruction.
    "h3": [
        ("tinyllama-1.1b", "train_4k", "fused",
         {"fused_decode": True}, ""),
        ("qwen1.5-0.5b", "train_4k", "fused",
         {"fused_decode": True}, ""),
    ],
}


def run_one(arch, shape, tag, variant, mesh):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--tag", tag, "--variant", json.dumps(variant)]
    if mesh:
        cmd += ["--mesh", mesh]
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    print("::", " ".join(cmd))
    subprocess.run(cmd, check=True, env=env, cwd=ROOT)


def main():
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    keys = list(RUNS) if which == "all" else [which]
    for k in keys:
        for run in RUNS[k]:
            run_one(*run)


if __name__ == "__main__":
    main()
