"""Dev scratch: validate entry lowering on a small host mesh + smoke configs."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import time

import jax

from repro.configs import base
from repro.configs.base import ARCH_IDS, ShapeConfig, get_smoke_config
from repro.launch import specs as specs_lib
from repro.utils import roofline as rl

# shrink the shape matrix + swap in smoke configs
SMALL_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 64, 8, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 128, 4, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 128, 8, "decode"),
    "long_500k": ShapeConfig("long_500k", 256, 1, "decode"),
}
specs_lib.INPUT_SHAPES = SMALL_SHAPES
specs_lib.LONG_CTX_WINDOW = 64
specs_lib.get_config = get_smoke_config

mesh = jax.make_mesh((4, 2), ("data", "model"))

archs = sys.argv[1:] or ARCH_IDS
for arch in archs:
    for shape in SMALL_SHAPES:
        t0 = time.time()
        try:
            made = specs_lib.make_entry(arch, shape, mesh)
            if made is None:
                print(f"SKIP {arch} x {shape}")
                continue
            entry, args = made
            lowered = jax.jit(entry).lower(*args)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            coll = rl.collective_bytes(compiled.as_text())
            print(f"OK {arch:24s} {shape:12s} {time.time()-t0:5.1f}s "
                  f"flops={cost.get('flops', 0):.3g} coll={sum(coll.values()):,}")
        except Exception as e:
            import traceback; traceback.print_exc()
            print(f"FAIL {arch} x {shape}: {type(e).__name__}: {str(e)[:300]}")
            sys.exit(1)
print("ALL LOWERED")
