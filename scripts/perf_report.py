"""Collate §Perf hillclimb variants vs baselines into experiments/PERF.md
(picked up by benchmarks.collate_experiments into EXPERIMENTS.md §Perf).

    PYTHONPATH=src python scripts/perf_report.py
"""
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun")


def load():
    out = {}
    for fn in glob.glob(os.path.join(DRY, "*.json")):
        r = json.load(open(fn))
        out[(r["arch"], r["shape"], r.get("tag", ""), r["mesh"])] = r
    return out


def row(r):
    roof = r["roofline"]
    mem = r["memory_per_dev"]
    return (f"peak/dev {mem['peak_bytes']/2**30:8.2f} GiB | "
            f"C {roof['compute_s']:9.3e} | M {roof['memory_s']:9.3e} | "
            f"X {roof['collective_s']:9.3e} | dom {roof['dominant']}")


def main():
    runs = load()
    L = ["## §Perf — hillclimb logs (hypothesis → change → before → after → verdict)",
         "",
         "Three pairs selected from the 39-pair baseline table (§Roofline): "
         "worst roofline fraction (H1), most collective-bound (H2), most "
         "paper-representative (H3). Methodology: napkin math → variant "
         "lowering → re-derived terms (trip-count-aware analyzer) → verdict.",
         ""]

    def block(title, narrative, entries, verdict):
        L.append(f"### {title}\n")
        L.append(narrative + "\n")
        for label, key in entries:
            r = runs.get(key)
            if r:
                L.append(f"* **{label}**: {row(r)}")
        L.append(f"\n**Verdict:** {verdict}\n")

    block(
        "H1 — llama4-scout-17b-a16e × train_4k (worst roofline: memory)",
        "Baseline peak/dev 184.8 GiB vs 16 GiB HBM — the two biggest "
        "residents are f32 params (replicated over the 16 client rows) and "
        "the f32 per-client EF tree, each ≈ 428 GB/16 model shards ≈ 27 GiB. "
        "**Hypothesis 1:** bf16 params+EF halve both (predicted peak ≈ 92 GiB). "
        "**Hypothesis 2:** a 4×64 mesh reshape (4 clients × 64-way TP, MoE "
        "experts falling back to ff-dim sharding) cuts the model-sharded "
        "share a further 4× (predicted ≈ 25-30 GiB args).",
        [("baseline f32 (16×16)", ("llama4-scout-17b-a16e", "train_4k", "", "16x16")),
         ("bf16 params+EF (16×16)", ("llama4-scout-17b-a16e", "train_4k", "bf16", "16x16")),
         ("bf16 + mesh 4×64", ("llama4-scout-17b-a16e", "train_4k", "bf16-mesh4x64", "4x64"))],
        "H1a CONFIRMED: peak 184.8 → 92.4 GiB (exactly 2×). H1b MIXED: "
        "args/dev 46.2 → 28.4 GiB (resident win) but per-client batch grows "
        "4× (256/4 vs 256/16 sequences), inflating activation traffic "
        "(M 313→822 s) and 64-way resharding (X 135→549 s). Lesson: for "
        "FL-style client-parallel training the client axis is also the "
        "batch-parallel axis — shrinking it trades residency against "
        "traffic. The right production fix is bf16 + per-client microbatch "
        "(already in the entry) + more HBM per client row, not fewer rows. "
        "A 107B-total-param MoE with 16 resident client states does not fit "
        "v5e-256 at any layout we found; DESIGN.md §9 records this as an "
        "honest capacity finding of the FL-on-pod mapping.")

    block(
        "H2 — llama4-scout-17b-a16e × prefill_32k (most collective-bound)",
        "Baseline X = 334 s (!), 15.4 TiB of all-reduce per step. "
        "**Iteration 1 (refuted):** pinning the attention head axis to "
        "'model' (activation constraints) — X unchanged (334.3 s): the "
        "gathers weren't propagation noise. **Diagnosis from the archived "
        "HLO:** ONE 320 GiB-operand all-reduce per layer on the QK^T "
        "einsum — 40 heads don't divide the 16-way model axis, so the "
        "sharding rules fell back to sharding head_dim, the *contraction* "
        "dim of QK^T, making GSPMD all-reduce the full (S×S) logits. "
        "**Iteration 2a:** mesh (32,8): 40 heads % 8 == 0 → heads shard "
        "cleanly, logits stay local. **Iteration 2b:** rule change "
        "(`set_qk_hd_fallback(False)`): replicate q/k instead of sharding "
        "hd (trades replicated attention compute for zero logits collective).",
        [("baseline (16×16)", ("llama4-scout-17b-a16e", "prefill_32k", "", "16x16")),
         ("iter1: act-shard pins (16×16)", ("llama4-scout-17b-a16e", "prefill_32k", "actshard", "16x16")),
         ("iter2a: mesh (32,8)", ("llama4-scout-17b-a16e", "prefill_32k", "mesh32x8", "32x8")),
         ("iter2b: no-qk-hd rule (16×16)", ("llama4-scout-17b-a16e", "prefill_32k", "noqkhd", "16x16")),
         ("internvl2-1b baseline", ("internvl2-1b", "prefill_32k", "", "16x16")),
         ("internvl2-1b no-qk-hd", ("internvl2-1b", "prefill_32k", "noqkhd", "16x16")),
         ("internvl2-1b train baseline", ("internvl2-1b", "train_4k", "", "16x16")),
         ("internvl2-1b train no-qk-hd", ("internvl2-1b", "train_4k", "noqkhd", "16x16")),
         ("recurrentgemma-2b prefill baseline", ("recurrentgemma-2b", "prefill_32k", "", "16x16")),
         ("recurrentgemma-2b prefill no-qk-hd", ("recurrentgemma-2b", "prefill_32k", "noqkhd", "16x16"))],
        "CONFIRMED (iteration 2): llama4 X 334 → 1.30 s (257×) and "
        "M 293 → 19.6 s on the (32,8) mesh; the pair flips from "
        "collective- to memory-dominated and the whole step bound drops "
        "~17×. The same rule fix takes internvl2 prefill (14 heads, same "
        "disease) X 58.9 → 0.23 s, internvl2 train_4k X 25.5 → 8.9 s "
        "(2.9×, M 31.1 → 27.1 s), and recurrentgemma prefill (10 heads) "
        "X 14.5 → 0.73 s (20×, M 12.8 → 9.4 s) — every collective-bound "
        "pair in the baseline census flips to memory-bound. Beyond-paper "
        "lesson now encoded in the sharding rules: never shard a "
        "contraction dim of attention as a fallback — pick the mesh so "
        "heads divide, or replicate q/k.")

    block(
        "H3 — tinyllama-1.1b × train_4k (paper-representative: the 3SFC uplink)",
        "The naive server path all-reduces each client's FULL reconstructed "
        "gradient over the client axis — the same collective bill as "
        "FedAvg, 'wasting' the paper's compression inside the pod. "
        "**Hypothesis:** fused server decode (Eq. 10 linearity: "
        "G(ĝ) = ∇_w (1/N)Σ s_i F(D_syn,i, w)) all-gathers only the (D_syn, s) "
        "payloads (0.5 MB vs 4.4 GB per client for 1.1B params) and runs one "
        "replicated backward; exactness proven in tests/test_fused_decode.py. "
        "Napkin math *before* lowering: the recon all-reduce operand is only "
        "|w|·4B/16 model shards ≈ 275 MB/device ≈ 5.5 ms at 50 GB/s — "
        "~0.4% of the baseline X = 1.50 s, which is dominated by layer-wise "
        "activation resharding inside local training.",
        [("baseline per-client decode", ("tinyllama-1.1b", "train_4k", "", "16x16")),
         ("fused decode", ("tinyllama-1.1b", "train_4k", "fused", "16x16")),
         ("qwen1.5-0.5b baseline", ("qwen1.5-0.5b", "train_4k", "", "16x16")),
         ("qwen1.5-0.5b fused", ("qwen1.5-0.5b", "train_4k", "fused", "16x16"))],
        "REFUTED at ICI scale, exactly as the napkin math predicts: terms "
        "unchanged to 3 digits (X 1.501 → 1.502 s) because the gradient "
        "all-reduce was never the pod bottleneck. The paper's win is a WAN "
        "phenomenon: the per-client uplink drops 4.4 GB → 0.5 MB (8,600×, "
        "= the payload_floats ledger), which is exactly what 3SFC promises "
        "— and the fused decode makes the server side O(payload) too. Kept "
        "as a first-class option (fl_round(fused_decode=True)); the refuted "
        "part is only the expectation that it would move the *ICI* roofline.")

    L.append("### Stopping criterion\n")
    L.append("H2 iteration 2 achieved its predicted order-of-magnitude win; "
             "subsequent candidates (H1 mesh variants, H3) produced <5% "
             "movement on their dominant terms across consecutive attempts, "
             "meeting the stop rule. The encoded rule fixes (no contraction-"
             "dim fallback, head-divisible mesh selection) apply to every "
             "arch in the fleet.\n")

    out = os.path.join(ROOT, "experiments", "PERF.md")
    with open(out, "w") as f:
        f.write("\n".join(L) + "\n")
    print(f"wrote {out} ({len(L)} lines)")


if __name__ == "__main__":
    main()
