"""Baseline compressors: reconstruction semantics + budget accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import baselines


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 50))
def test_topk_keeps_largest(seed, k):
    v = jax.random.normal(jax.random.PRNGKey(seed), (200,))
    payload, recon = baselines.topk_compress(v, k)
    kept = np.nonzero(np.asarray(recon))[0]
    assert len(kept) <= k
    # every kept magnitude >= every dropped magnitude
    dropped = np.setdiff1d(np.arange(200), kept)
    if len(kept) and len(dropped):
        assert np.abs(np.asarray(v))[kept].min() >= np.abs(np.asarray(v))[dropped].max() - 1e-6
    # kept values are exact
    np.testing.assert_allclose(np.asarray(recon)[kept], np.asarray(v)[kept])
    assert payload.floats == 2.0 * k


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_signsgd_recon(seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (333,))
    payload, recon = baselines.signsgd_compress(v)
    scale = jnp.mean(jnp.abs(v))
    np.testing.assert_allclose(recon, scale * jnp.sign(v), rtol=1e-6)
    assert payload.floats == 333 / 32.0 + 1.0


def test_stc_ternary():
    v = jax.random.normal(jax.random.PRNGKey(0), (500,))
    payload, recon = baselines.stc_compress(v, 50)
    vals = np.asarray(recon)[np.nonzero(np.asarray(recon))[0]]
    assert len(np.unique(np.abs(vals))) == 1          # single magnitude
    assert payload.floats == 50 + 50 / 32.0 + 1.0


def test_randk_unbiased_support():
    v = jnp.arange(1.0, 101.0)
    key = jax.random.PRNGKey(1)
    _, recon = baselines.randk_compress(key, v, 10)
    nz = np.nonzero(np.asarray(recon))[0]
    assert len(nz) == 10
    np.testing.assert_allclose(np.asarray(recon)[nz], np.asarray(v)[nz])


def test_compression_rate_eq1():
    # paper Eq. 1 on the MLP numbers: 795 floats / 199,210 params = 1/250.6
    assert abs(baselines.compression_rate(795.0, 199210) - 795.0 / 199210) < 1e-12


def test_tree_compressor_interface():
    from repro.configs.base import CompressorConfig
    from repro.core.compressor import make_compressor

    params = {"a": jnp.zeros((64, 8)), "b": jnp.zeros((100,))}
    g = jax.tree.map(lambda p: jax.random.normal(jax.random.PRNGKey(0), p.shape), params)
    for kind in ("identity", "topk", "randk", "signsgd", "stc"):
        comp = make_compressor(CompressorConfig(kind=kind, keep_ratio=0.1))
        e = comp.init_state(params)
        recon, e2, m = comp.step(jax.random.PRNGKey(1), g, e, params)
        assert jax.tree_util.tree_structure(recon) == jax.tree_util.tree_structure(params)
        assert np.isfinite(float(m.cosine))
        if kind == "identity":
            np.testing.assert_allclose(float(m.cosine), 1.0, rtol=1e-6)
