"""HLO analyzer: trip-count-aware totals must match ground truth on
loop-free programs and correct the known while-body undercount on scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils import hlo_analyzer as H


def _cost(f, *args):
    comp = jax.jit(f).lower(*args).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return H.analyze(comp.as_text()), ca


def test_matmul_exact():
    x = jnp.ones((128, 64))
    w = jnp.ones((64, 32))
    tot, ca = _cost(lambda a, b: a @ b, x, w)
    assert tot.flops == 2 * 128 * 64 * 32
    np.testing.assert_allclose(tot.flops, ca.get("flops"), rtol=1e-6)


def test_scan_multiplies_trip_count():
    w = jnp.ones((64, 64))

    def scanned(x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.ones((64, 64))
    tot, ca = _cost(scanned, x)
    truth = 7 * 2 * 64 ** 3
    assert tot.flops == truth
    # the raw cost_analysis undercounts (body counted once)
    assert ca.get("flops") < truth


def test_nested_scan():
    w = jnp.ones((32, 32))

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    tot, _ = _cost(nested, jnp.ones((32, 32)))
    assert tot.flops == 15 * 2 * 32 ** 3


def test_bytes_close_to_xla_on_loop_free():
    x = jnp.ones((256, 256))
    w = jnp.ones((256, 256))

    def f(a, b):
        return jnp.tanh(a @ b) + a

    tot, ca = _cost(f, x, w)
    assert 0.5 * ca.get("bytes accessed") <= tot.bytes <= 2.0 * ca.get("bytes accessed")


def test_collectives_scaled_by_trip_count():
    hlo = """
ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %t = (s32[], f32[64,128]) tuple(%c, %p0)
  %w = (s32[], f32[64,128]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%w), index=1
}
%body (a: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %a = (s32[], f32[64,128]) parameter(0)
  %g = f32[64,128]{1,0} get-tuple-element(%a), index=1
  %ar = f32[64,128]{1,0} all-reduce(%g), to_apply=%sum
  ROOT %r = (s32[], f32[64,128]) tuple(%i, %ar)
}
%cond (a: (s32[], f32[64,128])) -> pred[] {
  %a2 = (s32[], f32[64,128]) parameter(0)
  ROOT %lt = pred[] compare(%x, %y), direction=LT
}
"""
    tot = H.analyze(hlo)
    assert tot.coll_bytes["all-reduce"] == 4 * 64 * 128 * 4


def test_collective_extraction_with_scope_and_trip():
    """collectives(): per-op records carry operand bytes, enclosing trip
    multipliers, and the name-stack metadata used to gate the per-client
    encode region collective-free."""
    hlo = """
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%p0), dimensions={0}, metadata={op_name="jit(f)/shmap_body/all_gather"}
  %t = (s32[], f32[8,16]) tuple(%c, %p0)
  %w = (s32[], f32[8,16]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
%body (a: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %a = (s32[], f32[8,16]) parameter(0)
  %g = f32[8,16]{1,0} get-tuple-element(%a), index=1
  %ar = f32[8,16]{1,0} all-reduce(%g), to_apply=%sum, metadata={op_name="jit(f)/fl_client_local/bad_collective"}
  ROOT %r = (s32[], f32[8,16]) tuple(%i, %ar)
}
%cond (a: (s32[], f32[8,16])) -> pred[] {
  %a2 = (s32[], f32[8,16]) parameter(0)
  ROOT %lt = pred[] compare(%x, %y), direction=LT
}
"""
    cols = H.collectives(hlo)
    assert sorted(c.kind for c in cols) == ["all-gather", "all-reduce"]
    ag = next(c for c in cols if c.kind == "all-gather")
    ar = next(c for c in cols if c.kind == "all-reduce")
    assert ag.bytes == 8 * 16 * 4 and ag.trip == 1
    assert ar.bytes == 8 * 16 * 4 and ar.trip == 3       # while-body multiplier
    assert ar.total_bytes == 3 * 8 * 16 * 4
    assert H.collective_bytes(hlo) == ag.total_bytes + ar.total_bytes
    scoped = H.collectives_in_scope(hlo, "fl_client_local")
    assert [c.kind for c in scoped] == ["all-reduce"]
    assert H.collectives_in_scope(hlo, "nonexistent_scope") == []


# ---------------------------------------------------------------------------
# edge cases: the analyzer is fed arbitrary optimized-HLO text by benches and
# the dryrun cost model — degenerate modules must yield zeros, not crashes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("text", ["", "\n\n", "// no module here",
                                  "%orphan = f32[4]{0} add(%a, %b)"])
def test_empty_or_entryless_module(text):
    """No ENTRY computation -> zero totals and empty extractions."""
    tot = H.analyze(text)
    assert tot.flops == 0.0 and tot.bytes == 0.0
    assert all(v == 0.0 for v in tot.coll_bytes.values())
    assert H.collectives(text) == []
    assert H.collective_bytes(text) == 0.0
    assert H.collectives_in_scope(text, "any") == []


def test_no_collective_module():
    """A real loop-free compiled program with zero collectives: flop/byte
    totals populate, every collective bucket stays exactly zero."""
    x = jnp.ones((64, 32))
    w = jnp.ones((32, 16))
    tot, _ = _cost(lambda a, b: jnp.tanh(a @ b), x, w)
    assert tot.flops == 2 * 64 * 32 * 16
    assert tot.bytes > 0.0
    assert all(v == 0.0 for v in tot.coll_bytes.values())
    comp = jax.jit(lambda a, b: jnp.tanh(a @ b)).lower(x, w).compile()
    assert H.collectives(comp.as_text()) == []
    assert H.collective_bytes(comp.as_text()) == 0.0


def test_nested_scopes_and_nested_trip_counts():
    """A collective inside a while-within-a-while under a nested name stack:
    trip multipliers compound (2*3=6) and every enclosing named_scope level
    matches by substring on the op_name metadata."""
    hlo = """
ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %t = (s32[], f32[8,8]) tuple(%c, %p0)
  %w = (s32[], f32[8,8]) while(%t), condition=%ocond, body=%obody, backend_config={"known_trip_count":{"n":"2"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
%obody (a: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %a = (s32[], f32[8,8]) parameter(0)
  %t2 = (s32[], f32[8,8]) tuple(%i, %g)
  %w2 = (s32[], f32[8,8]) while(%t2), condition=%icond, body=%ibody, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %r = (s32[], f32[8,8]) tuple(%i, %g2)
}
%ibody (b: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %b = (s32[], f32[8,8]) parameter(0)
  %g3 = f32[8,8]{1,0} get-tuple-element(%b), index=1
  %ar = f32[8,8]{1,0} all-reduce(%g3), to_apply=%sum, metadata={op_name="jit(f)/outer_scope/inner_scope/all_reduce"}
  ROOT %r2 = (s32[], f32[8,8]) tuple(%j, %ar)
}
%ocond (a: (s32[], f32[8,8])) -> pred[] {
  %a2 = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] compare(%x, %y), direction=LT
}
%icond (b: (s32[], f32[8,8])) -> pred[] {
  %b2 = (s32[], f32[8,8]) parameter(0)
  ROOT %lt2 = pred[] compare(%x, %y), direction=LT
}
"""
    cols = H.collectives(hlo)
    assert len(cols) == 1                  # one op, trip-annotated
    ar = cols[0]
    assert ar.kind == "all-reduce"
    assert ar.trip == 2 * 3
    assert ar.total_bytes == 6 * 8 * 8 * 4
    # totals walk agrees with the extraction walk
    assert H.analyze(hlo).coll_bytes["all-reduce"] == ar.total_bytes
    # nested scopes both match; sibling/unknown scopes do not
    for scope in ("outer_scope", "inner_scope", "outer_scope/inner_scope"):
        assert [c.kind for c in H.collectives_in_scope(hlo, scope)] == \
            ["all-reduce"], scope
    assert H.collectives_in_scope(hlo, "other_scope") == []
