"""Fault-tolerant rounds: schedule determinism, EF correctness under every
fault pattern, the zero-fault bitwise contract, staleness, and transport
hardening.

The vmap half of the (method × fused × wire) zero-fault bitwise matrix runs
here (the shard_map half needs 8 devices — see the ``faults`` scenario in
tests/test_shard_round.py). The masked fault pipeline is forced onto a
zero-fault config through ``build_fl_round``'s ``fault_schedule_fn``
injection seam, so what is gated is the NON-trivial identity: masked
pipeline + null schedule ≡ unfaulted pipeline, bit for bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.comm import FaultyChannel, InProcessChannel, make_codec
from repro.comm.frame import (BadMagicError, FrameError, FrameSpec,
                              TruncatedFrameError, encode_header,
                              parse_header)
from repro.configs.base import CompressorConfig, FLConfig
from repro.configs.run import RunConfig
from repro.core import flat
from repro.core.strategy import STRATEGIES, make_strategy
from repro.fl import faults as F
from repro.fl.client import local_train
from repro.fl.engine import RetryPolicy, RoundEngine, device_pools, \
    vision_batcher
from repro.fl.round import build_fl_round, fl_init
from repro.models.build import vision_syn_spec
from repro.models.cnn import VisionSpec, make_paper_model

N, K, B = 4, 1, 8
SPEC = VisionSpec("tiny", (4, 4, 1), 3)


@pytest.fixture(scope="module")
def world():
    model = make_paper_model("mlp", SPEC)
    params = model.init(jax.random.PRNGKey(0))
    batches = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (N, K, B, 4, 4, 1)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (N, K, B), 0, 3),
    }
    return model, params, batches


def _strategy(model, ccfg):
    spec = vision_syn_spec(SPEC, ccfg)
    return make_strategy(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                         local_lr=0.05), spec


def _ccfg(kind):
    return CompressorConfig(kind=kind, keep_ratio=0.2, syn_steps=2,
                            syn_lr=0.1,
                            error_feedback=kind != "identity")


def _tree_eq(a, b, what=""):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{what} not bit-exact")


# ---------------------------------------------------------------------------
# schedule determinism
# ---------------------------------------------------------------------------


def test_fault_schedule_deterministic_and_exact_at_rate_edges():
    key = jax.random.PRNGKey(42)
    a = F.fault_schedule(key, jnp.int32(7), 16, participation_rate=0.5,
                         drop_rate=0.3, straggler_rate=0.4, staleness_max=3)
    b = F.fault_schedule(key, jnp.int32(7), 16, participation_rate=0.5,
                         drop_rate=0.3, straggler_rate=0.4, staleness_max=3)
    _tree_eq(a, b, "same (seed, round) schedule")
    c = F.fault_schedule(key, jnp.int32(8), 16, participation_rate=0.5,
                         drop_rate=0.3, straggler_rate=0.4, staleness_max=3)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, c)), "round must vary the pattern"
    # delays bounded, weights exact
    assert int(jnp.max(a.delay)) <= 3 and int(jnp.min(a.delay)) >= 0
    np.testing.assert_array_equal(
        np.asarray(a.weight),
        np.float32(1.0) / (np.float32(1.0)
                           + np.asarray(a.delay).astype(np.float32)))
    # rate edges are exact masks, not approximate ones
    e = F.fault_schedule(key, jnp.int32(3), 64)
    assert bool(jnp.all(e.participate)) and bool(jnp.all(e.delivered))
    assert bool(jnp.all(e.delay == 0)) and bool(jnp.all(e.weight == 1.0))
    z = F.fault_schedule(key, jnp.int32(3), 64, participation_rate=1.0,
                         drop_rate=0.0, straggler_rate=0.0, staleness_max=2)
    assert bool(jnp.all(z.arrives_now)) and not bool(jnp.any(z.arrives_late))
    n = F.null_schedule(5)
    assert bool(jnp.all(n.arrives_now)) and bool(jnp.all(n.weight == 1.0))


def test_fault_schedule_rates_are_roughly_honored():
    key = jax.random.PRNGKey(0)
    hits = np.mean([np.asarray(F.fault_schedule(
        key, jnp.int32(r), 64, participation_rate=0.5).participate).mean()
        for r in range(32)])
    assert 0.4 < hits < 0.6, hits


# ---------------------------------------------------------------------------
# zero-fault bitwise: masked pipeline + null schedule == unfaulted pipeline
# (vmap half of the matrix; shard_map half in test_shard_round.py 'faults')
# ---------------------------------------------------------------------------

ALL_KINDS = ("identity", "topk", "randk", "signsgd", "stc", "threesfc",
             "fedsynth")
CODEC_KINDS = ("identity", "topk", "signsgd", "stc", "threesfc")

VMAP_COMBOS = (
    [(k, "float", False) for k in ALL_KINDS]
    + [(k, "codec", False) for k in CODEC_KINDS]
    + [("threesfc", "float", True), ("threesfc", "codec", True)]
)


@pytest.mark.parametrize("kind,wire,fused", VMAP_COMBOS,
                         ids=[f"{k}-{w}{'-fused' if f else ''}"
                              for k, w, f in VMAP_COMBOS])
def test_zero_fault_schedule_bitwise_vmap(world, kind, wire, fused):
    model, params, batches = world
    ccfg = _ccfg(kind)
    strat, spec = _strategy(model, ccfg)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)
    run = RunConfig(fl=cfg, wire=wire, fused_decode=fused)
    codec = make_codec(ccfg, params, syn_spec=spec,
                       syn_loss_fn=model.syn_loss) if wire == "codec" else None
    rf = jax.jit(build_fl_round(model.loss, strat, run, codec=codec))
    rf_null = jax.jit(build_fl_round(
        model.loss, strat, run, codec=codec,
        fault_schedule_fn=lambda r, n: F.null_schedule(n)))
    sa, sb = fl_init(params, N, strat), fl_init(params, N, strat)
    key = jax.random.PRNGKey(5)
    for r in range(2):
        kr = jax.random.fold_in(key, r)
        sa, ma = rf(sa, batches, kr)
        sb, mb = rf_null(sb, batches, kr)
    _tree_eq(sa.params, sb.params, f"{kind}/{wire} params")
    _tree_eq(sa.ef, sb.ef, f"{kind}/{wire} ef")
    for f in ("loss", "cosine", "payload_floats", "update_norm"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ma, f)), np.asarray(getattr(mb, f)),
            err_msg=f"{kind}/{wire} metric {f}")
    assert float(mb.arrivals) == float(N)


# ---------------------------------------------------------------------------
# EF correctness under faults
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_ef_freezes_for_skipped_client_every_strategy(world, kind):
    """A client skipped for k rounds keeps its residual bit-for-bit — the
    same residual as one that was never scheduled (no silent decay)."""
    model, params, batches = world
    ccfg = _ccfg(kind)
    strat, _ = _strategy(model, ccfg)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)

    def sched(r, n):
        # round 0: everyone (builds a nonzero residual); rounds 1..: client
        # 0 is never scheduled
        part = (r < 1) | (jnp.arange(n) != 0)
        return F.FaultSchedule(part, jnp.ones((n,), bool),
                               jnp.zeros((n,), jnp.int32),
                               jnp.ones((n,), jnp.float32))

    rf = jax.jit(build_fl_round(model.loss, strat, RunConfig(fl=cfg),
                                fault_schedule_fn=sched))
    st = fl_init(params, N, strat)
    key = jax.random.PRNGKey(9)
    st, _ = rf(st, batches, jax.random.fold_in(key, 0))
    ef_after_r0 = jax.tree_util.tree_map(lambda e: e[0], st.ef)
    if kind != "identity" and strat.cfg.error_feedback:
        assert any(float(jnp.max(jnp.abs(l))) > 0 for l in
                   jax.tree_util.tree_leaves(ef_after_r0)), \
            "round 0 should leave a nonzero residual to freeze"
    for r in (1, 2):
        st, m = rf(st, batches, jax.random.fold_in(key, r))
        assert float(m.arrivals) == float(N - 1)
    _tree_eq(jax.tree_util.tree_map(lambda e: e[0], st.ef), ef_after_r0,
             f"{kind} frozen residual")


def test_dropped_payload_conserves_residual_mass(world):
    """delivered=0 with EF on: e' = u = g + e, delivered mass 0 — nothing
    silently lost; a healthy client keeps e' + recon == u."""
    model, params, batches = world
    ccfg = _ccfg("topk")
    strat, _ = _strategy(model, ccfg)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)

    def sched(r, n):
        return F.FaultSchedule(jnp.ones((n,), bool), jnp.arange(n) != 0,
                               jnp.zeros((n,), jnp.int32),
                               jnp.ones((n,), jnp.float32))

    rf = jax.jit(build_fl_round(model.loss, strat, RunConfig(fl=cfg),
                                fault_schedule_fn=sched))
    key = jax.random.PRNGKey(11)
    st, m = rf(fl_init(params, N, strat), batches, key)
    assert float(m.arrivals) == float(N - 1)

    keys = jax.random.split(key, N)       # the round's per-client keys
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    for i, atol in ((0, 0.0), (1, 1e-6)):
        bi = jax.tree_util.tree_map(lambda x: x[i], batches)
        g, _ = local_train(model.loss, params, bi, 0.05)
        u = g                              # initial residual is zero
        recon, _, _ = strat.step(keys[i], g, zeros, params)
        e_new = jax.tree_util.tree_map(lambda e: e[i], st.ef)
        delivered = zeros if i == 0 else recon
        assert F.residual_mass_conserved(u, e_new, delivered, atol=atol), \
            f"client {i}: residual mass not conserved"


def test_full_dropout_round_is_a_no_op_on_params(world):
    model, params, batches = world
    ccfg = _ccfg("topk")
    strat, _ = _strategy(model, ccfg)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)

    def sched(r, n):
        return F.FaultSchedule(jnp.ones((n,), bool), jnp.zeros((n,), bool),
                               jnp.zeros((n,), jnp.int32),
                               jnp.ones((n,), jnp.float32))

    rf = jax.jit(build_fl_round(model.loss, strat, RunConfig(fl=cfg),
                                fault_schedule_fn=sched))
    st, m = rf(fl_init(params, N, strat), batches, jax.random.PRNGKey(1))
    _tree_eq(st.params, params, "full-dropout params")
    assert float(m.arrivals) == 0.0 and float(m.update_norm) == 0.0


# ---------------------------------------------------------------------------
# staleness
# ---------------------------------------------------------------------------


def test_consume_and_bank_unit():
    params = {"w": jnp.zeros((3,))}
    buf, buf_w = F.init_stale_buffer(params, 2)
    recons = {"w": jnp.stack([jnp.full((3,), 2.0), jnp.full((3,), 4.0)])}
    delay = jnp.asarray([2, 0], jnp.int32)
    w_late = jnp.asarray([0.5, 0.0], jnp.float32)   # only client 0 banks
    # round 0: nothing mature yet; client 0's payload lands at slot 0
    # (consume-then-bank: delay == S reuses the just-freed slot)
    m, mw, buf, buf_w = F.consume_and_bank(buf, buf_w, jnp.int32(0), delay,
                                           w_late, recons)
    assert float(mw) == 0.0 and float(jnp.max(jnp.abs(m["w"]))) == 0.0
    assert float(F.pending_mass(buf_w)) == 0.5
    # round 1: slot 1 matures empty
    m, mw, buf, buf_w = F.consume_and_bank(
        buf, buf_w, jnp.int32(1), jnp.zeros((2,), jnp.int32),
        jnp.zeros((2,), jnp.float32), recons)
    assert float(mw) == 0.0
    # round 2: client 0's banked weighted sum matures exactly
    m, mw, buf, buf_w = F.consume_and_bank(
        buf, buf_w, jnp.int32(2), jnp.zeros((2,), jnp.int32),
        jnp.zeros((2,), jnp.float32), recons)
    assert float(mw) == 0.5
    np.testing.assert_allclose(np.asarray(m["w"]), 0.5 * 2.0 * np.ones(3))
    assert float(F.pending_mass(buf_w)) == 0.0


def test_stale_payloads_arrive_next_round(world):
    """All clients straggle by exactly 1: round 0 applies nothing, round 1
    applies round 0's payloads (weight 1/2 each, renormalized)."""
    model, params, batches = world
    ccfg = _ccfg("topk")
    strat, _ = _strategy(model, ccfg)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)
    run = RunConfig(fl=cfg, staleness_max=1)

    def sched(r, n):
        return F.FaultSchedule(jnp.ones((n,), bool), jnp.ones((n,), bool),
                               jnp.ones((n,), jnp.int32),
                               jnp.full((n,), 0.5, jnp.float32))

    rf = jax.jit(build_fl_round(model.loss, strat, run,
                                fault_schedule_fn=sched))
    st = fl_init(params, N, strat, staleness_max=1)
    key = jax.random.PRNGKey(3)
    st, m0 = rf(st, batches, jax.random.fold_in(key, 0))
    _tree_eq(st.params, params, "round-0 params (all payloads in flight)")
    assert float(m0.arrivals) == 0.0
    assert float(F.pending_mass(st.buf_w)) == pytest.approx(N * 0.5)
    st, m1 = rf(st, batches, jax.random.fold_in(key, 1))
    assert float(m1.arrivals) == pytest.approx(N * 0.5)
    assert float(m1.update_norm) > 0.0
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(st.params),
                               jax.tree_util.tree_leaves(params)))


def test_staleness_requires_buffered_state(world):
    model, params, batches = world
    ccfg = _ccfg("topk")
    strat, _ = _strategy(model, ccfg)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)
    rf = build_fl_round(model.loss, strat,
                        RunConfig(fl=cfg, staleness_max=2, straggler_rate=0.5))
    with pytest.raises(ValueError, match="staleness buffer"):
        rf(fl_init(params, N, strat), batches, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# engine integration: cadence invariance of the fault stream
# ---------------------------------------------------------------------------


def _engine(model, params, run, strat, train, parts):
    eng = RoundEngine(
        build_fl_round(model.loss, strat, run),
        vision_batcher(train.x, train.y, device_pools(parts), K, B),
        seed=0)
    return eng, eng.init_state(params, N,
                               strategy=strat,
                               staleness_max=run.staleness_max)


def test_fault_cadence_invariance():
    """Same fault_seed ⇒ same per-round fault pattern regardless of how
    rounds are grouped into scan blocks: blocks [4] ≡ [2, 2] bitwise."""
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset

    model = make_paper_model("mlp", SPEC)
    params = model.init(jax.random.PRNGKey(0))
    train = make_class_image_dataset(jax.random.PRNGKey(1), 200, (4, 4, 1), 3)
    parts = dirichlet_partition(train.y, N, alpha=0.5, seed=0,
                                min_per_client=B)
    ccfg = _ccfg("topk")
    strat, _ = _strategy(model, ccfg)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)
    run = RunConfig(fl=cfg, participation_rate=0.75, drop_rate=0.3,
                    fault_seed=13)

    e1, s1 = _engine(model, params, run, strat, train, parts)
    s1, _ = e1.run_block(s1, 4)
    e2, s2 = _engine(model, params, run, strat, train, parts)
    s2, _ = e2.run_block(s2, 2)
    s2, _ = e2.run_block(s2, 2)
    _tree_eq(s1.params, s2.params, "cadence params")
    _tree_eq(s1.ef, s2.ef, "cadence ef")
    assert int(s1.round) == int(s2.round) == 4

    # different fault_seed ⇒ different trajectory (the knob is live)
    e3, s3 = _engine(model, params, run.replace(fault_seed=14), strat,
                     train, parts)
    s3, _ = e3.run_block(s3, 4)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                               jax.tree_util.tree_leaves(s3.params)))


# ---------------------------------------------------------------------------
# transport hardening
# ---------------------------------------------------------------------------

_SPEC = FrameSpec("identity", "fp32", (8,))


def _valid_frame(round_idx=0, client_idx=0) -> np.ndarray:
    head = np.asarray(encode_header(_SPEC, round_idx, client_idx))
    return np.concatenate([head, np.arange(8, dtype=np.uint8)])


def test_linkstats_requires_open_round():
    ch = InProcessChannel()
    with pytest.raises(RuntimeError, match="begin_round"):
        ch.send_up(np.zeros((4,), np.uint8))
    ch.begin_round()
    ch.send_up(np.zeros((4,), np.uint8))
    assert ch.uplink.per_round == [4]
    ch.begin_round()
    ch.send_up(np.zeros((2,), np.uint8))
    assert ch.uplink.per_round == [4, 2]
    assert ch.uplink.total_bytes == 6 and ch.uplink.messages == 2


def test_faulty_channel_is_deterministic_and_billed():
    frames = [_valid_frame(client_idx=i) for i in range(64)]

    def run(seed):
        ch = FaultyChannel(drop_prob=0.25, truncate_prob=0.25,
                           bitflip_prob=0.25, seed=seed)
        ch.begin_round()
        return [ch.send_up(f) for f in frames], ch

    got1, ch1 = run(7)
    got2, _ = run(7)
    for a, b in zip(got1, got2):
        assert (a is None) == (b is None)
        if a is not None:
            np.testing.assert_array_equal(a, b)
    # the wire billed every send, including the ones it then ate
    assert ch1.uplink.messages == 64
    assert ch1.uplink.total_bytes == sum(f.nbytes for f in frames)
    assert ch1.dropped > 0 and ch1.corrupted > 0
    # corrupted frames are rejected with a typed error, never silently kept
    for f in got1:
        if f is None:
            continue
        try:
            hdr = parse_header(f)
            assert hdr["kind"] == "identity"
        except FrameError:
            pass


def test_engine_deliver_retry_and_give_up():
    frames = [_valid_frame(client_idx=i) for i in range(8)]
    # clean wire: everything arrives first try
    ch = FaultyChannel(seed=0)
    ch.begin_round()
    rep = RoundEngine.deliver(ch, frames)
    assert rep.delivered.all() and rep.retries == 0
    assert all(f is not None for f in rep.frames)
    # dead wire: give-up after the policy's retries, all marked dropped —
    # the delivered=False branch of the in-round fault model
    dead = FaultyChannel(drop_prob=1.0, seed=0)
    dead.begin_round()
    rep = RoundEngine.deliver(dead, frames, policy=RetryPolicy(max_retries=2))
    assert not rep.delivered.any()
    assert rep.retries == 8 * 2
    assert dead.uplink.messages == 8 * 3        # every re-send was billed
    # flaky wire: retries fill in most of the losses
    flaky = FaultyChannel(drop_prob=0.4, bitflip_prob=0.3, seed=3)
    flaky.begin_round()
    rep = RoundEngine.deliver(flaky, frames,
                              policy=RetryPolicy(max_retries=4))
    assert rep.delivered.sum() > 0 and rep.retries > 0


# ---------------------------------------------------------------------------
# parse_header fuzz: typed errors, never cryptic unpack exceptions
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=3),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_parse_header_fuzz_typed_errors(mode, seed):
    rng = np.random.default_rng(seed)
    base = _valid_frame(round_idx=3, client_idx=9)
    if mode == 0:       # truncation at a random point
        buf = base[: int(rng.integers(0, base.size))]
    elif mode == 1:     # random single-bit flips
        buf = base.copy()
        for _ in range(int(rng.integers(1, 6))):
            buf[int(rng.integers(0, buf.size))] ^= np.uint8(
                1 << int(rng.integers(0, 8)))
    elif mode == 2:     # pure garbage
        buf = rng.integers(0, 256, size=int(rng.integers(0, 64)),
                           dtype=np.uint8)
    else:               # valid frame, possibly extended with trailing junk
        buf = np.concatenate(
            [base, rng.integers(0, 256, size=int(rng.integers(0, 8)),
                                dtype=np.uint8)])
    try:
        hdr = parse_header(buf)
    except FrameError:
        return          # a typed rejection is always acceptable
    # no exception: the frame must be a coherent self-description
    assert hdr["nbytes"] == buf.size
    assert hdr["payload_bytes"] == sum(hdr["section_bytes"])
    assert isinstance(hdr["kind"], str) and isinstance(hdr["policy"], str)


def test_parse_header_typed_error_subclasses():
    base = _valid_frame()
    with pytest.raises(TruncatedFrameError):
        parse_header(base[:8])
    bad = base.copy()
    bad[0] ^= 0xFF
    with pytest.raises(BadMagicError):
        parse_header(bad)
    # every typed error is a FrameError is a ValueError (compat contract)
    assert issubclass(BadMagicError, FrameError)
    assert issubclass(FrameError, ValueError)


# ---------------------------------------------------------------------------
# FaultyChannel per-round fault attribution + downlink broadcast coverage
# ---------------------------------------------------------------------------


def test_faulty_channel_per_round_fault_attribution():
    """Every injected fault lands in the bucket of the round it hit, the
    buckets sum to the running totals, and opening rounds on the inner
    channel (desynchronizing buckets) is rejected."""
    ch = FaultyChannel(drop_prob=0.3, bitflip_prob=0.3, seed=5)
    per_round = []
    for r in range(4):
        assert ch.begin_round() == r
        for i in range(32):
            ch.send_up(_valid_frame(round_idx=r, client_idx=i))
        per_round.append((ch.dropped_per_round[-1],
                          ch.corrupted_per_round[-1]))
    assert len(ch.dropped_per_round) == len(ch.corrupted_per_round) == 4
    assert sum(ch.dropped_per_round) == ch.dropped > 0
    assert sum(ch.corrupted_per_round) == ch.corrupted > 0
    # buckets are per-round snapshots, not cumulative
    assert ch.dropped_per_round == [d for d, _ in per_round]
    assert ch.corrupted_per_round == [c for _, c in per_round]
    # byte buckets stay aligned: one bucket per round, every send billed
    assert len(ch.uplink.per_round) == 4
    assert ch.uplink.messages == 4 * 32

    # bypassing the wrapper is an error, not silent desynchronization
    fresh = FaultyChannel(drop_prob=1.0, seed=0)
    fresh.inner.begin_round()
    with pytest.raises(RuntimeError, match="begin_round"):
        fresh.send_up(_valid_frame())


def test_faulty_channel_downlink_broadcast():
    """Server->client broadcasts ride the same faulty wire: every byte of
    every broadcast is billed downlink, drops surface as None, and a
    corrupted broadcast is rejected by the frame parser with a typed
    FrameError — a client never trains on a silently mangled model."""
    frame = _valid_frame()
    ch = FaultyChannel(drop_prob=0.25, truncate_prob=0.25,
                       bitflip_prob=0.25, seed=11)
    ch.begin_round()
    n_clients = 64
    outcomes = {"ok": 0, "dropped": 0, "rejected": 0, "payload_flip": 0}
    for _ in range(n_clients):
        got = ch.send_down(frame)
        if got is None:
            outcomes["dropped"] += 1
            continue
        try:
            parse_header(got)
        except FrameError:
            outcomes["rejected"] += 1       # typed, never an unpack crash
            continue
        if np.array_equal(got, frame):
            outcomes["ok"] += 1             # intact broadcasts arrive bitwise
        else:
            # payload-region bitflip: header parses, body differs — the
            # channel still attributed it as corrupted (pinned below)
            outcomes["payload_flip"] += 1
    # the wire billed every broadcast, including the ones it then ate
    assert ch.downlink.messages == n_clients
    assert ch.downlink.per_round == [n_clients * frame.nbytes]
    assert outcomes["dropped"] == ch.dropped > 0
    assert outcomes["ok"] > 0
    # every non-intact delivered frame was counted corrupted by the wire
    assert (outcomes["rejected"] + outcomes["payload_flip"]
            <= ch.corrupted == ch.corrupted_per_round[0])
    assert ch.corrupted > 0
