"""EF telescoping invariant: sum_t recon_t = sum_t g_t + e_0 - e_T.

No gradient mass is ever lost by an EF compressor, only delayed — this is
the paper's Eq. 6 and the property behind the w/-EF ablation (C3).
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, st

from repro.core import baselines, error_feedback as ef


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12),
       st.sampled_from(["topk", "signsgd", "stc"]))
def test_ef_telescoping(seed, rounds, kind):
    d = 100
    key = jax.random.PRNGKey(seed)
    e = ef.ef_init(d)
    total_g = jnp.zeros((d,))
    total_recon = jnp.zeros((d,))

    def compress(u):
        if kind == "topk":
            return baselines.topk_compress(u, 7)
        if kind == "signsgd":
            return baselines.signsgd_compress(u)
        return baselines.stc_compress(u, 7)

    for t in range(rounds):
        key, kg = jax.random.split(key)
        g = jax.random.normal(kg, (d,))
        _, recon, e = ef.ef_step(compress, g, e)
        total_g += g
        total_recon += recon

    np.testing.assert_allclose(np.asarray(total_recon + e),
                               np.asarray(total_g), rtol=1e-4, atol=1e-4)


def test_ef_disabled_keeps_residual_zeroed():
    d = 50
    e = ef.ef_init(d)
    g = jax.random.normal(jax.random.PRNGKey(0), (d,))
    _, recon, e2 = ef.ef_step(lambda u: baselines.topk_compress(u, 5), g, e,
                              enabled=False)
    np.testing.assert_array_equal(np.asarray(e2), np.zeros(d))


def test_tree_ef_telescoping():
    """Same invariant through the TreeCompressor wrapper (Eq. 6 in the tree runtime)."""
    from repro.configs.base import CompressorConfig
    from repro.core import flat
    from repro.core.compressor import make_compressor

    params = {"w": jnp.zeros((40, 5)), "b": jnp.zeros((11,))}
    comp = make_compressor(CompressorConfig(kind="topk", keep_ratio=0.05))
    e = comp.init_state(params)
    tg = jax.tree.map(jnp.zeros_like, params)
    tr = jax.tree.map(jnp.zeros_like, params)
    key = jax.random.PRNGKey(0)
    for t in range(8):
        key, kg = jax.random.split(key)
        g = jax.tree.map(
            lambda p: jax.random.normal(jax.random.fold_in(kg, p.size), p.shape),
            params)
        recon, e, _ = comp.step(kg, g, e, params)
        tg = flat.tree_add(tg, g)
        tr = flat.tree_add(tr, recon)
    resid = flat.tree_sub(tg, tr)
    jax.tree.map(lambda r, ee: np.testing.assert_allclose(r, ee, rtol=1e-4, atol=1e-4),
                 resid, e)
