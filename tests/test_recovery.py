"""Crash-safe recovery: bitwise resume of the in-process engine from a
mid-run recovery point, the absolute-round checkpoint cadence, and — over
real sockets — a SIGKILLed worker rejoining with its EF residual re-synced
from the server's bank.

The bitwise-resume property rests on the engine's fold_in PRNG contract:
every round is a pure function of (seed, fault_seed, FLState.round), so
restoring the state tree IS restoring the trajectory — block grouping
around the checkpoint boundary is irrelevant.
"""
import signal
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_fl_checkpoint,
                              save_fl_checkpoint)
from repro.configs.base import CompressorConfig, FLConfig
from repro.configs.run import RunConfig


def _faulted_problem(num_clients=4):
    """Tiny faulted vision problem: drops + stragglers + staleness buffer,
    so a recovery point must carry every piece of mutable round state."""
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.engine import RoundEngine, device_pools, vision_batcher
    from repro.fl.round import build_fl_round
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import VisionSpec, make_paper_model

    spec = VisionSpec("tiny", (6, 6, 1), 3)
    comp = CompressorConfig(kind="stc", keep_ratio=0.1)
    fl = FLConfig(num_clients=num_clients, local_steps=2, local_lr=0.05,
                  local_batch=4, compressor=comp, seed=0)
    run = RunConfig(fl=fl, drop_rate=0.3, straggler_rate=0.25,
                    staleness_max=2, fault_seed=7)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(fl.seed))
    from repro.core.strategy import make_strategy
    strategy = make_strategy(comp, loss_fn=model.syn_loss,
                             syn_spec=vision_syn_spec(spec, comp),
                             local_lr=fl.local_lr)
    train = make_class_image_dataset(jax.random.PRNGKey(fl.seed), 120,
                                     spec.input_shape, spec.num_classes)
    parts = dirichlet_partition(train.y, num_clients, alpha=fl.dirichlet_alpha,
                                seed=fl.seed, min_per_client=fl.local_batch)
    pools = device_pools(parts)

    def make_engine():
        return RoundEngine(
            build_fl_round(model.loss, strategy, run),
            vision_batcher(train.x, train.y, pools, fl.local_steps,
                           fl.local_batch),
            seed=fl.seed)

    return make_engine, params, strategy, run


def _state_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def test_inproc_resume_is_bitwise_equal_to_uninterrupted_run(tmp_path):
    """Oracle: 8 straight faulted rounds. Recovery path: checkpoint every 2
    rounds (eval every 3 — deliberately coprime cadences), load the step-4
    recovery point into a FRESH engine, run the remaining 4 rounds. Params,
    per-client EF, staleness ring buffer, and round counter must all be
    bitwise identical."""
    make_engine, params, strategy, run = _faulted_problem()
    N, R, CUT = run.fl.num_clients, 8, 4

    oracle = make_engine()
    st = oracle.init_state(params, N, strategy, staleness_max=run.staleness_max)
    oracle_final, _ = oracle.run(st, R)

    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    eng = make_engine()
    st = eng.init_state(params, N, strategy, staleness_max=run.staleness_max)
    eng.run(st, CUT + 1, eval_every=3, ckpt_every=2,
            ckpt_fn=lambda s, r: save_fl_checkpoint(mgr, r, s, run=run))
    assert mgr.steps() == [2, 4]                # absolute-round cadence

    # fresh engine + fresh template state: the checkpoint is the only thing
    # carried across the "process boundary"
    resumed = make_engine()
    template = resumed.init_state(params, N, strategy,
                                  staleness_max=run.staleness_max)
    state, _, meta = load_fl_checkpoint(mgr, template, step=CUT)
    assert meta["round"] == CUT and int(state.round) == CUT
    assert meta["run"] == run.to_json()
    resumed_final, _ = resumed.run(state, R - CUT)

    assert int(resumed_final.round) == int(oracle_final.round) == R
    assert _state_equal(oracle_final, resumed_final)


def test_ckpt_hook_fires_on_absolute_round_boundaries(tmp_path):
    """ckpt_every anchors on FLState.round, not rounds-run-this-call: a
    state resumed at round 4 checkpoints at 6 and 8, exactly where the
    uninterrupted run does — and eval boundaries still fire relative."""
    make_engine, params, strategy, run = _faulted_problem()
    N = run.fl.num_clients
    fired = []
    eng = make_engine()
    st = eng.init_state(params, N, strategy, staleness_max=run.staleness_max)
    st, hist = eng.run(st, 8, eval_every=3, eval_fn=lambda s, m, r: r,
                       ckpt_every=2, ckpt_fn=lambda s, r: fired.append(r))
    assert fired == [2, 4, 6, 8]
    assert [r for r, _ in hist.evals] == [3, 6, 8]

    # second leg of a resumed run: absolute rounds continue
    fired2 = []
    st, _ = eng.run(st, 5, ckpt_every=4, ckpt_fn=lambda s, r: fired2.append(r))
    assert fired2 == [12] and int(st.round) == 13


def test_run_config_ckpt_every_roundtrips_and_validates():
    run = RunConfig(fl=FLConfig(num_clients=2), ckpt_every=5)
    assert RunConfig.from_json(run.to_json()).ckpt_every == 5
    # older checkpoints have no ckpt_every key: default 0
    d = run.to_json()
    d.pop("ckpt_every")
    assert RunConfig.from_json(d).ckpt_every == 0
    with pytest.raises(ValueError):
        RunConfig(fl=FLConfig(num_clients=2), ckpt_every=-1)


# ---------------------------------------------------------------------------
# live sockets: SIGKILLed worker rejoins with its banked EF residual
# ---------------------------------------------------------------------------


@pytest.mark.transport(timeout=480)
def test_killed_worker_rejoins_with_banked_ef_resynced():
    """SIGKILL a worker mid-run, drive rounds without it (delivered=False —
    its residual is frozen server-side), restart its process, and require:
    the rejoiner's installed EF is bitwise the banked commit, it re-enters
    delivery, and the missed rounds were recorded undelivered."""
    from repro.comm.transport import SocketServer, spawn_local_workers
    from repro.core.strategy import make_strategy
    from repro.fl.engine import LiveRoundLoop, RetryPolicy
    from repro.launch.worker import vision_setup
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import VisionSpec, make_paper_model

    N, KILL = 2, 1
    spec = VisionSpec("tiny", (6, 6, 1), 3)
    comp = CompressorConfig(kind="stc", keep_ratio=0.1)
    fl = FLConfig(num_clients=N, local_steps=2, local_lr=0.05,
                  local_batch=4, compressor=comp, seed=0)
    run = RunConfig(fl=fl, wire="codec", transport="socket",
                    round_deadline_s=60.0, recv_timeout_s=30.0,
                    transport_retries=0, heartbeat_s=0.2,
                    liveness_timeout_s=5.0)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(fl.seed))
    strategy = make_strategy(comp, loss_fn=model.syn_loss,
                             syn_spec=vision_syn_spec(spec, comp),
                             local_lr=fl.local_lr)
    codec = strategy.wire_codec(params, policy=run.wire_policy)

    warm = RetryPolicy(max_retries=0, recv_timeout_s=240.0,
                       max_timeout_s=240.0)
    server = SocketServer(N, heartbeat_s=run.heartbeat_s,
                          liveness_timeout_s=run.liveness_timeout_s)
    procs = spawn_local_workers(server.address, range(N))
    rejoin_procs = []
    try:
        server.wait_ready(60)
        server.send_setup(vision_setup(run, model="mlp", spec=spec,
                                       train_size=96))
        loop = LiveRoundLoop(server, strategy, codec, run, params)
        loop.run(2, deadline_s=240.0, policy=warm)      # 0 = jit warm-up
        assert server.wait_ef_bank(1, range(N), timeout=30.0)
        banked = server.ef_bank()                        # post-round-1 commits

        procs[KILL].send_signal(signal.SIGKILL)
        procs[KILL].wait()
        deadline = time.monotonic() + 20
        while KILL in server.live_workers():
            assert time.monotonic() < deadline, "server never noticed death"
            time.sleep(0.05)
        loop.run(2)                                      # rounds 2-3 without it

        rejoin_procs = spawn_local_workers(server.address, [KILL])
        deadline = time.monotonic() + 60
        while KILL not in server.live_workers():
            assert time.monotonic() < deadline, "rejoiner never connected"
            time.sleep(0.05)
        # EF conservation across the outage: the rejoiner was re-synced to
        # the exact round-1 commit (its missed rounds were delivered=False,
        # so the residual is unchanged — atol=0)
        ef = server.request_ef(KILL, timeout=60)
        assert ef is not None
        np.testing.assert_array_equal(ef, banked[KILL][1])

        # the rejoiner's first round recompiles: generous window again
        loop.run(1, deadline_s=240.0, policy=warm)
    finally:
        server.stop()
        for p in list(procs) + list(rejoin_procs):
            try:
                p.wait(timeout=15)
            except Exception:
                p.kill()

    recs = {r["round"]: r for r in loop.history}
    assert recs[1]["delivered"].all()                    # pre-kill: healthy
    assert not recs[2]["delivered"][KILL] and KILL in recs[2]["dead"]
    assert not recs[3]["delivered"][KILL] and KILL in recs[3]["dead"]
    assert recs[4]["delivered"].all()                    # rejoined + delivering
    assert KILL not in recs[4]["dead"]
