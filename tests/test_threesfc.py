"""3SFC core properties: Eq. 8 optimality, Eq. 10 decode exactness,
encoder progress, EF interaction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs.base import CompressorConfig
from repro.core import flat, threesfc
from repro.core.compressor import make_compressor
from repro.data.synthetic import make_class_image_dataset
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, make_paper_model


@pytest.fixture(scope="module")
def setup():
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    ds = make_class_image_dataset(jax.random.PRNGKey(1), 256, (28, 28, 1), 10)
    p = params
    for i in range(3):
        g = jax.grad(model.loss)(p, {"x": jnp.asarray(ds.x[i*64:(i+1)*64]),
                                     "y": jnp.asarray(ds.y[i*64:(i+1)*64])})
        p = jax.tree.map(lambda a, b: a - 0.01 * b, p, g)
    target = flat.tree_sub(params, p)
    spec = vision_syn_spec(MNIST_SPEC, CompressorConfig(syn_batch=1))
    return model, params, target, spec


def test_scale_is_least_squares_optimal(setup):
    """Eq. 8: s* minimizes ||s·∇F - target||²; any other s is worse."""
    model, params, target, spec = setup
    syn0 = threesfc.init_syn(jax.random.PRNGKey(2), spec)
    res = threesfc.encode(model.syn_loss, params, target, syn0, steps=3, lr=0.1)
    gw = jax.grad(model.syn_loss)(params, res.syn)

    def err(s):
        return float(flat.tree_sqnorm(flat.tree_sub(flat.tree_scale(gw, s), target)))

    s_star = float(res.s)
    e_star = err(s_star)
    for ds in (-0.5, -0.1, 0.1, 0.5):
        assert err(s_star * (1 + ds) + 1e-3 * ds) >= e_star - 1e-10


def test_decode_matches_encoder_recon(setup):
    """Eq. 10: the server's decode from (D_syn, s) reproduces the client's
    reconstruction exactly (both sides evaluate at the same w^t)."""
    model, params, target, spec = setup
    syn0 = threesfc.init_syn(jax.random.PRNGKey(3), spec)
    res = threesfc.encode(model.syn_loss, params, target, syn0, steps=2, lr=0.1)
    server_recon = threesfc.decode(model.syn_loss, params, res.syn, res.s)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7),
                 res.recon, server_recon)


def test_encoder_steps_improve_cosine(setup):
    model, params, target, spec = setup
    syn0 = threesfc.init_syn(jax.random.PRNGKey(4), spec)
    cs = []
    for steps in (1, 5, 15):
        res = threesfc.encode(model.syn_loss, params, target, syn0,
                              steps=steps, lr=0.1)
        cs.append(abs(float(res.cosine)))
    assert cs[-1] > cs[0], f"cosine did not improve with steps: {cs}"


def test_recon_is_colinear_with_syn_grad(setup):
    """recon = s·∇F lies on the syn-grad ray -> |cos(recon, ∇F)| == 1."""
    model, params, target, spec = setup
    syn0 = threesfc.init_syn(jax.random.PRNGKey(5), spec)
    res = threesfc.encode(model.syn_loss, params, target, syn0, steps=1, lr=0.1)
    gw = jax.grad(model.syn_loss)(params, res.syn)
    assert abs(abs(float(flat.tree_cosine(res.recon, gw))) - 1.0) < 1e-5


def test_encode_aux_matches_fresh_objective(setup):
    """The (obj, gw, stats) carried out of the last scan step equal a fresh
    ``_objective`` evaluation at the *returned* D_syn — i.e. the final
    recompute the seed encoder did is genuinely redundant now."""
    model, params, target, spec = setup
    syn0 = threesfc.init_syn(jax.random.PRNGKey(8), spec)
    res = threesfc.encode(model.syn_loss, params, target, syn0, steps=2, lr=0.1)
    val, (gw, st) = threesfc._objective(
        model.syn_loss, params, res.syn, target, 0.0)
    np.testing.assert_allclose(res.objective, val, rtol=1e-6)
    np.testing.assert_allclose(res.stats, st, rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5,
                                                         atol=1e-8),
                 res.gw, gw)


def test_encode_cosine_matches_recon_cosine(setup):
    """res.cosine (derived from the fused stats triple via the sign trick)
    equals a direct tree_cosine of the materialized recon."""
    model, params, target, spec = setup
    syn0 = threesfc.init_syn(jax.random.PRNGKey(9), spec)
    res = threesfc.encode(model.syn_loss, params, target, syn0, steps=1, lr=0.1)
    want = flat.tree_cosine(res.recon, target)
    np.testing.assert_allclose(res.cosine, want, rtol=1e-5, atol=1e-7)


def test_budget_accounting(setup):
    """||D_syn||_0 + 1 <= B (paper Eq. 7 constraint), exact float count."""
    model, params, target, spec = setup
    syn = threesfc.init_syn(jax.random.PRNGKey(6), spec)
    assert syn.floats == spec.floats == 28 * 28 * 1 + 10
    # MLP budget: 795 floats -> the paper's 250.6x ratio on 199,210 params
    d = flat.tree_size(params)
    assert d == 199210
    assert abs((spec.floats + 1) / d - 1 / 250.57) < 1e-4


def test_low_rank_labels():
    spec = threesfc.SynSpec(x_shape=(1, 8, 32), num_classes=1000,
                            label_rank=4, label_lead=(1, 8))
    syn = threesfc.init_syn(jax.random.PRNGKey(0), spec)
    assert syn.y.shape == (1, 8, 4) and syn.y_rank.shape == (4, 1000)
    assert syn.labels().shape == (1, 8, 1000)
    assert spec.floats == 1 * 8 * 32 + 1 * 8 * 4 + 4 * 1000


def test_threesfc_with_ef_reduces_error(setup):
    """EF residual shrinks the *effective* error over rounds (C3 mechanism):
    cumulative reconstruction tracks cumulative target."""
    model, params, target, spec = setup
    comp_cfg = CompressorConfig(kind="threesfc", syn_steps=5, syn_lr=0.1)
    comp = make_compressor(comp_cfg, loss_fn=model.syn_loss, syn_spec=spec)
    e = comp.init_state(params)
    tot_recon = jax.tree.map(jnp.zeros_like, e)
    key = jax.random.PRNGKey(7)
    rel_errs = []
    for t in range(4):
        key, kr = jax.random.split(key)
        recon, e, m = comp.step(kr, target, e, params)
        tot_recon = flat.tree_add(tot_recon, recon)
        want = flat.tree_scale(target, float(t + 1))
        rel = float(flat.tree_norm(flat.tree_sub(tot_recon, want))
                    / flat.tree_norm(want))
        rel_errs.append(rel)
    # the telescoped relative error must not grow (EF keeps it = |e_T|/|sum g|)
    assert rel_errs[-1] <= rel_errs[0] + 1e-6, rel_errs
