"""Pallas kernels vs ref.py oracles: shape x dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import ssm as ssm_mod

SIZES = [1, 1000, 4096, 131072, 300001]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_cosine(n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,), dtype)
    y = jax.random.normal(jax.random.PRNGKey(n + 1), (n,), dtype)
    got = ops.fused_cosine(x, y)
    want = ref.fused_cosine(x, y)
    np.testing.assert_allclose(got, want, rtol=5e-3 if dtype == jnp.bfloat16 else 2e-4)


@pytest.mark.parametrize("n", SIZES)
def test_ef_update(n):
    u = jax.random.normal(jax.random.PRNGKey(n), (n,))
    d = jax.random.normal(jax.random.PRNGKey(n + 1), (n,))
    got = ops.ef_update(u, d, jnp.float32(0.37))
    want = ref.ef_update(u, d, jnp.float32(0.37))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert got.shape == u.shape


@pytest.mark.parametrize("n", SIZES)
def test_sign_quant(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    signs, scale = ops.sign_quant(x)
    rsigns, rscale = ref.sign_quant(x)
    np.testing.assert_array_equal(np.asarray(signs), np.asarray(rsigns))
    np.testing.assert_allclose(scale, rscale, rtol=1e-5)
    assert signs.dtype == jnp.int8


@pytest.mark.parametrize("n", [1000, 131072, 300001])
@pytest.mark.parametrize("k_frac", [0.001, 0.01, 0.1])
def test_topk_mask_threshold(n, k_frac):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    k = max(1, int(k_frac * n))
    tau = ops.topk_threshold(x, k)
    got, cnt = ops.topk_mask(x, tau)
    want, rcnt = ref.topk_mask(x, tau)
    np.testing.assert_allclose(got, want)
    np.testing.assert_allclose(cnt, rcnt)
    # sampled threshold lands near the requested k (exact when n <= sample)
    if n <= 65536:
        assert abs(int(cnt) - k) <= 1
    else:
        assert 0.3 * k <= int(cnt) <= 3 * k


@pytest.mark.parametrize("shape", [(1, 16, 2, 8, 4), (2, 64, 4, 16, 8),
                                   (1, 128, 8, 32, 16)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_ssd_chunk_vs_scan_oracle(shape, chunk):
    b, s, h, p, n = shape
    if s % chunk:
        pytest.skip("seq must divide chunk")
    k = jax.random.PRNGKey(0)
    xdt = 0.1 * jax.random.normal(k, (b, s, h, p))
    dA = -0.2 * jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    B = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (b, s, n))
    C = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (b, s, n))
    y1, f1 = ssm_mod.ssd_scan(xdt, dA, B, C, chunk)
    y2, f2 = ops.ssd_chunked(xdt, dA, B, C, chunk)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(f1, f2, rtol=1e-4, atol=1e-5)


def test_ssd_single_chunk_kernel_vs_ref():
    """Direct kernel-cell contract vs ref.ssd_chunk (one chunk, one head)."""
    from repro.kernels.ssd_chunk import ssd_chunk_call
    Q, P, N = 16, 8, 4
    k = jax.random.PRNGKey(0)
    x = 0.1 * jax.random.normal(k, (1, 1, 1, Q, P))
    dA = -0.3 * jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, Q)))
    B = jax.random.normal(jax.random.PRNGKey(2), (1, 1, Q, N))
    C = jax.random.normal(jax.random.PRNGKey(3), (1, 1, Q, N))
    y, st, dec = ssd_chunk_call(x, dA, B, C)
    ry, rst, rdec = ref.ssd_chunk(x[0, 0, 0], dA[0, 0, 0], B[0, 0], C[0, 0])
    np.testing.assert_allclose(y[0, 0, 0], ry, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(st[0, 0, 0], rst, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dec[0, 0, 0], rdec, rtol=1e-5, atol=1e-6)
