"""tree_fused_stats engine: parity vs ref.py / naive tree_dot across ragged
leaf shapes, mixed dtypes, interpret + jit-compiled modes, and the AD/vmap
contracts the 3SFC encoder relies on (custom-JVP grad-of-grad)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, flat
from repro.kernels import ops, ref

# ragged on purpose: scalar leaf, sub-lane leaf, exact tile, tile+1, odd big
RAGGED_SHAPES = [(), (7,), (1024,), (1025,), (3, 341), (128, 1024), (13, 77, 5)]


def _pair(key, shapes, dtypes=None):
    ks = jax.random.split(key, 2 * max(1, len(shapes)))
    dtypes = dtypes or [jnp.float32] * len(shapes)
    a = {f"p{i}": jax.random.normal(ks[2 * i], s, dt)
         for i, (s, dt) in enumerate(zip(shapes, dtypes))}
    b = {f"p{i}": jax.random.normal(ks[2 * i + 1], s, dt)
         for i, (s, dt) in enumerate(zip(shapes, dtypes))}
    return a, b


def _oracle(a, b):
    """Whole-tree stats via ref.py on the monolithic concat (the contract)."""
    fa = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                          for l in jax.tree.leaves(a)])
    fb = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                          for l in jax.tree.leaves(b)])
    return ref.fused_cosine(fa, fb)


def test_ragged_tree_matches_oracle():
    a, b = _pair(jax.random.PRNGKey(0), RAGGED_SHAPES)
    got = ops.tree_fused_stats(a, b)
    np.testing.assert_allclose(got, _oracle(a, b), rtol=2e-4)


def test_matches_naive_tree_dot():
    a, b = _pair(jax.random.PRNGKey(1), RAGGED_SHAPES)
    st = flat.tree_stats(a, b)
    np.testing.assert_allclose(st[0], flat.tree_dot(a, b), rtol=1e-5)
    np.testing.assert_allclose(st[1], flat.tree_sqnorm(a), rtol=2e-4)
    np.testing.assert_allclose(st[2], flat.tree_sqnorm(b), rtol=2e-4)


def test_single_scalar_leaf():
    st = ops.tree_fused_stats({"w": jnp.float32(3.0)}, {"w": jnp.float32(-2.0)})
    np.testing.assert_allclose(st, [-6.0, 9.0, 4.0], rtol=1e-6)


def test_empty_tree_and_empty_leaf():
    np.testing.assert_array_equal(ops.tree_fused_stats({}, {}), jnp.zeros(3))
    a = {"e": jnp.zeros((0,)), "x": jnp.ones((5,))}
    b = {"e": jnp.zeros((0,)), "x": 2.0 * jnp.ones((5,))}
    np.testing.assert_allclose(ops.tree_fused_stats(a, b), [10.0, 5.0, 20.0],
                               rtol=1e-6)


@pytest.mark.parametrize("dtypes", [
    [jnp.bfloat16] * len(RAGGED_SHAPES),
    [jnp.bfloat16 if i % 2 else jnp.float32 for i in range(len(RAGGED_SHAPES))],
])
def test_mixed_dtype_trees(dtypes):
    a, b = _pair(jax.random.PRNGKey(2), RAGGED_SHAPES, dtypes)
    got = ops.tree_fused_stats(a, b)
    np.testing.assert_allclose(got, _oracle(a, b), rtol=5e-3)
    assert got.dtype == jnp.float32


def test_chunking_crosses_leaf_boundaries():
    """Force multiple kernel chunks by shrinking the chunk budget."""
    old = ops.TREE_CHUNK_ELEMS
    ops.TREE_CHUNK_ELEMS = 2048
    try:
        a, b = _pair(jax.random.PRNGKey(3), [(5000,), (17,), (3000,)])
        np.testing.assert_allclose(ops.tree_fused_stats(a, b), _oracle(a, b),
                                   rtol=2e-4)
    finally:
        ops.TREE_CHUNK_ELEMS = old


def test_jit_compiled_mode():
    a, b = _pair(jax.random.PRNGKey(4), RAGGED_SHAPES)
    got = jax.jit(ops.tree_fused_stats)(a, b)
    np.testing.assert_allclose(got, _oracle(a, b), rtol=2e-4)


def test_vmap_batched_clients():
    """fl/round vmaps the compressor over clients; stats must batch."""
    def one(key):
        a, b = _pair(key, [(300,), (1025,)])
        return a, b
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    ab = [one(k) for k in keys]
    a = jax.tree.map(lambda *xs: jnp.stack(xs), *[x[0] for x in ab])
    b = jax.tree.map(lambda *xs: jnp.stack(xs), *[x[1] for x in ab])
    got = jax.vmap(ops.tree_fused_stats)(a, b)
    want = jnp.stack([_oracle(x, y) for x, y in ab])
    np.testing.assert_allclose(got, want, rtol=2e-4)


def test_grad_and_grad_of_grad():
    """The encoder differentiates cosine-of-stats twice (grad-of-grad)."""
    a, b = _pair(jax.random.PRNGKey(6), [(129,), (1025,)])

    def cos(a):
        d, aa, bb = flat.tree_stats(a, b)
        return d / (jnp.sqrt(aa) * jnp.sqrt(bb) + 1e-12)

    def cos_ref(a):
        fa = jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(a)])
        fb = jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(b)])
        return jnp.vdot(fa, fb) / (jnp.linalg.norm(fa) * jnp.linalg.norm(fb)
                                   + 1e-12)

    g = jax.grad(cos)(a)
    gr = jax.grad(cos_ref)(a)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-4,
                                                         atol=1e-6), g, gr)

    def gnorm(f):
        return lambda a: flat.tree_sqnorm(jax.grad(f)(a))

    gg = jax.grad(gnorm(cos))(a)
    ggr = jax.grad(gnorm(cos_ref))(a)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-3,
                                                         atol=1e-6), gg, ggr)


def test_mismatched_trees_raise():
    """Lockstep streaming must reject shape mismatches loudly (zero padding
    would otherwise silently swallow them)."""
    with pytest.raises(ValueError, match="lockstep"):
        ops.tree_fused_stats({"w": jnp.ones((4,))}, {"w": jnp.ones((6,))})
    with pytest.raises(ValueError, match="lockstep"):
        ops.tree_ef_update({"w": jnp.ones((2, 3))}, {"w": jnp.ones((3, 2))},
                           jnp.float32(1.0))


def test_tree_ef_update_chunked_across_leaves():
    """EF streaming packs leaves into shared chunks; outputs must slice back
    to the right leaves even when a chunk boundary splits a leaf."""
    old = ops.TREE_CHUNK_ELEMS
    ops.TREE_CHUNK_ELEMS = 2048
    try:
        u, d = _pair(jax.random.PRNGKey(10), [(5000,), (3,), (1500,)])
        s = jnp.float32(-1.25)
        got = ops.tree_ef_update(u, d, s)
        want = jax.tree.map(lambda ui, di: ui - s * di, u, d)
        jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-5,
                                                             atol=1e-6),
                     got, want)
    finally:
        ops.TREE_CHUNK_ELEMS = old


def test_tree_ef_update_matches_axpy():
    u, d = _pair(jax.random.PRNGKey(7), RAGGED_SHAPES)
    s = jnp.float32(0.37)
    got = ops.tree_ef_update(u, d, s)
    want = jax.tree.map(lambda ui, di: ui - s * di, u, d)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-5,
                                                         atol=1e-6), got, want)


def test_reconstruction_stats_fused():
    v = jax.random.normal(jax.random.PRNGKey(8), (4097,))
    r = 0.8 * v + 0.1 * jax.random.normal(jax.random.PRNGKey(9), (4097,))
    cos, rel = baselines.reconstruction_stats(v, r)
    want_cos = jnp.vdot(r, v) / (jnp.linalg.norm(r) * jnp.linalg.norm(v))
    want_rel = jnp.linalg.norm(r - v) / jnp.linalg.norm(v)
    np.testing.assert_allclose(cos, want_cos, rtol=1e-4)
    np.testing.assert_allclose(rel, want_rel, rtol=1e-3)


def test_reconstruction_stats_small_error_regime():
    """The error term must resolve errors far below f32 cancellation of the
    ||r||² − 2⟨r,v⟩ + ||v||² identity (~3e-4 relative)."""
    v = jax.random.normal(jax.random.PRNGKey(11), (1 << 20,))
    r = v + 1e-4 * jax.random.normal(jax.random.PRNGKey(12), (1 << 20,))
    _, rel = baselines.reconstruction_stats(v, r)
    want = jnp.linalg.norm(r - v) / jnp.linalg.norm(v)
    assert float(want) > 0
    np.testing.assert_allclose(rel, want, rtol=1e-3)
