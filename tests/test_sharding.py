"""Sharding rules: specs are rank-correct, divisibility-safe, and the FL
round + serving entries lower & compile on a small host mesh (the same code
path dryrun.py uses at 16x16 and 2x16x16)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig, get_smoke_config
from repro.launch import specs as specs_lib
from repro.launch.mesh import client_axes, num_clients_for
from repro.models import params as params_lib
from repro.models.build import build_model

# per-test (not module-wide): the subprocess-backed tests below run their
# multi-device half in a forced-8-device child and work from any parent
needs_multidev = pytest.mark.skipif(
    len(jax.devices()) < 2 and os.environ.get("FORCE_SHARDING_TESTS") != "1",
    reason="needs >=2 devices (run under dryrun flags for multi-dev)")


def _mesh():
    n = len(jax.devices())
    m = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // m, m), ("data", "model"))


@needs_multidev
def test_param_specs_rank_and_divisibility():
    mesh = _mesh()
    for arch in ("tinyllama-1.1b", "qwen3-moe-30b-a3b", "mamba2-370m",
                 "recurrentgemma-2b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = params_lib.sharding_specs(shapes, mesh)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

        def check(sd, sp):
            assert len(sp) <= len(sd.shape), (sd.shape, sp)
            for dim, ax in zip(sd.shape, tuple(sp) + (None,) * 8):
                if ax is not None:
                    axs = ax if isinstance(ax, tuple) else (ax,)
                    k = 1
                    for a in axs:
                        k *= sizes[a]
                    assert dim % k == 0, (sd.shape, sp)

        jax.tree.map(check, shapes, specs)


SMALL = {
    "train_4k": ShapeConfig("train_4k", 64, 8, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 64, 4, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 64, 8, "decode"),
}


@needs_multidev
@pytest.mark.parametrize("shape", list(SMALL))
def test_entries_lower_on_host_mesh(shape, monkeypatch):
    monkeypatch.setattr(specs_lib, "INPUT_SHAPES", SMALL)
    monkeypatch.setattr(specs_lib, "get_config", get_smoke_config)
    mesh = _mesh()
    made = specs_lib.make_entry("qwen1.5-0.5b", shape, mesh)
    assert made is not None
    entry, args = made
    compiled = jax.jit(entry).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    assert cost.get("flops", 0) > 0


@needs_multidev
def test_client_axes():
    mesh = _mesh()
    assert client_axes(mesh) == ("data",)
    assert num_clients_for(mesh) == mesh.devices.shape[0]


def test_make_host_mesh_rejects_nondivisible_model():
    """A truncated (n // model, model) mesh would silently drop devices —
    make_host_mesh must refuse instead."""
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError, match="n % model"):
        make_host_mesh(model=n + 1)          # n % (n+1) != 0 for any n >= 1
    with pytest.raises(ValueError, match="n % model"):
        make_host_mesh(model=0)


def test_fl_shardings_units_on_eight_devices(multidev_scenario):
    """FLShardings placement contract on a real 8-device host mesh
    (subprocess — the pytest process is pinned to 1 device): replicated
    params, 8-way EF/pool shards, in-jit batch constraint, divisibility
    guards in both FLShardings and make_host_mesh."""
    multidev_scenario("sharding_units")
