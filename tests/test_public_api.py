"""Public-API snapshot: the exported names of the four runtime packages.

The golden lists below are the PR 5 contract. A future refactor that adds,
renames or drops an export must update this file deliberately — silent
surface drift fails here first. Module attributes are excluded (submodule
imports are an implementation detail); everything else a user can reach as
``repro.<pkg>.<name>`` is pinned.

Also pins the deprecation behavior of the two legacy entry points: the
``make_compressor``/``make_fl_round`` shims emit ``DeprecationWarning``
exactly once per process each, then go quiet.
"""
import types
import warnings

import jax
import numpy as np
import pytest

GOLDEN = {
    "repro.core": [
        "CompressionStrategy", "make_strategy", "register_strategy",
        "strategy_kinds",
    ],
    "repro.fl": [
        "ClientPools", "DeliveryReport", "EngineStats", "FLShardings",
        "FLState", "FaultSchedule", "LiveRoundLoop", "RetryPolicy",
        "RoundEngine", "aggregate", "build_fl_round", "device_pools",
        "fault_schedule", "fl_init", "fl_round", "local_train",
        "make_fl_round", "make_fl_shardings", "matched_compressors",
        "null_schedule", "payload_budget", "residual_mass_conserved",
        "server_update", "token_batcher", "vision_batcher",
    ],
    "repro.comm": [
        "CODECS", "Channel", "Codec", "FaultyChannel", "FrameError",
        "FrameSpec", "InProcessChannel", "LinkStats", "ProtocolError",
        "ServerLink", "SocketServer", "make_codec", "parse_header",
        "register_codec", "register_kind_id", "spawn_local_workers",
        "wire_bytes",
    ],
    "repro.configs": [
        "ARCH_IDS", "CompressorConfig", "FLConfig", "INPUT_SHAPES",
        "ModelConfig", "RunConfig", "ShapeConfig", "get_config",
        "get_smoke_config", "list_archs",
    ],
}


@pytest.mark.parametrize("modname", sorted(GOLDEN))
def test_exported_names_pinned(modname):
    import importlib

    mod = importlib.import_module(modname)
    actual = sorted(n for n, v in vars(mod).items()
                    if not n.startswith("_")
                    and not isinstance(v, types.ModuleType))
    assert actual == GOLDEN[modname], (
        f"{modname} exports changed; update the golden list DELIBERATELY "
        f"(added: {sorted(set(actual) - set(GOLDEN[modname]))}, "
        f"removed: {sorted(set(GOLDEN[modname]) - set(actual))})")


def test_builtin_strategy_kinds_pinned():
    from repro.core.strategy import STRATEGIES

    builtin = {"identity", "topk", "randk", "signsgd", "stc", "threesfc",
               "fedsynth"}
    assert builtin <= set(STRATEGIES), sorted(STRATEGIES)


def _one_warning_only(fn):
    """Call ``fn`` twice; return the DeprecationWarnings raised in total."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        fn()
        fn()
    return [w for w in rec if issubclass(w.category, DeprecationWarning)]


def test_deprecated_shims_warn_exactly_once():
    from repro.configs.base import CompressorConfig, FLConfig
    from repro.core import strategy as S
    from repro.core.compressor import make_compressor
    from repro.fl.round import make_fl_round
    from repro.models.cnn import VisionSpec, make_paper_model

    model = make_paper_model("mlp", VisionSpec("tiny", (4, 4, 1), 3))
    ccfg = CompressorConfig(kind="topk", keep_ratio=0.2)
    cfg = FLConfig(num_clients=2, compressor=ccfg)

    # reset the once-latch: earlier tests in the session may have tripped it
    S._DEPRECATION_SEEN.clear()
    ws = _one_warning_only(lambda: make_compressor(ccfg))
    assert len(ws) == 1 and "make_compressor" in str(ws[0].message), ws

    comp = make_compressor(ccfg)
    ws = _one_warning_only(lambda: make_fl_round(model.loss, comp, cfg))
    assert len(ws) == 1 and "make_fl_round" in str(ws[0].message), ws

    # the shims still produce a working round function
    rf = make_fl_round(model.loss, comp, cfg)
    from repro.fl.round import fl_init
    params = model.init(jax.random.PRNGKey(0))
    batches = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (2, 1, 4, 4, 4, 1)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (2, 1, 4), 0, 3),
    }
    state, m = rf(fl_init(params, 2), batches, jax.random.PRNGKey(3))
    assert np.isfinite(float(m.loss))


def test_run_config_validates_and_roundtrips():
    import json

    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig

    with pytest.raises(ValueError, match="'float' or 'codec'"):
        RunConfig(wire="bytes")
    with pytest.raises(ValueError, match="'vmap' or 'shard_map'"):
        RunConfig(client_parallel="pmap")
    with pytest.raises(ValueError, match="requires an explicit mesh"):
        RunConfig(client_parallel="shard_map")
    with pytest.raises(ValueError, match="num_micro"):
        RunConfig(num_micro=0)

    # fault-knob validation (repro.fl.faults semantics)
    with pytest.raises(ValueError, match="participation_rate"):
        RunConfig(participation_rate=0.0)
    with pytest.raises(ValueError, match="drop_rate"):
        RunConfig(drop_rate=1.0)
    with pytest.raises(ValueError, match="staleness_max"):
        RunConfig(staleness_max=-1)
    with pytest.raises(ValueError, match="requires staleness_max"):
        RunConfig(straggler_rate=0.5)
    with pytest.raises(ValueError, match="fused_decode is incompatible"):
        RunConfig(fused_decode=True, staleness_max=2)

    run = RunConfig(
        fl=FLConfig(num_clients=4, local_steps=2, local_lr=0.05,
                    compressor=CompressorConfig(kind="stc", keep_ratio=0.1)),
        wire="codec", fused_decode=False, num_micro=2,
        participation_rate=0.7, drop_rate=0.3, straggler_rate=0.25,
        staleness_max=2, fault_seed=11)
    assert run.has_faults
    # through actual JSON text, not just dicts
    back = RunConfig.from_json(json.loads(json.dumps(run.to_json())))
    assert back == run
    assert back.fl.compressor.kind == "stc"
    assert back.staleness_max == 2 and back.fault_seed == 11

    # a default config is fault-free and stays that way through JSON
    assert not RunConfig().has_faults
    assert not RunConfig.from_json(
        json.loads(json.dumps(RunConfig().to_json()))).has_faults


def test_run_config_fault_knobs_from_flags():
    """The training CLI's argparse namespace reaches the fault model."""
    import argparse

    from repro.configs.base import CompressorConfig
    from repro.configs.run import RunConfig

    ns = argparse.Namespace(
        clients=4, local_steps=1, lr=0.05, batch=8, rounds=2, seed=0,
        participation_rate=0.5, drop_rate=0.25, straggler_rate=0.0,
        staleness_max=0, fault_seed=3)
    run = RunConfig.from_flags(
        ns, compressor=CompressorConfig(kind="identity"))
    assert run.participation_rate == 0.5
    assert run.drop_rate == 0.25
    assert run.fault_seed == 3
    assert run.has_faults
    # flag-less namespaces (older drivers) keep the zero-fault defaults
    bare = argparse.Namespace(clients=4, local_steps=1, lr=0.05, batch=8,
                              rounds=2, seed=0)
    assert not RunConfig.from_flags(
        bare, compressor=CompressorConfig(kind="identity")).has_faults


def test_retry_policy_validates_and_schedules():
    """Transport give-up policy: invalid knobs are rejected at
    construction, and the backoff schedule is the documented
    ``min(recv_timeout_s * recv_backoff**attempt, max_timeout_s)``.
    (Retries re-send the SAME frame and are billed like any send —
    pinned behaviorally in tests/test_faults.py and test_transport.py.)"""
    from repro.fl.engine import RetryPolicy

    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="recv_timeout_s"):
        RetryPolicy(recv_timeout_s=0.0)
    with pytest.raises(ValueError, match="recv_backoff"):
        RetryPolicy(recv_backoff=0.5)
    with pytest.raises(ValueError, match="max_timeout_s"):
        RetryPolicy(recv_timeout_s=5.0, max_timeout_s=1.0)
    pol = RetryPolicy(max_retries=0, recv_timeout_s=1.5, recv_backoff=3.0,
                      max_timeout_s=9.0)
    assert [pol.timeout(a) for a in range(4)] == [1.5, 4.5, 9.0, 9.0]


def test_run_config_transport_knobs_validate_and_roundtrip():
    import json

    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig

    with pytest.raises(ValueError, match="transport must be"):
        RunConfig(transport="carrier-pigeon")
    with pytest.raises(ValueError, match="requires wire='codec'"):
        RunConfig(transport="socket", wire="float")
    with pytest.raises(ValueError, match="incompatible with the schedule"):
        RunConfig(transport="socket", wire="codec", drop_rate=0.3)
    with pytest.raises(ValueError, match="round_deadline_s"):
        RunConfig(round_deadline_s=0.0)
    with pytest.raises(ValueError, match="transport_retries"):
        RunConfig(transport_retries=-1)
    with pytest.raises(ValueError, match="liveness_timeout_s"):
        RunConfig(heartbeat_s=2.0, liveness_timeout_s=1.0)

    run = RunConfig(
        fl=FLConfig(num_clients=3, local_steps=2, local_lr=0.05,
                    compressor=CompressorConfig(kind="stc", keep_ratio=0.1)),
        wire="codec", transport="socket", round_deadline_s=12.5,
        recv_timeout_s=1.25, recv_backoff=1.5, transport_retries=3,
        heartbeat_s=0.25, liveness_timeout_s=4.0)
    back = RunConfig.from_json(json.loads(json.dumps(run.to_json())))
    assert back == run
    assert back.transport == "socket" and back.round_deadline_s == 12.5
    # the knobs compile into the transport's RetryPolicy, deadline-capped
    pol = run.retry_policy()
    assert pol.max_retries == 3 and pol.recv_timeout_s == 1.25
    assert pol.max_timeout_s == 12.5     # no receive outwaits the round


def test_run_config_transport_knobs_from_flags():
    """The training CLI's --transport family reaches the socket driver."""
    import argparse

    from repro.configs.base import CompressorConfig
    from repro.configs.run import RunConfig

    ns = argparse.Namespace(
        clients=3, local_steps=1, lr=0.05, batch=8, rounds=2, seed=0,
        wire="codec", transport="socket", round_deadline_s=7.0,
        recv_timeout_s=0.5, recv_backoff=1.5, transport_retries=1,
        heartbeat_s=0.2, liveness_timeout_s=2.0)
    run = RunConfig.from_flags(
        ns, compressor=CompressorConfig(kind="stc", keep_ratio=0.1))
    assert run.transport == "socket" and run.round_deadline_s == 7.0
    assert run.transport_retries == 1 and run.heartbeat_s == 0.2
    # flag-less namespaces (older drivers) keep the in-process default
    bare = argparse.Namespace(clients=4, local_steps=1, lr=0.05, batch=8,
                              rounds=2, seed=0)
    assert RunConfig.from_flags(
        bare, compressor=CompressorConfig(kind="identity")).transport \
        == "inproc"
