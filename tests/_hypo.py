"""Hypothesis passthrough with a deterministic fallback.

The tier-1 environment does not ship ``hypothesis``; these tests only use a
tiny slice of its API (``given``/``settings`` + integer/float/sampled_from
strategies), so when the real package is absent we degrade to a seeded,
deterministic example sweep: each ``@given`` test runs against a fixed
number of pseudo-random draws from the declared strategies. Properties are
checked on concrete examples either way — with real hypothesis installed
this module is a pure re-export (no shrinking is lost).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX_EXAMPLES = 10  # cap: keep the CPU suite fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    class st:  # noqa: N801 - mirrors `strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False,
                   allow_infinity=False, width=64):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(f):
            f._hypo_max_examples = max_examples
            return f

        return deco

    def given(*strats, **kw_strats):
        def deco(f):
            # no functools.wraps: __wrapped__ would make pytest unwrap to
            # f's signature and hunt fixtures for the strategy params
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_hypo_max_examples", 10),
                        _FALLBACK_MAX_EXAMPLES)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    vals = [s._draw(rng) for s in strats]
                    kws = {k: s._draw(rng) for k, s in kw_strats.items()}
                    f(*args, *vals, **kws, **kwargs)

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco
