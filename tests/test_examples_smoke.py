"""Examples can't silently rot: import each one and run its main path.

Every example exposes ``main(argv)`` so the smoke runs at tiny shapes
(seconds, not the examples' demo defaults). What's asserted is the
example's own headline claim — decode exactness for the two encode demos,
a completed training run with metrics + checkpoint for the driver demo.
"""
import importlib
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = os.path.join(REPO, "examples")


def _load(name):
    if EXAMPLES not in sys.path:
        sys.path.insert(0, EXAMPLES)
    return importlib.import_module(name)


def test_quickstart_main_tiny():
    qs = _load("quickstart")
    err = qs.main(["--train-size", "64", "--test-size", "32",
                   "--local-steps", "2", "--batch", "8", "--syn-steps", "2"])
    # the example's headline claim: server decode == client recon exactly
    assert err <= 1e-6, err


def test_compress_llm_update_main_tiny():
    ex = _load("compress_llm_update")
    err = ex.main(["--arch", "tinyllama-1.1b", "--steps", "2",
                   "--local-iters", "1"])
    assert err <= 1e-4, err


@pytest.mark.parametrize("wire", ["float", "codec"])
def test_fl_training_main_tiny(tmp_path, wire):
    ex = _load("fl_training")
    out = str(tmp_path / f"run_{wire}")
    ex.main(["--rounds", "2", "--clients", "2", "--train-size", "128",
             "--batch", "16", "--eval-every", "1", "--wire", wire,
             "--out", out])
    # metrics + run config + checkpoint all written
    lines = [json.loads(l) for l in
             open(os.path.join(out, "metrics.jsonl"))]
    assert lines and lines[-1]["round"] == 2
    rc = json.load(open(os.path.join(out, "run_config.json")))
    assert rc["wire"] == wire and rc["fl"]["num_clients"] == 2
    assert os.path.isdir(os.path.join(out, "final"))
