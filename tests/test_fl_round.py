"""FL runtime integration: rounds reduce loss; identity-compressor round
equals plain FedAvg math; aggregation options."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CompressorConfig, FLConfig
from repro.core import flat
from repro.core.compressor import make_compressor
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_class_image_dataset
from repro.fl.client import local_train
from repro.fl.round import fl_init, make_fl_round
from repro.fl.server import aggregate, server_update
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, make_paper_model

N, K, BATCH = 4, 3, 16


@pytest.fixture(scope="module")
def world():
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    ds = make_class_image_dataset(jax.random.PRNGKey(1), 600, (28, 28, 1), 10)
    rng = np.random.default_rng(0)
    bx = np.stack([ds.x[rng.choice(600, (K, BATCH))] for _ in range(N)])
    by = np.stack([ds.y[rng.choice(600, (K, BATCH))] for _ in range(N)])
    batches = {"x": jnp.asarray(bx), "y": jnp.asarray(by)}
    return model, params, batches


def _round(model, comp_cfg, **kw):
    spec = vision_syn_spec(MNIST_SPEC, comp_cfg)
    comp = make_compressor(comp_cfg, loss_fn=model.syn_loss, syn_spec=spec,
                           local_lr=0.05)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05, compressor=comp_cfg)
    return make_fl_round(model.loss, comp, cfg, **kw)


def test_fedavg_round_matches_manual(world):
    """identity compressor + mean aggregate == hand-rolled FedAvg."""
    model, params, batches = world
    rf = _round(model, CompressorConfig(kind="identity", error_feedback=False))
    state = fl_init(params, N)
    new_state, m = rf(state, batches, jax.random.PRNGKey(2))

    gs = []
    for i in range(N):
        bi = jax.tree.map(lambda x: x[i], batches)
        g, _ = local_train(model.loss, params, bi, 0.05)
        gs.append(g)
    agg = jax.tree.map(lambda *x: jnp.mean(jnp.stack(x), 0), *gs)
    want = server_update(params, agg, 1.0)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
                 new_state.params, want)
    np.testing.assert_allclose(float(jnp.mean(m.cosine)), 1.0, rtol=1e-5)


@pytest.mark.parametrize("kind", ["identity", "topk", "signsgd", "threesfc"])
def test_rounds_reduce_loss(world, kind):
    model, params, batches = world
    comp_cfg = CompressorConfig(kind=kind, keep_ratio=0.05, syn_steps=5,
                                error_feedback=kind != "identity")
    rf = jax.jit(_round(model, comp_cfg))
    state = fl_init(params, N)
    losses = []
    key = jax.random.PRNGKey(3)
    for r in range(6):
        key, kr = jax.random.split(key)
        state, m = rf(state, batches, kr)
        losses.append(float(m.loss))
    assert losses[-1] < losses[0], f"{kind}: loss did not drop: {losses}"


def test_weighted_aggregation():
    recons = {"w": jnp.stack([jnp.ones((3,)), 3 * jnp.ones((3,))])}
    out = aggregate(recons, weights=jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(out["w"], 2.5 * jnp.ones((3,)))
    out = aggregate(recons)
    np.testing.assert_allclose(out["w"], 2.0 * jnp.ones((3,)))


def test_microbatched_grad_matches(world):
    model, params, batches = world
    bi = jax.tree.map(lambda x: x[0], batches)
    g1, l1 = local_train(model.loss, params, bi, 0.05, num_micro=1)
    g4, l4 = local_train(model.loss, params, bi, 0.05, num_micro=4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6),
                 g1, g4)


def test_dirichlet_partition_properties():
    labels = np.random.default_rng(0).integers(0, 10, 2000)
    parts = dirichlet_partition(labels, 8, alpha=0.3, seed=1)
    assert len(parts) == 8
    covered = np.concatenate(parts)
    assert len(np.unique(covered)) >= 0.95 * 2000     # near-total coverage
    sizes = [len(p) for p in parts]
    assert min(sizes) >= 2
    # skew exists: not all clients have uniform label hist
    from repro.data.partition import partition_stats
    st = partition_stats(labels, parts)
    hist = st["label_hist"] / np.maximum(st["label_hist"].sum(1, keepdims=True), 1)
    assert hist.std() > 0.02
