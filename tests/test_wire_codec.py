"""Wire codec round-trips: framed bytes in, bit-exact payloads out.

Property-style sweeps (via the ``_hypo`` shim) over ragged pytrees whose
total size is NOT divisible by 32, keep-budgets at both extremes
(k = 1 and k = d), and all three dtype policies for the 3SFC payload —
each in eager and jit. The contract under test is ``repro.comm.codec``'s:
``decode(encode(wire))`` equals the canonical payload bitwise (canonical =
after the policy cast; fp32 is strictly lossless), the decoded server
reconstruction equals the client's dequantized view, and every buffer is
self-describing through ``frame.parse_header``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.comm import InProcessChannel, make_codec, parse_header, wire_bytes
from repro.comm.codec import (bytes_to_array, pack_uint_stream,
                              unpack_uint_stream)
from repro.configs.base import CompressorConfig
from repro.core import flat, threesfc
from repro.core.compressor import make_compressor
from repro.kernels import bitpack


def ragged_tree(seed: int, scale: float = 1.0):
    """Total size 7 + 15 + 33 + 256 + 1 = 312... deliberately irregular:
    scalars, odd vectors, matrices; d % 32 != 0."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    t = {
        "a": scale * jax.random.normal(ks[0], (7,)),
        "b": {"w": scale * jax.random.normal(ks[1], (3, 5)),
              "c": scale * jax.random.normal(ks[2], (33,))},
        "d": scale * jax.random.normal(ks[3], (128, 2)),
        "s": scale * jax.random.normal(ks[4], ()),
    }
    # plant exact zeros (the signsgd 1-bit corner)
    return jax.tree_util.tree_map(
        lambda x: x.at[(0,) * x.ndim].set(0.0) if x.ndim else x, t)


def tree_eq(a, b, msg=""):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def roundtrip(cfg, params, u, *, jit: bool, policy=None, syn_spec=None,
              syn_loss_fn=None):
    comp = make_compressor(cfg, loss_fn=syn_loss_fn, syn_spec=syn_spec)
    codec = make_codec(cfg, params, syn_spec=syn_spec,
                       syn_loss_fn=syn_loss_fn, policy=policy)
    out = comp.compress_tree(jax.random.PRNGKey(0), u, params)
    enc = (lambda w: codec.encode(w, round_idx=5, client_idx=2))
    dec = codec.decode
    if jit:
        enc, dec = jax.jit(enc), jax.jit(dec)
    buf = enc(out.wire)
    assert buf.dtype == jnp.uint8 and buf.shape == (codec.nbytes,)
    # static-size function agrees with the actual buffer
    assert wire_bytes(cfg, params, syn_spec=syn_spec,
                      policy=policy) == codec.nbytes
    hdr = parse_header(np.asarray(buf))
    assert hdr["kind"] == cfg.kind and hdr["round"] == 5 \
        and hdr["client"] == 2
    assert hdr["nbytes"] == codec.nbytes
    return codec, out, dec(buf)


# ---------------------------------------------------------------------------
# bit-stream primitives
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 200), st.integers(1, 20))
def test_uint_stream_roundtrip(seed, k, width):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.integers(0, 2**width, size=k, dtype=np.uint32))
    b = pack_uint_stream(vals, width)
    assert b.size == -(-k * width // 8)
    back = unpack_uint_stream(b, k, width)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(vals))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 31, 32, 33, 311, 5000]))
def test_bitpack_kernel_roundtrip(seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    x = x.at[0].set(0.0)
    words = bitpack.pack_signs(x)
    assert words.shape == (-(-n // 32),) and words.dtype == jnp.uint32
    back = bitpack.unpack_signs(words, n)
    ref = np.where(np.asarray(x) >= 0, 1.0, -1.0).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(back), ref)
    # jit + vmap
    f = jax.jit(jax.vmap(lambda v: bitpack.unpack_signs(
        bitpack.pack_signs(v), n)))
    np.testing.assert_array_equal(np.asarray(f(x[None])[0]), ref)


def test_bytes_to_array_empty_and_scalar():
    assert bytes_to_array(jnp.zeros((0,), jnp.uint8), (0, 0)).shape == (0, 0)
    s = bytes_to_array(
        jax.lax.bitcast_convert_type(jnp.float32(3.5), jnp.uint8), ())
    assert float(s) == 3.5


# ---------------------------------------------------------------------------
# baseline codecs over ragged trees (d % 32 != 0), eager + jit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("jit", [False, True], ids=["eager", "jit"])
@pytest.mark.parametrize("kind", ["identity", "topk", "signsgd", "stc"])
def test_baseline_codecs_bitexact(kind, jit):
    params = ragged_tree(0)
    u = ragged_tree(1)
    cfg = CompressorConfig(kind=kind, keep_ratio=0.1)
    codec, out, canon = roundtrip(cfg, params, u, jit=jit)
    # canonical payload round-trips bitwise
    ref = codec.decode(codec.encode(out.wire))
    tree_eq(canon, ref, f"{kind} canonical payload not bit-exact")
    # decoded server recon == client dequantized view, bitwise
    recon_cli, direction, scale = codec.client_view(out)
    assert direction is None
    tree_eq(codec.recon_tree(canon, params), recon_cli,
            f"{kind} decoded recon != client view")
    # lossless codecs reproduce the float-path recon exactly
    if kind in ("identity", "topk"):
        tree_eq(recon_cli, out.recon)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["topk", "stc"]))
def test_keep_budget_extremes(seed, kind):
    """k = 1 (ratio -> 0) and k = d (ratio = 1) per leaf, bit-exact."""
    params = ragged_tree(seed)
    u = ragged_tree(seed + 1)
    for ratio in (1e-9, 1.0):
        cfg = CompressorConfig(kind=kind, keep_ratio=ratio)
        codec, out, canon = roundtrip(cfg, params, u, jit=False)
        recon_cli, _, _ = codec.client_view(out)
        tree_eq(codec.recon_tree(canon, params), recon_cli)
        if ratio == 1.0 and kind == "topk":
            # full keep must reproduce u itself
            tree_eq(recon_cli, u)


def test_signsgd_one_bit_convention():
    """Exact zeros decode to +scale — the documented 1-bit semantics —
    and everything else matches the float path bitwise."""
    params = ragged_tree(0)
    u = ragged_tree(3)
    cfg = CompressorConfig(kind="signsgd")
    codec, out, canon = roundtrip(cfg, params, u, jit=False)
    recon = codec.recon_tree(canon, params)
    for lu, lr, lf in zip(jax.tree_util.tree_leaves(u),
                          jax.tree_util.tree_leaves(recon),
                          jax.tree_util.tree_leaves(out.recon)):
        lu, lr, lf = map(np.asarray, (lu, lr, lf))
        nz = lu != 0.0
        np.testing.assert_array_equal(lr[nz], lf[nz])
        if (~nz).any():
            scale = np.abs(lu).mean(dtype=np.float32)
            assert (lr[~nz] > 0).all()      # zeros -> +scale
            np.testing.assert_allclose(lr[~nz], scale, rtol=1e-6)


# ---------------------------------------------------------------------------
# 3SFC payload: all three dtype policies, eager + jit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("jit", [False, True], ids=["eager", "jit"])
@pytest.mark.parametrize("policy", ["fp32", "fp16", "bf16"])
def test_threesfc_policies_bitexact(policy, jit):
    spec = threesfc.SynSpec(x_shape=(1, 5, 3), num_classes=7)
    syn = threesfc.init_syn(jax.random.PRNGKey(0), spec)
    s = jnp.float32(0.37)
    params = ragged_tree(0)
    cfg = CompressorConfig(kind="threesfc")
    codec = make_codec(cfg, params, syn_spec=spec, policy=policy)
    enc = (lambda w: codec.encode(w))
    dec = codec.decode
    if jit:
        enc, dec = jax.jit(enc), jax.jit(dec)
    syn2, s2 = dec(enc((syn, s)))
    # canonical = cast to the policy dtype and back: bit-exact at that level
    from repro.comm.codec import POLICY_DTYPES
    dt = POLICY_DTYPES[policy]
    want = threesfc.SynData(*[jnp.asarray(a, dt).astype(jnp.float32)
                              for a in syn])
    tree_eq((syn2, s2), (want, s), f"threesfc {policy} round trip")
    # s is always f32, policy notwithstanding
    assert np.asarray(s2) == np.float32(0.37)
    # fp16/bf16 payloads are exactly half the fp32 stream
    if policy != "fp32":
        full = make_codec(cfg, params, syn_spec=spec, policy="fp32")
        assert (codec.nbytes - codec.header_bytes - 4) * 2 \
            == (full.nbytes - full.header_bytes - 4)


def test_threesfc_low_rank_labels_roundtrip():
    spec = threesfc.SynSpec(x_shape=(2, 4, 3), num_classes=11, label_rank=2)
    syn = threesfc.init_syn(jax.random.PRNGKey(1), spec)
    cfg = CompressorConfig(kind="threesfc")
    params = ragged_tree(0)
    codec = make_codec(cfg, params, syn_spec=spec)
    syn2, s2 = codec.decode(codec.encode((syn, jnp.float32(1.5))))
    tree_eq(syn2, syn)
    assert float(s2) == 1.5


# ---------------------------------------------------------------------------
# frame + channel + registry edges
# ---------------------------------------------------------------------------


def test_frame_rejects_garbage():
    params = ragged_tree(0)
    cfg = CompressorConfig(kind="identity", error_feedback=False)
    codec = make_codec(cfg, params)
    comp = make_compressor(cfg)
    out = comp.compress_tree(jax.random.PRNGKey(0), ragged_tree(1), params)
    buf = np.asarray(codec.encode(out.wire))
    with pytest.raises(ValueError, match="magic"):
        parse_header(np.roll(buf, 1))
    with pytest.raises(ValueError, match="short"):
        parse_header(buf[:8])
    with pytest.raises(ValueError, match="frame says"):
        parse_header(buf[:-1])
    bad = buf.copy()
    bad[2] = 99
    with pytest.raises(ValueError, match="version"):
        parse_header(bad)


def test_channel_bills_only_frames():
    ch = InProcessChannel()
    ch.begin_round()
    with pytest.raises(TypeError, match="uint8"):
        ch.send_up(jnp.zeros((4,), jnp.float32))
    got = ch.send_up(jnp.arange(10, dtype=jnp.uint8))
    assert isinstance(got, np.ndarray) and got.nbytes == 10
    ch.send_down(jnp.zeros((6,), jnp.uint8))
    ch.begin_round()
    ch.send_up(jnp.zeros((3,), jnp.uint8))
    assert ch.uplink.per_round == [10, 3]
    assert ch.downlink.per_round == [6, 0]
    assert ch.uplink.total_bytes == 13 and ch.uplink.messages == 2


def test_unregistered_kinds_raise():
    params = ragged_tree(0)
    with pytest.raises(KeyError, match="randk"):
        make_codec(CompressorConfig(kind="randk"), params)
    with pytest.raises(KeyError, match="fedsynth"):
        make_codec(CompressorConfig(kind="fedsynth"), params)


# ---------------------------------------------------------------------------
# one whole wire-mode round == float round (vmap, tiny model)
# ---------------------------------------------------------------------------


def test_fl_round_wire_matches_float():
    from repro.configs.base import FLConfig
    from repro.fl.round import fl_init, make_fl_round
    from repro.models.cnn import VisionSpec, make_paper_model

    spec = VisionSpec("tiny", (4, 4, 1), 3)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(0))
    ccfg = CompressorConfig(kind="topk", keep_ratio=0.05)
    cfg = FLConfig(num_clients=2, local_steps=1, local_lr=0.05,
                   local_batch=4, compressor=ccfg)
    comp = make_compressor(ccfg)
    codec = make_codec(ccfg, params)
    batches = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (2, 1, 4, 4, 4, 1)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (2, 1, 4), 0, 3),
    }
    state = fl_init(params, 2)
    key = jax.random.PRNGKey(3)
    s1, m1 = jax.jit(make_fl_round(model.loss, comp, cfg))(
        state, batches, key)
    s2, m2 = jax.jit(make_fl_round(model.loss, comp, cfg, wire="codec",
                                   codec=codec))(state, batches, key)
    tree_eq(s1.params, s2.params)
    tree_eq(s1.ef, s2.ef)
    for f in ("loss", "cosine", "payload_floats", "update_norm"):
        np.testing.assert_array_equal(np.asarray(getattr(m1, f)),
                                      np.asarray(getattr(m2, f)))
    assert float(m1.wire_bytes_up) == 0.0
    assert float(m2.wire_bytes_up) == codec.nbytes


def test_wire_mode_rejects_bad_pairs():
    from repro.configs.base import FLConfig
    from repro.fl.round import make_fl_round
    from repro.models.cnn import VisionSpec, make_paper_model

    spec = VisionSpec("tiny", (4, 4, 1), 3)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(0))
    ccfg = CompressorConfig(kind="topk", keep_ratio=0.05)
    cfg = FLConfig(num_clients=2, compressor=ccfg)
    comp = make_compressor(ccfg)
    with pytest.raises(ValueError, match="requires a codec"):
        make_fl_round(model.loss, comp, cfg, wire="codec")
    with pytest.raises(ValueError, match="does not match"):
        make_fl_round(model.loss, comp, cfg, wire="codec",
                      codec=make_codec(CompressorConfig(kind="signsgd"),
                                       params))
    with pytest.raises(ValueError, match="'float' or 'codec'"):
        make_fl_round(model.loss, comp, cfg, wire="bytes")
    tcfg = CompressorConfig(kind="threesfc")
    tfl = FLConfig(num_clients=2, compressor=tcfg)
    tspec = threesfc.SynSpec(x_shape=(1, 4, 4, 1), num_classes=3)
    tcomp = make_compressor(tcfg, loss_fn=model.syn_loss, syn_spec=tspec)
    with pytest.raises(ValueError, match="fp32"):
        make_fl_round(model.loss, tcomp, tfl, wire="codec",
                      codec=make_codec(tcfg, params, syn_spec=tspec,
                                       policy="bf16"))
