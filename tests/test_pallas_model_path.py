"""Model-level parity: mamba2 forward with use_pallas_ssd=True must match
the pure-jnp ssd_scan path (the Pallas kernel as a drop-in mixer backend)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.build import build_model


def test_mamba2_pallas_path_matches_jnp():
    cfg = get_smoke_config("mamba2-370m").replace(dtype="float32")
    model_jnp = build_model(cfg)
    model_pls = build_model(cfg.replace(use_pallas_ssd=True))
    key = jax.random.PRNGKey(0)
    params = model_jnp.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    h1, _ = model_jnp.forward_hidden(params, tokens)
    h2, _ = model_pls.forward_hidden(params, tokens)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_mamba2_pallas_loss_and_grad():
    cfg = get_smoke_config("mamba2-370m").replace(dtype="float32",
                                                  use_pallas_ssd=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    loss, grads = jax.value_and_grad(model.loss)(params, {"tokens": tokens})
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
