"""Optimizers, checkpointing, data pipeline, roofline parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.synthetic import make_class_image_dataset, make_token_dataset
from repro.optim import make_optimizer
from repro.utils.roofline import Roofline, collective_bytes, model_flops_estimate


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam"])
def test_optimizers_minimize_quadratic(name):
    init, update = make_optimizer(name, lr=0.1)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = update(params, g, state)
    assert float(loss(params)) < 1e-3


def test_optimizer_preserves_dtype():
    init, update = make_optimizer("adam", lr=0.01)
    params = {"x": jnp.ones((4,), jnp.bfloat16)}
    state = init(params)
    g = {"x": jnp.ones((4,), jnp.bfloat16)}
    params, state = update(params, g, state)
    assert params["x"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.asarray(3))}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, tree, meta={"round": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    out = load_checkpoint(path, like)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, out)
    assert out["nested"]["b"].dtype == jnp.bfloat16


def test_class_image_dataset_learnable_structure():
    tr = make_class_image_dataset(jax.random.PRNGKey(0), 500, (8, 8, 1), 5)
    te = make_class_image_dataset(jax.random.PRNGKey(9), 200, (8, 8, 1), 5)
    # same templates across splits: per-class means correlate
    for c in range(5):
        m_tr = tr.x[tr.y == c].mean(0).ravel()
        m_te = te.x[te.y == c].mean(0).ravel()
        r = np.corrcoef(m_tr, m_te)[0, 1]
        assert r > 0.8, f"class {c}: templates differ across splits (r={r})"


def test_token_dataset_bigram_structure():
    seqs = make_token_dataset(jax.random.PRNGKey(0), 64, 32, 50, noise=0.0)
    assert seqs.shape == (64, 32)
    # zero-noise: transition deterministic -> each token maps to one successor
    nxt = {}
    for s in seqs:
        for a, b in zip(s[:-1], s[1:]):
            assert nxt.setdefault(int(a), int(b)) == int(b)


def test_collective_bytes_parser():
    hlo = """
  %p = f32[1024,512]{1,0} parameter(0)
  %ag = f32[4096,512]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[1024,512]{1,0} all-reduce(%p), to_apply=%sum
  %cp = f32[1024,512]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
  %done = f32[1024,512]{1,0} all-reduce-done(%ar)
"""
    out = collective_bytes(hlo)
    leaf = 1024 * 512 * 4
    assert out["all-gather"] == leaf
    assert out["all-reduce"] == leaf
    assert out["collective-permute"] == leaf
    assert out["reduce-scatter"] == 0


def test_roofline_terms():
    r = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes={"all-reduce": 50e9},
                 chips=256, model_flops=197e12 * 256 * 0.5)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert abs(r.useful_ratio - 0.5) < 1e-9


def test_model_flops_estimate_dense_vs_moe():
    from repro.configs.base import get_config
    dense = model_flops_estimate(get_config("tinyllama-1.1b"), 1e6)
    # tinyllama ~1.1B params -> 6*N*D ~ 6.6e15 for 1M tokens
    assert 4e15 < dense < 9e15
    moe = model_flops_estimate(get_config("qwen3-moe-30b-a3b"), 1e6)
    moe_total_like = model_flops_estimate(
        get_config("qwen3-moe-30b-a3b").replace(num_experts=0, experts_per_token=0,
                                                d_ff=768 * 128), 1e6)
    assert moe < 0.3 * moe_total_like     # active << total for 8/128 experts
