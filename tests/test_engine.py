"""Device-resident round engine: the scanned multi-round path is bit-exact
against the per-round reference loop for every compressor kind; donation
consumes the state safely (with and without a mesh); the sampling PRNG
contract makes the trajectory independent of the eval cadence."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CompressorConfig, FLConfig
from repro.core.compressor import make_compressor
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_class_image_dataset
from repro.fl.engine import (RoundEngine, device_pools, token_batcher,
                             vision_batcher)
from repro.fl.round import make_fl_round
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, make_paper_model

N, K, BATCH, ROUNDS = 4, 2, 8, 3

KINDS = {
    "fedavg": CompressorConfig(kind="identity", error_feedback=False),
    "dgc": CompressorConfig(kind="topk", keep_ratio=0.05),
    "signsgd": CompressorConfig(kind="signsgd"),
    "stc": CompressorConfig(kind="stc", keep_ratio=0.05),
    "threesfc": CompressorConfig(kind="threesfc", syn_steps=2, syn_lr=0.1),
}


@pytest.fixture(scope="module")
def world():
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    train = make_class_image_dataset(jax.random.PRNGKey(1), 400, (28, 28, 1), 10)
    parts = dirichlet_partition(train.y, N, alpha=0.5, seed=0,
                                min_per_client=16)
    batch_fn = vision_batcher(train.x, train.y, device_pools(parts), K, BATCH)
    return model, params, batch_fn


def _engine(world, comp_cfg, **kw):
    model, params, batch_fn = world
    spec = vision_syn_spec(MNIST_SPEC, comp_cfg)
    comp = make_compressor(comp_cfg, loss_fn=model.syn_loss, syn_spec=spec,
                           local_lr=0.05)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=BATCH, compressor=comp_cfg)
    rf = make_fl_round(model.loss, comp, cfg)
    eng = RoundEngine(rf, batch_fn, seed=0, **kw)
    return eng, eng.init_state(params, N)


def _assert_tree_equal(a, b, what):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{what} not bit-exact")


@pytest.mark.parametrize("kind", list(KINDS))
def test_scan_bit_exact_vs_python_loop(world, kind):
    """ONE scanned dispatch over 3 rounds == 3 per-round dispatches, bitwise:
    params, EF residuals, and every per-round metric."""
    eng, state = _engine(world, KINDS[kind])
    s_scan, ms = eng.run_block(state, ROUNDS)

    eng2, state2 = _engine(world, KINDS[kind], donate=False)
    s_loop, ml = eng2.run_loop(state2, ROUNDS)

    _assert_tree_equal(s_scan.params, s_loop.params, f"{kind} params")
    _assert_tree_equal(s_scan.ef, s_loop.ef, f"{kind} ef")
    assert int(s_scan.round) == int(s_loop.round) == ROUNDS
    for f in ("loss", "cosine", "payload_floats", "update_norm"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ms, f)), np.asarray(getattr(ml, f)),
            err_msg=f"{kind} metric {f} not bit-exact")


def test_eval_cadence_invariance(world):
    """fold_in on the absolute round => regrouping rounds into different
    scan lengths (blocks [3] vs [2, 1]) does not change the trajectory."""
    eng, state = _engine(world, KINDS["dgc"])
    s_a, _ = eng.run_block(state, 3)

    eng_b, state_b = _engine(world, KINDS["dgc"])
    state_b, _ = eng_b.run_block(state_b, 2)
    s_b, _ = eng_b.run_block(state_b, 1)

    _assert_tree_equal(s_a.params, s_b.params, "cadence params")
    _assert_tree_equal(s_a.ef, s_b.ef, "cadence ef")


def test_donation_consumes_state_and_caller_params_survive(world):
    """donate_argnums consumes the FLState buffers: the old state must not be
    reused, the engine's returned state keeps working, and the caller's
    params tree (deep-copied by init_state) stays alive."""
    model, params, _ = world
    eng, state = _engine(world, KINDS["fedavg"])
    old_leaves = jax.tree_util.tree_leaves((state.params, state.ef))
    state2, _ = eng.run_block(state, 2)
    donated = [l.is_deleted() for l in old_leaves]
    if any(donated):                     # backend actually honored donation
        assert all(donated), "donation must consume the whole FLState tree"
    # caller's params were copied at init_state: still alive and usable
    for l in jax.tree_util.tree_leaves(params):
        assert not l.is_deleted()
    _ = float(jax.tree_util.tree_leaves(params)[0].sum())
    # the returned state is the live one: another block runs fine
    state3, ms = eng.run_block(state2, 2)
    assert np.isfinite(np.asarray(ms.loss)).all()
    assert int(state3.round) == 4


def test_donation_safe_under_mesh(world):
    """Same dispatch with an explicit device mesh installed (the production
    context): donation + scan + sampling all trace and run."""
    from jax.sharding import Mesh
    devices = np.array(jax.devices()).reshape(-1)
    eng, state = _engine(world, KINDS["fedavg"])
    with Mesh(devices, ("d",)):
        state, ms = eng.run_block(state, 2)
    assert np.isfinite(np.asarray(ms.loss)).all()
    assert int(state.round) == 2


def test_engine_stats_accounting(world):
    """One dispatch and one host sync per eval block; the reference loop
    pays one dispatch + two syncs per round."""
    eng, state = _engine(world, KINDS["fedavg"])
    state, _ = eng.run_block(state, 3)
    assert eng.stats.dispatches == 1 and eng.stats.host_syncs == 1
    assert eng.stats.rounds == 3

    eng2, state2 = _engine(world, KINDS["fedavg"], donate=False)
    eng2.run_loop(state2, 3)
    assert eng2.stats.dispatches == 3 and eng2.stats.host_syncs == 6


def test_run_blocks_match_eval_cadence(world):
    """engine.run: metrics cover every round, evals land on the block ends
    (the seed cadence: every eval_every rounds plus the final round)."""
    eng, state = _engine(world, KINDS["fedavg"])
    state, hist = eng.run(state, 5, eval_every=2,
                          eval_fn=lambda st, ms, r: (int(st.round),
                                                     len(ms.loss)))
    assert hist.metrics.loss.shape == (5,)
    assert hist.metrics.cosine.shape == (5, N)
    assert [r for r, _ in hist.evals] == [2, 4, 5]
    assert [v for _, v in hist.evals] == [(2, 2), (4, 2), (5, 1)]


def test_run_handles_nonpositive_eval_every(world):
    """eval_every <= 0 means 'no eval cadence': one block for everything."""
    eng, state = _engine(world, KINDS["fedavg"])
    state, hist = eng.run(state, 3, eval_every=0)
    assert hist.metrics.loss.shape == (3,)
    assert eng.stats.dispatches == 1
    assert hist.evals == []


def test_run_zero_rounds_returns_empty_metrics(world):
    eng, state = _engine(world, KINDS["fedavg"])
    state, hist = eng.run(state, 0, eval_every=2)
    assert hist.metrics.loss.shape == (0,)
    assert hist.evals == [] and eng.stats.dispatches == 0
    assert int(state.round) == 0


def test_token_batcher_shapes_and_determinism():
    toks = np.arange(50 * 7, dtype=np.int32).reshape(50, 7) % 13
    bf = token_batcher(toks, num_clients=3, local_steps=2, local_batch=4,
                       extras={"frames": (5, 8)})
    key = jax.random.PRNGKey(0)
    b1 = bf(key, jnp.int32(4))
    b2 = bf(key, jnp.int32(4))
    b3 = bf(key, jnp.int32(5))
    assert b1["tokens"].shape == (3, 2, 4, 7)
    assert b1["frames"].shape == (3, 2, 4, 5, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_device_pools_padding_never_sampled():
    """Padded pool entries (index 0) must be unreachable THROUGH the real
    batcher: every gathered row belongs to the client's own partition.
    Each dataset row encodes its own index in x, so the gathered batch
    reveals exactly which rows the batcher touched."""
    n = 200
    x = np.broadcast_to(np.arange(n, dtype=np.float32).reshape(n, 1, 1, 1),
                        (n, 2, 2, 1)).copy()
    y = np.random.default_rng(0).integers(0, 10, n).astype(np.int32)
    parts = dirichlet_partition(y, 5, alpha=0.3, seed=2, min_per_client=4)
    bf = vision_batcher(x, y, device_pools(parts), 3, 6)
    key = jax.random.PRNGKey(9)

    for rnd in range(4):
        batch = bf(key, jnp.int32(rnd))
        rows = np.asarray(batch["x"])[..., 0, 0, 0].astype(np.int64)  # (5,3,6)
        for i, pool in enumerate(parts):
            assert np.isin(rows[i], pool).all(), \
                f"client {i} sampled rows outside its pool at round {rnd}"
        np.testing.assert_array_equal(np.asarray(batch["y"]), y[rows])


def test_device_pools_zero_sample_client_clamped():
    """An empty Dirichlet part must not reach randint(maxval=0) (undefined
    inside jit): device_pools clamps its size to 1 over the zero index row,
    i.e. the degenerate client deterministically resamples dataset row 0."""
    n = 60
    x = np.broadcast_to(np.arange(n, dtype=np.float32).reshape(n, 1, 1, 1),
                        (n, 2, 2, 1)).copy()
    y = (np.arange(n) % 10).astype(np.int32)
    parts = [np.arange(20), np.array([], dtype=np.int64), np.arange(20, 60)]
    pools = device_pools(parts)
    assert pools.size.tolist() == [20, 1, 40]
    assert int(pools.index[1].sum()) == 0

    bf = vision_batcher(x, y, pools, local_steps=2, local_batch=4)
    batch = bf(jax.random.PRNGKey(0), jnp.int32(0))
    rows = np.asarray(batch["x"])[..., 0, 0, 0].astype(np.int64)
    np.testing.assert_array_equal(rows[1], np.zeros((2, 4)))   # all row 0
    assert np.isin(rows[0], parts[0]).all()
    assert np.isin(rows[2], parts[2]).all()

    # all-empty partition: still a valid (clamped) pool, no zero-width array
    pools2 = device_pools([np.array([], dtype=np.int64)] * 2)
    assert pools2.index.shape == (2, 1) and pools2.size.tolist() == [1, 1]


def test_benchmarks_run_only_badname_exits_2(capsys):
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit) as e:
        bench_run.main(["--only", "definitely_not_a_bench"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "definitely_not_a_bench" in err
    for name in bench_run.BENCHES:
        assert name in err
