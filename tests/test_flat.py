"""Flattener round-trip + tree algebra (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import flat


def _tree(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s) for i, (k, s) in enumerate(zip(ks, shapes))}


SHAPES = [(3,), (2, 4), (5, 1, 2)]


def test_flatten_roundtrip():
    t = _tree(jax.random.PRNGKey(0), SHAPES)
    fl = flat.Flattener(t)
    v = fl.flatten(t)
    assert v.shape == (sum(int(np.prod(s)) for s in SHAPES),)
    t2 = fl.unflatten(v)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), t, t2)


def test_flatten_jit_safe():
    t = _tree(jax.random.PRNGKey(0), SHAPES)
    fl = flat.Flattener(t)

    @jax.jit
    def f(t):
        return fl.unflatten(fl.flatten(t) * 2.0)

    t2 = f(t)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(2 * a, b, rtol=1e-6), t, t2)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(-3, 3, allow_nan=False))
def test_tree_algebra_matches_flat(seed, alpha):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    a = _tree(k1, SHAPES)
    b = _tree(k2, SHAPES)
    fl = flat.Flattener(a)
    va, vb = fl.flatten(a), fl.flatten(b)
    np.testing.assert_allclose(flat.tree_dot(a, b), jnp.vdot(va, vb),
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(flat.tree_norm(a), jnp.linalg.norm(va), rtol=1e-5)
    got = fl.flatten(flat.tree_axpy(alpha, a, b))
    np.testing.assert_allclose(got, alpha * va + vb, rtol=1e-5, atol=1e-6)
    cos = flat.tree_cosine(a, b)
    want = jnp.vdot(va, vb) / (jnp.linalg.norm(va) * jnp.linalg.norm(vb))
    np.testing.assert_allclose(cos, want, rtol=1e-4, atol=1e-6)


def test_tree_cosine_self_is_one():
    a = _tree(jax.random.PRNGKey(3), SHAPES)
    np.testing.assert_allclose(flat.tree_cosine(a, a), 1.0, rtol=1e-5)
