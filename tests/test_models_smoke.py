"""Per-arch smoke tests (deliverable f): reduced variant of each family,
one forward/train step on CPU, output shapes + no NaNs.

Covers: loss+grad, prefill shape, single decode step, and 3SFC encodability
(grad-of-grad through every family: attention, MoE dispatch, SSD scan,
RG-LRU associative scan, cross-attention).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, CompressorConfig, get_smoke_config
from repro.core import threesfc
from repro.models.build import build_model, syn_loss_fn, syn_spec_for
from repro.models.encdec import EncDec

B, S = 2, 16


def _batch(cfg, model, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if isinstance(model, EncDec):
        return {"frames": jax.random.normal(key, (B, cfg.num_mm_tokens, cfg.d_model)),
                "tokens": tokens}
    if cfg.num_mm_tokens:
        return {"tokens": tokens,
                "prefix_embeds": jax.random.normal(
                    key, (B, cfg.num_mm_tokens, cfg.d_model))}
    return {"tokens": tokens}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 5
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, model, key)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, f"{arch}: bad grads"
    # one SGD step moves the loss
    p2 = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    loss2 = model.loss(p2, batch)
    assert float(loss2) < float(loss), f"{arch}: SGD step did not reduce loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serving(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if isinstance(model, EncDec):
        frames = jax.random.normal(key, (B, cfg.num_mm_tokens, cfg.d_model))
        logits, cache, t0 = model.prefill(params, frames, tokens, cache_len=S + 4)
    else:
        logits, cache, t0 = model.prefill(params, tokens, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN prefill logits"
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, t0)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_threesfc_encode(arch):
    """The paper's compressor applies to every family (DESIGN.md
    §Arch-applicability): grad-of-grad must be finite and decodable."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, model, key)
    _, grads = jax.value_and_grad(model.loss)(params, batch)
    comp = CompressorConfig(syn_batch=1, syn_seq=4)
    spec = syn_spec_for(cfg, comp)
    syn0 = threesfc.init_syn(key, spec)
    lf = syn_loss_fn(model)
    res = threesfc.encode(lf, params, grads, syn0, steps=2, lr=0.1)
    assert np.isfinite(float(res.cosine)), f"{arch}: NaN encode cosine"
    assert np.isfinite(float(res.s))
    server = threesfc.decode(lf, params, res.syn, res.s)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, rtol=1e-4, atol=1e-6), res.recon, server)
