"""Fused server decode (§Perf beyond-paper optimization) must be EXACT:

    G(ĝ_1..ĝ_N) = ∇_w (1/N) Σ_i s_i F(D_syn,i, w^t)

so the fused round produces the same new global model and the same EF
residuals as the per-client-decode round, while never materializing a
full-gradient collective.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CompressorConfig, FLConfig
from repro.core.compressor import make_compressor
from repro.data.synthetic import make_class_image_dataset
from repro.fl.round import fl_init, make_fl_round
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, make_paper_model

N, K, B = 3, 2, 16


def test_fused_decode_matches_per_client_decode():
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    ds = make_class_image_dataset(jax.random.PRNGKey(1), 400, (28, 28, 1), 10)
    rng = np.random.default_rng(0)
    bx = np.stack([ds.x[rng.choice(400, (K, B))] for _ in range(N)])
    by = np.stack([ds.y[rng.choice(400, (K, B))] for _ in range(N)])
    batches = {"x": jnp.asarray(bx), "y": jnp.asarray(by)}

    ccfg = CompressorConfig(kind="threesfc", syn_steps=3, syn_lr=0.1)
    spec = vision_syn_spec(MNIST_SPEC, ccfg)
    comp = make_compressor(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                           local_lr=0.05)
    fl_cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                      compressor=ccfg)

    base_round = make_fl_round(model.loss, comp, fl_cfg)
    fused_round = make_fl_round(model.loss, comp, fl_cfg, fused_decode=True,
                                syn_loss_fn=model.syn_loss, syn_spec=spec)

    key = jax.random.PRNGKey(2)
    s0 = fl_init(params, N)
    s1, m1 = base_round(s0, batches, key)
    s2, m2 = fused_round(s0, batches, key)

    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-4,
                                                         atol=1e-6),
                 s1.params, s2.params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-4,
                                                         atol=1e-5),
                 s1.ef, s2.ef)
    np.testing.assert_allclose(np.asarray(m1.cosine), np.asarray(m2.cosine),
                               rtol=1e-4)


def test_fused_round_trains():
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    ds = make_class_image_dataset(jax.random.PRNGKey(1), 400, (28, 28, 1), 10)
    ccfg = CompressorConfig(kind="threesfc", syn_steps=5, syn_lr=0.1)
    spec = vision_syn_spec(MNIST_SPEC, ccfg)
    comp = make_compressor(ccfg, loss_fn=model.syn_loss, syn_spec=spec)
    fl_cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                      compressor=ccfg)
    rf = jax.jit(make_fl_round(model.loss, comp, fl_cfg, fused_decode=True,
                               syn_loss_fn=model.syn_loss, syn_spec=spec))
    state = fl_init(params, N)
    rng = np.random.default_rng(1)
    losses = []
    key = jax.random.PRNGKey(3)
    for r in range(6):
        bx = np.stack([ds.x[rng.choice(400, (K, B))] for _ in range(N)])
        by = np.stack([ds.y[rng.choice(400, (K, B))] for _ in range(N)])
        key, kr = jax.random.split(key)
        state, m = rf(state, {"x": jnp.asarray(bx), "y": jnp.asarray(by)}, kr)
        losses.append(float(m.loss))
    assert losses[-1] < losses[0], losses
