"""Durable checkpoints: atomic save/load round-trips over awkward trees,
typed failure modes, the versioned step index, crash-mid-write survival,
and the LinkStats ledger snapshot that makes resumed round numbering
continue where the crashed run stopped.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (MANIFEST_VERSION, CheckpointError,
                              CheckpointKeyError, CheckpointManager,
                              CheckpointMissingError, CheckpointShapeError,
                              CheckpointVersionError, load_arrays,
                              load_checkpoint, load_fl_checkpoint,
                              load_manifest, save_checkpoint,
                              save_fl_checkpoint)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# single-checkpoint round-trips
# ---------------------------------------------------------------------------


def test_ragged_nested_tree_roundtrips_bitwise(tmp_path):
    """Mixed container kinds, ragged shapes, mixed dtypes, 0-d scalars —
    everything comes back bitwise in the target structure's dtypes."""
    rng = np.random.default_rng(0)
    tree = {
        "w": (jnp.asarray(rng.normal(size=(7, 3)), jnp.float32),
              jnp.asarray(rng.normal(size=(3,)), jnp.float32)),
        "counts": [jnp.arange(5, dtype=jnp.int32),
                   rng.integers(0, 9, size=(2, 2)).astype(np.int64)],
        "mask": jnp.asarray([True, False, True]),
        "scalar": jnp.asarray(0.125, jnp.float32),      # 0-d leaf
        "wide": np.float64(3.0),                        # numpy scalar leaf
    }
    p = save_checkpoint(str(tmp_path / "ck"), tree, meta={"round": 7})
    # numpy zeros keep the f64 leaf's dtype (jnp would truncate under
    # disabled x64, and a like-tree must carry the target dtypes)
    like = jax.tree_util.tree_map(lambda l: np.zeros_like(np.asarray(l)), tree)
    out = load_checkpoint(p, like)
    assert _tree_equal(tree, out)
    for got, want in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(like)):
        assert got.dtype == jnp.result_type(want)
    assert load_manifest(p)["meta"] == {"round": 7}


def test_bf16_leaves_roundtrip_exactly_via_f32_storage(tmp_path):
    """bf16 has no stable npz representation: leaves are widened to f32
    (exact — bf16 is a truncated f32) and cast back on load, bit-for-bit."""
    vals = jnp.asarray([1.0, -2.5, 3.0e-20, 65280.0, 1.0 / 3.0], jnp.bfloat16)
    tree = {"p": vals}
    p = save_checkpoint(str(tmp_path / "ck"), tree)
    flat, manifest = load_arrays(p)
    assert flat["p"].dtype == np.float32           # storage is f32
    out = load_checkpoint(p, {"p": jnp.zeros_like(vals)})
    assert out["p"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["p"], np.float32), np.asarray(vals, np.float32))


def test_bare_array_tree_uses_root_key(tmp_path):
    arr = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    p = save_checkpoint(str(tmp_path / "ck"), arr)
    flat, _ = load_arrays(p)
    assert set(flat) == {"_root"}
    out = load_checkpoint(p, jnp.zeros_like(arr))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(arr))


# ---------------------------------------------------------------------------
# typed failure modes
# ---------------------------------------------------------------------------


def test_missing_checkpoint_is_typed_file_not_found(tmp_path):
    with pytest.raises(CheckpointMissingError) as ei:
        load_checkpoint(str(tmp_path / "nope"), {"a": jnp.zeros(2)})
    assert isinstance(ei.value, FileNotFoundError)
    assert isinstance(ei.value, CheckpointError)


def test_missing_leaf_is_typed_key_error(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"), {"a": jnp.zeros(2)})
    with pytest.raises(CheckpointKeyError) as ei:
        load_checkpoint(p, {"a": jnp.zeros(2), "b": jnp.zeros(3)})
    assert isinstance(ei.value, KeyError)


def test_shape_and_dtype_mismatch_are_typed_value_errors(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"),
                        {"a": jnp.zeros((2, 3), jnp.float32)})
    with pytest.raises(CheckpointShapeError):
        load_checkpoint(p, {"a": jnp.zeros((3, 2), jnp.float32)})
    with pytest.raises(CheckpointShapeError) as ei:
        load_checkpoint(p, {"a": jnp.zeros((2, 3), jnp.int32)})
    assert isinstance(ei.value, ValueError)


def test_future_manifest_version_is_rejected(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"), {"a": jnp.zeros(2)})
    mpath = os.path.join(p, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["version"] = MANIFEST_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointVersionError):
        load_checkpoint(p, {"a": jnp.zeros(2)})


def test_future_index_version_is_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "root"))
    mgr.save(1, {"a": jnp.zeros(2)})
    ipath = os.path.join(mgr.root, "MANIFEST.json")
    with open(ipath) as f:
        idx = json.load(f)
    idx["version"] = MANIFEST_VERSION + 1
    with open(ipath, "w") as f:
        json.dump(idx, f)
    with pytest.raises(CheckpointVersionError):
        mgr.latest()


def test_corrupt_manifest_json_is_missing_not_crash(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"), {"a": jnp.zeros(2)})
    with open(os.path.join(p, "manifest.json"), "w") as f:
        f.write('{"version": 1, "leaves"')       # truncated write w/o rename
    with pytest.raises(CheckpointMissingError):
        load_checkpoint(p, {"a": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# versioned step index: retention, commit point, crash-mid-write
# ---------------------------------------------------------------------------


def test_manager_retention_prunes_oldest_after_commit(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "root"), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": jnp.full((3,), float(s))})
    assert mgr.steps() == [3, 4] and mgr.latest() == 4
    assert not os.path.exists(mgr.path(1))
    assert not os.path.exists(mgr.path(2))
    tree, _ = mgr.load({"a": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.full((3,), 4.0))
    tree, _ = mgr.load({"a": jnp.zeros(3)}, step=3)
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.full((3,), 3.0))


def test_crash_mid_payload_write_leaves_previous_loadable(tmp_path):
    """A kill while step 4's payload was being written (dir + arrays.npz,
    no manifest, no index entry) must leave latest() naming step 2 — and a
    retried save over the debris must succeed."""
    mgr = CheckpointManager(str(tmp_path / "root"))
    mgr.save(2, {"a": jnp.full((3,), 2.0)}, meta={"round": 2})
    debris = mgr.path(4)
    os.makedirs(debris)
    with open(os.path.join(debris, "arrays.npz"), "wb") as f:
        f.write(b"PK\x03\x04 partial zip the crash truncated")
    assert mgr.latest() == 2
    tree, meta = mgr.load({"a": jnp.zeros(3)})
    assert meta["round"] == 2
    with pytest.raises(CheckpointMissingError):
        mgr.load({"a": jnp.zeros(3)}, step=4)    # never committed
    mgr.save(4, {"a": jnp.full((3,), 4.0)}, meta={"round": 4})
    assert mgr.latest() == 4
    tree, _ = mgr.load({"a": jnp.zeros(3)}, step=4)
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.full((3,), 4.0))


def test_crash_before_index_commit_leaves_step_invisible(tmp_path):
    """A fully-written step directory whose index rename never happened is
    not a committed checkpoint: latest() ignores it."""
    mgr = CheckpointManager(str(tmp_path / "root"))
    mgr.save(2, {"a": jnp.full((3,), 2.0)})
    save_checkpoint(mgr.path(6), {"a": jnp.full((3,), 6.0)})  # no index write
    assert mgr.latest() == 2 and mgr.steps() == [2]
    with pytest.raises(CheckpointMissingError):
        mgr.load({"a": jnp.zeros(3)}, step=6)


def test_stray_index_tmp_is_ignored(tmp_path):
    """A crash between tmp write and rename leaves MANIFEST.json.tmp lying
    around; the committed index is untouched."""
    mgr = CheckpointManager(str(tmp_path / "root"))
    mgr.save(2, {"a": jnp.zeros(3)})
    with open(os.path.join(mgr.root, "MANIFEST.json.tmp"), "w") as f:
        f.write('{"version": 1, "steps": [2, 9')
    assert mgr.latest() == 2


def test_empty_manager_raises_missing(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "root"))
    assert mgr.latest() is None and mgr.steps() == []
    with pytest.raises(CheckpointMissingError):
        mgr.load({"a": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# full-FLState recovery points
# ---------------------------------------------------------------------------


def _fl_state(staleness_max: int):
    from repro.fl.round import fl_init

    params = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(4, 2)),
                               jnp.float32),
              "b": jnp.zeros((2,), jnp.float32)}
    state = fl_init(params, 3, None, staleness_max=staleness_max)
    # make every component non-trivial so bitwise equality means something
    bump = jax.tree_util.tree_map(
        lambda l: l + jnp.arange(l.size, dtype=l.dtype).reshape(l.shape)
        if jnp.issubdtype(l.dtype, jnp.floating) else l, state)
    return bump._replace(round=jnp.asarray(5, state.round.dtype))


@pytest.mark.parametrize("staleness_max", [0, 2])
def test_fl_checkpoint_roundtrips_state_bank_and_meta(tmp_path, staleness_max):
    state = _fl_state(staleness_max)
    bank = {0: (5, np.arange(10, dtype=np.float32)),
            2: (4, np.linspace(-1, 1, 10).astype(np.float32))}
    mgr = CheckpointManager(str(tmp_path / "root"))
    save_fl_checkpoint(mgr, 5, state, ledger={"uplink": {"total_bytes": 123}},
                       history=[{"round": 4, "delivered": [True, False, True]}],
                       ef_bank=bank, extra={"transport": "socket"})
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    got, got_bank, meta = load_fl_checkpoint(mgr, like)
    assert _tree_equal(state, got)
    assert int(got.round) == 5
    assert set(got_bank) == {0, 2}
    for cid in bank:
        assert got_bank[cid][0] == bank[cid][0]
        np.testing.assert_array_equal(got_bank[cid][1], bank[cid][1])
    assert meta["round"] == 5 and meta["transport"] == "socket"
    assert meta["ledger"]["uplink"]["total_bytes"] == 123
    assert meta["history"][0]["delivered"] == [True, False, True]


def test_fl_checkpoint_structure_mismatch_is_typed(tmp_path):
    """A buffer-less checkpoint refuses to load into a state that expects
    the staleness ring buffer — typed error, not garbage buffers."""
    mgr = CheckpointManager(str(tmp_path / "root"))
    save_fl_checkpoint(mgr, 5, _fl_state(0))
    like = jax.tree_util.tree_map(jnp.zeros_like, _fl_state(2))
    with pytest.raises(CheckpointError):
        load_fl_checkpoint(mgr, like)


# ---------------------------------------------------------------------------
# ledger snapshot/restore: resumed round numbering
# ---------------------------------------------------------------------------


def test_channel_ledger_restore_resumes_round_numbering():
    from repro.comm.channel import InProcessChannel

    ch = InProcessChannel()
    for _ in range(3):
        ch.begin_round()
        ch.send_up(np.zeros((17,), np.uint8))
        ch.send_down(np.zeros((5,), np.uint8))
    led = ch.ledger()
    assert led["uplink"]["per_round"] == [17, 17, 17]
    assert led["uplink"]["total_bytes"] == 51 and led["uplink"]["messages"] == 3

    fresh = InProcessChannel()
    fresh.restore_ledger(json.loads(json.dumps(led)))   # via JSON, like a ckpt
    assert fresh.begin_round() == 3                     # continues, not resets
    fresh.send_up(np.zeros((17,), np.uint8))
    assert fresh.uplink.per_round == [17, 17, 17, 17]
    assert fresh.uplink.total_bytes == 68
    assert fresh.downlink.per_round == [5, 5, 5, 0]
