"""The strategy registry as a third-party extension point.

Registers a toy compression method (per-leaf mean-magnitude x sign) plus a
trivial lossless codec ENTIRELY in this test file — no repro/ source is
edited — and drives it through complete FL rounds: the vmap+float path
in-process, and the shard_map+codec path on the 8-device child (run this
file's scenario by hand with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python tests/test_strategy_api.py shard_codec

). Registry edge cases — duplicate kinds rejected, unknown kinds listing
the valid names — are pinned here too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.codec import Codec, array_to_bytes, bytes_to_array, register_codec
from repro.configs.base import CompressorConfig, FLConfig
from repro.configs.run import RunConfig
from repro.core import strategy as S
from repro.fl.round import build_fl_round, fl_init

TOY_KIND = "toy_meansign"


@S.register_strategy(TOY_KIND)
class ToyMeanSign(S.CompressionStrategy):
    """Per-leaf mean-|x| scale times sign — a 10-line custom method."""

    def payload_floats(self, params):
        leaves = jax.tree_util.tree_leaves(params)
        return sum(l.size for l in leaves) / 32.0 + len(leaves)

    def client_encode(self, key, u, params):
        leaves, treedef = jax.tree_util.tree_flatten(u)
        scales = [jnp.mean(jnp.abs(l)) for l in leaves]
        recon = jax.tree_util.tree_unflatten(
            treedef, [s * jnp.sign(l) for s, l in zip(scales, leaves)])
        return S.TreeCompressed(
            recon, jnp.float32(self.payload_floats(params)), jnp.float32(0),
            wire=recon)

    def server_decode(self, payload, params):
        return payload


@register_codec
class ToyCodec(Codec):
    """Trivial lossless codec: the recon tree as one raw f32 stream."""

    kind = TOY_KIND

    def _section_bytes(self):
        return (4 * self.d,)

    def _pack(self, wire):
        leaves = jax.tree_util.tree_leaves(wire)
        return [jnp.concatenate([array_to_bytes(l) for l in leaves])]

    def _unpack(self, sections):
        vec = bytes_to_array(sections[0], (self.d,))
        leaves, off = [], 0
        for shape, n in zip(self.shapes, self.sizes):
            leaves.append(vec[off:off + n].reshape(shape))
            off += n
        return self._leaf_tree(leaves)

    def canonical(self, wire):
        return jax.tree_util.tree_map(
            lambda l: jnp.asarray(l, jnp.float32), wire)


# ---------------------------------------------------------------------------
# registry edges
# ---------------------------------------------------------------------------


def test_duplicate_strategy_kind_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @S.register_strategy(TOY_KIND)
        class Dupe(S.CompressionStrategy):
            pass
    # the original registration is untouched
    assert S.STRATEGIES[TOY_KIND] is ToyMeanSign


def test_duplicate_codec_kind_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @register_codec
        class DupeCodec(Codec):
            kind = TOY_KIND


def test_unknown_kind_lists_valid_names():
    with pytest.raises(ValueError) as ei:
        S.make_strategy(CompressorConfig(kind="definitely_not_a_kind"))
    msg = str(ei.value)
    for known in ("threesfc", "topk", TOY_KIND):
        assert known in msg, msg


def test_strategy_kinds_introspection():
    kinds = S.strategy_kinds()
    assert kinds == sorted(kinds)
    assert TOY_KIND in kinds and "threesfc" in kinds


# ---------------------------------------------------------------------------
# the toy method through a full round, vmap + float (in-process)
# ---------------------------------------------------------------------------


def _world(N=4):
    from repro.models.cnn import VisionSpec, make_paper_model

    model = make_paper_model("mlp", VisionSpec("tiny", (4, 4, 1), 3))
    params = model.init(jax.random.PRNGKey(0))
    K, B = 2, 8
    batches = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (N, K, B, 4, 4, 1)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (N, K, B), 0, 3),
    }
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   compressor=CompressorConfig(kind=TOY_KIND))
    return model, params, batches, cfg


def test_toy_strategy_full_round_vmap_float():
    model, params, batches, cfg = _world()
    strat = S.make_strategy(cfg.compressor)
    rf = jax.jit(build_fl_round(model.loss, strat, RunConfig(fl=cfg)))
    state = fl_init(params, cfg.num_clients, strat)
    s1, m = rf(state, batches, jax.random.PRNGKey(3))
    assert np.isfinite(float(m.loss))
    assert float(m.payload_floats) == strat.payload_floats(params)
    assert float(m.wire_bytes_up) == 0.0
    # params actually moved and EF carries the residual u - recon
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree_util.tree_leaves(state.params),
                                jax.tree_util.tree_leaves(s1.params)))
    assert moved
    assert any(float(jnp.max(jnp.abs(l))) > 0
               for l in jax.tree_util.tree_leaves(s1.ef))


def test_toy_strategy_wire_codec_matches_float_vmap():
    model, params, batches, cfg = _world()
    strat = S.make_strategy(cfg.compressor)
    codec = strat.wire_codec(params)
    run_f = RunConfig(fl=cfg)
    run_w = RunConfig(fl=cfg, wire="codec")
    state = fl_init(params, cfg.num_clients, strat)
    sf, mf = jax.jit(build_fl_round(model.loss, strat, run_f))(
        state, batches, jax.random.PRNGKey(3))
    sw, mw = jax.jit(build_fl_round(model.loss, strat, run_w, codec=codec))(
        state, batches, jax.random.PRNGKey(3))
    for a, b in zip(jax.tree_util.tree_leaves((sf.params, sf.ef)),
                    jax.tree_util.tree_leaves((sw.params, sw.ef))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="toy codec not transparent")
    assert float(mw.wire_bytes_up) == codec.nbytes
    assert float(mf.wire_bytes_up) == 0.0


# ---------------------------------------------------------------------------
# shard_map + codec on the 8-device child
# ---------------------------------------------------------------------------


def test_toy_strategy_shard_map_codec(multidev_scenario):
    """The toy method over the sharded fan-out in wire mode must be bitwise
    the vmap float oracle (its codec is lossless)."""
    multidev_scenario("shard_codec", file="tests/test_strategy_api.py")


def scenario_shard_codec():
    model, params, batches, cfg = _world(N=8)
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    strat = S.make_strategy(cfg.compressor)
    codec = strat.wire_codec(params)
    state = fl_init(params, cfg.num_clients, strat)
    key = jax.random.PRNGKey(3)
    s_f, m_f = jax.jit(build_fl_round(model.loss, strat, RunConfig(fl=cfg)))(
        state, batches, key)
    run_w = RunConfig(fl=cfg, wire="codec", client_parallel="shard_map",
                      mesh=mesh)
    s_w, m_w = jax.jit(build_fl_round(model.loss, strat, run_w,
                                      codec=codec))(state, batches, key)
    for a, b in zip(jax.tree_util.tree_leaves((s_f.params, s_f.ef)),
                    jax.tree_util.tree_leaves((s_w.params, s_w.ef))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for f in ("loss", "cosine", "payload_floats", "update_norm"):
        np.testing.assert_array_equal(np.asarray(getattr(m_f, f)),
                                      np.asarray(getattr(m_w, f)))
    assert float(np.asarray(m_w.wire_bytes_up)) == codec.nbytes
    print("ok toy shard_codec")


SCENARIOS = {"shard_codec": scenario_shard_codec}


if __name__ == "__main__":
    import sys

    SCENARIOS[sys.argv[1]]()
