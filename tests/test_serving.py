"""Serving correctness: decode_step must agree with teacher-forced forward.

For each family: prefill a prompt, decode the next position, and compare
against the logits the full (non-cached) forward produces at that position.
This pins KV-ring indexing, RoPE positions, SSM state carry-over, and
RG-LRU hidden carry-over.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.build import build_model
from repro.models.transformer import LM

# one representative per serving-relevant family
FAMS = ["tinyllama-1.1b", "qwen1.5-0.5b", "qwen3-moe-30b-a3b", "mamba2-370m",
        "recurrentgemma-2b"]
B, T = 2, 12


def full_logits_at(model: LM, params, tokens, pos):
    h, _ = model.forward_hidden(params, tokens)
    from repro.models import layers
    h = layers.rmsnorm(params["final_norm"], h, model.cfg.norm_eps)
    return model._logits(params, h[:, pos, :])


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32", param_dtype="float32")
    if cfg.num_experts:
        # capacity drops differ between teacher-forced (S tokens queueing)
        # and decode (1 token) — raise capacity so neither path drops and
        # the exactness contract is testable
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)

    # teacher-forced logits at position T-1 given tokens[0:T]
    want = full_logits_at(model, params, tokens, T - 1)

    # prefill on first T-1 tokens, then decode token T-1
    _, cache, t0 = model.prefill(params, tokens[:, : T - 1], cache_len=T + 2)
    got, _ = model.decode_step(params, cache, tokens[:, T - 1], t0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b"])
def test_multi_step_decode_matches_forward(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    prefix = 6
    _, cache, t = model.prefill(params, tokens[:, :prefix], cache_len=T + 2)
    for i in range(prefix, T):
        got, cache = model.decode_step(params, cache, tokens[:, i], t)
        t = t + 1
        want = full_logits_at(model, params, tokens[:, : i + 1], i)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer():
    """Window semantics: with attn_window=w, a decode at position t must
    equal full attention over only the last w positions."""
    cfg = get_smoke_config("tinyllama-1.1b").replace(
        dtype="float32", param_dtype="float32", attn_window=4)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    want = full_logits_at(model, params, tokens, T - 1)   # windowed forward
    # ring cache is only `window` slots deep
    _, cache, t0 = model.prefill(params, tokens[:, : T - 1], cache_len=T)
    assert cache["layers"]["0"].k.shape[2] == 4           # (L, B, win, KV, hd)
    got, _ = model.decode_step(params, cache, tokens[:, T - 1], t0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_encdec_decode_consistency():
    cfg = get_smoke_config("seamless-m4t-medium").replace(
        dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    frames = jax.random.normal(key, (B, cfg.num_mm_tokens, cfg.d_model))
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    # teacher-forced decoder logits at last position
    from repro.models import layers
    memory = model.encode(params, frames)
    x = layers.embed(params["embed"], tokens, model.dtype)
    h = model._decoder_hidden(params, x, memory)
    want = layers.lm_head(params["lm_head"], h[:, -1, :])
    _, cache, t0 = model.prefill(params, frames, tokens[:, : T - 1],
                                 cache_len=T + 2)
    got, _ = model.decode_step(params, cache, tokens[:, T - 1], t0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
