"""§Perf variant paths must lower on a host mesh (the exact code paths the
hillclimb driver exercises at 256/512 chips): fused decode, reduced-precision
EF, the no-qk-hd sharding rule, and activation-sharding pins."""
import os

import jax
import pytest

from repro.configs.base import ShapeConfig, get_smoke_config
from repro.launch import specs as specs_lib

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >=2 devices (see dryrun flags)")

SMALL = {"train_4k": ShapeConfig("train_4k", 64, 8, "train"),
         "prefill_32k": ShapeConfig("prefill_32k", 64, 4, "prefill")}


@pytest.fixture(autouse=True)
def _small(monkeypatch):
    monkeypatch.setattr(specs_lib, "INPUT_SHAPES", SMALL)
    monkeypatch.setattr(specs_lib, "get_config", get_smoke_config)
    yield
    from repro.models import params as P_, shard
    P_.set_qk_hd_fallback(True)
    shard.enable(False)


def _mesh():
    n = len(jax.devices())
    return jax.make_mesh((n // 2, 2), ("data", "model"))


@pytest.mark.parametrize("variant", [
    {"fused_decode": True},
    {"ef_dtype": "bfloat16", "param_dtype": "bfloat16"},
])
def test_train_variants_lower(variant):
    entry, args = specs_lib.make_entry("qwen1.5-0.5b", "train_4k", _mesh(),
                                       variant=variant)
    compiled = jax.jit(entry).lower(*args).compile()
    assert compiled is not None


@pytest.mark.parametrize("variant", [
    {"no_qk_hd_shard": True},
    {"act_shard": True},
])
def test_prefill_variants_lower(variant):
    entry, args = specs_lib.make_entry("internvl2-1b", "prefill_32k", _mesh(),
                                       variant=variant)
    compiled = jax.jit(entry).lower(*args).compile()
    assert compiled is not None
