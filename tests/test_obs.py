"""Observability subsystem: tracer ring, meters registry, HTTP endpoints,
structured logger, trace analyzer, and the ledger's overhead surfacing."""
import importlib.util
import json
import logging
import os
import urllib.request

import pytest

from repro.comm.channel import InProcessChannel
from repro.obs import (Tracer, get_logger, merge_traces, read_trace_jsonl,
                       write_chrome_trace)
from repro.obs.meters import MetricsRegistry
from repro.obs.trace import _NOOP_SPAN

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_trace_report():
    spec = importlib.util.spec_from_file_location(
        "trace_report", os.path.join(REPO, "scripts", "trace_report.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_span_records_tags_and_monotonic_interval():
    t = Tracer(enabled=True, proc="p1")
    with t.span("phase", round=3) as sp:
        sp.end(bytes=17)          # idempotent: __exit__ after end() is a no-op
    recs = t.drain()
    assert len(recs) == 1
    r = recs[0]
    assert r["kind"] == "span" and r["name"] == "phase" and r["proc"] == "p1"
    assert r["round"] == 3 and r["bytes"] == 17
    assert isinstance(r["t0"], int) and r["t1"] >= r["t0"]
    assert t.drain() == []        # drain cleared the ring


def test_event_records_instant():
    t = Tracer(enabled=True, proc="w")
    t.event("rx_frame", round=1, client=2, bytes=99, outcome="ok")
    (r,) = t.drain()
    assert r["kind"] == "event" and r["outcome"] == "ok" and "t" in r


def test_disabled_tracer_is_noop_and_allocation_free():
    t = Tracer(enabled=False)
    sp = t.span("x", round=0)
    assert sp is _NOOP_SPAN       # shared object: no per-call allocation
    with sp:
        sp.end(bytes=1)
    t.event("y")
    assert t.to_dicts() == []


def test_ring_bounds_memory_and_counts_drops():
    t = Tracer(enabled=True, capacity=4)
    for i in range(10):
        t.event("e", i=i)
    recs = t.drain()
    assert len(recs) == 4
    assert [r["i"] for r in recs] == [6, 7, 8, 9]     # oldest evicted
    assert t.dropped == 6                              # eviction is visible


def test_jsonl_roundtrip(tmp_path):
    t = Tracer(enabled=True)
    with t.span("a", k="v"):
        pass
    t.event("b")
    path = str(tmp_path / "trace.jsonl")
    assert t.write_jsonl(path) == 2
    back = read_trace_jsonl(path)
    assert [r["name"] for r in back] == ["a", "b"]


def test_merge_traces_shifts_worker_clocks():
    server = [{"kind": "span", "name": "round", "proc": "server",
               "t0": 1000, "t1": 2000, "round": 0}]
    worker = {"client-1": [
        {"kind": "span", "name": "worker.compute", "proc": "client-1",
         "t0": 100, "t1": 200, "round": 0},
        {"kind": "event", "name": "ef_push", "proc": "client-1", "t": 300}]}
    merged = merge_traces(server, worker, {"client-1": 1_000_000})
    by_name = {r["name"]: r for r in merged}
    assert by_name["worker.compute"]["t0"] == 1_000_100
    assert by_name["worker.compute"]["t1"] == 1_000_200
    assert by_name["ef_push"]["t"] == 1_000_300
    assert by_name["round"]["t0"] == 1000                 # server untouched
    # sorted by start time
    starts = [r.get("t0", r.get("t")) for r in merged]
    assert starts == sorted(starts)


def test_chrome_trace_export(tmp_path):
    recs = [
        {"kind": "span", "name": "round", "proc": "server",
         "t0": 5_000_000, "t1": 9_000_000, "round": 0},
        {"kind": "event", "name": "rx_frame", "proc": "client-0",
         "t": 6_000_000, "bytes": 4},
    ]
    path = str(tmp_path / "t.json")
    n = write_chrome_trace(recs, path)
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    assert n == len(evs)
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} == {"server", "client-0"}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == 0.0 and x["dur"] == 4000.0          # rebased, us units
    i = next(e for e in evs if e["ph"] == "i")
    assert i["ts"] == 1000.0 and i["args"]["bytes"] == 4


# ---------------------------------------------------------------------------
# meters
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)                   # get-or-create: same instance
    reg.gauge("g").set(2.5)
    h = reg.histogram("h")
    for v in range(100):
        h.observe(float(v))
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"] == 2.5
    hs = snap["histograms"]["h"]
    assert hs["count"] == 100 and hs["min"] == 0.0 and hs["max"] == 99.0
    assert 45 <= hs["p50"] <= 55 and 90 <= hs["p95"] <= 99
    assert hs["p99"] >= hs["p95"] >= hs["p50"]


def test_histogram_ring_bounded():
    reg = MetricsRegistry()
    h = reg.histogram("h", capacity=8)
    for v in range(100):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100                  # count/sum track everything
    assert s["p50"] >= 92.0                   # quantiles from the recent ring


def test_sources_polled_and_exception_captured():
    reg = MetricsRegistry()
    reg.register_source("ok", lambda: {"x": 1})

    def boom():
        raise RuntimeError("dead source")

    reg.register_source("bad", boom)
    snap = reg.snapshot()
    assert snap["sources"]["ok"] == {"x": 1}
    assert "RuntimeError" in snap["sources"]["bad"]["error"]
    reg.unregister_source("bad")
    assert "bad" not in reg.snapshot()["sources"]


def test_http_endpoints():
    pytest.importorskip("http.server")
    from repro.obs.http import ObsHTTPServer

    reg = MetricsRegistry()
    reg.counter("hits").inc(3)
    srv = ObsHTTPServer(port=0, registry=reg)
    try:
        with urllib.request.urlopen(f"{srv.url}/healthz", timeout=5) as r:
            health = json.loads(r.read())
        assert health["status"] == "ok" and health["uptime_s"] >= 0
        with urllib.request.urlopen(f"{srv.url}/metrics", timeout=5) as r:
            snap = json.loads(r.read())
        assert snap["counters"]["hits"] == 3
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{srv.url}/nope", timeout=5)
        assert ei.value.code == 404
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------


def test_logger_prefixes_context():
    # the "repro" root logger is propagate=False (it owns its stderr
    # handler), so capture on the named logger itself
    records = []

    class Collect(logging.Handler):
        def emit(self, rec):
            records.append(rec.getMessage())

    log = get_logger("worker", client=7)
    h = Collect()
    log.logger.addHandler(h)
    try:
        log.info("hello %d", 42)
        log.bind(round=3).info("served")
    finally:
        log.logger.removeHandler(h)
    assert records[0] == "[client=7] hello 42"
    assert records[1] == "[client=7 round=3] served"


# ---------------------------------------------------------------------------
# trace analyzer (scripts/trace_report.py)
# ---------------------------------------------------------------------------


def _synthetic_trace():
    """Two rounds, three clients: round 0 all delivered; round 1 has a
    straggler (cid 1, worker busy > deadline), a dead worker (cid 2), and a
    filtered frame is recorded against round 0 for byte totals."""
    S = 1_000_000_000                                  # 1s in ns
    recs = []

    def span(name, t0, t1, **tags):
        recs.append({"kind": "span", "name": name, "proc": "server",
                     "t0": t0, "t1": t1, **tags})

    def ev(name, t, **tags):
        recs.append({"kind": "event", "name": name, "proc": "server",
                     "t": t, **tags})

    for rnd, base in ((0, 0), (1, 2 * S)):
        span("round", base, base + S, round=rnd, deadline_s=0.5)
        for i, ph in enumerate(("encode", "broadcast", "collect", "ack",
                                "aggregate")):
            span(f"round.{ph}", base + i * 1000, base + i * 1000 + 500,
                 round=rnd, phase=ph)
        for cid in range(3):
            ev("tx_frame", base + 100, round=rnd, client=cid, bytes=200)
    # round 0: all frames arrive ok, plus one filtered duplicate
    for cid in range(3):
        ev("rx_frame", 500_000, round=0, client=cid, bytes=100, outcome="ok")
        ev("round.outcome", S, round=0, client=cid, outcome="delivered")
    ev("rx_frame", 600_000, round=0, client=0, bytes=100, outcome="filtered")
    # round 1: cid 0 ok, cid 1 straggles, cid 2 dead
    ev("rx_frame", 2 * S + 500_000, round=1, client=0, bytes=100,
       outcome="ok")
    ev("round.outcome", 3 * S, round=1, client=0, outcome="delivered")
    ev("round.outcome", 3 * S, round=1, client=1, outcome="undelivered")
    ev("round.outcome", 3 * S, round=1, client=2, outcome="dead")
    # the straggler's own (merged) spans overrun the 0.5s deadline
    recs.append({"kind": "span", "name": "worker.compute", "proc": "client-1",
                 "t0": 2 * S, "t1": 2 * S + 300_000_000, "round": 1})
    recs.append({"kind": "span", "name": "worker.straggle", "proc": "client-1",
                 "t0": 2 * S + 300_000_000, "t1": 4 * S, "round": 1,
                 "sleep_s": 1.7})
    return recs


def test_trace_report_phases_and_attribution():
    tr = _load_trace_report()
    recs = _synthetic_trace()
    rep = tr.report(recs)
    assert rep["rounds"] == [0, 1]
    assert rep["phase_complete"] and rep["missing_phases"] == {}
    assert rep["phases"]["round"]["count"] == 2
    assert abs(rep["phases"]["round"]["p50"] - 1.0) < 1e-6    # 1s spans
    att = rep["attribution"]
    assert att["stragglers"] == {1: [1]}
    assert att["dead_workers"] == {2: [1]}
    assert att["frame_lost"] == {}            # the filtered frame was a dup
    causes = {(c["round"], c["client"]): c["cause"]
              for c in att["undelivered"]}
    assert causes == {(1, 1): "straggler", (1, 2): "dead"}


def test_trace_report_detects_missing_phase():
    tr = _load_trace_report()
    recs = [r for r in _synthetic_trace()
            if not (r.get("name") == "round.ack" and r.get("round") == 1)]
    rep = tr.report(recs)
    assert not rep["phase_complete"]
    assert rep["missing_phases"] == {"1": ["round.ack"]}


def test_trace_report_reconciliation_exact_and_mismatch():
    tr = _load_trace_report()
    recs = _synthetic_trace()
    # trace saw 5 rx frames x 100B (incl. the filtered one: it was billed)
    # and 6 tx frames x 200B
    good = {"uplink": {"total_bytes": 500}, "downlink": {"total_bytes": 1200},
            "overhead_up": 77, "overhead_down": 88}
    rec = tr.reconcile(recs, good)
    assert rec["uplink_exact"] and rec["downlink_exact"]
    assert rec["overhead_up"] == 77 and rec["overhead_down"] == 88
    bad = {"uplink": {"total_bytes": 501}, "downlink": {"total_bytes": 1200}}
    rec = tr.reconcile(recs, bad)
    assert not rec["uplink_exact"] and rec["downlink_exact"]


def test_trace_report_replay_summary():
    tr = _load_trace_report()
    rep = tr.replay_summary(_synthetic_trace())
    assert rep["schema"] == "repro.trace-replay/v1"
    assert [r["round"] for r in rep["rounds"]] == [0, 1]
    r0 = rep["rounds"][0]
    assert r0["wall_s"] == 1.0 and r0["deadline_s"] == 0.5
    assert r0["bytes_up"] == 400 and r0["bytes_down"] == 600
    assert r0["clients"]["0"]["outcome"] == "delivered"
    assert abs(r0["clients"]["0"]["arrival_s"] - 0.0005) < 1e-9
    r1 = rep["rounds"][1]
    assert r1["clients"]["1"]["outcome"] == "undelivered"
    assert r1["clients"]["1"]["arrival_s"] is None


def test_trace_report_cli(tmp_path):
    tr = _load_trace_report()
    trace = tmp_path / "trace.jsonl"
    with open(trace, "w") as f:
        for r in _synthetic_trace():
            f.write(json.dumps(r) + "\n")
    ledger = tmp_path / "ledger.json"
    ledger.write_text(json.dumps(
        {"uplink": {"total_bytes": 500}, "downlink": {"total_bytes": 1200},
         "overhead_up": 0, "overhead_down": 0}))
    replay = tmp_path / "replay.json"
    rc = tr.main([str(trace), "--ledger", str(ledger),
                  "--replay", str(replay), "--json"])
    assert rc == 0
    assert json.loads(replay.read_text())["rounds"]


# ---------------------------------------------------------------------------
# ledger overhead surfacing (the billed-but-dropped fix)
# ---------------------------------------------------------------------------


def test_ledger_roundtrips_overhead_and_defaults_old_snapshots():
    ch = InProcessChannel()
    ch.overhead_up += 123
    ch.overhead_down += 456
    led = ch.ledger()
    assert led["overhead_up"] == 123 and led["overhead_down"] == 456
    ch2 = InProcessChannel()
    ch2.restore_ledger(led)
    assert ch2.overhead_up == 123 and ch2.overhead_down == 456
    # a pre-PR9 ledger has no overhead keys: restore defaults them to 0
    old = {"uplink": led["uplink"], "downlink": led["downlink"]}
    ch3 = InProcessChannel()
    ch3.restore_ledger(old)
    assert ch3.overhead_up == 0 and ch3.overhead_down == 0


def test_live_result_surfaces_overhead():
    from benchmarks.fl_harness import ExperimentResult

    history = [{"round": 0, "losses": {0: 1.0, 1: 3.0}},
               {"round": 1, "losses": {}}]
    ledger = {"uplink": {"total_bytes": 1000},
              "downlink": {"total_bytes": 2000},
              "overhead_up": 50, "overhead_down": 60}
    res = ExperimentResult.from_live_run(
        "live", history, ledger, payload_floats=10.0, model_params=100,
        seconds=1.0)
    assert res.overhead_up_bytes == 50.0
    assert res.overhead_down_bytes == 60.0
    assert res.loss_curve == [2.0]
    assert res.wire_bytes == 500.0
