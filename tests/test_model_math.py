"""Math-level properties of the model substrate components."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import causal_mask
from repro.models.rope import apply_rope


# --- RoPE ---------------------------------------------------------------


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.arange(8)
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """q_i · k_j after RoPE depends only on (i - j)."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))

    def score(i, j):
        qi = apply_rope(q, jnp.asarray([i]), 10000.0)
        kj = apply_rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.vdot(qi, kj))

    assert abs(score(5, 3) - score(9, 7)) < 1e-4
    assert abs(score(10, 10) - score(0, 0)) < 1e-4
    assert abs(score(5, 3) - score(5, 4)) > 1e-6  # actually varies with gap


# --- masks ----------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 24), st.integers(0, 8))
def test_causal_window_mask(s, w):
    m = np.asarray(causal_mask(s, s, window=w))
    for i in range(s):
        for j in range(s):
            want = j <= i and (w == 0 or j > i - w)
            assert m[i, j] == want, (i, j, w)


# --- SSD scan vs step recurrence ------------------------------------------


def test_ssd_scan_matches_step_recurrence():
    """Chunked SSD == token-by-token linear recurrence (ground truth)."""
    b, s, h, p, n = 2, 24, 3, 8, 4
    key = jax.random.PRNGKey(0)
    xdt = 0.2 * jax.random.normal(key, (b, s, h, p))
    dA = -0.3 * jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    B = 0.7 * jax.random.normal(jax.random.PRNGKey(2), (b, s, n))
    C = 0.7 * jax.random.normal(jax.random.PRNGKey(3), (b, s, n))

    y_scan, final = ssm_mod.ssd_scan(xdt, dA, B, C, chunk=8)

    hstate = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(dA[:, t])[:, :, None, None]
        dBx = jnp.einsum("bn,bhp->bhpn", B[:, t], xdt[:, t])
        hstate = decay * hstate + dBx
        ys.append(jnp.einsum("bhpn,bn->bhp", hstate, C[:, t]))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(final), np.asarray(hstate),
                               rtol=1e-4, atol=1e-5)


def test_ssd_scan_chunk_invariance():
    """Result must not depend on the chunk size (incl. non-divisible)."""
    b, s, h, p, n = 1, 20, 2, 4, 4
    key = jax.random.PRNGKey(4)
    xdt = 0.2 * jax.random.normal(key, (b, s, h, p))
    dA = -0.2 * jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5), (b, s, h)))
    B = jax.random.normal(jax.random.PRNGKey(6), (b, s, n))
    C = jax.random.normal(jax.random.PRNGKey(7), (b, s, n))
    y4, f4 = ssm_mod.ssd_scan(xdt, dA, B, C, chunk=4)
    y7, f7 = ssm_mod.ssd_scan(xdt, dA, B, C, chunk=7)   # 20 % 7 != 0 -> pad path
    y20, f20 = ssm_mod.ssd_scan(xdt, dA, B, C, chunk=20)
    np.testing.assert_allclose(y4, y7, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(y4, y20, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(f4, f7, rtol=1e-4, atol=1e-5)


# --- MoE -------------------------------------------------------------------


def _moe_setup(E=4, k=2, d=16, ff=8, B=2, S=12):
    p = moe_mod.moe_init(jax.random.PRNGKey(0), d, ff, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    return p, x, E, k


def test_moe_output_finite_and_aux_near_one():
    p, x, E, k = _moe_setup()
    out = moe_mod.moe_ffn(p, x, experts_per_token=k)
    assert out.y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.y)))
    # Switch aux loss ~= coef for near-uniform routing, >= ~coef lower bound
    assert 0.0 < float(out.aux_loss) < 0.1


def test_moe_capacity_drops_tokens_not_crash():
    """At capacity_factor -> tiny, most tokens drop; output shrinks but stays
    finite (residual carries dropped tokens in the block)."""
    p, x, E, k = _moe_setup()
    full = moe_mod.moe_ffn(p, x, experts_per_token=k, capacity_factor=8.0)
    tiny = moe_mod.moe_ffn(p, x, experts_per_token=k, capacity_factor=0.1)
    assert bool(jnp.all(jnp.isfinite(tiny.y)))
    assert float(jnp.linalg.norm(tiny.y)) < float(jnp.linalg.norm(full.y))


def test_moe_respects_router():
    """With a router forced to a single expert, output must equal that
    expert's SwiGLU applied to x (up to capacity truncation)."""
    d, ff, E = 8, 16, 4
    p = moe_mod.moe_init(jax.random.PRNGKey(0), d, ff, E)
    # bias router hard toward expert 2: logits[e] = (sum_d x_d) * r_e, so the
    # tokens must have positive feature sums for the +100 column to win
    p = dict(p, router=p["router"] * 0 + jnp.asarray([-100., -100., 100., -100.]))
    x = 0.05 + 0.1 * jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (1, 2, d)))
    out = moe_mod.moe_ffn(p, x, experts_per_token=1, capacity_factor=8.0)
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"][2])
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"][2])
    want = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["w_out"][2])
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
