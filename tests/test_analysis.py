"""Negative fixtures for the static-analysis subsystem (``repro.analysis``).

A checker that never fires is indistinguishable from one that works, so
every layer gets a fixture in which the invariant is deliberately broken
and the test asserts the rule FIRES:

* a synthetic all-gather injected inside ``CLIENT_SCOPE`` HLO text
  (contract ``client-scope-clean``);
* a real ``jax.pure_callback`` compiled into a jitted body
  (contract ``no-host-callbacks``);
* a real compile WITHOUT ``donate_argnums`` (contract
  ``ef-donation-aliased``);
* known-bad AST snippets — broad ``except``, a host ``time.time()``
  reachable from ``build_fl_round``, an unregistered strategy kind, an
  ``__all__`` drifted off its GOLDEN pin (the four lint rules);
* a transport handler deletion — the worker's ``MSG_EF_SYNC`` branch
  stripped from the real source (protocol ``black-hole send``) — plus a
  synthetic racy class for the lock analyzer.

The HEAD sources themselves are pinned clean here too (lint + protocol run
in milliseconds; the full IR matrix stays in ``scripts/check_static.py``'s
forced-8-device child).
"""
import ast

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (CLIENT_SCOPE, RoundArtifact,
                            aliased_param_indices, encode_region_collectives,
                            host_callbacks, run_contracts)
from repro.analysis import lint, protocol

# ---------------------------------------------------------------------------
# synthetic HLO fixtures (hand-written in the optimized-HLO grammar that
# utils.hlo_analyzer parses: module header, ENTRY computation, metadata)
# ---------------------------------------------------------------------------

_ALIAS_HDR = "input_output_alias={ {}: (0, {}, may-alias) }, "


def _hlo_module(body_lines, alias=False):
    hdr = ("HloModule jit_round, " + (_ALIAS_HDR if alias else "")
           + "entry_computation_layout={(f32[16,4]{1,0})->f32[16,4]{1,0}}")
    body = "".join(f"  {ln}\n" for ln in body_lines)
    return (hdr + "\n\n"
            "ENTRY %main.1 (p0.1: f32[16,4]) -> f32[16,4] {\n"
            "  %p0.1 = f32[16,4]{1,0} parameter(0)\n"
            + body +
            "  ROOT %out.1 = f32[16,4]{1,0} add(%p0.1, %p0.1)\n"
            "}\n")


def _gather_line(op_name, operand="p0.1", ty="f32[16,4]{1,0}"):
    # collective bytes are accounted from the OPERAND type (one transfer)
    return (f"%ag.1 = {ty} all-gather(%{operand}), channel_id=1, "
            f"replica_groups={{{{0,1,2,3}}}}, dimensions={{0}}, "
            f'metadata={{op_name="{op_name}" source_file="fx.py"}}')


def _big_gather_module():
    # a 16 KiB f32 operand fed into the gather: dwarfs both the fused
    # bound (FACTOR x payload + slack) and the codec metadata slack
    return _hlo_module(
        ["%big.1 = f32[1024,4]{1,0} broadcast(%p0.1), dimensions={0,1}",
         _gather_line("jit(fl_round)/server_decode/all_gather",
                      operand="big.1", ty="f32[4096,4]{1,0}")],
        alias=True)


SCOPED_GATHER_HLO = _hlo_module(
    [_gather_line(f"jit(fl_round)/{CLIENT_SCOPE}/encode/all_gather")],
    alias=True)
UNSCOPED_GATHER_HLO = _hlo_module(
    [_gather_line("jit(fl_round)/server_decode/all_gather")], alias=True)
CLEAN_HLO = _hlo_module([], alias=True)


def _artifact(hlo, fanout="shard_map", wire="float", fused=False, **kw):
    cfg = {"kind": "threesfc", "fanout": fanout, "wire": wire,
           "fused": fused, "faulted": False}
    return RoundArtifact(config=cfg, hlo_text=hlo, **kw)


def _violations(report, name):
    return report["contracts"][name]["violations"]


# ---------------------------------------------------------------------------
# contract negatives
# ---------------------------------------------------------------------------


def test_scoped_collective_fires():
    # injected all-gather inside the per-client encode region -> the
    # client-scope contract must name it
    assert len(encode_region_collectives(SCOPED_GATHER_HLO)) == 1
    rep = run_contracts([_artifact(SCOPED_GATHER_HLO,
                                   ef_param_indices=(0,))])
    viol = _violations(rep, "client-scope-clean")
    assert viol and CLIENT_SCOPE in viol[0] and "all-gather" in viol[0]
    # the same collective OUTSIDE the scope is server-side traffic: clean
    rep = run_contracts([_artifact(UNSCOPED_GATHER_HLO,
                                   ef_param_indices=(0,))])
    assert not _violations(rep, "client-scope-clean")


def test_vmap_round_must_be_collective_free():
    # a mesh-free vmap round has no business holding ANY collective,
    # scoped or not
    rep = run_contracts([_artifact(UNSCOPED_GATHER_HLO, fanout="vmap",
                                   ef_param_indices=(0,))])
    assert _violations(rep, "client-scope-clean")


def test_clean_module_passes_all_contracts():
    rep = run_contracts([_artifact(CLEAN_HLO, ef_param_indices=(0,))])
    assert rep["violations"] == 0
    assert rep["rules_evaluated"] >= 3      # scope, callbacks, donation


def test_host_callback_fires():
    # a REAL pure_callback lowered by jit: the contract must see the
    # *callback* custom-call in the optimized HLO
    def round_body(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1.0

    text = jax.jit(round_body).lower(
        jnp.ones((4,), jnp.float32)).compile().as_text()
    assert host_callbacks(text), "pure_callback not visible in HLO"
    rep = run_contracts([_artifact(text, fanout="vmap")])
    viol = _violations(rep, "no-host-callbacks")
    assert viol and "callback" in viol[0]
    # and a callback-free compile stays clean
    clean = jax.jit(lambda x: x + 1.0).lower(
        jnp.ones((4,), jnp.float32)).compile().as_text()
    assert not host_callbacks(clean)


def test_ef_donation_negative_without_donate():
    # same function compiled twice: only the donated executable aliases
    # parameter 0, and the contract fires on the un-donated one
    x = jnp.ones((64,), jnp.float32)
    donated = jax.jit(lambda v: v * 2.0,
                      donate_argnums=(0,)).lower(x).compile().as_text()
    plain = jax.jit(lambda v: v * 2.0).lower(x).compile().as_text()
    assert 0 in aliased_param_indices(donated)
    assert 0 not in aliased_param_indices(plain)
    rep = run_contracts([_artifact(plain, fanout="vmap",
                                   ef_param_indices=(0,))])
    viol = _violations(rep, "ef-donation-aliased")
    assert viol and "not input->output aliased" in viol[0]


def test_fused_gather_bound_fires():
    # 16 KiB gathered against a 1 B local payload budget: way past
    # FACTOR x payload + SLACK
    rep = run_contracts([_artifact(_big_gather_module(), fused=True,
                                   ef_param_indices=(0,),
                                   payload_bytes_local=1.0)])
    viol = _violations(rep, "fused-gather-bounded")
    assert viol and "> bound" in viol[0]


def test_wire_dtype_policy_fires():
    # codec mode with an unregistered policy and a frame smaller than its
    # own header: both structural checks fire
    bad = _artifact(CLEAN_HLO, wire="codec", ef_param_indices=(0,),
                    codec_policy="fp7", codec_nbytes=4)
    rep = run_contracts([bad])
    viol = _violations(rep, "wire-dtype-policy")
    assert any("unregistered dtype policy" in v for v in viol)
    assert any("header" in v for v in viol)
    # valid frame layout but a fat f32 gather on the wire: the float-tree
    # leak check fires
    leaky = _artifact(_big_gather_module(), wire="codec",
                      ef_param_indices=(0,), codec_policy="fp16",
                      codec_nbytes=256, num_clients=4, client_shards=4)
    rep = run_contracts([leaky])
    viol = _violations(rep, "wire-dtype-policy")
    assert any("crossing the wire" in v for v in viol)


# ---------------------------------------------------------------------------
# lint negatives (synthetic {path: source} trees through the same rules)
# ---------------------------------------------------------------------------


def _lint_one(rule, files):
    trees = {p: ast.parse(s) for p, s in files.items()}
    return rule(files, trees)


def test_lint_broad_except_fires():
    src = ("def f():\n"
           "    try:\n"
           "        return 1\n"
           "    except Exception:\n"
           "        return None\n")
    _, viol = _lint_one(lint.check_untyped_except,
                        {"src/repro/bad.py": src})
    assert viol and "broad except" in viol[0]
    # the escape hatch: a # noqa justification on the handler line
    _, viol = _lint_one(
        lint.check_untyped_except,
        {"src/repro/ok.py": src.replace(
            "except Exception:", "except Exception:  # noqa: BLE001 why")})
    assert not viol


def test_lint_host_call_fires_only_when_reachable():
    src = ("import time\n"
           "\n"
           "def helper():\n"
           "    return time.time()\n"
           "\n"
           "def build_fl_round(loss_fn, strategy, run):\n"
           "    return helper()\n"
           "\n"
           "def host_side_logger():\n"
           "    return time.time()\n")
    _, viol = _lint_one(lint.check_host_calls, {"src/repro/bad.py": src})
    # helper() is on the round path through build_fl_round -> fires ...
    assert any("time.time" in v and "helper" in v for v in viol)
    # ... but host_side_logger is NOT reachable from a round root: the
    # reachability pruning must keep it out
    assert not any("host_side_logger" in v for v in viol)


def test_lint_registry_kind_fires():
    files = {
        "src/repro/core/newstrat.py": (
            "from repro.core import register_strategy\n"
            "@register_strategy('newkind')\n"
            "class NewStrat:\n"
            "    pass\n"),
        "src/repro/comm/frame.py": "KIND_IDS = {'identity': 0}\n",
    }
    _, viol = _lint_one(lint.check_registry_kinds, files)
    assert viol and "newkind" in viol[0] and "KIND_IDS" in viol[0]


def test_lint_public_exports_fires():
    files = {"src/repro/comm/__init__.py": "__all__ = ['a', 'b']\n"}
    trees = {p: ast.parse(s) for p, s in files.items()}
    _, viol = lint.check_public_exports(
        files, trees, golden={"repro.comm": ["a"]})
    assert viol and "extra: ['b']" in viol[0]


def test_lint_clean_at_head():
    # the committed tree must hold its own invariants — same gate
    # scripts/check_static.py enforces, pinned in tier-1
    rep = lint.run_lint()
    assert rep["violations"] == 0, rep["rules"]
    assert rep["rules_evaluated"] > 0


# ---------------------------------------------------------------------------
# protocol negatives
# ---------------------------------------------------------------------------


def test_protocol_handler_deletion_fires():
    # surgically delete the worker's MSG_EF_SYNC handler from the REAL
    # source: the server still sends it -> black-hole send
    w_src = protocol._read(protocol.WORKER_PATH)
    assert "mtype == MSG_EF_SYNC" in w_src, "worker handler shape changed"
    broken = w_src.replace("mtype == MSG_EF_SYNC", "False")
    _, viol = protocol.check_protocol(worker_src=broken)
    assert any("MSG_EF_SYNC" in v and "black-hole" in v for v in viol)


def test_protocol_black_hole_and_dead_vocabulary():
    t_src = ("MSG_A = 0\n"
             "MSG_B = 1\n"
             "MSG_C = 2\n"
             "class SocketServer:\n"
             "    def pump(self, mtype):\n"
             "        if mtype == MSG_A:\n"
             "            pass\n"
             "        send_msg(None, MSG_B, b'')\n"
             "class ServerLink:\n"
             "    pass\n")
    w_src = "def serve(link):\n    send_msg(None, MSG_A, b'')\n"
    _, viol = protocol.check_protocol(transport_src=t_src, worker_src=w_src)
    # MSG_B is sent by the server but the worker never handles it;
    # MSG_C exists in the vocabulary but nobody sends it
    assert any("MSG_B" in v and "black-hole" in v for v in viol)
    assert any("MSG_C" in v and "dead vocabulary" in v for v in viol)
    assert not any("MSG_A" in v for v in viol)


def test_protocol_clean_at_head():
    rep = protocol.run_protocol()
    assert rep["violations"] == 0, rep["rules"]
    # the full vocabulary is mirrored: every message sent on one side,
    # handled on the other
    t = rep["transitions"]
    assert len(t["messages"]) >= 10
    assert set(t["sends"]["server"]) == set(t["handles"]["worker"])
    assert set(t["sends"]["worker"]) == set(t["handles"]["server"])


def test_race_detector_fires_on_unguarded_write():
    racy = ("import threading\n"
            "class Racy:\n"
            "    def __init__(self):\n"
            "        self._lock = threading.Lock()\n"
            "        self.counter = 0\n"
            "        t = threading.Thread(target=self._loop)\n"
            "        t.start()\n"
            "    def _loop(self):\n"
            "        self.counter += 1\n"
            "    def bump(self):\n"
            "        self.counter += 1\n")
    _, viol = protocol.analyze_class_races(ast.parse(racy), "Racy")
    assert viol and all("counter" in v for v in viol)
    # same class with every write under the lock: clean
    guarded = racy.replace(
        "        self.counter += 1\n",
        "        with self._lock:\n            self.counter += 1\n")
    _, viol = protocol.analyze_class_races(ast.parse(guarded), "Racy")
    assert not viol


def test_race_detector_rejects_missing_class():
    with pytest.raises(ValueError):
        protocol.analyze_class_races(ast.parse("x = 1\n"), "SocketServer")
