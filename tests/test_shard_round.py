"""Sharded client fan-out: shard_map rounds must match the vmap oracle and
the EF placement contract must survive donation.

The scenarios need 8 devices, so each test runs its scenario in a child
process via the ``multidev_scenario`` conftest fixture (the pytest process
itself is pinned to 1 CPU device). Child scenarios live in this same file
under ``__main__`` — run one by hand with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python tests/test_shard_round.py bitexact

Exactness contract (measured, see bench_collectives): XLA CPU lowers
batched dots differently per vmap width (~1e-8 param drift), so compressors
whose per-client math differentiates the model (3SFC) are bitwise only on a
width-matched mesh (client axis 1); fedavg/dgc/signsgd/stc are bitwise on
the real 8-way client axis.
"""
def test_shard_map_bitexact_vs_vmap_all_compressors(multidev_scenario):
    """3 scanned rounds on the 8-way client mesh: bitwise params/EF/metrics
    for the width-stable compressors; 3SFC bitwise width-matched + tight
    allclose on the 8-way mesh."""
    multidev_scenario("bitexact")


def test_ef_sharding_roundtrip_through_donation(multidev_scenario):
    """Donated scan blocks must consume and reproduce the *sharded* EF
    buffers: spec pinned across blocks, old state consumed, caller's params
    alive."""
    multidev_scenario("ef_donation")


def test_shard_map_wire_mode_equals_vmap_float(multidev_scenario):
    """wire='codec' on the sharded fan-out (only framed uint8 buffers cross
    the shard_map boundary) over 3 scanned rounds: bitwise the vmap float
    oracle for topk; signsgd bitwise its own vmap wire mode (the 1-bit wire
    is fan-out-transparent); threesfc ≤1e-5 vs the vmap float oracle (the
    server-side decode recompute is vmap-width-sensitive, like the fused
    path)."""
    multidev_scenario("wire")


def test_shard_map_fault_pipeline(multidev_scenario):
    """The fault model on the 8-way sharded fan-out (the shard_map half of
    the 28-combo matrix; the vmap half runs in tests/test_faults.py):
    null-schedule masked rounds bitwise the unfaulted shard_map rounds for
    every (kind × wire) combo (fused threesfc at the established 1e-5
    width-lowering tolerance); a 50%-dropout schedule produces the same
    state as the vmap fan-out and drops the identical client set (mask
    transparency — state at 1e-6, since the renormalized masked mean is no
    longer the exact all-true identity under the 8-way psum); and the compiled
    faulted round keeps ZERO collectives inside the per-client
    ``CLIENT_SCOPE`` encode region (the masks ride the client axis, they
    never synchronize it)."""
    multidev_scenario("faults")


# ---------------------------------------------------------------------------
# child scenarios (8 devices)
# ---------------------------------------------------------------------------


def _world():
    import jax

    from repro.configs.base import CompressorConfig, FLConfig
    from repro.core.compressor import make_compressor
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.engine import RoundEngine, device_pools, vision_batcher
    from repro.fl.round import make_fl_round
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import MNIST_SPEC, make_paper_model

    N, K, B = 8, 2, 8
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    train = make_class_image_dataset(jax.random.PRNGKey(1), 400,
                                     MNIST_SPEC.input_shape, 10)
    parts = dirichlet_partition(train.y, N, alpha=0.5, seed=0,
                                min_per_client=16)

    def engine(ccfg, shardings=None, mode="vmap", mesh=None, donate=True,
               wire="float"):
        spec = vision_syn_spec(MNIST_SPEC, ccfg)
        comp = make_compressor(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                               local_lr=0.05)
        cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                       local_batch=B, compressor=ccfg)
        pools = device_pools(parts)
        if shardings is not None:
            pools = shardings.place_pools(pools)
        wire_kw = {}
        if wire == "codec":
            from repro.comm import make_codec
            wire_kw = dict(wire="codec",
                           codec=make_codec(ccfg, params, syn_spec=spec,
                                            syn_loss_fn=model.syn_loss))
        eng = RoundEngine(
            make_fl_round(model.loss, comp, cfg, client_parallel=mode,
                          mesh=mesh, **wire_kw),
            vision_batcher(train.x, train.y, pools, K, B),
            seed=0, donate=donate, shardings=shardings)
        return eng, eng.init_state(params, N)

    return params, engine, CompressorConfig


def _tree_equal(a, b, what):
    import jax
    import numpy as np
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{what} not bit-exact")


def scenario_bitexact():
    import jax
    import numpy as np

    from repro.fl.sharding import make_fl_shardings

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    sh = make_fl_shardings(mesh)
    mesh_w = jax.make_mesh((1, 8), ("data", "model"))   # width-matched
    sh_w = make_fl_shardings(mesh_w)
    _, engine, CompressorConfig = _world()

    kinds = {
        "fedavg": CompressorConfig(kind="identity", error_feedback=False),
        "dgc": CompressorConfig(kind="topk", keep_ratio=0.05),
        "signsgd": CompressorConfig(kind="signsgd"),
        "stc": CompressorConfig(kind="stc", keep_ratio=0.05),
        "threesfc": CompressorConfig(kind="threesfc", syn_steps=2, syn_lr=0.1),
    }
    for name, ccfg in kinds.items():
        ev, stv = engine(ccfg)
        sv, mv = ev.run_block(stv, 3)
        es, sts = engine(ccfg, sh, "shard_map", mesh)
        ss, ms = es.run_block(sts, 3)
        if name == "threesfc":
            # width-matched mesh: bitwise, proving the shard_map plumbing
            # (specs, gathers, key contract) is exactly transparent
            ew, stw = engine(ccfg, sh_w, "shard_map", mesh_w)
            sw, _ = ew.run_block(stw, 3)
            _tree_equal(sv.params, sw.params, "threesfc width-matched params")
            _tree_equal(sv.ef, sw.ef, "threesfc width-matched ef")
            # 8-way mesh: pinned to tight tolerance (width-dependent XLA
            # batched-dot lowering, ~1e-8 observed)
            for a, b in zip(jax.tree_util.tree_leaves(sv.params),
                            jax.tree_util.tree_leaves(ss.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=0, atol=1e-5)
        else:
            _tree_equal(sv.params, ss.params, f"{name} params")
            _tree_equal(sv.ef, ss.ef, f"{name} ef")
            for f in mv._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(mv, f)), np.asarray(getattr(ms, f)),
                    err_msg=f"{name} metric {f} not bit-exact")
        print(f"ok {name}")

    # fused 3SFC fan-out: gathered (D_syn, s) + replicated backward must
    # match the vmap fused path to the same width tolerance
    from repro.configs.base import FLConfig
    from repro.core.compressor import make_compressor
    from repro.fl.round import make_fl_round
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import MNIST_SPEC, make_paper_model
    ccfg = kinds["threesfc"]
    model = make_paper_model("mlp", MNIST_SPEC)
    spec = vision_syn_spec(MNIST_SPEC, ccfg)
    comp = make_compressor(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                           local_lr=0.05)
    cfg = FLConfig(num_clients=8, local_steps=2, local_lr=0.05,
                   local_batch=8, compressor=ccfg)
    kw = dict(fused_decode=True, syn_loss_fn=model.syn_loss, syn_spec=spec)
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.round import fl_init
    import jax.numpy as jnp
    ds = make_class_image_dataset(jax.random.PRNGKey(5), 200,
                                  MNIST_SPEC.input_shape, 10)
    rng = np.random.default_rng(0)
    bx = np.stack([np.asarray(ds.x)[rng.choice(200, (2, 8))] for _ in range(8)])
    by = np.stack([np.asarray(ds.y)[rng.choice(200, (2, 8))] for _ in range(8)])
    batches = {"x": jnp.asarray(bx), "y": jnp.asarray(by)}
    params = model.init(jax.random.PRNGKey(0))
    s0 = fl_init(params, 8)
    key = jax.random.PRNGKey(7)
    rf_v = make_fl_round(model.loss, comp, cfg, mesh=mesh, **kw)
    rf_s = make_fl_round(model.loss, comp, cfg, client_parallel="shard_map",
                         mesh=mesh, **kw)
    s1, _ = jax.jit(rf_v)(s0, batches, key)
    s2, _ = jax.jit(rf_s)(s0, batches, key)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-5)
    print("ok fused")


def scenario_ef_donation():
    import jax
    import numpy as np

    from repro.fl.sharding import make_fl_shardings

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    sh = make_fl_shardings(mesh)
    params, engine, CompressorConfig = _world()
    eng, state = engine(CompressorConfig(kind="identity",
                                         error_feedback=False),
                        sh, "shard_map", mesh)

    def ef_spec(st):
        leaf = jax.tree_util.tree_leaves(st.ef)[0]
        return leaf.sharding.spec, leaf.sharding

    spec0, sharding0 = ef_spec(state)
    assert sharding0 == sh.client, (spec0, sh.client.spec)
    # each device owns exactly its N/8 clients' residual slice
    shards = jax.tree_util.tree_leaves(state.ef)[0].addressable_shards
    assert len(shards) == 8
    assert all(s.data.shape[0] == 1 for s in shards), \
        [s.data.shape for s in shards]

    old_leaves = jax.tree_util.tree_leaves((state.params, state.ef))
    state2, _ = eng.run_block(state, 2)
    donated = [l.is_deleted() for l in old_leaves]
    assert any(donated) and all(donated), \
        "donation must consume the whole sharded FLState"
    spec2, sharding2 = ef_spec(state2)
    assert sharding2 == sh.client, \
        f"EF gathered off the client axis after donation: {spec2}"
    # caller's params (deep-copied at init) survive
    for l in jax.tree_util.tree_leaves(params):
        assert not l.is_deleted()
    # second block: the donated round-trip keeps working, spec still pinned
    state3, ms = eng.run_block(state2, 2)
    assert np.isfinite(np.asarray(ms.loss)).all()
    _, sharding3 = ef_spec(state3)
    assert sharding3 == sh.client
    assert int(state3.round) == 4
    print("ok ef_donation")


def scenario_sharding_units():
    import jax
    import jax.numpy as jnp
    import numpy as np
    import pytest as _pytest

    from repro.fl.engine import ClientPools
    from repro.fl.round import FLState, fl_init
    from repro.fl.sharding import make_fl_shardings
    from repro.launch.mesh import client_axes, make_host_mesh

    mesh = make_host_mesh()
    assert mesh.devices.shape == (8, 1)
    sh = make_fl_shardings(mesh)
    assert sh.axes == client_axes(mesh) == ("data",)
    assert sh.client_shards == 8
    assert sh.replicated.spec == jax.sharding.PartitionSpec()

    with _pytest.raises(ValueError, match="not divisible"):
        sh.check_divisible(10)

    # placement: params replicated, EF leading-axis split 8 ways
    params = {"w": jnp.ones((16, 4)), "b": jnp.ones((4,))}
    state = sh.place_state(fl_init(params, 16))
    assert state.params["w"].sharding.is_fully_replicated
    efs = state.ef["w"].addressable_shards
    assert len(efs) == 8 and all(s.data.shape == (2, 16, 4) for s in efs)

    pools = sh.place_pools(ClientPools(jnp.zeros((16, 5), jnp.int32),
                                       jnp.ones((16,), jnp.int32)))
    assert all(s.data.shape == (2, 5)
               for s in pools.index.addressable_shards)

    # in-jit constraint pins a traced client tree to the same sharding
    @jax.jit
    def f(x):
        return sh.constrain_client_tree({"x": x})["x"] * 2

    out = f(jnp.ones((16, 3)))
    assert out.sharding == sh.client

    # make_host_mesh divisibility guard
    with _pytest.raises(ValueError, match="n % model"):
        make_host_mesh(model=3)
    print("ok sharding_units")


def scenario_wire():
    import jax
    import numpy as np

    from repro.fl.sharding import make_fl_shardings

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    sh = make_fl_shardings(mesh)
    _, engine, CompressorConfig = _world()

    shared = ("loss", "cosine", "payload_floats", "update_norm")

    # topk: the codec is lossless, so shard_map wire mode must be bitwise
    # the vmap float oracle — transport AND serialization fully transparent
    ccfg = CompressorConfig(kind="topk", keep_ratio=0.05)
    ev, stv = engine(ccfg)
    sv, mv = ev.run_block(stv, 3)
    es, sts = engine(ccfg, sh, "shard_map", mesh, wire="codec")
    ss, ms = es.run_block(sts, 3)
    _tree_equal(sv.params, ss.params, "topk wire params")
    _tree_equal(sv.ef, ss.ef, "topk wire ef")
    for f in shared:
        np.testing.assert_array_equal(
            np.asarray(getattr(mv, f)), np.asarray(getattr(ms, f)),
            err_msg=f"topk wire metric {f} not bit-exact")
    assert float(np.asarray(ms.wire_bytes_up)[0]) > 0
    print("ok topk")

    # signsgd: the 1-bit wire diverges from the 3-valued float sign on exact
    # zeros (documented), but must be fan-out-transparent: shard_map wire
    # mode bitwise equals vmap wire mode
    ccfg = CompressorConfig(kind="signsgd")
    ev, stv = engine(ccfg, wire="codec")
    sv, mv = ev.run_block(stv, 3)
    es, sts = engine(ccfg, sh, "shard_map", mesh, wire="codec")
    ss, ms = es.run_block(sts, 3)
    _tree_equal(sv.params, ss.params, "signsgd wire params")
    _tree_equal(sv.ef, ss.ef, "signsgd wire ef")
    for f in shared:
        np.testing.assert_array_equal(
            np.asarray(getattr(mv, f)), np.asarray(getattr(ms, f)),
            err_msg=f"signsgd wire metric {f} not bit-exact")
    print("ok signsgd")

    # threesfc: serialized (D_syn, s) frames cross the boundary; the server
    # decode recompute is vmap-width-sensitive (like the fused path), so the
    # 8-way mesh is pinned to the established 1e-5 tolerance
    ccfg = CompressorConfig(kind="threesfc", syn_steps=2, syn_lr=0.1)
    ev, stv = engine(ccfg)
    sv, _ = ev.run_block(stv, 3)
    es, sts = engine(ccfg, sh, "shard_map", mesh, wire="codec")
    ss, _ = es.run_block(sts, 3)
    for a, b in zip(jax.tree_util.tree_leaves(sv.params),
                    jax.tree_util.tree_leaves(ss.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-5)
    print("ok threesfc")


def scenario_faults():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import encode_region_collectives
    from repro.comm import make_codec
    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig
    from repro.core.strategy import make_strategy
    from repro.fl import faults as F
    from repro.fl.round import build_fl_round, fl_init
    from repro.fl.sharding import make_fl_shardings
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import VisionSpec, make_paper_model

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    sh = make_fl_shardings(mesh)
    N, K, B = 8, 1, 8
    SPEC = VisionSpec("tiny", (4, 4, 1), 3)
    model = make_paper_model("mlp", SPEC)
    params = model.init(jax.random.PRNGKey(0))
    batches = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (N, K, B, 4, 4, 1)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (N, K, B), 0, 3),
    }
    key = jax.random.PRNGKey(5)

    def build(kind, wire, fused, parallel="shard_map", sched_fn=None, **rkw):
        ccfg = CompressorConfig(kind=kind, keep_ratio=0.2, syn_steps=2,
                                syn_lr=0.1,
                                error_feedback=kind != "identity")
        spec = vision_syn_spec(SPEC, ccfg)
        strat = make_strategy(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                              local_lr=0.05)
        cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                       local_batch=B, compressor=ccfg)
        run = RunConfig(fl=cfg, wire=wire, fused_decode=fused,
                        client_parallel=parallel,
                        mesh=mesh if parallel == "shard_map" else None, **rkw)
        codec = make_codec(ccfg, params, syn_spec=spec,
                           syn_loss_fn=model.syn_loss) \
            if wire == "codec" else None
        rf = build_fl_round(model.loss, strat, run, codec=codec,
                            fault_schedule_fn=sched_fn)
        return jax.jit(rf), strat

    def run2(rf):
        st = fl_init(params, N)
        for r in range(2):
            st, m = rf(st, batches, jax.random.fold_in(key, r))
        return st, m

    # 1) zero-fault bitwise on the sharded fan-out: every combo of the
    #    shard_map half of the matrix, masked-with-null vs plain
    ALL = ("identity", "topk", "randk", "signsgd", "stc", "threesfc",
           "fedsynth")
    CODEC = ("identity", "topk", "signsgd", "stc", "threesfc")
    combos = ([(k, "float", False) for k in ALL]
              + [(k, "codec", False) for k in CODEC]
              + [("threesfc", "float", True), ("threesfc", "codec", True)])
    for kind, wire, fused in combos:
        rf, _ = build(kind, wire, fused)
        rfn, _ = build(kind, wire, fused,
                       sched_fn=lambda r, n: F.null_schedule(n))
        sa, ma = run2(rf)
        sb, mb = run2(rfn)
        tag = f"{kind}/{wire}{'/fused' if fused else ''}"
        if fused:
            # the all-ones payload weight shifts XLA's fusion of the
            # gathered batched backward — the same width-sensitive
            # batched-dot lowering already pinned at 1e-5 for fused/8-way
            # threesfc above (observed ~5e-10 absolute); vmap fused is
            # bitwise (tests/test_faults.py)
            for a, b in zip(jax.tree_util.tree_leaves((sa.params, sa.ef)),
                            jax.tree_util.tree_leaves((sb.params, sb.ef))):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=0, atol=1e-5,
                                           err_msg=f"{tag} state")
        else:
            _tree_equal(sa.params, sb.params, f"{tag} shard_map params")
            _tree_equal(sa.ef, sb.ef, f"{tag} shard_map ef")
        # the scalar loss metric is reduced across devices and XLA may
        # reassociate the 8-way reduction differently between the two
        # programs (observed 1 ulp) — the vmap half of the matrix pins
        # the metrics bitwise
        np.testing.assert_allclose(np.asarray(ma.loss), np.asarray(mb.loss),
                                   rtol=0, atol=1e-6,
                                   err_msg=f"{tag} loss")
        assert float(mb.arrivals) == float(N)
        print(f"ok null {tag}")

    # 2) mask fan-out transparency: a real dropout pattern produces the
    #    same state on vmap and shard_map and drops the same clients.
    #    With a non-trivial mask the N/cnt renormalized aggregation is no
    #    longer the exact all-true mean identity, so the 8-way psum may
    #    reassociate it differently from vmap's single-program reduction
    #    (observed 1 ulp, ~4e-11 absolute) — pin at 1e-6 like the loss
    fkw = dict(participation_rate=0.75, drop_rate=0.5, fault_seed=7)
    rf_v, _ = build("topk", "float", False, parallel="vmap", **fkw)
    rf_s, _ = build("topk", "float", False, **fkw)
    sv, mv = run2(rf_v)
    ss, ms = run2(rf_s)
    for a, b in zip(jax.tree_util.tree_leaves((sv.params, sv.ef)),
                    jax.tree_util.tree_leaves((ss.params, ss.ef))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6,
                                   err_msg="faulted vmap-vs-shard_map state")
    np.testing.assert_array_equal(np.asarray(mv.arrivals),
                                  np.asarray(ms.arrivals))
    assert float(ms.arrivals) < float(N)   # the pattern actually dropped
    print("ok fault transparency")

    # 3) HLO gate: the participation/delivery masks ride the client axis —
    #    ZERO collectives inside the per-client encode region
    ccfg = CompressorConfig(kind="topk", keep_ratio=0.2)
    spec = vision_syn_spec(SPEC, ccfg)
    strat = make_strategy(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                          local_lr=0.05)
    cfg = FLConfig(num_clients=N, local_steps=K, local_lr=0.05,
                   local_batch=B, compressor=ccfg)
    run = RunConfig(fl=cfg, client_parallel="shard_map", mesh=mesh, **fkw)
    rf = build_fl_round(model.loss, strat, run)
    abstract = {
        "x": jax.ShapeDtypeStruct((N, K, B, 4, 4, 1), jnp.float32),
        "y": jax.ShapeDtypeStruct((N, K, B), jnp.int32),
    }
    compiled = jax.jit(
        rf,
        in_shardings=(sh.state, sh.client, sh.replicated),
        out_shardings=(sh.state, sh.replicated),
    ).lower(fl_init(params, N), abstract,
            jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
    # the scope filter is the analysis contract's, defined once
    scoped = encode_region_collectives(compiled.as_text())
    assert not scoped, \
        f"faulted client encode region grew collectives: {scoped}"
    print("ok hlo gate")


SCENARIOS = {
    "bitexact": scenario_bitexact,
    "ef_donation": scenario_ef_donation,
    "sharding_units": scenario_sharding_units,
    "wire": scenario_wire,
    "faults": scenario_faults,
}


if __name__ == "__main__":
    import sys

    SCENARIOS[sys.argv[1]]()
