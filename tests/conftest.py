import os
import sys

import pytest

# tests must see 1 CPU device (the 512-device flag is dryrun-only)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

sys.path.insert(0, REPO)  # benchmarks/

from benchmarks.bench_collectives import multidev_env  # noqa: E402


def run_multidev(args, timeout=1200):
    """Run ``python <args...>`` in a child process that sees 8 host CPU
    devices. The device count is locked at first jax init, so multi-device
    sharding tests cannot run in the (single-device) pytest process itself —
    they run their scenario in a subprocess and assert on its exit status.
    The environment recipe is shared with benchmarks/bench_collectives.
    """
    import subprocess
    return subprocess.run([sys.executable] + list(args), env=multidev_env(),
                          cwd=REPO, capture_output=True, text=True,
                          timeout=timeout)


@pytest.fixture(scope="session")
def multidev_scenario():
    """Session fixture running one child scenario (``__main__`` entry of
    ``file``, default tests/test_shard_round.py) on 8 forced host devices
    and asserting it exits clean."""

    def run_scenario(scenario, timeout=1200, file="tests/test_shard_round.py"):
        p = run_multidev([file, scenario], timeout)
        assert p.returncode == 0, (
            f"scenario {scenario!r} failed (exit {p.returncode})\n"
            f"--- stdout ---\n{p.stdout}\n--- stderr ---\n{p.stderr}")

    return run_scenario
