import os
import sys

# tests must see 1 CPU device (the 512-device flag is dryrun-only)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/
