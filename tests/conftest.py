import os
import signal
import sys

import pytest

# tests must see 1 CPU device (the 512-device flag is dryrun-only)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

sys.path.insert(0, REPO)  # benchmarks/

from benchmarks.bench_collectives import multidev_env  # noqa: E402


def run_multidev(args, timeout=1200):
    """Run ``python <args...>`` in a child process that sees 8 host CPU
    devices. The device count is locked at first jax init, so multi-device
    sharding tests cannot run in the (single-device) pytest process itself —
    they run their scenario in a subprocess and assert on its exit status.
    The environment recipe is shared with benchmarks/bench_collectives.
    """
    import subprocess
    return subprocess.run([sys.executable] + list(args), env=multidev_env(),
                          cwd=REPO, capture_output=True, text=True,
                          timeout=timeout)


@pytest.fixture(scope="session")
def multidev_scenario():
    """Session fixture running one child scenario (``__main__`` entry of
    ``file``, default tests/test_shard_round.py) on 8 forced host devices
    and asserting it exits clean."""

    def run_scenario(scenario, timeout=1200, file="tests/test_shard_round.py"):
        p = run_multidev([file, scenario], timeout)
        assert p.returncode == 0, (
            f"scenario {scenario!r} failed (exit {p.returncode})\n"
            f"--- stdout ---\n{p.stdout}\n--- stderr ---\n{p.stderr}")

    return run_scenario


# ---------------------------------------------------------------------------
# transport marker: live-socket tests get a hard wall-clock ceiling
# ---------------------------------------------------------------------------

TRANSPORT_TIMEOUT_S = 120


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "transport: live socket-transport test (real sockets / subprocess "
        "workers); armed with a hard SIGALRM timeout (default "
        f"{TRANSPORT_TIMEOUT_S}s, override per-test with timeout=<s>) so a "
        "hung wire fails loudly instead of hanging the suite")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    m = item.get_closest_marker("transport")
    if m is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    limit = int(m.kwargs.get("timeout", TRANSPORT_TIMEOUT_S))

    def _alarm(signum, frame):
        raise TimeoutError(
            f"transport test exceeded the hard {limit}s timeout — a socket "
            f"or worker subprocess is hung (the transport's own deadlines "
            f"should have fired long before this)")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(limit)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
