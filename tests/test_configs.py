"""Config registry: all 10 assigned archs resolve with the exact assigned
hyperparameters; smoke variants respect the reduction contract."""
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config, get_smoke_config

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment table
ASSIGNED = {
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
    "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source, "every config cites its source"


def test_moe_configs():
    q = get_config("qwen3-moe-30b-a3b")
    assert q.num_experts == 128 and q.experts_per_token == 8
    m = get_config("moonshot-v1-16b-a3b")
    assert m.num_experts == 64 and m.experts_per_token == 6
    l = get_config("llama4-scout-17b-a16e")
    assert l.num_experts == 16 and l.experts_per_token == 1


def test_family_specifics():
    assert get_config("mamba2-370m").ssm_state == 128
    assert get_config("mamba2-370m").block_pattern == ("ssm",)
    assert get_config("recurrentgemma-2b").block_pattern == ("rec", "rec", "attn")
    assert get_config("seamless-m4t-medium").enc_layers == 12
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("internvl2-1b").qkv_bias
    assert get_config("internvl2-1b").modality == "vision"
    assert get_config("seamless-m4t-medium").modality == "audio"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduction_contract(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 5
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1
