"""Socket transport: framing primitives, deadline/retry/liveness semantics
against fake raw-socket workers, and a seeded end-to-end multi-process round
gated bitwise against the in-process oracle.

Everything that opens real sockets or subprocesses carries
``@pytest.mark.transport``: ``conftest`` arms those tests with a hard
SIGALRM ceiling, so "the server never hangs on a dead peer" is itself
enforced — a hang fails the test, it cannot stall the suite.
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.comm.frame import FrameSpec, encode_header
from repro.comm.transport import (MAX_MSG, MSG_FRAME, MSG_HEARTBEAT,
                                  MSG_HELLO, MSG_RESEND, MSG_ROUND,
                                  ProtocolError, SocketServer, recv_msg,
                                  send_msg)
from repro.fl.engine import RetryPolicy

_SPEC = FrameSpec("identity", "fp32", (8,))


def _codec_frame(round_idx=0, client_idx=0) -> np.ndarray:
    head = np.asarray(encode_header(_SPEC, round_idx, client_idx))
    return np.concatenate([head, np.arange(8, dtype=np.uint8)])


# ---------------------------------------------------------------------------
# framing primitives (socketpair: no listener, cannot hang)
# ---------------------------------------------------------------------------


def test_msg_roundtrip_including_zero_length_body():
    a, b = socket.socketpair()
    try:
        # zero-length frame: a heartbeat is 5 bytes of header, 0 of body
        n = send_msg(a, MSG_HEARTBEAT)
        assert n == 5
        assert recv_msg(b) == (MSG_HEARTBEAT, b"")
        # ndarray bodies serialize as their raw bytes
        payload = np.arange(32, dtype=np.uint8)
        n = send_msg(a, MSG_FRAME, payload)
        assert n == 5 + 32
        mtype, body = recv_msg(b)
        assert mtype == MSG_FRAME
        np.testing.assert_array_equal(np.frombuffer(body, np.uint8), payload)
        # explicit zero-length data frame round-trips too
        send_msg(a, MSG_FRAME, b"")
        assert recv_msg(b) == (MSG_FRAME, b"")
    finally:
        a.close()
        b.close()


def test_partial_read_at_length_prefix_boundary_is_connection_error():
    # peer dies mid-prefix: 3 of the 5 header bytes, then EOF
    a, b = socket.socketpair()
    a.sendall(struct.pack("<IB", 100, MSG_FRAME)[:3])
    a.close()
    with pytest.raises(ConnectionError):
        recv_msg(b)
    b.close()
    # peer dies mid-body: full prefix promising 100 B, 10 B delivered
    a, b = socket.socketpair()
    a.sendall(struct.pack("<IB", 100, MSG_FRAME) + b"x" * 10)
    a.close()
    with pytest.raises(ConnectionError):
        recv_msg(b)
    b.close()


def test_insane_length_prefix_is_protocol_error():
    a, b = socket.socketpair()
    a.sendall(struct.pack("<IB", MAX_MSG + 1, MSG_FRAME))
    with pytest.raises(ProtocolError):
        recv_msg(b)
    a.close()
    b.close()


def test_retry_policy_backoff_schedule():
    pol = RetryPolicy(max_retries=3, recv_timeout_s=1.0, recv_backoff=2.0,
                      max_timeout_s=5.0)
    # exponential per attempt, capped at max_timeout_s
    assert [pol.timeout(a) for a in range(4)] == [1.0, 2.0, 4.0, 5.0]
    flat = RetryPolicy(max_retries=2, recv_timeout_s=0.5, recv_backoff=1.0,
                       max_timeout_s=10.0)
    assert [flat.timeout(a) for a in range(3)] == [0.5, 0.5, 0.5]


# ---------------------------------------------------------------------------
# server semantics against fake raw-socket workers
# ---------------------------------------------------------------------------


def _fake_worker(server, cid):
    sock = socket.create_connection(server.address, timeout=10)
    send_msg(sock, MSG_HELLO, struct.pack("<I", cid))
    return sock


@pytest.mark.transport
def test_corrupt_frames_exhaust_retries_then_dropped():
    """A worker that answers every (re)send with garbage burns exactly
    ``max_retries`` RESENDs, is marked undelivered, and every garbage
    frame is still billed — the bytes crossed the wire."""
    server = SocketServer(1, heartbeat_s=0.5, liveness_timeout_s=60.0)
    sock = _fake_worker(server, 0)
    stop = threading.Event()
    resends = []

    def worker():
        while not stop.is_set():
            try:
                mtype, body = recv_msg(sock)
            except (ConnectionError, OSError):
                return
            if mtype == MSG_RESEND:
                resends.append(struct.unpack("<I", body)[0])
            if mtype in (MSG_ROUND, MSG_RESEND):
                send_msg(sock, MSG_FRAME, b"\x00" * 64)   # never parses

    t = threading.Thread(target=worker, daemon=True)
    try:
        server.wait_ready(10)
        t.start()
        r = server.begin_round()
        server.broadcast_round(r, np.zeros((16,), np.uint8))
        pol = RetryPolicy(max_retries=2, recv_timeout_s=0.5,
                          recv_backoff=1.0, max_timeout_s=1.0)
        t0 = time.monotonic()
        rep = server.collect(r, [True], policy=pol, deadline_s=20.0)
        wall = time.monotonic() - t0
        assert not rep.delivered[0] and rep.frames[0] is None
        assert rep.retries == 2 and resends == [r, r]
        assert wall < 10.0                     # gave up, did not sit on the
        assert server.uplink.per_round[-1] >= 64  # deadline; garbage billed
    finally:
        stop.set()
        server.stop()
        sock.close()


@pytest.mark.transport
def test_worker_killed_mid_frame_maps_to_dropped_never_hangs():
    """A peer that dies halfway through a frame (length prefix promised
    4096 B, 100 arrived) becomes delivered=False within the dead-sweep,
    NOT a hang until the deadline."""
    server = SocketServer(1, heartbeat_s=0.5, liveness_timeout_s=60.0)
    sock = _fake_worker(server, 0)

    def worker():
        try:
            mtype, _ = recv_msg(sock)
            assert mtype == MSG_ROUND
            sock.sendall(struct.pack("<IB", 4096, MSG_FRAME) + b"y" * 100)
            sock.close()                       # SIGKILL from the wire's view
        except (ConnectionError, OSError):
            pass

    t = threading.Thread(target=worker, daemon=True)
    try:
        server.wait_ready(10)
        t.start()
        r = server.begin_round()
        server.broadcast_round(r, np.zeros((16,), np.uint8))
        pol = RetryPolicy(max_retries=5, recv_timeout_s=10.0,
                          max_timeout_s=10.0)
        t0 = time.monotonic()
        rep = server.collect(r, [True], policy=pol, deadline_s=60.0)
        wall = time.monotonic() - t0
        assert not rep.delivered[0]
        assert wall < 10.0                     # death sentinel, not deadline
        assert server.live_workers() == []
    finally:
        server.stop()


@pytest.mark.transport
def test_stale_frame_is_billed_then_discarded():
    """A frame carrying last round's header is billed (the bytes moved)
    but never counted delivered; the retry timer then recovers the real
    frame."""
    server = SocketServer(1, heartbeat_s=0.5, liveness_timeout_s=60.0)
    sock = _fake_worker(server, 0)
    stale = _codec_frame(round_idx=0, client_idx=0)
    sent = {"n": 0}

    def worker():
        while True:
            try:
                mtype, _ = recv_msg(sock)
            except (ConnectionError, OSError):
                return
            if mtype == MSG_ROUND:
                sent["n"] += 1
                send_msg(sock, MSG_FRAME, stale)          # wrong round
            elif mtype == MSG_RESEND:
                sent["n"] += 1
                send_msg(sock, MSG_FRAME, _codec_frame(1, 0))  # the real one

    t = threading.Thread(target=worker, daemon=True)
    try:
        server.wait_ready(10)
        t.start()
        assert server.begin_round() == 0      # round 0 exists but is skipped
        r = server.begin_round()
        assert r == 1
        server.broadcast_round(r, np.zeros((16,), np.uint8))
        pol = RetryPolicy(max_retries=2, recv_timeout_s=0.5,
                          recv_backoff=1.0, max_timeout_s=1.0)
        rep = server.collect(r, [True], policy=pol, deadline_s=20.0)
        assert rep.delivered[0] and rep.retries == 1 and sent["n"] == 2
        hdr_bytes = server.uplink.per_round[-1]
        assert hdr_bytes == 2 * stale.nbytes  # stale + good, both billed
    finally:
        server.stop()
        sock.close()


# ---------------------------------------------------------------------------
# seeded end-to-end: real worker subprocesses vs the in-process oracle
# ---------------------------------------------------------------------------


@pytest.mark.transport(timeout=300)
def test_live_socket_round_bitwise_equals_inprocess_oracle():
    """Two real worker subprocesses drive a round over the socket; params,
    per-client EF, and per-round billing must be bitwise what the
    in-process vmapped oracle computes from the same seed."""
    import jax
    import jax.numpy as jnp

    from repro.comm.transport import spawn_local_workers
    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig
    from repro.core.strategy import make_strategy
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.engine import (LiveRoundLoop, RoundEngine, device_pools,
                                 vision_batcher)
    from repro.fl.faults import null_schedule
    from repro.fl.round import build_fl_round
    from repro.launch.worker import vision_setup
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import VisionSpec, make_paper_model

    N, R, train_n = 2, 2, 96
    spec = VisionSpec("tiny", (6, 6, 1), 3)
    comp = CompressorConfig(kind="stc", keep_ratio=0.1)
    fl = FLConfig(num_clients=N, local_steps=2, local_lr=0.05,
                  local_batch=4, compressor=comp, seed=0)
    run = RunConfig(fl=fl, wire="codec", transport="socket",
                    round_deadline_s=60.0, recv_timeout_s=30.0,
                    transport_retries=0, heartbeat_s=0.2,
                    liveness_timeout_s=5.0)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(fl.seed))
    strategy = make_strategy(comp, loss_fn=model.syn_loss,
                             syn_spec=vision_syn_spec(spec, comp),
                             local_lr=fl.local_lr)
    codec = strategy.wire_codec(params, policy=run.wire_policy)

    train = make_class_image_dataset(jax.random.PRNGKey(fl.seed), train_n,
                                     spec.input_shape, spec.num_classes)
    parts = dirichlet_partition(train.y, N, alpha=fl.dirichlet_alpha,
                                seed=fl.seed, min_per_client=fl.local_batch)
    pools = device_pools(parts)
    engine = RoundEngine(
        build_fl_round(model.loss, strategy, RunConfig(fl=fl, wire="codec"),
                       codec=codec,
                       fault_schedule_fn=lambda r, n: null_schedule(n)),
        vision_batcher(train.x, train.y, pools, fl.local_steps,
                       fl.local_batch),
        seed=fl.seed)
    state = engine.init_state(params, N, strategy)
    state, _ = engine.run_loop(state, R)
    oracle_params, oracle_ef = jax.device_get((state.params, state.ef))

    server = SocketServer(N, heartbeat_s=run.heartbeat_s,
                          liveness_timeout_s=run.liveness_timeout_s)
    procs = spawn_local_workers(server.address, range(N))
    try:
        server.wait_ready(60)
        server.send_setup(vision_setup(run, model="mlp", spec=spec,
                                       train_size=train_n))
        loop = LiveRoundLoop(server, strategy, codec, run, params)
        # round 0 compiles inside the workers: generous window, no resends
        warm = RetryPolicy(max_retries=0, recv_timeout_s=240.0,
                           max_timeout_s=240.0)
        loop.run(1, deadline_s=240.0, policy=warm)
        live_params = jax.device_get(loop.run(R - 1))
        efs = [server.request_ef(i, timeout=30) for i in range(N)]
    finally:
        server.stop()
        for p in procs:
            try:
                p.wait(timeout=15)
            except Exception:
                p.kill()

    def ravel(t):
        return np.concatenate([np.asarray(l, np.float32).ravel()
                               for l in jax.tree_util.tree_leaves(t)])

    assert all(rec["delivered"].all() for rec in loop.history)
    np.testing.assert_array_equal(ravel(oracle_params), ravel(live_params))
    for i in range(N):
        oe = np.concatenate([np.asarray(l[i], np.float32).ravel()
                             for l in jax.tree_util.tree_leaves(oracle_ef)])
        assert efs[i] is not None
        np.testing.assert_array_equal(efs[i], oe)
    # the settled round billed exactly the codec bytes — headers, ACKs and
    # heartbeats live in the overhead buckets, not the data-plane stats
    assert loop.history[1]["bytes_up"] == N * codec.nbytes
    assert server.overhead_up > 0 and server.overhead_down > 0
