"""End-to-end FL training: 20 non-iid clients, 3SFC at 250x compression,
a few hundred rounds of MLP training with live accuracy.

    PYTHONPATH=src python examples/fl_training.py [--rounds 200]

This is the end-to-end driver deliverable (examples category b): the full
stack — data synthesis, Dirichlet partition, vmapped clients, EF-compressed
uplink, server aggregation, eval, checkpointing.
"""
import argparse

from repro.launch.train import main as train_main
import sys


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--compressor", default="threesfc")
    args = ap.parse_args()
    sys.argv = ["train", "--model", "mlp", "--dataset", "mnist",
                "--compressor", args.compressor,
                "--rounds", str(args.rounds), "--clients", str(args.clients),
                "--eval-every", "10", "--out", "experiments/example_fl_run"]
    train_main()
