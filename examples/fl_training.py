"""End-to-end FL training: 20 non-iid clients, 3SFC at 250x compression,
a few hundred rounds of MLP training with live accuracy.

    PYTHONPATH=src python examples/fl_training.py [--rounds 200] [--wire codec]

This is the end-to-end driver deliverable (examples category b): the full
stack — data synthesis, Dirichlet partition, vmapped clients, EF-compressed
uplink (serialized uint8 frames with ``--wire codec``), server aggregation,
eval, checkpointing — driven through ``repro.launch.train``'s
``RunConfig``-based CLI.
"""
import argparse

from repro.launch.train import main as train_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--compressor", default="threesfc")
    ap.add_argument("--wire", default="float", choices=["float", "codec"])
    ap.add_argument("--train-size", type=int, default=4000)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--out", default="experiments/example_fl_run")
    args = ap.parse_args(argv)
    train_main(["--model", "mlp", "--dataset", "mnist",
                "--compressor", args.compressor, "--wire", args.wire,
                "--rounds", str(args.rounds), "--clients", str(args.clients),
                "--train-size", str(args.train_size),
                "--batch", str(args.batch),
                "--eval-every", str(args.eval_every), "--out", args.out])


if __name__ == "__main__":
    main()
