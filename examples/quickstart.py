"""Quickstart: compress one federated update with 3SFC and decode it back.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper end to end on one client: train locally for K steps, encode
the accumulated update into ONE synthetic sample + one scalar (795+1 floats
against 199,210 gradient entries -> the paper's 250x ratio), ship it, decode
on the server with one backward pass, apply.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CompressorConfig
from repro.core import baselines, flat, threesfc
from repro.data.synthetic import make_class_image_dataset
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, accuracy, make_paper_model

key = jax.random.PRNGKey(0)
model = make_paper_model("mlp", MNIST_SPEC)          # 199,210 params (paper Fig. 1)
w_global = model.init(key)
ds = make_class_image_dataset(jax.random.PRNGKey(1), 512, (28, 28, 1), 10)

# --- client: K=5 local SGD steps --------------------------------------------
w = w_global
for i in range(5):
    batch = {"x": jnp.asarray(ds.x[i * 64:(i + 1) * 64]),
             "y": jnp.asarray(ds.y[i * 64:(i + 1) * 64])}
    g = jax.grad(model.loss)(w, batch)
    w = jax.tree.map(lambda p, gr: p - 0.05 * gr, w, g)
g_accum = flat.tree_sub(w_global, w)                 # g = w^t - w_i^t (Eq. 3)

# --- client: 3SFC encode (Eq. 8/9) ------------------------------------------
comp = CompressorConfig(kind="threesfc", syn_batch=1, syn_steps=10, syn_lr=0.1)
spec = vision_syn_spec(MNIST_SPEC, comp)
syn0 = threesfc.init_syn(jax.random.PRNGKey(2), spec)
enc = threesfc.encode(model.syn_loss, w_global, g_accum, syn0,
                      steps=comp.syn_steps, lr=comp.syn_lr)
d = flat.tree_size(w_global)
print(f"uplink payload: {spec.floats + 1:.0f} floats vs {d:,} gradient entries "
      f"-> {(d / (spec.floats + 1)):.1f}x compression (paper: 250.6x)")
print(f"compression efficiency (cosine, paper Fig. 7 metric): "
      f"{float(enc.cosine):+.3f}")

# --- server: decode (Eq. 10) + update ----------------------------------------
recon = threesfc.decode(model.syn_loss, w_global, enc.syn, enc.s)
err = flat.tree_norm(flat.tree_sub(recon, enc.recon))
print(f"server decode == client recon: L2 diff {float(err):.2e} (exactness)")
fl = flat.Flattener(w_global)
fcos, frel = baselines.reconstruction_stats(fl.flatten(g_accum), fl.flatten(recon))
print(f"reconstruction fidelity vs true update: cos {float(fcos):+.3f}, "
      f"rel L2 err {float(frel):.3f}")
w_next = jax.tree.map(lambda p, u: p - u, w_global, recon)

te = make_class_image_dataset(jax.random.PRNGKey(3), 400, (28, 28, 1), 10)
a0 = accuracy(model.apply(w_global, jnp.asarray(te.x)), jnp.asarray(te.y))
a1 = accuracy(model.apply(w_next, jnp.asarray(te.x)), jnp.asarray(te.y))
print(f"test acc before {float(a0):.3f} -> after 1 compressed round {float(a1):.3f}")
