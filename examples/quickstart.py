"""Quickstart: compress one federated update with 3SFC and decode it back.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper end to end on one client through the ``CompressionStrategy``
API (``repro.core.strategy``): train locally for K steps, encode the
accumulated update into ONE synthetic sample + one scalar (795+1 floats
against 199,210 gradient entries -> the paper's 250x ratio), serialize it
into the method's wire frame, decode on the server with one backward pass,
apply. Swapping ``kind="threesfc"`` for any registered kind
(``strategy_kinds()``) swaps the whole method — encoder, decoder, codec and
accounting travel together on the strategy object.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import CompressorConfig
from repro.core import baselines, flat
from repro.core.strategy import make_strategy
from repro.data.synthetic import make_class_image_dataset
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, accuracy, make_paper_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-size", type=int, default=512)
    ap.add_argument("--test-size", type=int, default=400)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--syn-steps", type=int, default=10)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    model = make_paper_model("mlp", MNIST_SPEC)   # 199,210 params (paper Fig. 1)
    w_global = model.init(key)
    ds = make_class_image_dataset(jax.random.PRNGKey(1), args.train_size,
                                  (28, 28, 1), 10)

    # --- client: K local SGD steps ------------------------------------------
    w = w_global
    for i in range(args.local_steps):
        lo, hi = i * args.batch, (i + 1) * args.batch
        batch = {"x": jnp.asarray(ds.x[lo:hi]), "y": jnp.asarray(ds.y[lo:hi])}
        g = jax.grad(model.loss)(w, batch)
        w = jax.tree.map(lambda p, gr: p - 0.05 * gr, w, g)
    g_accum = flat.tree_sub(w_global, w)             # g = w^t - w_i^t (Eq. 3)

    # --- client: 3SFC encode (Eq. 8/9) via the registered strategy ----------
    comp = CompressorConfig(kind="threesfc", syn_batch=1,
                            syn_steps=args.syn_steps, syn_lr=0.1)
    spec = vision_syn_spec(MNIST_SPEC, comp)
    strategy = make_strategy(comp, loss_fn=model.syn_loss, syn_spec=spec)
    enc = strategy.client_encode(jax.random.PRNGKey(2), g_accum, w_global)
    d = flat.tree_size(w_global)
    payload = strategy.payload_floats(w_global)
    print(f"uplink payload: {payload:.0f} floats vs {d:,} gradient entries "
          f"-> {d / payload:.1f}x compression (paper: 250.6x)")
    print(f"compression efficiency (cosine, paper Fig. 7 metric): "
          f"{float(enc.cosine):+.3f}")

    # --- the wire: the strategy's codec serializes the (D_syn, s) payload ---
    codec = strategy.wire_codec(w_global)
    buf = codec.encode(enc.wire)
    print(f"serialized uplink frame: {codec.nbytes} bytes "
          f"({codec.nbytes - codec.header_bytes} payload + "
          f"{codec.header_bytes} header)")

    # --- server: decode the framed payload (Eq. 10) + update ----------------
    recon = strategy.server_decode(codec.decode(buf), w_global)
    err = flat.tree_norm(flat.tree_sub(recon, enc.recon))
    print(f"server decode == client recon: L2 diff {float(err):.2e} "
          f"(exactness)")
    fl = flat.Flattener(w_global)
    fcos, frel = baselines.reconstruction_stats(fl.flatten(g_accum),
                                                fl.flatten(recon))
    print(f"reconstruction fidelity vs true update: cos {float(fcos):+.3f}, "
          f"rel L2 err {float(frel):.3f}")
    w_next = jax.tree.map(lambda p, u: p - u, w_global, recon)

    te = make_class_image_dataset(jax.random.PRNGKey(3), args.test_size,
                                  (28, 28, 1), 10)
    a0 = accuracy(model.apply(w_global, jnp.asarray(te.x)), jnp.asarray(te.y))
    a1 = accuracy(model.apply(w_next, jnp.asarray(te.x)), jnp.asarray(te.y))
    print(f"test acc before {float(a0):.3f} -> after 1 compressed round "
          f"{float(a1):.3f}")
    return float(err)


if __name__ == "__main__":
    main()
