"""3SFC beyond the paper: compress an LLM federated update.

    PYTHONPATH=src python examples/compress_llm_update.py [--arch tinyllama-1.1b]

The paper compresses CNN/MLP updates on image classifiers. Here the same
registered strategy (``repro.core.strategy``) runs on a (reduced) assigned
LLM architecture: the synthetic payload is soft input EMBEDDINGS + LOW-RANK
soft labels over the vocab — the generalization DESIGN.md §5 describes.
Works for every family, including MoE (EF carries non-activated experts)
and SSM.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, CompressorConfig, get_smoke_config
from repro.core import flat
from repro.core.strategy import make_strategy
from repro.data.synthetic import make_token_dataset
from repro.models.build import build_model, syn_loss_fn, syn_spec_for
from repro.models.encdec import EncDec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--local-iters", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    w = model.init(key)
    d = flat.tree_size(w)

    data = make_token_dataset(jax.random.PRNGKey(1), 64, 32, cfg.vocab_size)
    batch = {"tokens": jnp.asarray(data[:8])}
    if isinstance(model, EncDec):
        batch["frames"] = jax.random.normal(
            key, (8, cfg.num_mm_tokens, cfg.d_model))
    elif cfg.num_mm_tokens:
        batch["prefix_embeds"] = jax.random.normal(
            key, (8, cfg.num_mm_tokens, cfg.d_model))

    # accumulate a local update
    wi = w
    for _ in range(args.local_iters):
        g = jax.grad(model.loss)(wi, batch)
        wi = jax.tree.map(lambda p, gr: p - 0.01 * gr, wi, g)
    target = flat.tree_sub(w, wi)

    comp = CompressorConfig(kind="threesfc", syn_batch=1, syn_seq=8,
                            soft_label_rank=8, syn_steps=args.steps,
                            syn_lr=0.1)
    spec = syn_spec_for(cfg, comp)
    strategy = make_strategy(comp, loss_fn=syn_loss_fn(model), syn_spec=spec)
    enc = strategy.client_encode(jax.random.PRNGKey(2), target, w)
    recon = strategy.server_decode(enc.wire, w)
    err = float(flat.tree_norm(flat.tree_sub(recon, enc.recon)))

    print(f"arch={args.arch}  params={d:,}")
    print(f"payload = {strategy.payload_floats(w):.0f} floats "
          f"(soft embeds {np.prod(spec.x_shape)}, low-rank labels rank "
          f"{comp.soft_label_rank}) -> "
          f"{d / strategy.payload_floats(w):.1f}x compression")
    print(f"encode cosine = {float(enc.cosine):+.4f}  "
          f"(decode exactness: {err:.2e})")
    return err


if __name__ == "__main__":
    main()
