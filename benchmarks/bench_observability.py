"""Observability gates: tracing overhead, trace completeness, byte parity.

PR 9 added ``repro.obs`` — host-side spans around every hot boundary the
driver crosses (engine dispatch/sync, live round phases, transport frames,
codec bytes, checkpoint fsync) plus cross-process span piggybacking and an
offset-corrected merge. Telemetry that distorts what it measures, or that
silently loses rounds, is worse than none, so three gates:

* **overhead**: the scanned engine driving null rounds at the paper batch
  shapes — the most dispatch-dense path we have — with tracing ON runs
  within 3% of tracing OFF. Interleaved A/B pairs, median of per-pair
  ratios (same CPU-drift-cancelling protocol as ``bench_round_engine``).
* **complete trace**: a live socket run (3 workers, real subprocesses) with
  one worker straggling past the deadline every round and the wire eating
  one frame (``rx_filter``) yields a merged trace in which EVERY executed
  round carries the full server phase set (encode/broadcast/collect/ack/
  aggregate), and the outcome tags match what actually happened: the
  straggler undelivered-not-dead each measured round and attributed as a
  straggler (its own worker-side straggle spans overrun the server
  deadline), the eaten frame attributed as ``frame_lost`` — both read back
  through ``scripts/trace_report.py --json``, not from bench-internal
  state.
* **bytes parity**: data-frame bytes summed from trace events equal the
  transport ledger's billed bytes EXACTLY, both directions — every
  ``LinkStats`` bill emits exactly one rx_frame/tx_frame event, so the
  trace is a complete record of the wire, not a sample of it.

Deterministic except wall-clock ratios (slack-padded); ``--quick`` ==
``--full``. Emits ``BENCH_observability.json`` (repo root) +
``experiments/results/observability.json`` for ``scripts/check_bench.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# -- overhead gate ----------------------------------------------------------
N_CLIENTS, LOCAL_STEPS, LOCAL_BATCH = 10, 5, 32      # paper MLP/MNIST config
BLOCK = 5                                            # rounds per eval block
PAIRS = 8
BLOCKS_PER_SIDE = 2                                  # 10 rounds per side/pair
OVERHEAD_BUDGET = 0.03                               # traced >= 97% throughput

# -- live trace scenario ----------------------------------------------------
LIVE_N = 3
LIVE_ROUNDS = 5                                      # measured, after warm 0
STRAGGLE_CID, STRAGGLE_S = 1, 2.0
DEADLINE_S = 0.75
DROP = (2, 0)                                        # (round, cid) eaten frame
WARM_DEADLINE_S = 600.0                              # round-0 jit in workers
SPAN_DRAIN_TIMEOUT_S = 90.0                          # straggler backlog drain


def _overhead_gate() -> Dict:
    """Null-round engine blocks, tracer ON vs OFF interleaved."""
    from benchmarks.bench_round_engine import _null_round
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.engine import RoundEngine, device_pools, vision_batcher
    from repro.fl.round import FLState
    from repro.models.cnn import MNIST_SPEC
    from repro.obs import configure_tracer, get_tracer, set_tracer

    train = make_class_image_dataset(jax.random.PRNGKey(0), 2048,
                                     MNIST_SPEC.input_shape, 10)
    parts = dirichlet_partition(train.y, N_CLIENTS, alpha=0.5, seed=0,
                                min_per_client=LOCAL_BATCH)
    batch_fn = vision_batcher(train.x, train.y, device_pools(parts),
                              LOCAL_STEPS, LOCAL_BATCH)
    engine = RoundEngine(_null_round, batch_fn, seed=0)

    def fresh():
        return FLState({}, {}, jnp.zeros((), jnp.int32))

    prev = get_tracer()
    tracer = configure_tracer(True, proc="bench", capacity=1 << 16)
    try:
        state, _ = engine.run_block(fresh(), BLOCK)      # compile warmup
        ratios, on_ts, off_ts = [], [], []
        for _ in range(PAIRS):
            tracer.enabled = False
            t0 = time.perf_counter()
            for _ in range(BLOCKS_PER_SIDE):
                state, _ = engine.run_block(state, BLOCK)
            t_off = time.perf_counter() - t0
            tracer.enabled = True
            t0 = time.perf_counter()
            for _ in range(BLOCKS_PER_SIDE):
                state, _ = engine.run_block(state, BLOCK)
            t_on = time.perf_counter() - t0
            off_ts.append(t_off)
            on_ts.append(t_on)
            ratios.append(t_off / t_on)       # >= 1 - eps when tracing is free
        traced_spans = len(tracer.drain())
    finally:
        set_tracer(prev)
    rel = float(np.median(ratios))
    rounds = BLOCKS_PER_SIDE * BLOCK
    return {
        "pairs": PAIRS, "rounds_per_side": rounds,
        "ms_per_round_off": float(np.median(off_ts)) / rounds * 1e3,
        "ms_per_round_on": float(np.median(on_ts)) / rounds * 1e3,
        "traced_throughput_ratio": rel,       # traced/untraced rounds-per-sec
        "budget": OVERHEAD_BUDGET,
        "spans_recorded": traced_spans,
        "ok": bool(rel >= 1.0 - OVERHEAD_BUDGET),
    }


def _live_trace_scenario(out_dir: str) -> Dict:
    """Live socket run with a straggler + an eaten frame, tracing on end to
    end; returns the trace_report --json analysis plus raw parity numbers."""
    from benchmarks.bench_transport import _build, _tiny_problem
    from repro.comm.transport import SocketServer, spawn_local_workers
    from repro.configs.run import RunConfig
    from repro.fl.engine import LiveRoundLoop, RetryPolicy
    from repro.launch.worker import vision_setup
    from repro.obs import (configure_tracer, get_tracer, merge_traces,
                           set_tracer, write_chrome_trace)

    spec, fl = _tiny_problem()
    run = RunConfig(fl=fl, wire="codec", transport="socket",
                    round_deadline_s=DEADLINE_S, recv_timeout_s=DEADLINE_S,
                    recv_backoff=1.5, transport_retries=0,
                    heartbeat_s=0.2, liveness_timeout_s=5.0)
    _, params, strategy, codec = _build("mlp", spec, fl, run)

    def rx_filter(cid, rnd, buf):
        return None if (rnd, cid) == DROP else buf

    prev = get_tracer()
    configure_tracer(True, proc="server", capacity=1 << 17)
    server = SocketServer(LIVE_N, heartbeat_s=run.heartbeat_s,
                          liveness_timeout_s=run.liveness_timeout_s,
                          rx_filter=rx_filter)
    procs = spawn_local_workers(server.address, range(LIVE_N))
    try:
        server.wait_ready(60)
        server.send_setup(vision_setup(run, model="mlp", spec=spec,
                                       train_size=120,
                                       straggle={STRAGGLE_CID: STRAGGLE_S},
                                       trace=True))
        loop = LiveRoundLoop(server, strategy, codec, run, params)
        warm = RetryPolicy(max_retries=0, recv_timeout_s=WARM_DEADLINE_S,
                           max_timeout_s=WARM_DEADLINE_S)
        loop.run(1, deadline_s=WARM_DEADLINE_S, policy=warm)
        loop.run(LIVE_ROUNDS)

        # the straggler is still chewing through its round backlog; its
        # spans ride the (late) MSG_METRICs, so wait until its final-round
        # spans have landed before draining
        worker_spans: Dict[str, list] = {}
        last = LIVE_ROUNDS                            # absolute round index
        key = f"client-{STRAGGLE_CID}"
        deadline = time.monotonic() + SPAN_DRAIN_TIMEOUT_S
        while time.monotonic() < deadline:
            for k, v in server.pop_worker_spans().items():
                worker_spans.setdefault(k, []).extend(v)
            if any(s.get("round") == last and s.get("name") == "worker.compute"
                   for s in worker_spans.get(key, ())):
                break
            time.sleep(0.25)
        time.sleep(0.5)                               # trailing straggle span
        for k, v in server.pop_worker_spans().items():
            worker_spans.setdefault(k, []).extend(v)
        offsets = server.clock_offsets()
        ledger = server.ledger()
        history = list(loop.history)
    finally:
        server.stop()
        for p in procs:
            try:
                p.wait(timeout=15)
            except Exception:
                p.kill()
        tracer = get_tracer()
        set_tracer(prev)

    merged = merge_traces(tracer.drain(), worker_spans, offsets)
    trace_path = os.path.join(out_dir, "trace.jsonl")
    with open(trace_path, "w") as f:
        for rec in merged:
            f.write(json.dumps(rec) + "\n")
    write_chrome_trace(merged, os.path.join(out_dir, "trace.chrome.json"))
    ledger_path = os.path.join(out_dir, "ledger.json")
    with open(ledger_path, "w") as f:
        json.dump(ledger, f)

    # the gates read the trace the way a user would: through the analyzer
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "trace_report.py"),
         trace_path, "--ledger", ledger_path, "--json",
         "--replay", os.path.join(out_dir, "replay.json")],
        capture_output=True, text=True, timeout=120)
    if proc.returncode != 0:
        raise RuntimeError(f"trace_report failed:\n{proc.stderr}")
    report = json.loads(proc.stdout)

    return {
        "config": {"clients": LIVE_N, "rounds": 1 + LIVE_ROUNDS,
                   "straggle_cid": STRAGGLE_CID, "straggle_s": STRAGGLE_S,
                   "deadline_s": DEADLINE_S, "drop": list(DROP)},
        "history": [{"round": r["round"],
                     "delivered": np.asarray(r["delivered"]).tolist(),
                     "dead": r["dead"], "wall_s": float(r["wall_s"])}
                    for r in history],
        "worker_span_counts": {k: len(v) for k, v in worker_spans.items()},
        "clock_offsets_ns": offsets,
        "ledger": {"uplink_bytes": int(ledger["uplink"]["total_bytes"]),
                   "downlink_bytes": int(ledger["downlink"]["total_bytes"]),
                   "overhead_up": int(ledger["overhead_up"]),
                   "overhead_down": int(ledger["overhead_down"])},
        "report": report,
    }


def _gate(results: Dict) -> Dict:
    ov, live = results["overhead"], results["live"]
    rep = live["report"]
    results["pass_overhead"] = bool(ov["ok"])

    executed = [r["round"] for r in live["history"]]
    rounds_ok = sorted(rep["rounds"]) == sorted(executed)
    att = rep["attribution"]
    straggler_rounds = att["stragglers"].get(str(STRAGGLE_CID), [])
    # every measured round (the warm round has no deadline pressure)
    straggle_ok = set(straggler_rounds) >= set(executed[1:])
    # ... and nobody else blamed
    straggle_ok &= set(att["stragglers"]) <= {str(STRAGGLE_CID)}
    straggle_ok &= not att["dead_workers"]            # alive the whole run
    drop_ok = att["frame_lost"].get(str(DROP[1]), []) == [DROP[0]]
    unknown = [c for c in att["undelivered"] if c["cause"] == "unknown"]
    results["pass_complete_trace"] = bool(
        rounds_ok and rep["phase_complete"] and straggle_ok and drop_ok
        and not unknown)

    rec = rep["reconciliation"]
    results["pass_bytes_parity"] = bool(
        rec["uplink_exact"] and rec["downlink_exact"]
        and rec["uplink_billed"] > 0 and rec["downlink_billed"] > 0)

    results["pass"] = all(results[k] for k in (
        "pass_overhead", "pass_complete_trace", "pass_bytes_parity"))
    return results


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    del quick                                 # deterministic; quick == full
    print(f"tracing overhead: null-round engine blocks, {PAIRS} interleaved "
          f"on/off pairs...")
    overhead = _overhead_gate()
    print(f"live trace: {LIVE_N} workers, cid {STRAGGLE_CID} sleeps "
          f"{STRAGGLE_S:.1f}s/round under a {DEADLINE_S:.2f}s deadline, wire "
          f"eats frame {DROP}...")
    os.makedirs(out_dir, exist_ok=True)
    with tempfile.TemporaryDirectory(prefix="obs_bench_") as tmp:
        live = _live_trace_scenario(tmp)

    results = _gate({"overhead": overhead, "live": live})

    ov, rep = overhead, live["report"]
    print("\n== Observability ==")
    print(f"  [{'PASS' if results['pass_overhead'] else 'FAIL'}] tracing-on "
          f"within {OVERHEAD_BUDGET:.0%} of tracing-off: "
          f"{ov['ms_per_round_on']:.2f} vs {ov['ms_per_round_off']:.2f} "
          f"ms/round (throughput ratio {ov['traced_throughput_ratio']:.3f}, "
          f"{ov['spans_recorded']} spans)")
    att = rep["attribution"]
    print(f"  [{'PASS' if results['pass_complete_trace'] else 'FAIL'}] "
          f"merged trace complete + correctly attributed: rounds "
          f"{rep['rounds']}, phases complete={rep['phase_complete']}, "
          f"stragglers={att['stragglers']}, frame_lost={att['frame_lost']}")
    rec = rep["reconciliation"]
    print(f"  [{'PASS' if results['pass_bytes_parity'] else 'FAIL'}] trace "
          f"bytes == ledger bytes exactly: up {rec['uplink_trace']}/"
          f"{rec['uplink_billed']}, down {rec['downlink_trace']}/"
          f"{rec['downlink_billed']}")

    with open(os.path.join(out_dir, "observability.json"), "w") as f:
        json.dump(results, f, indent=2)
    with open(os.path.join(REPO, "BENCH_observability.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True,
                   help="accepted for orchestrator symmetry; quick == full")
    g.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
