"""On-mesh wire-bytes accounting for the sharded client fan-out.

The fused-decode path's claim (fl/round.py) is a *collective-bill* claim:
with clients sharded over ``client_axes(mesh)``, the naive server path must
move O(d) bytes per device per round (the full-gradient gather — FedAvg's
bill), while the fused 3SFC path moves only the O(N·payload) ``(D_syn, s)``
trees. This benchmark compiles BOTH shard_map round functions on a forced
8-device host-CPU mesh and reads the bill off the optimized HLO with the
trip-count-aware analyzer (``repro.utils.hlo_analyzer.collectives``) —
measured bytes, not a docstring. Gated:

* fused per-round collective bytes ≤ 1% of the naive path's (observed
  ~240x: 4d ≈ 797 KB vs ~3 KB at the paper MLP/MNIST shapes);
* fused bytes stay O(N·payload): ≤ 2x the local clients' (D_syn, s)
  payload bytes + 1 KiB of metrics-gather slack;
* the per-client local-train+encode region (the ``CLIENT_SCOPE`` named
  scope) contains ZERO collectives on either path;
* shard_map ≡ vmap oracle over 3 scanned rounds, all five compressors:
  bitwise for fedavg/dgc/signsgd/stc (their per-client math is
  vmap-width-invariant), and for 3SFC bitwise on a width-matched mesh
  (client axis 1) plus ≤1e-5 max |Δparams| on the 8-way mesh — XLA CPU
  lowers batched dots differently per vmap width (~1e-8 observed), so
  gradient-in-the-loop encoders are exact only at matched width; the
  width-matched case isolates the shard_map plumbing itself.

The 8-device mesh needs ``--xla_force_host_platform_device_count=8`` BEFORE
jax initializes, so the measurement runs in a child process (``--child``)
and the orchestrator-facing ``run()`` parses its JSON. Emits
``BENCH_collectives.json`` (repo root) + ``experiments/results/
collectives.json`` for the ``scripts/check_bench.py`` trajectory gate.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def multidev_env() -> Dict[str, str]:
    """Child environment for forced-8-device host-CPU runs: the XLA device
    flag (must precede jax init), CPU platform pin, and src+repo on
    PYTHONPATH. Shared with the tests' ``multidev`` subprocess runner
    (tests/conftest.py) so the recipe lives in one place."""
    env = os.environ.copy()
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    return env


N_CLIENTS = 8                      # divisible over the 8-device client axis
LOCAL_STEPS, LOCAL_BATCH = 5, 32   # paper MLP/MNIST round shape
EXACT_ROUNDS = 3
THREESFC_TOL = 1e-5


def _child() -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig
    from repro.core import flat
    from repro.core.strategy import make_strategy
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.budget import matched_compressors
    from repro.fl.engine import RoundEngine, device_pools, vision_batcher
    from repro.analysis import collective_summary
    from repro.fl.round import build_fl_round, fl_init
    from repro.fl.sharding import make_fl_shardings
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import MNIST_SPEC, make_paper_model

    assert len(jax.devices()) == 8, \
        f"child expected 8 forced host devices, got {len(jax.devices())}"
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    sh = make_fl_shardings(mesh)
    # width-matched mesh: client axis of size 1 -> each "shard" runs the
    # full vmap width, isolating the shard_map plumbing from XLA's
    # width-dependent batched-dot lowering
    mesh_w = jax.make_mesh((1, 8), ("data", "model"))
    sh_w = make_fl_shardings(mesh_w)

    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    d = flat.tree_size(params)

    # ---- wire accounting at paper round shapes ---------------------------
    ccfg = matched_compressors("mlp", MNIST_SPEC, d)["threesfc"]
    spec = vision_syn_spec(MNIST_SPEC, ccfg)
    payload_floats = float(spec.floats + 1)
    strat = make_strategy(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                          local_lr=0.01)
    cfg = FLConfig(num_clients=N_CLIENTS, local_steps=LOCAL_STEPS,
                   local_lr=0.01, local_batch=LOCAL_BATCH, compressor=ccfg)
    run_sh = RunConfig(fl=cfg, client_parallel="shard_map", mesh=mesh)
    naive_rf = build_fl_round(model.loss, strat, run_sh)
    fused_rf = build_fl_round(model.loss, strat,
                              run_sh.replace(fused_decode=True))

    state = fl_init(params, N_CLIENTS)
    batches = {
        "x": jax.ShapeDtypeStruct(
            (N_CLIENTS, LOCAL_STEPS, LOCAL_BATCH, *MNIST_SPEC.input_shape),
            jnp.float32),
        "y": jax.ShapeDtypeStruct((N_CLIENTS, LOCAL_STEPS, LOCAL_BATCH),
                                  jnp.int32),
    }
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def wire(rf) -> Dict:
        compiled = jax.jit(
            rf,
            in_shardings=(sh.state, sh.client, sh.replicated),
            out_shardings=(sh.state, sh.replicated),
        ).lower(state, batches, key).compile()
        # scope filter + byte census live ONCE, in repro.analysis — the
        # same extraction the check_static contract matrix gates on
        return collective_summary(compiled.as_text())

    print("compiling naive shard_map round...", file=sys.stderr)
    naive = wire(naive_rf)
    print("compiling fused shard_map round...", file=sys.stderr)
    fused = wire(fused_rf)

    # ---- shard_map == vmap oracle, 3 scanned rounds, 5 compressors -------
    EN, EK, EB = N_CLIENTS, 2, 8
    train = make_class_image_dataset(jax.random.PRNGKey(1), 512,
                                     MNIST_SPEC.input_shape, 10)
    parts = dirichlet_partition(train.y, EN, alpha=0.5, seed=0,
                                min_per_client=16)
    kinds = {
        "fedavg": CompressorConfig(kind="identity", error_feedback=False),
        "dgc": CompressorConfig(kind="topk", keep_ratio=0.05),
        "signsgd": CompressorConfig(kind="signsgd"),
        "stc": CompressorConfig(kind="stc", keep_ratio=0.05),
        "threesfc": CompressorConfig(kind="threesfc", syn_steps=2, syn_lr=0.1),
    }

    def engine_for(kcfg, shardings, mode, m):
        kspec = vision_syn_spec(MNIST_SPEC, kcfg)
        kstrat = make_strategy(kcfg, loss_fn=model.syn_loss, syn_spec=kspec,
                               local_lr=0.05)
        kfl = FLConfig(num_clients=EN, local_steps=EK, local_lr=0.05,
                       local_batch=EB, compressor=kcfg)
        pools = device_pools(parts)
        if shardings is not None:
            pools = shardings.place_pools(pools)
        eng = RoundEngine(
            build_fl_round(model.loss, kstrat,
                           RunConfig(fl=kfl, client_parallel=mode, mesh=m)),
            vision_batcher(train.x, train.y, pools, EK, EB),
            seed=0, shardings=shardings)
        return eng, eng.init_state(params, EN)

    def run3(kcfg, shardings, mode, m):
        eng, st = engine_for(kcfg, shardings, mode, m)
        return eng.run_block(st, EXACT_ROUNDS)

    def tree_equal(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))

    def tree_maxdiff(a, b):
        return max(float(jnp.max(jnp.abs(x - y)))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))

    exact: Dict[str, Dict] = {}
    for name, kcfg in kinds.items():
        print(f"exactness sweep: {name}...", file=sys.stderr)
        sv, mv = run3(kcfg, None, "vmap", None)
        ss, ms = run3(kcfg, sh, "shard_map", mesh)
        rec = {
            "params_bitexact": tree_equal(sv.params, ss.params),
            "ef_bitexact": tree_equal(sv.ef, ss.ef),
            "metrics_bitexact": all(
                np.array_equal(np.asarray(getattr(mv, f)),
                               np.asarray(getattr(ms, f)))
                for f in mv._fields),
            "max_abs_param_diff": tree_maxdiff(sv.params, ss.params),
        }
        if name == "threesfc":
            sw, _ = run3(kcfg, sh_w, "shard_map", mesh_w)
            rec["width_matched_bitexact"] = (
                tree_equal(sv.params, sw.params) and tree_equal(sv.ef, sw.ef))
        exact[name] = rec

    payload_bytes_local = 4.0 * payload_floats * (N_CLIENTS // sh.client_shards)
    return {
        "config": {
            "devices": 8, "mesh_shape": [8, 1], "client_axes": list(sh.axes),
            "model": "mlp", "dataset": "mnist", "model_params": d,
            "num_clients": N_CLIENTS, "local_steps": LOCAL_STEPS,
            "local_batch": LOCAL_BATCH, "payload_floats": payload_floats,
            "exact_rounds": EXACT_ROUNDS,
        },
        "naive": naive,
        "fused": fused,
        "payload_bytes_local": payload_bytes_local,
        "exact": exact,
    }


WIDTH_STABLE = ("fedavg", "dgc", "signsgd", "stc")


def _gate(results: Dict) -> Dict:
    # the fused-gather bound is the contract's, stated once in
    # repro.analysis.contracts and shared with the check_static matrix
    from repro.analysis.contracts import (FUSED_GATHER_FACTOR,
                                          FUSED_GATHER_SLACK_BYTES)
    naive_b = results["naive"]["collective_bytes_per_round"]
    fused_b = results["fused"]["collective_bytes_per_round"]
    exact = results["exact"]
    results["wire_ratio"] = naive_b / max(fused_b, 1.0)
    results["pass_wire_ratio"] = bool(fused_b <= 0.01 * naive_b)
    results["pass_payload_scaling"] = bool(
        fused_b <= FUSED_GATHER_FACTOR * results["payload_bytes_local"]
        + FUSED_GATHER_SLACK_BYTES)
    results["pass_encode_region_clean"] = bool(
        results["naive"]["encode_region_collectives"] == 0
        and results["fused"]["encode_region_collectives"] == 0)
    results["pass_bitexact"] = bool(
        all(exact[k]["params_bitexact"] and exact[k]["ef_bitexact"]
            and exact[k]["metrics_bitexact"] for k in WIDTH_STABLE)
        and exact["threesfc"]["width_matched_bitexact"])
    results["pass_threesfc_tol"] = bool(
        exact["threesfc"]["max_abs_param_diff"] <= THREESFC_TOL)
    results["pass"] = all(results[k] for k in (
        "pass_wire_ratio", "pass_payload_scaling", "pass_encode_region_clean",
        "pass_bitexact", "pass_threesfc_tol"))
    return results


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    # ``quick`` is accepted for orchestrator symmetry but has no effect:
    # every number here is compile-time/deterministic (HLO bytes, bitwise
    # oracle over 3 short rounds) — there is no heavier "full" variant.
    del quick
    cmd = [sys.executable, "-m", "benchmarks.bench_collectives", "--child"]
    p = subprocess.run(cmd, env=multidev_env(), cwd=REPO, capture_output=True,
                       text=True, timeout=1800)
    if p.returncode != 0:
        sys.stderr.write(p.stdout + p.stderr)
        raise RuntimeError(
            f"bench_collectives child failed (exit {p.returncode})")
    results = _gate(json.loads(p.stdout))

    nb = results["naive"]["collective_bytes_per_round"]
    fb = results["fused"]["collective_bytes_per_round"]
    d = results["config"]["model_params"]
    print(f"\n== Per-round collective bytes (8-device host mesh, "
          f"mlp/mnist d={d}) ==")
    print(f"  naive decode : {nb:12.0f} B  "
          f"({results['naive']['collective_count']} collectives; "
          f"O(d) full-gradient gather, 4d = {4 * d} B)")
    print(f"  fused decode : {fb:12.0f} B  "
          f"({results['fused']['collective_count']} collectives; payload = "
          f"{results['payload_bytes_local']:.0f} B/device)")
    print(f"  [{'PASS' if results['pass_wire_ratio'] else 'FAIL'}] fused <= 1% "
          f"of naive wire bytes ({results['wire_ratio']:.0f}x less)")
    print(f"  [{'PASS' if results['pass_payload_scaling'] else 'FAIL'}] fused "
          f"bytes are O(N*payload) (<= 2x payload + 1KiB slack)")
    print(f"  [{'PASS' if results['pass_encode_region_clean'] else 'FAIL'}] "
          f"zero collectives inside the per-client encode region "
          f"(naive {results['naive']['encode_region_collectives']}, "
          f"fused {results['fused']['encode_region_collectives']})")
    ex = results["exact"]
    stable = all(ex[k]["params_bitexact"] for k in WIDTH_STABLE)
    print(f"  [{'PASS' if results['pass_bitexact'] else 'FAIL'}] shard_map == "
          f"vmap oracle over {results['config']['exact_rounds']} rounds "
          f"(bitwise: {', '.join(WIDTH_STABLE)} = {stable}; threesfc "
          f"width-matched = {ex['threesfc']['width_matched_bitexact']})")
    print(f"  [{'PASS' if results['pass_threesfc_tol'] else 'FAIL'}] threesfc "
          f"8-way max |dparams| = {ex['threesfc']['max_abs_param_diff']:.1e} "
          f"<= {THREESFC_TOL:.0e} (XLA batched-dot lowering is vmap-width-"
          f"dependent; exactness is defined width-matched)")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "collectives.json"), "w") as f:
        json.dump(results, f, indent=2)
    with open(os.path.join(REPO, "BENCH_collectives.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true",
                    help="measurement half (needs the 8-device XLA flag "
                         "already in the environment); prints JSON to stdout")
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True,
                   help="accepted for orchestrator symmetry; the measurement "
                        "is deterministic, quick == full")
    g.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()
    if args.child:
        json.dump(_child(), sys.stdout)
        return
    run(quick=args.quick)


if __name__ == "__main__":
    main()
