"""Chaos-kill gate: durable recovery points, bitwise resume, elastic rejoin.

Four scenarios, four absolute gates (emitted as ``pass_*`` flags for
``scripts/check_bench.py``):

* **server SIGKILL + resume** (``pass_bitwise_resume``, socket half): a
  ``repro.launch.train`` socket run (mlp/mnist/stc, 2 workers,
  ``--ckpt-every 2``) is SIGKILLed — whole process group, server and
  workers — as soon as its first recovery point commits, then restarted
  with ``--resume``. Its FINAL recovery point must be bitwise identical to
  an uninterrupted oracle run's: every payload leaf (params + per-client
  EF bank), the per-round delivered/participate masks in the history, and
  the byte ledger. The resumed run must also have appended (not truncated)
  the metrics JSONL.
* **in-process resume** (``pass_bitwise_resume``, engine half): the
  faulted scanned engine (drops + stragglers + staleness buffer) resumed
  from a mid-run recovery point replays the remaining rounds bitwise
  against the uninterrupted ``FLState`` — params, N×d EF, ring buffer,
  round counter.
* **worker SIGKILL + rejoin** (``pass_rejoin_ef_conserved``,
  ``pass_rejoin_convergence``): a live worker is SIGKILLed mid-run; the
  loop drives on (its rounds map to delivered=False, its banked residual
  frozen); a restarted process rejoins and must come back with its EF
  bitwise equal to the banked commit (atol=0 — residual-mass conservation
  across the outage), after which the run must reach the no-crash run's
  final loss within 2x the no-crash round count.
* **crash during checkpoint write** (``pass_prev_ckpt_survives``): a kill
  at any point of a save — mid-payload, before the manifest, before the
  index rename — leaves the PREVIOUS recovery point committed and
  loadable, and a retried save over the debris succeeds.

Deterministic except wall clock; ``--quick`` == ``--full``. Emits
``BENCH_recovery.json`` (repo root) + ``experiments/results/recovery.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import time
from typing import Dict, List, Optional

import jax
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# -- server-kill scenario (train.py subprocesses) ---------------------------
SRV_ROUNDS = 6
SRV_CKPT_EVERY = 2
SRV_KILL_AFTER_STEP = 2              # SIGKILL once this step has committed
SRV_BOOT_TIMEOUT_S = 900             # worker jit compile inside the run

# -- worker-kill scenario (live loop in this process) -----------------------
WK_N = 3
WK_KILL = 2
WK_CLEAN_ROUNDS = 6                  # measured (after warm-up)
WK_PRE_KILL = 2                      # healthy rounds before the SIGKILL
WK_DEAD_ROUNDS = 2                   # rounds driven while the worker is dead
WK_WARM_S = 600.0


def _ravel(tree) -> np.ndarray:
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree_util.tree_leaves(tree)])


# ---------------------------------------------------------------------------
# scenario: in-process bitwise resume
# ---------------------------------------------------------------------------


def _inproc_resume(out_dir: str) -> Dict:
    """Faulted scanned engine, checkpoint at round 4, resume in a fresh
    engine, compare the full FLState to the uninterrupted oracle."""
    from repro.checkpoint import (CheckpointManager, load_fl_checkpoint,
                                  save_fl_checkpoint)
    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig
    from repro.core.strategy import make_strategy
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.engine import RoundEngine, device_pools, vision_batcher
    from repro.fl.round import build_fl_round
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import VisionSpec, make_paper_model

    N, R, CUT = 4, 8, 4
    spec = VisionSpec("tiny", (6, 6, 1), 3)
    comp = CompressorConfig(kind="stc", keep_ratio=0.1)
    fl = FLConfig(num_clients=N, local_steps=2, local_lr=0.05,
                  local_batch=4, compressor=comp, seed=0)
    run = RunConfig(fl=fl, drop_rate=0.3, straggler_rate=0.25,
                    staleness_max=2, fault_seed=7)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(fl.seed))
    strategy = make_strategy(comp, loss_fn=model.syn_loss,
                             syn_spec=vision_syn_spec(spec, comp),
                             local_lr=fl.local_lr)
    train = make_class_image_dataset(jax.random.PRNGKey(fl.seed), 120,
                                     spec.input_shape, spec.num_classes)
    parts = dirichlet_partition(train.y, N, alpha=fl.dirichlet_alpha,
                                seed=fl.seed, min_per_client=fl.local_batch)
    pools = device_pools(parts)

    def make_engine():
        return RoundEngine(
            build_fl_round(model.loss, strategy, run),
            vision_batcher(train.x, train.y, pools, fl.local_steps,
                           fl.local_batch),
            seed=fl.seed)

    oracle = make_engine()
    st = oracle.init_state(params, N, strategy, staleness_max=run.staleness_max)
    oracle_final, _ = oracle.run(st, R)

    mgr = CheckpointManager(os.path.join(out_dir, "inproc_ckpt"))
    eng = make_engine()
    st = eng.init_state(params, N, strategy, staleness_max=run.staleness_max)
    eng.run(st, CUT + 1, eval_every=3, ckpt_every=SRV_CKPT_EVERY,
            ckpt_fn=lambda s, r: save_fl_checkpoint(mgr, r, s, run=run))

    resumed = make_engine()
    template = resumed.init_state(params, N, strategy,
                                  staleness_max=run.staleness_max)
    state, _, meta = load_fl_checkpoint(mgr, template, step=CUT)
    resumed_final, _ = resumed.run(state, R - CUT)

    fields = {}
    for name in ("params", "ef", "buf", "buf_w"):
        a, b = getattr(oracle_final, name), getattr(resumed_final, name)
        fields[name] = bool(np.array_equal(_ravel(a), _ravel(b)))
    fields["round"] = int(oracle_final.round) == int(resumed_final.round) == R
    return {"rounds": R, "cut_round": CUT, "resumed_from": int(meta["round"]),
            "bitwise": fields, "bitwise_all": all(fields.values())}


# ---------------------------------------------------------------------------
# scenario: server SIGKILL mid-run + --resume (train.py subprocesses)
# ---------------------------------------------------------------------------


def _train_cmd(out: str, resume: Optional[str] = None) -> List[str]:
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--model", "mlp", "--dataset", "mnist", "--compressor", "stc",
           "--rounds", str(SRV_ROUNDS), "--clients", "2",
           "--local-steps", "1", "--batch", "8", "--train-size", "128",
           "--eval-every", "10", "--seed", "0",
           "--wire", "codec", "--transport", "socket",
           "--ckpt-every", str(SRV_CKPT_EVERY), "--out", out]
    if resume:
        cmd += ["--resume", resume]
    return cmd


def _spawn_train(out: str, resume: Optional[str] = None) -> subprocess.Popen:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    log = open(os.path.join(out, "driver.log"), "w")
    # its own session => one killpg takes out the server AND its workers,
    # exactly like a box losing power
    return subprocess.Popen(_train_cmd(out, resume), cwd=REPO, env=env,
                            stdout=log, stderr=subprocess.STDOUT,
                            start_new_session=True)


def _wait_step(ckpt_root: str, step: int, proc: subprocess.Popen,
               timeout: float) -> None:
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(ckpt_root)
    end = time.monotonic() + timeout
    while time.monotonic() < end:
        if proc.poll() is not None:
            raise RuntimeError(
                f"train run exited (code {proc.returncode}) before step "
                f"{step} committed — see driver.log")
        latest = mgr.latest()
        if latest is not None and latest >= step:
            return
        time.sleep(0.2)
    raise RuntimeError(f"step {step} never committed within {timeout}s")


def _final_ckpt(out: str):
    from repro.checkpoint import CheckpointManager, load_arrays, load_manifest

    mgr = CheckpointManager(os.path.join(out, "ckpt"))
    step = mgr.latest()
    flat, manifest = load_arrays(mgr.path(step))
    return step, flat, manifest["meta"]


def _data_plane(ledger) -> Optional[Dict]:
    if ledger is None:
        return None
    return {k: v for k, v in ledger.items()
            if k not in ("overhead_up", "overhead_down")}


def _server_kill_resume(out_dir: str) -> Dict:
    """Oracle run start-to-finish; chaos run SIGKILLed (whole group) after
    its first recovery point, restarted with --resume; final recovery
    points compared leaf-by-leaf."""
    oracle_out = os.path.join(out_dir, "server_oracle")
    chaos_out = os.path.join(out_dir, "server_chaos")
    for d in (oracle_out, chaos_out):
        shutil.rmtree(d, ignore_errors=True)
        os.makedirs(d)

    print("  oracle run (uninterrupted)...")
    p = _spawn_train(oracle_out)
    rc = p.wait(timeout=SRV_BOOT_TIMEOUT_S)
    if rc != 0:
        raise RuntimeError(f"oracle train run failed (exit {rc}) — see "
                           f"{oracle_out}/driver.log")

    print(f"  chaos run: SIGKILL the process group once step "
          f"{SRV_KILL_AFTER_STEP} commits...")
    p = _spawn_train(chaos_out)
    _wait_step(os.path.join(chaos_out, "ckpt"), SRV_KILL_AFTER_STEP, p,
               SRV_BOOT_TIMEOUT_S)
    os.killpg(os.getpgid(p.pid), signal.SIGKILL)
    p.wait(timeout=30)
    metrics_path = os.path.join(chaos_out, "metrics.jsonl")
    pre_lines = sum(1 for _ in open(metrics_path)) \
        if os.path.exists(metrics_path) else 0

    print("  resume run (--resume from the surviving recovery point)...")
    p = _spawn_train(chaos_out, resume=os.path.join(chaos_out, "ckpt"))
    rc = p.wait(timeout=SRV_BOOT_TIMEOUT_S)
    if rc != 0:
        raise RuntimeError(f"resumed train run failed (exit {rc}) — see "
                           f"{chaos_out}/driver.log")

    o_step, o_flat, o_meta = _final_ckpt(oracle_out)
    c_step, c_flat, c_meta = _final_ckpt(chaos_out)
    keys = sorted(set(o_flat) | set(c_flat))
    leaf_diffs = [k for k in keys
                  if k not in o_flat or k not in c_flat
                  or not np.array_equal(o_flat[k], c_flat[k])]
    o_hist = [(r["round"], r["participate"], r["delivered"])
              for r in o_meta.get("history", [])]
    c_hist = [(r["round"], r["participate"], r["delivered"])
              for r in c_meta.get("history", [])]
    post_lines = sum(1 for _ in open(metrics_path)) \
        if os.path.exists(metrics_path) else 0
    detail = {
        "rounds": SRV_ROUNDS,
        "kill_after_step": SRV_KILL_AFTER_STEP,
        "final_step": {"oracle": o_step, "resumed": c_step},
        "payload_leaves": len(keys),
        "leaf_diffs": leaf_diffs,
        "params_and_bank_bitwise": not leaf_diffs and o_step == c_step,
        "masks_match": o_hist == c_hist,
        # overhead_up/down count heartbeat/control traffic, whose volume is
        # wall-clock-dependent — only data-plane bytes are deterministic
        "ledger_match": _data_plane(o_meta.get("ledger"))
        == _data_plane(c_meta.get("ledger")),
        "ef_bank_rounds_match": (o_meta.get("ef_bank_rounds")
                                 == c_meta.get("ef_bank_rounds")),
        "metrics_appended": post_lines > pre_lines >= 0,
    }
    detail["bitwise_all"] = bool(
        detail["params_and_bank_bitwise"] and detail["masks_match"]
        and detail["ledger_match"] and detail["ef_bank_rounds_match"])
    return detail


# ---------------------------------------------------------------------------
# scenario: worker SIGKILL + rejoin (live loop in this process)
# ---------------------------------------------------------------------------


def _wk_problem():
    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig
    from repro.core.strategy import make_strategy
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import VisionSpec, make_paper_model

    spec = VisionSpec("tiny", (6, 6, 1), 3)
    comp = CompressorConfig(kind="stc", keep_ratio=0.1)
    fl = FLConfig(num_clients=WK_N, local_steps=2, local_lr=0.05,
                  local_batch=4, compressor=comp, seed=0)
    run = RunConfig(fl=fl, wire="codec", transport="socket",
                    round_deadline_s=60.0, recv_timeout_s=30.0,
                    transport_retries=0, heartbeat_s=0.2,
                    liveness_timeout_s=5.0)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(fl.seed))
    strategy = make_strategy(comp, loss_fn=model.syn_loss,
                             syn_spec=vision_syn_spec(spec, comp),
                             local_lr=fl.local_lr)
    codec = strategy.wire_codec(params, policy=run.wire_policy)
    return spec, run, params, strategy, codec


def _mean_losses(history) -> List[float]:
    """Per measured round (warm-up excluded): mean reported local loss over
    the workers that got one through."""
    out = []
    for rec in history[1:]:
        vals = list(rec["losses"].values())
        out.append(float(np.mean(vals)) if vals else float("inf"))
    return out


def _rounds_to(losses: List[float], target: float) -> Optional[int]:
    for i, v in enumerate(losses):
        if v <= target:
            return i + 1
    return None


def _worker_kill_rejoin(quick: bool) -> Dict:
    from repro.comm.transport import SocketServer, spawn_local_workers
    from repro.fl.engine import LiveRoundLoop, RetryPolicy
    from repro.launch.worker import vision_setup

    spec, run, params, strategy, codec = _wk_problem()
    warm = RetryPolicy(max_retries=0, recv_timeout_s=WK_WARM_S,
                       max_timeout_s=WK_WARM_S)

    def session(drive):
        server = SocketServer(WK_N, heartbeat_s=run.heartbeat_s,
                              liveness_timeout_s=run.liveness_timeout_s)
        procs = spawn_local_workers(server.address, range(WK_N))
        extra = []
        try:
            server.wait_ready(120)
            server.send_setup(vision_setup(run, model="mlp", spec=spec,
                                           train_size=96))
            loop = LiveRoundLoop(server, strategy, codec, run, params)
            loop.run(1, deadline_s=WK_WARM_S, policy=warm)   # jit warm-up
            out = drive(server, loop, procs, extra)
        finally:
            server.stop()
            for p in list(procs) + extra:
                try:
                    p.wait(timeout=15)
                except Exception:
                    p.kill()
        return out, loop.history

    print("  no-crash reference run...")

    def drive_clean(server, loop, procs, extra):
        loop.run(WK_CLEAN_ROUNDS)
        return {}

    _, clean_hist = session(drive_clean)
    clean_losses = _mean_losses(clean_hist)
    target = clean_losses[-1]

    print(f"  chaos run: SIGKILL worker {WK_KILL} after round "
          f"{WK_PRE_KILL}, rejoin after {WK_DEAD_ROUNDS} dead rounds...")

    def drive_chaos(server, loop, procs, extra):
        loop.run(WK_PRE_KILL)
        ok = server.wait_ef_bank(WK_PRE_KILL, range(WK_N), timeout=30.0)
        banked = server.ef_bank()
        procs[WK_KILL].send_signal(signal.SIGKILL)
        procs[WK_KILL].wait()
        end = time.monotonic() + 30
        while WK_KILL in server.live_workers() and time.monotonic() < end:
            time.sleep(0.05)
        loop.run(WK_DEAD_ROUNDS)
        dead_recs = loop.history[-WK_DEAD_ROUNDS:]

        extra.extend(spawn_local_workers(server.address, [WK_KILL]))
        end = time.monotonic() + 120
        while WK_KILL not in server.live_workers() \
                and time.monotonic() < end:
            time.sleep(0.05)
        rejoined = WK_KILL in server.live_workers()
        ef = server.request_ef(WK_KILL, timeout=120) if rejoined else None
        ef_bitwise = ef is not None and np.array_equal(ef, banked[WK_KILL][1])
        # rejoiner recompiles in its first round; then the configured pace.
        # budget: the 2x-convergence bound, minus what was already driven
        budget = 2 * WK_CLEAN_ROUNDS - WK_PRE_KILL - WK_DEAD_ROUNDS
        loop.run(1, deadline_s=WK_WARM_S, policy=warm)
        loop.run(budget - 1)
        return {
            "bank_settled": bool(ok),
            "banked_round": int(banked[WK_KILL][0]),
            "rejoined": bool(rejoined),
            "ef_bitwise_after_rejoin": bool(ef_bitwise),
            "missed_rounds_undelivered": bool(all(
                (not r["delivered"][WK_KILL]) and WK_KILL in r["dead"]
                for r in dead_recs)),
        }

    detail, chaos_hist = session(drive_chaos)
    chaos_losses = _mean_losses(chaos_hist)
    r_clean = _rounds_to(clean_losses, target)
    r_chaos = _rounds_to(chaos_losses, target)
    detail.update({
        "clean_rounds": WK_CLEAN_ROUNDS,
        "target_loss": target,
        "clean_losses": clean_losses,
        "chaos_losses": chaos_losses,
        "rounds_to_target": {"clean": r_clean, "chaos": r_chaos},
        "convergence_ok": (r_clean is not None and r_chaos is not None
                           and r_chaos <= 2 * r_clean),
        "rejoin_masks": [r["delivered"].tolist() for r in chaos_hist],
    })
    return detail


# ---------------------------------------------------------------------------
# scenario: crash during checkpoint write
# ---------------------------------------------------------------------------


def _crash_during_write(out_dir: str) -> Dict:
    """Every kill point of a save leaves the previous recovery point
    committed + loadable; a retried save over the debris succeeds."""
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager, save_checkpoint

    root = os.path.join(out_dir, "crash_ckpt")
    shutil.rmtree(root, ignore_errors=True)
    mgr = CheckpointManager(root)
    tree = {"a": jnp.arange(12, dtype=jnp.float32)}
    mgr.save(2, tree, meta={"round": 2})

    checks = {}
    # kill mid-payload: step dir with a truncated arrays.npz, no manifest
    debris = mgr.path(4)
    os.makedirs(debris)
    with open(os.path.join(debris, "arrays.npz"), "wb") as f:
        f.write(b"PK\x03\x04 truncated by the crash")
    checks["mid_payload_prev_loadable"] = _loads_step(mgr, tree, 2)
    checks["mid_payload_debris_invisible"] = _rejects_step(mgr, tree, 4)

    # kill after the step dir, before the index rename
    save_checkpoint(mgr.path(6), tree, meta={"round": 6})
    checks["pre_index_prev_loadable"] = _loads_step(mgr, tree, 2)
    checks["pre_index_step_invisible"] = _rejects_step(mgr, tree, 6)

    # kill between index tmp write and rename
    with open(os.path.join(root, "MANIFEST.json.tmp"), "w") as f:
        f.write('{"version": 1, "steps": [2, 9')
    checks["index_tmp_prev_loadable"] = _loads_step(mgr, tree, 2)

    # a retried save over the mid-payload debris commits cleanly
    mgr.save(4, tree, meta={"round": 4})
    checks["retry_over_debris_commits"] = (mgr.latest() == 4
                                           and _loads_step(mgr, tree, 4))
    checks["all_ok"] = all(checks.values())
    return checks


def _loads_step(mgr, tree, step) -> bool:
    try:
        got, meta = mgr.load(tree, step=step)
        return (mgr.latest() is not None and meta.get("round") == step
                and bool(np.array_equal(_ravel(got), _ravel(tree))))
    except Exception:
        return False


def _rejects_step(mgr, tree, step) -> bool:
    from repro.checkpoint import CheckpointMissingError

    try:
        mgr.load(tree, step=step)
        return False
    except CheckpointMissingError:
        return True


# ---------------------------------------------------------------------------
# gate + entry
# ---------------------------------------------------------------------------


def _gate(results: Dict) -> Dict:
    srv, inp = results["server_kill"], results["inproc_resume"]
    rej, crash = results["worker_rejoin"], results["crash_write"]
    results["pass_bitwise_resume"] = bool(
        srv["bitwise_all"] and inp["bitwise_all"])
    results["pass_rejoin_ef_conserved"] = bool(
        rej["bank_settled"] and rej["rejoined"]
        and rej["ef_bitwise_after_rejoin"]
        and rej["missed_rounds_undelivered"])
    results["pass_rejoin_convergence"] = bool(rej["convergence_ok"])
    results["pass_prev_ckpt_survives"] = bool(crash["all_ok"])
    results["pass"] = all(results[k] for k in (
        "pass_bitwise_resume", "pass_rejoin_ef_conserved",
        "pass_rejoin_convergence", "pass_prev_ckpt_survives"))
    return results


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    work = os.path.join(REPO, "experiments", "bench_recovery")
    os.makedirs(work, exist_ok=True)

    print("crash during checkpoint write: previous recovery point must "
          "survive every kill point...")
    crash = _crash_during_write(work)
    print("in-process faulted engine: checkpoint at round 4, resume in a "
          "fresh engine, compare bitwise...")
    inproc = _inproc_resume(work)
    print("server SIGKILL mid-run + --resume (train.py process groups)...")
    server_kill = _server_kill_resume(work)
    print(f"worker SIGKILL + rejoin (live loop, {WK_N} workers)...")
    rejoin = _worker_kill_rejoin(quick)

    results = _gate({
        "config": {
            "server": {"rounds": SRV_ROUNDS, "ckpt_every": SRV_CKPT_EVERY,
                       "kill_after_step": SRV_KILL_AFTER_STEP},
            "rejoin": {"clients": WK_N, "kill_cid": WK_KILL,
                       "pre_kill_rounds": WK_PRE_KILL,
                       "dead_rounds": WK_DEAD_ROUNDS,
                       "clean_rounds": WK_CLEAN_ROUNDS},
        },
        "crash_write": crash,
        "inproc_resume": inproc,
        "server_kill": server_kill,
        "worker_rejoin": rejoin,
    })

    s, i, r, c = server_kill, inproc, rejoin, crash
    print("\n== Crash-safe recovery & elastic membership ==")
    print(f"  [{'PASS' if results['pass_bitwise_resume'] else 'FAIL'}] "
          f"bitwise resume: server-kill leaf diffs {s['leaf_diffs'] or 'none'}"
          f", masks {s['masks_match']}, ledger {s['ledger_match']}; "
          f"inproc {i['bitwise']}")
    print(f"  [{'PASS' if results['pass_rejoin_ef_conserved'] else 'FAIL'}] "
          f"rejoin EF conserved (atol=0): banked@r{r['banked_round']}, "
          f"bitwise {r['ef_bitwise_after_rejoin']}, missed rounds "
          f"undelivered {r['missed_rounds_undelivered']}")
    print(f"  [{'PASS' if results['pass_rejoin_convergence'] else 'FAIL'}] "
          f"rejoin convergence: clean {r['rounds_to_target']['clean']} "
          f"rounds to loss {r['target_loss']:.4f}, chaos "
          f"{r['rounds_to_target']['chaos']} (bound 2x)")
    print(f"  [{'PASS' if results['pass_prev_ckpt_survives'] else 'FAIL'}] "
          f"crash-during-write: {c}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "recovery.json"), "w") as f:
        json.dump(results, f, indent=2)
    with open(os.path.join(REPO, "BENCH_recovery.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True,
                   help="accepted for orchestrator symmetry; quick == full")
    g.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
