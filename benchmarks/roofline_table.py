"""Collate experiments/dryrun/*.json into the §Roofline table (EXPERIMENTS.md).

Run AFTER ``python -m repro.launch.dryrun --all`` has produced the per-pair
JSONs. Prints the 40-pair table with the three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, and a one-line "what would move it" note.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

_NOTES = {
    "compute": "bigger per-chip tile / fewer remat recomputes",
    "memory": "fuse elementwise chains; bf16 residuals; bigger arithmetic intensity",
    "collective": "shard to cut gathered bytes; overlap collectives with compute",
}


def load(mesh: str = "16x16") -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r: Dict) -> str:
    roof = r["roofline"]
    return (f"| {r['arch']:<22} | {r['shape']:<11} "
            f"| {roof['compute_s']:.3e} | {roof['memory_s']:.3e} "
            f"| {roof['collective_s']:.3e} | {roof['dominant']:<10} "
            f"| {roof['useful_ratio']:.3f} |")


def run(mesh: str = "16x16", out_path: str = None) -> str:
    rows = load(mesh)
    lines = [
        f"Roofline terms per (arch x shape) on the {mesh} mesh "
        f"(seconds per step; v5e 197TF/819GBps/50GBps):",
        "",
        "| arch                   | shape       | compute_s | memory_s  "
        "| collect_s | dominant   | useful |",
        "|------------------------|-------------|-----------|-----------"
        "|-----------|------------|--------|",
    ]
    for r in rows:
        lines.append(fmt_row(r))
    txt = "\n".join(lines)
    print(txt)
    if out_path:
        with open(out_path, "w") as f:
            f.write(txt + "\n")
    return txt


if __name__ == "__main__":
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "16x16")
