"""Encoder-iteration sweep (S in Algorithm 1) — the cost/quality knob behind
the paper's O(NE(K+S)) complexity claim.

Shows cosine compression efficiency vs S for both encoder-update rules:
the paper's plain GD and this repo's RMS-normalized variant (beyond-paper,
DESIGN.md §8.5). One simulation step throughout — the paper's "single-step"
refers to the simulation depth, not S.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import CompressorConfig
from repro.core import flat, threesfc
from repro.data.synthetic import make_class_image_dataset
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, make_paper_model


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    ds = make_class_image_dataset(jax.random.PRNGKey(1), 512, (28, 28, 1), 10)
    p = params
    for i in range(5):
        g = jax.grad(model.loss)(p, {"x": jnp.asarray(ds.x[i*64:(i+1)*64]),
                                     "y": jnp.asarray(ds.y[i*64:(i+1)*64])})
        p = jax.tree.map(lambda a, b: a - 0.01*b, p, g)
    target = flat.tree_sub(params, p)
    spec = vision_syn_spec(MNIST_SPEC, CompressorConfig(syn_batch=1))

    steps_list = [1, 2, 5, 10] if quick else [1, 2, 5, 10, 20, 50]
    results: Dict = {"normalized": {}, "plain_gd": {}}
    for steps in steps_list:
        for norm in (True, False):
            syn0 = threesfc.init_syn(jax.random.PRNGKey(2), spec)
            res = threesfc.encode(model.syn_loss, params, target, syn0,
                                  steps=steps, lr=0.1, normalize_updates=norm)
            key = "normalized" if norm else "plain_gd"
            results[key][steps] = abs(float(res.cosine))
    print("\n== S-sweep: encoder iterations vs compression efficiency ==")
    print("S      | normalized | plain GD (paper)")
    for s in steps_list:
        print(f"{s:6d} | {results['normalized'][s]:10.4f} "
              f"| {results['plain_gd'][s]:8.4f}")
    mono = all(results["normalized"][steps_list[i+1]]
               >= results["normalized"][steps_list[i]] - 0.02
               for i in range(len(steps_list) - 1))
    print(f"  [{'PASS' if mono else 'FAIL'}] efficiency grows with S "
          f"(O(K+S) cost knob)")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ssweep.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
