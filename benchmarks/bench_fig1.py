"""Paper Fig. 1 — convergence rate degrades as compression rate shrinks
(top-k sparsification at several rates on MLP/MNIST-like)."""
from __future__ import annotations

import json
import os
from typing import Dict

from repro.configs.base import CompressorConfig

from benchmarks.fl_harness import fmt_table, run_fl


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    rounds = 30 if quick else 100
    rates = [1.0, 0.1, 0.01, 0.001]
    results, rows = {}, []
    for rate in rates:
        comp = (CompressorConfig(kind="identity", error_feedback=False)
                if rate >= 1.0 else
                CompressorConfig(kind="topk", keep_ratio=rate / 2))
        r = run_fl("mlp", "mnist", comp, num_clients=10, rounds=rounds,
                   train_size=2000 if quick else 6000,
                   eval_every=max(rounds // 6, 1), label=f"rate={rate}")
        results[str(rate)] = r.acc_curve
        rows.append((f"{rate:g}", f"{r.final_acc:.4f}",
                     " ".join(f"{a:.2f}" for a in r.acc_curve)))
    print("\n== Fig 1 (reduced): convergence vs compression rate (top-k) ==")
    print(fmt_table(rows, ["comp rate", "final acc", "acc curve"]))
    monotone = all(results[str(rates[i])][-1] >= results[str(rates[i+1])][-1] - 0.05
                   for i in range(len(rates) - 1))
    print(f"  [{'PASS' if monotone else 'FAIL'}] lower rate => slower convergence")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig1.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
