"""Fault-injection harness: convergence under dropout/staleness + the
zero-fault bitwise gate.

Two claims are measured and gated at reduced MLP/MNIST shapes:

* **zero-fault bitwise**: the masked fault pipeline under a null schedule
  (forced via ``build_fl_round``'s ``fault_schedule_fn`` seam) produces
  bit-for-bit the params AND EF state of the unfaulted round for
  fedavg/threesfc/signsgd — turning the fault machinery on costs nothing
  when there are no faults, by IEEE identity rather than by luck;
* **graceful degradation**: with the server renormalizing over arrivals and
  client EF banking dropped payloads, fedavg and threesfc still reach the
  zero-fault target loss under 30% dropout within 2x the zero-fault
  round count (rounds-to-target, measured on the smoothed loss curve).

The full grid — {fedavg, threesfc, signsgd} x dropout {0, 30, 50%} x
staleness k in {0, 2} (k=2 adds 40% stragglers, late payloads weighted
1/(1+delay)) — is recorded for the table; only the 30%-dropout column is
gated (50% dropout and staleness are reported, not promised). Fault
schedules are a pure function of (fault_seed, round), so every cell is
deterministic — ``--quick`` differs from ``--full`` only in rounds. Emits
``BENCH_faults.json`` (repo root) + ``experiments/results/faults.json``
for the ``scripts/check_bench.py`` trajectory gate.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

import jax
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_CLIENTS = 8
LOCAL_STEPS, LOCAL_BATCH = 2, 16
DROPOUTS = (0.0, 0.3, 0.5)
STALENESS = (0, 2)
STRAGGLER_RATE = 0.4          # only in the k=2 cells
FAULT_SEED = 17
SMOOTH = 3                    # rounds-to-target on a 3-round moving average


def _methods():
    from repro.configs.base import CompressorConfig

    return {
        "fedavg": CompressorConfig(kind="identity", error_feedback=False),
        "threesfc": CompressorConfig(kind="threesfc", syn_steps=3,
                                     syn_lr=0.1),
        "signsgd": CompressorConfig(kind="signsgd"),
    }


def _world(train_size: int):
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.models.cnn import MNIST_SPEC, make_paper_model

    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    train = make_class_image_dataset(jax.random.PRNGKey(1), train_size,
                                     MNIST_SPEC.input_shape, 10)
    parts = dirichlet_partition(train.y, N_CLIENTS, alpha=0.5, seed=0,
                                min_per_client=LOCAL_BATCH)
    return model, params, train, parts


def _run_cell(model, params, train, parts, ccfg, rounds: int, *,
              drop: float = 0.0, k: int = 0, sched_fn=None) -> Dict:
    """One (method, fault-config) trajectory: the stacked per-round loss
    curve and mean arrivals, from ONE scanned dispatch."""
    from repro.configs.base import FLConfig
    from repro.configs.run import RunConfig
    from repro.core.strategy import make_strategy
    from repro.fl.engine import RoundEngine, device_pools, vision_batcher
    from repro.fl.round import build_fl_round
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import MNIST_SPEC

    spec = vision_syn_spec(MNIST_SPEC, ccfg)
    strat = make_strategy(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                          local_lr=0.05)
    cfg = FLConfig(num_clients=N_CLIENTS, local_steps=LOCAL_STEPS,
                   local_lr=0.05, local_batch=LOCAL_BATCH, compressor=ccfg)
    run = RunConfig(fl=cfg, drop_rate=drop, fault_seed=FAULT_SEED,
                    straggler_rate=STRAGGLER_RATE if k > 0 else 0.0,
                    staleness_max=k)
    eng = RoundEngine(
        build_fl_round(model.loss, strat, run, fault_schedule_fn=sched_fn),
        vision_batcher(train.x, train.y, device_pools(parts),
                       LOCAL_STEPS, LOCAL_BATCH), seed=0)
    state = eng.init_state(params, N_CLIENTS, strat,
                           staleness_max=run.staleness_max)
    state, ms = eng.run_block(state, rounds)
    return {
        "state": state,
        "loss": np.asarray(ms.loss, np.float64),
        "arrivals_mean": float(np.mean(np.asarray(ms.arrivals))),
    }


def _rounds_to_target(loss: np.ndarray, target: float) -> Optional[int]:
    """First round (1-based) where the SMOOTH-round trailing mean of the
    participant loss crosses the target; None = never within the run."""
    smooth = np.convolve(loss, np.ones(SMOOTH) / SMOOTH, mode="valid")
    hits = np.nonzero(smooth <= target)[0]
    return int(hits[0]) + SMOOTH if hits.size else None


def _bitwise_gate(model, params, train, parts, kinds) -> Dict:
    """Masked pipeline + null schedule vs the unfaulted round, 2 rounds."""
    from repro.fl import faults as F

    out = {}
    for name, ccfg in kinds.items():
        plain = _run_cell(model, params, train, parts, ccfg, 2)
        null = _run_cell(model, params, train, parts, ccfg, 2,
                         sched_fn=lambda r, n: F.null_schedule(n))
        eq = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for tree in (("params",), ("ef",))
            for a, b in zip(
                jax.tree_util.tree_leaves(getattr(plain["state"], tree[0])),
                jax.tree_util.tree_leaves(getattr(null["state"], tree[0]))))
        out[name] = bool(
            eq and np.array_equal(plain["loss"], null["loss"]))
    return out


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    rounds = 24 if quick else 60
    target_round = max(rounds * 2 // 5, SMOOTH)   # 2x headroom fits the run
    kinds = _methods()
    model, params, train, parts = _world(800 if quick else 2000)

    print(f"zero-fault bitwise gate (2 rounds x {len(kinds)} methods)...")
    bitwise = _bitwise_gate(model, params, train, parts, kinds)

    grid: Dict[str, Dict] = {}
    targets: Dict[str, float] = {}
    for name, ccfg in kinds.items():
        grid[name] = {}
        for k in STALENESS:
            for drop in DROPOUTS:
                cell = f"drop{int(drop * 100)}_k{k}"
                print(f"{name}: {cell} ({rounds} rounds)...")
                r = _run_cell(model, params, train, parts, ccfg, rounds,
                              drop=drop, k=k)
                rec = {"final_loss": float(r["loss"][-1]),
                       "arrivals_mean": r["arrivals_mean"],
                       "loss_curve": [round(float(x), 4) for x in r["loss"]]}
                if drop == 0.0 and k == 0:
                    # the method's own healthy run sets its target
                    smooth = np.convolve(r["loss"],
                                         np.ones(SMOOTH) / SMOOTH, "valid")
                    targets[name] = float(smooth[target_round - SMOOTH])
                rec["rounds_to_target"] = _rounds_to_target(
                    r["loss"], targets[name])
                grid[name][cell] = rec

    results: Dict = {
        "config": {
            "model": "mlp", "dataset": "mnist", "num_clients": N_CLIENTS,
            "local_steps": LOCAL_STEPS, "local_batch": LOCAL_BATCH,
            "rounds": rounds, "dropouts": list(DROPOUTS),
            "staleness": list(STALENESS), "straggler_rate": STRAGGLER_RATE,
            "fault_seed": FAULT_SEED, "smooth": SMOOTH,
        },
        "targets": targets,
        "zero_fault_bitwise": bitwise,
        "grid": grid,
    }

    results["pass_zero_fault_bitwise"] = bool(all(bitwise.values()))
    gate_30 = {}
    for name in ("fedavg", "threesfc"):
        r0 = grid[name]["drop0_k0"]["rounds_to_target"]
        r30 = grid[name]["drop30_k0"]["rounds_to_target"]
        gate_30[name] = bool(r0 is not None and r30 is not None
                             and r30 <= 2 * r0)
    results["gate_30_dropout"] = gate_30
    results["pass_dropout_convergence"] = bool(all(gate_30.values()))
    results["pass"] = bool(results["pass_zero_fault_bitwise"]
                           and results["pass_dropout_convergence"])

    print(f"\n== Rounds to zero-fault target loss (mlp/mnist, "
          f"{rounds} rounds, target @ round {target_round}) ==")
    print(f"  {'method':9s} {'target':>7s} "
          + " ".join(f"{f'd{int(d*100)}/k{k}':>8s}"
                     for k in STALENESS for d in DROPOUTS))
    for name in kinds:
        cells = " ".join(
            f"{str(grid[name][f'drop{int(d*100)}_k{k}']['rounds_to_target'] or '-'):>8s}"
            for k in STALENESS for d in DROPOUTS)
        print(f"  {name:9s} {targets[name]:7.4f} {cells}")
    print(f"  [{'PASS' if results['pass_zero_fault_bitwise'] else 'FAIL'}] "
          f"null fault schedule == unfaulted round, bitwise params+EF+loss "
          f"({', '.join(k for k, v in bitwise.items() if v) or 'none'})")
    print(f"  [{'PASS' if results['pass_dropout_convergence'] else 'FAIL'}] "
          f"fedavg+threesfc reach the zero-fault target under 30% dropout "
          f"within 2x the zero-fault rounds")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "faults.json"), "w") as f:
        json.dump(results, f, indent=2)
    with open(os.path.join(REPO, "BENCH_faults.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True)
    g.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
