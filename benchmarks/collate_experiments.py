"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json + experiments/results/*.json.

    PYTHONPATH=src python -m benchmarks.collate_experiments
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")
RES = os.path.join(ROOT, "experiments", "results")


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_section() -> str:
    lines = ["## §Dry-run — (arch × shape) × {16×16, 2×16×16}", ""]
    for mesh in ("16x16", "2x16x16"):
        files = sorted(glob.glob(os.path.join(DRY, f"*__{mesh}.json")))
        if not files:
            continue
        lines += [f"### mesh {mesh} ({256 if mesh=='16x16' else 512} chips)", "",
                  "| arch | shape | lower+compile s | args/dev GiB | peak/dev GiB "
                  "| HLO GFLOPs/dev | coll MB/dev |",
                  "|---|---|---|---|---|---|---|"]
        for fn in files:
            r = json.load(open(fn))
            m = r["memory_per_dev"]
            roof = r["roofline"]
            coll = sum(roof["coll_bytes_per_dev"].values())
            lines.append(
                f"| {r['arch']} | {r['shape']} "
                f"| {r['lower_s'] + r['compile_s']:.0f} "
                f"| {_fmt_bytes(m['argument_bytes'])} "
                f"| {_fmt_bytes(m['peak_bytes'])} "
                f"| {roof['flops_per_dev']/1e9:.1f} "
                f"| {coll/2**20:.2f} |")
        lines.append("")
    lines += ["Documented skip: seamless-m4t-medium × long_500k (full "
              "cross-attention enc-dec has no 500k decode use-case — DESIGN.md §5). "
              "All other pairs lower AND compile on both meshes.", ""]
    return "\n".join(lines)


def roofline_section() -> str:
    files = sorted(glob.glob(os.path.join(DRY, "*__16x16.json")))
    lines = ["## §Roofline — per (arch × shape), single-pod 16×16", "",
             "Terms in seconds/step on v5e (197 TF/s bf16, 819 GB/s HBM, "
             "50 GB/s ICI); per-device post-partition program.", "",
             "| arch | shape | compute_s | memory_s | collective_s | dominant "
             "| useful (6ND/HLO) |",
             "|---|---|---|---|---|---|---|"]
    doms = {"compute": 0, "memory": 0, "collective": 0}
    for fn in files:
        r = json.load(open(fn))
        roof = r["roofline"]
        doms[roof["dominant"]] += 1
        lines.append(
            f"| {r['arch']} | {r['shape']} | {roof['compute_s']:.3e} "
            f"| {roof['memory_s']:.3e} | {roof['collective_s']:.3e} "
            f"| **{roof['dominant']}** | {roof['useful_ratio']:.3f} |")
    lines += ["", f"Dominant-term census: {doms}", ""]
    return "\n".join(lines)


def repro_section() -> str:
    lines = ["## §Repro — paper claims C1–C6 (reduced scale, synthetic data)", ""]
    t2 = os.path.join(RES, "table2.json")
    if os.path.exists(t2):
        data = json.load(open(t2))
        lines += ["### Table 2 analogue — final acc / acc-AUC (compression ratio)",
                  "",
                  "C1 is a convergence-RATE claim: the acc-curve AUC resolves "
                  "orderings that the saturated final point hides.", "",
                  "| cell | fedavg | dgc | signsgd | stc | 3sfc |", "|---|---|---|---|---|---|"]
        for cell, res in data.items():
            row = [cell]
            for m in ("fedavg", "dgc", "signsgd", "stc", "threesfc"):
                auc = res[m].get("auc")
                a = f"{res[m]['acc']:.3f}"
                if auc is not None:
                    a += f"/{auc:.3f}"
                row.append(f"{a} ({res[m]['ratio']:.0f}x)")
            lines.append("| " + " | ".join(row) + " |")
        lines.append("")
    t3 = os.path.join(RES, "table3.json")
    if os.path.exists(t3):
        data = json.load(open(t3))
        lines += ["### Table 3 analogue — 3SFC budget scaling vs STC (C2)", "",
                  "| cell | method | final acc | ratio |", "|---|---|---|---|"]
        for cell, res in data.items():
            for m, v in res.items():
                lines.append(f"| {cell} | {m} | {v['acc']:.4f} | {v['ratio']:.1f}x |")
        lines.append("")
    t4 = os.path.join(RES, "table4.json")
    if os.path.exists(t4):
        data = json.load(open(t4))
        lines += ["### Table 4 analogue — 3SFC ablation (MLP+MNIST-like)", "",
                  "| variant | final acc |", "|---|---|"]
        for k, v in data.items():
            lines.append(f"| {k} | {v['acc']:.4f} |")
        lines.append("")
    f7 = os.path.join(RES, "fig7.json")
    if os.path.exists(f7):
        import numpy as np
        data = json.load(open(f7))
        lines += ["### Fig 7 analogue — mean compression efficiency (cosine)", ""]
        for k, v in data.items():
            lines.append(f"* {k}: {float(np.mean(v)):.4f}")
        lines.append("")
    e2e = os.path.join(ROOT, "experiments", "e2e_train", "metrics.jsonl")
    if os.path.exists(e2e):
        recs = [json.loads(l) for l in open(e2e)]
        if recs:
            best = max(recs, key=lambda r: r["acc"])
            last = recs[-1]
            lines += ["### End-to-end driver (examples/fl_training.py "
                      "→ repro.launch.train)", "",
                      f"200 rounds × 20 non-iid clients, MLP + 3SFC @ 250.6×: "
                      f"loss {recs[0]['loss']:.3f} → {last['loss']:.3f}, "
                      f"best test acc {best['acc']:.3f} (round {best['round']}), "
                      f"{last['elapsed_s']:.0f}s on 1 CPU core; checkpoint at "
                      "experiments/e2e_train/final.", ""]
    fs = os.path.join(RES, "fedsynth_collapse.json")
    if os.path.exists(fs):
        data = json.load(open(fs))
        lines += ["### Fig 2/3 + Table 1 analogue — FedSynth instability", "",
                  "| unroll depth | grad-through-unroll norm | fit cosine |",
                  "|---|---|---|"]
        for u, v in sorted(data["fedsynth"].items(), key=lambda kv: int(kv[0])):
            lines.append(f"| {u} | {v['syn_grad_norm']:.4g} | {v['cosine']:+.4f} |")
        lines.append(f"\n3SFC (single simulation step) fit cosine: "
                     f"{data['threesfc']['cosine']:+.4f}")
        lines.append("")
    return "\n".join(lines)


def main():
    parts = [
        "# EXPERIMENTS — 3SFC reproduction + multi-pod dry-run + roofline + perf",
        "",
        "Reproduce: `python -m benchmarks.run`, `python -m repro.launch.dryrun "
        "--all [--multi-pod]`, `python -m benchmarks.collate_experiments`.",
        "",
        "Hardware model: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM, "
        "~50 GB/s ICI. Container is CPU-only: §Repro numbers are *executed* "
        "(reduced scale, synthetic data — orderings/gaps are the claims, "
        "DESIGN.md §9); §Dry-run/§Roofline come from AOT "
        "`.lower().compile()` artifacts.",
        "",
        repro_section(),
        dryrun_section(),
        roofline_section(),
    ]
    perf_path = os.path.join(ROOT, "experiments", "PERF.md")
    if os.path.exists(perf_path):
        parts.append(open(perf_path).read())
    else:
        parts.append("## §Perf — hillclimb logs\n\n*pending*\n")
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
