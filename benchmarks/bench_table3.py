"""Paper Table 3 — 3SFC at 2xB / 4xB budgets vs STC (32x).

Claim C2: 3SFC reaches comparable-or-better accuracy than STC while
communicating 10-100x less.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict

from benchmarks.fl_harness import (DATASETS, fmt_table, matched_compressors,
                                   run_fl)

CELLS_QUICK = [("mlp", "mnist")]
CELLS_FULL = [("mlp", "mnist"), ("mlp", "emnist"), ("mnistnet", "fmnist"),
              ("regnet", "cifar100")]


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    cells = CELLS_QUICK if quick else CELLS_FULL
    rounds = 30 if quick else 120
    results: Dict[str, Dict] = {}
    rows = []
    for model_name, dataset in cells:
        import jax
        from repro.core import flat
        from repro.models.cnn import make_paper_model
        spec = DATASETS[dataset]
        d = flat.tree_size(make_paper_model(model_name, spec).init(jax.random.PRNGKey(0)))
        comps = matched_compressors(model_name, spec, d)
        cell = {}
        variants = {
            "stc_32x": comps["stc"],
            "3sfc_2xB": dataclasses.replace(comps["threesfc"], syn_batch=2),
            "3sfc_4xB": dataclasses.replace(comps["threesfc"], syn_batch=4),
        }
        for name, comp in variants.items():
            r = run_fl(model_name, dataset, comp, num_clients=10, rounds=rounds,
                       train_size=2000 if quick else 6000,
                       test_size=500 if quick else 1500,
                       eval_every=max(rounds // 6, 1), label=name)
            cell[name] = {"acc": r.final_acc, "ratio": r.comp_ratio}
            rows.append((f"{model_name}+{dataset}", name, f"{r.final_acc:.4f}",
                         f"{r.comp_ratio:.1f}x"))
        results[f"{model_name}+{dataset}"] = cell
    print("\n== Table 3 (reduced): 3SFC budget scaling vs STC ==")
    print(fmt_table(rows, ["cell", "method", "final acc", "ratio"]))
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "table3.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
