"""Round-engine benchmark: rounds/sec, dispatches/round, host syncs/round.

Python-loop baseline (the seed drivers' shape) vs the scanned
``repro.fl.engine`` on the MLP/MNIST paper config (N=10 clients, K=5 local
steps, B=32): the loop samples batches on the host with numpy, uploads the
``(N, K, B, 28, 28, 1)`` tree, dispatches one jitted round and blocks on two
scalar syncs — every round. The engine gathers batches on device inside one
``lax.scan`` dispatch per eval block (L rounds) with a single stacked-metrics
sync and donated EF state.

Two measurements, both recorded (same philosophy as ``bench_kernels``: the
CI box is a noisy shared CPU, so the *gated* numbers must be the ones the
hardware cannot blur):

* ``driver``: the two drivers running a null round body at the full paper
  batch shapes. The round compute is ~zero, so rounds/sec here *is* the
  per-round driver tax (host sampling + upload + dispatch + syncs) that the
  engine removes — the quantity this PR optimizes. Gate: engine ≥2x loop.
* ``e2e``: the same comparison with the real FedAvg round body (and 3SFC
  under ``--full``). On accelerators this converges to the driver ratio; on
  the CPU CI box the vmapped local-SGD body dominates wall-clock (~85-95%),
  so this ratio is recorded for the trajectory but not gated.

All wall-clock comparisons are *interleaved*: each timing pair runs a loop
segment and an engine block back to back and the speedup is the median of
per-pair ratios, so the box's minutes-scale throughput drift (2x+ observed)
cancels out of the trajectory number. Structural accounting comes from
instrumentation, not wall-clock: dispatch/sync counters (gate: ≤1 host sync
per eval block for the engine) and a ``transfer_guard`` probe block that
raises on ANY host->device transfer inside the engine dispatch (gate: zero
violations — the loop, by contrast, uploads the full batch tree per round).
Emits ``BENCH_round_engine.json`` (repo root) + ``experiments/results/
round_engine.json`` for the ``scripts/check_bench.py`` trajectory gate.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.run import RunConfig
from repro.core.strategy import make_strategy
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_class_image_dataset
from repro.fl.budget import matched_compressors
from repro.fl.engine import RoundEngine, device_pools, vision_batcher
from repro.fl.round import FLState, RoundMetrics, build_fl_round
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, make_paper_model

N_CLIENTS, LOCAL_STEPS, LOCAL_BATCH = 10, 5, 32      # paper MLP/MNIST config
BLOCK = 5                                            # rounds per eval block


def _null_round(state: FLState, batches, key):
    """State-passthrough round whose metrics depend on the real batch (so
    neither path can dead-code-eliminate the sampling/upload)."""
    x, y = batches["x"], batches["y"]
    per_client = jnp.mean(x.reshape(x.shape[0], -1), axis=1)
    loss = jnp.mean(per_client) + 0.0 * jnp.sum(y)
    return (FLState(state.params, state.ef, state.round + 1),
            RoundMetrics(loss=loss, cosine=per_client,
                         payload_floats=jnp.float32(0),
                         update_norm=jnp.mean(per_client)))


def _host_sampler(train, parts, rng):
    """The seed drivers' per-round host path: numpy choice + gather + upload."""
    def sample():
        bx = np.empty((N_CLIENTS, LOCAL_STEPS, LOCAL_BATCH,
                       *MNIST_SPEC.input_shape), np.float32)
        by = np.empty((N_CLIENTS, LOCAL_STEPS, LOCAL_BATCH), np.int32)
        for i, pool in enumerate(parts):
            idx = rng.choice(pool, size=(LOCAL_STEPS, LOCAL_BATCH), replace=True)
            bx[i] = train.x[idx]
            by[i] = train.y[idx]
        return {"x": jnp.asarray(bx), "y": jnp.asarray(by)}, bx.nbytes + by.nbytes
    return sample


def _paired_measure(round_fn, loop_state, sample, engine: RoundEngine,
                    make_engine_state, pairs: int, loop_seg: int) -> Dict:
    """Interleaved A/B timing: each pair runs a loop segment then an engine
    block back to back, and the reported speedup is the median of per-pair
    ratios. The CI box's throughput drifts by 2x+ on a minutes scale
    (shared cores, throttling epochs); measuring the two drivers inside the
    same pair cancels that drift, which sequential whole-side measurements
    do not — the per-pair ratio is the trajectory-stable number."""
    rfj = jax.jit(round_fn)
    kr = jax.random.PRNGKey(1)
    b, nbytes = sample()
    loop_state, m = rfj(loop_state, b, kr)            # compile warmups
    float(m.loss)
    engine_state, _ = engine.run_block(make_engine_state(), BLOCK)
    # real upload instrumentation, not a counter that nothing increments:
    # one probe block under a disallow guard — ANY host->device transfer
    # inside the engine's dispatch raises, flipping the bench gate. Other
    # failures re-raise (they are bench bugs, not upload regressions), and
    # a tripped probe leaves a possibly-consumed donated state behind, so
    # the timing loop below restarts from a fresh one.
    try:
        with jax.transfer_guard_host_to_device("disallow"):
            engine_state, _ = engine.run_block(engine_state, BLOCK)
        upload_violation = False
    except Exception as e:
        if "transfer" not in str(e).lower():
            raise
        upload_violation = True
        engine_state, _ = engine.run_block(make_engine_state(), BLOCK)
    engine.stats.__init__()                           # drop warmup from counts

    loop_ts, eng_ts, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.perf_counter()
        for _ in range(loop_seg):                     # seed driver pattern
            b, _ = sample()
            kr, kk = jax.random.split(kr)
            loop_state, m = rfj(loop_state, b, kk)
            float(m.loss)
            float(jnp.mean(m.cosine))
        tl = (time.perf_counter() - t0) / loop_seg
        t0 = time.perf_counter()
        engine_state, _ = engine.run_block(engine_state, BLOCK)
        te = (time.perf_counter() - t0) / BLOCK
        loop_ts.append(tl)
        eng_ts.append(te)
        ratios.append(tl / te)
    l_med, e_med = float(np.median(loop_ts)), float(np.median(eng_ts))
    per = engine.stats.per_round()
    return {
        "loop": {"rounds_per_sec": 1.0 / l_med, "ms_per_round": l_med * 1e3,
                 "dispatches_per_round": 1.0, "host_syncs_per_round": 2.0,
                 "h2d_bytes_per_round": float(nbytes)},
        "engine": {"rounds_per_sec": 1.0 / e_med, "ms_per_round": e_med * 1e3,
                   "host_syncs_per_eval_block":
                       engine.stats.host_syncs / max(engine.stats.dispatches, 1),
                   "upload_guard_violations": int(upload_violation),
                   **per},
        "speedup": float(np.median(ratios)),
    }


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    train_size = 2048 if quick else 4000
    train = make_class_image_dataset(jax.random.PRNGKey(0), train_size,
                                     MNIST_SPEC.input_shape, 10)
    parts = dirichlet_partition(train.y, N_CLIENTS, alpha=0.5, seed=0,
                                min_per_client=LOCAL_BATCH)
    pools = device_pools(parts)
    batch_fn = vision_batcher(train.x, train.y, pools, LOCAL_STEPS, LOCAL_BATCH)
    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(2))
    d = sum(l.size for l in jax.tree_util.tree_leaves(params))

    results: Dict = {
        "config": {"model": "mlp", "dataset": "mnist", "num_clients": N_CLIENTS,
                   "local_steps": LOCAL_STEPS, "local_batch": LOCAL_BATCH,
                   "rounds_per_eval_block": BLOCK, "train_size": train_size,
                   "model_params": d},
    }

    # ---- driver tax: null round body, real batch shapes -------------------
    pairs = 8 if quick else 20
    empty = FLState({}, {}, jnp.zeros((), jnp.int32))
    eng = RoundEngine(_null_round, batch_fn, seed=0)
    results["driver"] = _paired_measure(
        _null_round, empty, _host_sampler(train, parts,
                                          np.random.default_rng(0)),
        eng, lambda: FLState({}, {}, jnp.zeros((), jnp.int32)),
        pairs, loop_seg=BLOCK)
    drv_loop, drv_eng = results["driver"]["loop"], results["driver"]["engine"]
    speedup = results["driver"]["speedup"]
    print("\n== Driver tax (null round body, paper batch shapes) ==")
    print(f"  python loop : {drv_loop['rounds_per_sec']:8.1f} rounds/s "
          f"({drv_loop['ms_per_round']:.2f} ms/round, 1 dispatch + 2 syncs + "
          f"{drv_loop['h2d_bytes_per_round']/1e6:.2f} MB upload per round)")
    print(f"  scanned     : {drv_eng['rounds_per_sec']:8.1f} rounds/s "
          f"({drv_eng['ms_per_round']:.2f} ms/round, "
          f"{drv_eng['dispatches_per_round']:.2f} dispatches + "
          f"{drv_eng['host_syncs_per_round']:.2f} syncs per round)")
    print(f"  [{'PASS' if speedup >= 2.0 else 'FAIL'}] engine >= 2x loop "
          f"rounds/sec on the driver path ({speedup:.1f}x)")

    # ---- end to end -------------------------------------------------------
    comps = matched_compressors("mlp", MNIST_SPEC, d)
    kinds = ["fedavg"] if quick else ["fedavg", "threesfc"]
    results["e2e"] = {}
    for kind in kinds:
        comp = comps[kind]
        strategy = make_strategy(comp, loss_fn=model.syn_loss,
                                 syn_spec=vision_syn_spec(MNIST_SPEC, comp),
                                 local_lr=0.01)
        cfg = FLConfig(num_clients=N_CLIENTS, local_steps=LOCAL_STEPS,
                       local_lr=0.01, local_batch=LOCAL_BATCH, compressor=comp)
        rf = build_fl_round(model.loss, strategy, RunConfig(fl=cfg))
        e_pairs = (3 if kind == "fedavg" else 1) * (1 if quick else 2)
        eng2 = RoundEngine(rf, batch_fn, seed=0)
        results["e2e"][kind] = _paired_measure(
            rf, eng2.init_state(params, N_CLIENTS),
            _host_sampler(train, parts, np.random.default_rng(1)),
            eng2, lambda: eng2.init_state(params, N_CLIENTS), e_pairs,
            loop_seg=2 if kind == "fedavg" else 1)
        e_loop, e_eng = results["e2e"][kind]["loop"], results["e2e"][kind]["engine"]
        sp = results["e2e"][kind]["speedup"]
        print(f"\n== End to end ({kind}) ==")
        print(f"  python loop : {e_loop['rounds_per_sec']:8.2f} rounds/s "
              f"({e_loop['ms_per_round']:.1f} ms/round)")
        print(f"  scanned     : {e_eng['rounds_per_sec']:8.2f} rounds/s "
              f"({e_eng['ms_per_round']:.1f} ms/round) -> {sp:.2f}x "
              f"(compute-bound on CPU; not gated)")

    # ---- structural gates -------------------------------------------------
    syncs_per_block = drv_eng["host_syncs_per_eval_block"]
    violations = drv_eng["upload_guard_violations"]
    results.update({
        "pass_driver_speedup": bool(speedup >= 2.0),
        "pass_syncs_per_eval_block": bool(syncs_per_block <= 1.0),
        "pass_no_per_round_upload": bool(violations == 0),
    })
    results["pass"] = all(results[k] for k in
                          ("pass_driver_speedup", "pass_syncs_per_eval_block",
                           "pass_no_per_round_upload"))
    print(f"\n  [{'PASS' if results['pass_syncs_per_eval_block'] else 'FAIL'}] "
          f"<= 1 host sync per eval block (measured "
          f"{syncs_per_block:.2f})")
    print(f"  [{'PASS' if results['pass_no_per_round_upload'] else 'FAIL'}] "
          f"no host->device transfer inside the engine dispatch "
          f"(transfer-guard probe, {violations} violation(s))")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "round_engine.json"), "w") as f:
        json.dump(results, f, indent=2)
    # trajectory artifact, anchored to the repo root (see scripts/check_bench)
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(repo_root, "BENCH_round_engine.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True,
                   help="small sizes, CPU-friendly (default)")
    g.add_argument("--full", dest="quick", action="store_false",
                   help="paper-scale sizes + 3SFC end-to-end row")
    args = ap.parse_args()
    run(quick=args.quick)
