"""Paper Table 2 — test accuracy x compression ratio across the five methods.

Reduced-scale reproduction (CPU, synthetic class-conditional data): the
*orderings and gaps* are the claims under test (DESIGN.md §9):
  C1: 3SFC > DGC at the SAME (extremely low) rate.
  C2: 3SFC at ~10-100x lower budget is competitive with signSGD/STC (32x).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.fl_harness import (DATASETS, fmt_table, matched_compressors,
                                   run_fl)

# (model, dataset) cells; paper's 9-cell grid, reduced to a representative set.
# Quick mode uses the MLP cell only: conv nets need >100 rounds to resolve
# the ordering (the paper trains 200 epochs; see full mode).
CELLS_QUICK = [("mlp", "mnist")]
CELLS_FULL = [("mlp", "mnist"), ("mlp", "emnist"), ("mlp", "fmnist"),
              ("mnistnet", "fmnist"), ("convnet", "cifar10"),
              ("resnet", "cifar10"), ("regnet", "cifar100")]


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    cells = CELLS_QUICK if quick else CELLS_FULL
    rounds = 60 if quick else 200
    clients = 10
    results: Dict[str, Dict] = {}
    rows: List = []
    for model_name, dataset in cells:
        import jax
        from repro.core import flat
        from repro.models.cnn import make_paper_model
        spec = DATASETS[dataset]
        d = flat.tree_size(make_paper_model(model_name, spec).init(jax.random.PRNGKey(0)))
        comps = matched_compressors(model_name, spec, d)
        cell = {}
        for method, comp in comps.items():
            r = run_fl(model_name, dataset, comp, num_clients=clients,
                       rounds=rounds, train_size=2000 if quick else 6000,
                       test_size=500 if quick else 1500,
                       eval_every=max(rounds // 6, 1),
                       label=f"{model_name}/{dataset}/{method}")
            auc = sum(r.acc_curve) / max(len(r.acc_curve), 1)
            cell[method] = {"acc": r.final_acc, "auc": auc,
                            "ratio": r.comp_ratio,
                            "curve": r.acc_curve, "cosine": r.cosine_curve}
            rows.append((f"{model_name}+{dataset}", method,
                         f"{r.final_acc:.4f}", f"{auc:.4f}",
                         f"{r.comp_ratio:.1f}x", f"{r.seconds:.0f}s"))
        results[f"{model_name}+{dataset}"] = cell
    print("\n== Table 2 (reduced): accuracy x compression ratio ==")
    print(fmt_table(rows, ["cell", "method", "final acc", "acc AUC", "ratio", "time"]))
    # claim checks
    checks = []
    # C1 is a CONVERGENCE-RATE claim -> compare accuracy AUC, not only the
    # final point (the paper's Fig. 6 shows 3SFC ahead along the curve)
    for cell, res in results.items():
        checks.append((cell, "C1: 3SFC convergence (acc AUC) >= DGC @ same rate",
                       res["threesfc"]["auc"] >= res["dgc"]["auc"] - 0.02))
    print("\nclaim checks:")
    for c in checks:
        print(f"  [{'PASS' if c[2] else 'FAIL'}] {c[0]}: {c[1]}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "table2.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
