"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-friendly)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale rounds
    PYTHONPATH=src python -m benchmarks.run --only table2,fig7

Dry-run/roofline tables are produced separately (they need the 512-device
XLA flag): ``python -m repro.launch.dryrun --all`` then
``python -m benchmarks.roofline_table``.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_collectives, bench_faults, bench_fedsynth,
                        bench_fig1, bench_fig7, bench_kernels,
                        bench_observability, bench_recovery,
                        bench_round_engine, bench_ssweep, bench_table2,
                        bench_table3, bench_table4, bench_transport,
                        bench_wire)

BENCHES = {
    "fig1": bench_fig1.run,          # convergence vs rate
    "table2": bench_table2.run,      # 5-method accuracy x ratio grid
    "table3": bench_table3.run,      # 3SFC budget scaling vs STC
    "table4": bench_table4.run,      # EF / B / K ablation
    "fig7": bench_fig7.run,          # compression efficiency curves
    "fedsynth": bench_fedsynth.run,  # table1 + fig2/3 collapse
    "ssweep": bench_ssweep.run,      # encoder-iteration knob (Algorithm 1 S)
    "kernels": bench_kernels.run,    # fused-kernel pass accounting
    "round_engine": bench_round_engine.run,  # scanned engine vs python loop
    "collectives": bench_collectives.run,    # sharded fan-out wire bytes
    "wire": bench_wire.run,                  # serialized codec bytes + parity
    "faults": bench_faults.run,              # dropout/staleness degradation
    "transport": bench_transport.run,        # live socket rounds vs oracle
    "recovery": bench_recovery.run,          # chaos-kill: resume + rejoin
    "observability": bench_observability.run,  # trace overhead + completeness
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--strategies", action="store_true",
                    help="list the registered compression strategies (and "
                         "which have a wire codec) instead of running")
    args = ap.parse_args(argv)
    if args.strategies:
        from repro.comm.codec import CODECS
        from repro.core.strategy import STRATEGIES, strategy_kinds
        for kind in strategy_kinds():
            cls = STRATEGIES[kind]
            tags = [t for t, on in (
                ("fused-aggregate", cls.supports_fused_aggregate),
                ("wire-codec", kind in CODECS)) if on]
            print(f"{kind:12s} {cls.__module__}.{cls.__name__}"
                  + (f"  [{', '.join(tags)}]" if tags else ""))
        return
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        print(f"unknown bench name(s): {', '.join(unknown)}", file=sys.stderr)
        print(f"valid names: {', '.join(BENCHES)}", file=sys.stderr)
        sys.exit(2)
    t0 = time.time()
    for name in names:
        print(f"\n######## {name} " + "#" * (70 - len(name)))
        BENCHES[name](quick=not args.full)
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
