"""Kernel-level benchmark: HBM-pass accounting for the fused Pallas kernels.

No wall-clock on CPU — the structural metric is bytes-accessed from
``cost_analysis`` of the lowered fused vs unfused reductions, at two levels:

* vector level: ``fused_cosine``'s contract (ONE pass over 2d floats for
  the (x·y, ||x||², ||y||²) triple instead of three separate reductions);
* encoder level: the 3SFC objective-evaluation hot path. The seed encoder
  ran ~8 O(d) reduction sweeps plus a materialized s·∇F tree per
  evaluation; the fused ``tree_stats`` path reads each gradient tree
  exactly once (≤ 2d·4 bytes + tolerance) and derives Eq. 8's scale,
  Eq. 9's value and the efficiency cosine as scalar algebra on the triple.

Also validates ``ops.fused_cosine`` / ``ops.tree_fused_stats`` against
their oracles across ragged shape sweeps, and emits ``BENCH_kernels.json``
(fused vs unfused bytes + pass counts) so the perf trajectory is tracked
round over round by ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat
from repro.kernels import ops, ref

# ragged, non-tile-aligned leaves — sums to d below
TREE_SHAPES = [(300, 1000), (1025,), (7,), (), (64, 1024), (123, 45)]


def _tree_pair(key):
    ks = jax.random.split(key, 2 * len(TREE_SHAPES))
    a = {f"p{i}": jax.random.normal(ks[2 * i], s)
         for i, s in enumerate(TREE_SHAPES)}
    b = {f"p{i}": jax.random.normal(ks[2 * i + 1], s)
         for i, s in enumerate(TREE_SHAPES)}
    return a, b


def _bytes(fn, *args) -> float:
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0))


def _seed_encoder_reductions(gw, t):
    """The seed encode's post-scan reduction sequence (structural baseline):
    tree_cosine(gw,t) inside the objective, Eq. 8's dot + sqnorm, a
    materialized recon tree, and a second tree_cosine(recon, t)."""
    def dot(x, y):
        return sum(jnp.sum(xi * yi) for xi, yi in
                   zip(jax.tree.leaves(x), jax.tree.leaves(y)))

    def sq(x):
        return sum(jnp.sum(jnp.square(xi)) for xi in jax.tree.leaves(x))

    obj_cos = dot(gw, t) / (jnp.sqrt(sq(gw)) * jnp.sqrt(sq(t)) + 1e-12)
    num = dot(t, gw)
    den = sq(gw) + 1e-12
    s = num / den
    recon = jax.tree.map(lambda x: s * x, gw)
    cos = dot(recon, t) / (jnp.sqrt(sq(recon)) * jnp.sqrt(sq(t)) + 1e-12)
    return s, cos, 1.0 - jnp.abs(obj_cos)


def _fused_encoder_reductions(gw, t):
    """The rewritten path: ONE stats triple per objective evaluation
    (structural stand-in for the Pallas kernel: same reads, same math)."""
    st = flat._tree_stats_naive(gw, t)
    d, gg, tt = st[0], st[1], st[2]
    s = d / (gg + 1e-12)
    cos = jnp.sign(s) * d / (jnp.sqrt(gg) * jnp.sqrt(tt) + 1e-12)
    return s, cos, 1.0 - jnp.abs(d / (jnp.sqrt(gg) * jnp.sqrt(tt) + 1e-12))


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    n = 1 << 20 if quick else 1 << 24
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    y = jax.random.normal(jax.random.PRNGKey(1), (n,))

    def unfused(x, y):
        return jnp.stack([jnp.vdot(x, y), jnp.vdot(x, x), jnp.vdot(y, y)])

    ideal = 2 * n * 4          # one read of x + one read of y
    results = {
        "n": n,
        "ideal_bytes": ideal,
        "unfused_bytes": _bytes(unfused, x, y),
        "fused_oracle_bytes": _bytes(ref.fused_cosine, x, y),
    }
    print("\n== Kernel pass accounting (fused_cosine, flat vectors) ==")
    print(f"  ideal single-pass bytes : {ideal:,}")
    print(f"  unfused (3x vdot)       : {results['unfused_bytes']:,.0f}")
    print(f"  fused oracle            : {results['fused_oracle_bytes']:,.0f}")

    # ---- encoder hot path: bytes per 3SFC objective evaluation ------------
    # Two accountings, both recorded:
    #  * cost_analysis of the lowered jnp stand-ins — what XLA charges on
    #    THIS backend (CPU charges every unfused elementwise intermediate,
    #    so both numbers are inflated; the ratio is still structural);
    #  * the Pallas block-spec contract — the kernel's grid DMAs exactly two
    #    (block, 1024) tiles per step, so its TPU HBM traffic is *static*
    #    (ops.tree_stats_hbm_bytes). That is the single-pass gate.
    gw, t = _tree_pair(jax.random.PRNGKey(2))
    d_tree = sum(l.size for l in jax.tree.leaves(gw))
    tree_ideal = 2 * d_tree * 4
    seed_bytes = _bytes(_seed_encoder_reductions, gw, t)
    fused_xla_bytes = _bytes(_fused_encoder_reductions, gw, t)
    kernel_bytes = ops.tree_stats_hbm_bytes(gw)
    # tolerance: tail zero padding (<8 rows/chunk by the block plan) + acc
    tol = 0.02 * tree_ideal + 2 * 8 * 1024 * 4
    results.update({
        "tree_d": d_tree,
        "tree_ideal_bytes": tree_ideal,
        "encoder_seed_bytes": seed_bytes,
        "encoder_fused_xla_bytes": fused_xla_bytes,
        "encoder_fused_kernel_bytes": kernel_bytes,
        "encoder_seed_passes": seed_bytes / (d_tree * 4),
        "encoder_fused_xla_passes": fused_xla_bytes / (d_tree * 4),
        "encoder_fused_kernel_passes": kernel_bytes / (d_tree * 4),
        "encoder_fused_single_pass": bool(kernel_bytes <= tree_ideal + tol),
        "encoder_bytes_ratio": seed_bytes / max(kernel_bytes, 1.0),
        "encoder_xla_bytes_ratio": seed_bytes / max(fused_xla_bytes, 1.0),
    })
    print("\n== Encoder stats path (per objective evaluation, tree of "
          f"d={d_tree:,}) ==")
    print(f"  ideal (read gw + read t): {tree_ideal:,}")
    print(f"  seed reductions + recon : {seed_bytes:,.0f} "
          f"({results['encoder_seed_passes']:.1f} passes, cost_analysis)")
    print(f"  fused stand-in (XLA)    : {fused_xla_bytes:,.0f} "
          f"({results['encoder_fused_xla_passes']:.1f} passes, cost_analysis; "
          f"{results['encoder_xla_bytes_ratio']:.1f}x less than seed)")
    print(f"  fused kernel contract   : {kernel_bytes:,.0f} "
          f"({results['encoder_fused_kernel_passes']:.2f} passes, BlockSpec "
          f"accounting)")
    print(f"  [{'PASS' if results['encoder_fused_single_pass'] else 'FAIL'}] "
          f"fused stats path <= one read of each tree (+padding tolerance); "
          f"{results['encoder_bytes_ratio']:.1f}x fewer bytes than the seed "
          f"encoder reductions")

    # correctness sweep (also covered in tests/)
    checks = []
    for size in (1000, 131072, 300001):
        xs = jax.random.normal(jax.random.PRNGKey(size), (size,))
        ys = jax.random.normal(jax.random.PRNGKey(size + 1), (size,))
        got = ops.fused_cosine(xs, ys)
        want = ref.fused_cosine(xs, ys)
        checks.append(bool(np.allclose(got, want, rtol=2e-4)))
    st_got = ops.tree_fused_stats(gw, t)
    st_want = flat._tree_stats_naive(gw, t)
    checks.append(bool(np.allclose(st_got, st_want, rtol=2e-4)))
    results["allclose"] = all(checks)
    print(f"  [{'PASS' if results['allclose'] else 'FAIL'}] "
          f"pallas(interpret) == oracle across sizes (vector + tree)")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "kernels.json"), "w") as f:
        json.dump(results, f, indent=2)
    # trajectory artifact tracked from this PR onward (see ROADMAP) —
    # anchored to the repo root so any launch cwd updates the same file
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(repo_root, "BENCH_kernels.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True,
                   help="small sizes, CPU-friendly (default)")
    g.add_argument("--full", dest="quick", action="store_false",
                   help="paper-scale sizes")
    args = ap.parse_args()
    run(quick=args.quick)
