"""Kernel-level benchmark: HBM-pass accounting for the fused Pallas kernels.

No wall-clock on CPU — the structural metric is bytes-accessed from
``cost_analysis`` of the lowered fused vs unfused encoder reductions
(fused_cosine's contract: ONE pass over 2d floats instead of three).
Also validates every kernel against its ref.py oracle across a shape sweep.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    n = 1 << 20 if quick else 1 << 24
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    y = jax.random.normal(jax.random.PRNGKey(1), (n,))

    def unfused(x, y):
        return jnp.stack([jnp.vdot(x, y), jnp.vdot(x, x), jnp.vdot(y, y)])

    cost_u = jax.jit(unfused).lower(x, y).compile().cost_analysis()
    if isinstance(cost_u, list):
        cost_u = cost_u[0]
    # fused: a single pass over both vectors
    cost_f = jax.jit(ref.fused_cosine).lower(x, y).compile().cost_analysis()
    if isinstance(cost_f, list):
        cost_f = cost_f[0]

    ideal = 2 * n * 4          # one read of x + one read of y
    results = {
        "n": n,
        "ideal_bytes": ideal,
        "unfused_bytes": cost_u.get("bytes accessed", 0.0),
        "fused_oracle_bytes": cost_f.get("bytes accessed", 0.0),
    }
    print("\n== Kernel pass accounting (fused_cosine) ==")
    print(f"  ideal single-pass bytes : {ideal:,}")
    print(f"  unfused (3x vdot)       : {results['unfused_bytes']:,.0f}")
    print(f"  fused oracle            : {results['fused_oracle_bytes']:,.0f}")

    # correctness sweep (also covered in tests/)
    checks = []
    for size in (1000, 131072, 300001):
        xs = jax.random.normal(jax.random.PRNGKey(size), (size,))
        ys = jax.random.normal(jax.random.PRNGKey(size + 1), (size,))
        got = ops.fused_cosine(xs, ys)
        want = ref.fused_cosine(xs, ys)
        checks.append(bool(np.allclose(got, want, rtol=2e-4)))
    results["allclose"] = all(checks)
    print(f"  [{'PASS' if results['allclose'] else 'FAIL'}] "
          f"pallas(interpret) == oracle across sizes")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "kernels.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
