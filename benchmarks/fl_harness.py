"""Shared FL experiment harness for the paper-reproduction benchmarks.

Runs the full federated pipeline: synthetic class-conditional dataset with
the paper's shapes -> Dirichlet non-iid partition -> N clients x K local SGD
steps -> EF-compressed uplink -> server aggregate -> test accuracy curve.

Budget accounting reproduces the paper exactly: for MLP (199,210 params) the
3SFC payload is 28·28·1 + 10 + 1 = 795 floats -> compression ratio 250.6x,
the number in the paper's Table 2. Competitor knobs are derived from the
same budget (DGC: 2k = B; STC/signSGD: the 32x quantization limit).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CompressorConfig, FLConfig
from repro.core.compressor import make_compressor
from repro.core import flat
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_class_image_dataset
from repro.fl.round import fl_init, make_fl_round
from repro.models.build import vision_syn_spec
from repro.models.cnn import (CIFAR10_SPEC, CIFAR100_SPEC, EMNIST_SPEC,
                              FMNIST_SPEC, MNIST_SPEC, VisionSpec, accuracy,
                              make_paper_model)

DATASETS = {
    "mnist": MNIST_SPEC,
    "emnist": EMNIST_SPEC,
    "fmnist": FMNIST_SPEC,
    "cifar10": CIFAR10_SPEC,
    "cifar100": CIFAR100_SPEC,
}


@dataclasses.dataclass
class ExperimentResult:
    name: str
    acc_curve: List[float]            # test accuracy per eval point
    loss_curve: List[float]
    cosine_curve: List[float]         # mean compression efficiency per round
    payload_floats: float             # per-client uplink floats per round
    model_params: int
    comp_rate: float                  # paper Eq. 1
    seconds: float

    @property
    def final_acc(self) -> float:
        return self.acc_curve[-1] if self.acc_curve else float("nan")

    @property
    def comp_ratio(self) -> float:
        return 1.0 / self.comp_rate if self.comp_rate else float("inf")


def payload_budget(model_name: str, spec: VisionSpec, syn_batch: int = 1) -> float:
    """3SFC budget B for this (model, dataset): syn pixels + soft labels + s."""
    return float(syn_batch * (int(np.prod(spec.input_shape)) + spec.num_classes) + 1)


def matched_compressors(model_name: str, spec: VisionSpec, d: int,
                        syn_batch: int = 1) -> Dict[str, CompressorConfig]:
    """The paper's five methods at the paper's budget relations."""
    B = payload_budget(model_name, spec, syn_batch)
    topk_ratio = max(B / 2.0, 1.0) / d          # 2k floats = B
    stc_ratio = (d / 33.0) / d                  # k + k/32 + 1 ~= d/32
    return {
        "fedavg": CompressorConfig(kind="identity", error_feedback=False),
        "dgc": CompressorConfig(kind="topk", keep_ratio=topk_ratio),
        "signsgd": CompressorConfig(kind="signsgd"),
        "stc": CompressorConfig(kind="stc", keep_ratio=stc_ratio),
        # S=10 encoder iterations (Algorithm 1 line 7; "single-step" refers to
        # the single SIMULATION step, vs FedSynth's K-step unroll)
        "threesfc": CompressorConfig(kind="threesfc", syn_batch=syn_batch,
                                     syn_steps=10, syn_lr=0.1),
    }


def run_fl(
    model_name: str,
    dataset: str,
    comp: CompressorConfig,
    *,
    num_clients: int = 10,
    rounds: int = 40,
    local_steps: int = 5,
    local_batch: int = 32,
    local_lr: float = 0.01,
    train_size: int = 4000,
    test_size: int = 1000,
    alpha: float = 0.5,
    eval_every: int = 5,
    seed: int = 0,
    label: Optional[str] = None,
    sigma: float = 0.35,
) -> ExperimentResult:
    t_start = time.time()
    spec = DATASETS[dataset]
    key = jax.random.PRNGKey(seed)
    kd, kt, km, kr = jax.random.split(key, 4)

    train = make_class_image_dataset(kd, train_size, spec.input_shape,
                                     spec.num_classes, sigma=sigma)
    test = make_class_image_dataset(kt, test_size, spec.input_shape,
                                    spec.num_classes, sigma=sigma)
    parts = dirichlet_partition(train.y, num_clients, alpha=alpha, seed=seed,
                                min_per_client=local_batch)

    model = make_paper_model(model_name, spec)
    params = model.init(km)
    d = flat.tree_size(params)
    syn_spec = vision_syn_spec(spec, comp)
    compressor = make_compressor(comp, loss_fn=model.syn_loss,
                                 syn_spec=syn_spec, local_lr=local_lr)
    fl_cfg = FLConfig(num_clients=num_clients, local_steps=local_steps,
                      local_lr=local_lr, local_batch=local_batch,
                      compressor=comp, seed=seed)
    round_fn = jax.jit(make_fl_round(model.loss, compressor, fl_cfg))
    state = fl_init(params, num_clients)

    test_x = jnp.asarray(test.x)
    test_y = jnp.asarray(test.y)

    @jax.jit
    def eval_acc(p):
        return accuracy(model.apply(p, test_x), test_y)

    rng = np.random.default_rng(seed + 1)
    payload = compressor.payload_floats(params)

    accs, losses, coses = [], [], []
    for r in range(rounds):
        # host-side batch sampling (non-iid pools per client)
        bx = np.empty((num_clients, local_steps, local_batch, *spec.input_shape),
                      np.float32)
        by = np.empty((num_clients, local_steps, local_batch), np.int32)
        for i, pool in enumerate(parts):
            idx = rng.choice(pool, size=(local_steps, local_batch), replace=True)
            bx[i] = train.x[idx]
            by[i] = train.y[idx]
        batches = {"x": jnp.asarray(bx), "y": jnp.asarray(by)}
        kr, kround = jax.random.split(kr)
        state, metrics = round_fn(state, batches, kround)
        losses.append(float(metrics.loss))
        coses.append(float(jnp.mean(metrics.cosine)))
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            accs.append(float(eval_acc(state.params)))

    return ExperimentResult(
        name=label or f"{model_name}/{dataset}/{comp.kind}",
        acc_curve=accs, loss_curve=losses, cosine_curve=coses,
        payload_floats=float(payload), model_params=d,
        comp_rate=float(payload) / d, seconds=time.time() - t_start)


def fmt_table(rows: Sequence[Tuple], headers: Sequence[str]) -> str:
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
              for i, h in enumerate(headers)]
    def line(vals):
        return " | ".join(str(v).ljust(w) for v, w in zip(vals, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
