"""Shared FL experiment harness for the paper-reproduction benchmarks.

Runs the full federated pipeline: synthetic class-conditional dataset with
the paper's shapes -> Dirichlet non-iid partition -> N clients x K local SGD
steps -> EF-compressed uplink -> server aggregate -> test accuracy curve.

Since PR 2 the round loop is the device-resident ``repro.fl.engine``: the
partition lives on device as padded index pools, batches are gathered inside
the jitted scan (no host numpy in the hot loop), and each eval block of
``eval_every`` rounds costs one dispatch + one host sync with the EF state
donated across blocks.

Budget accounting reproduces the paper exactly (see ``repro.fl.budget``,
shared with the ``launch/train.py`` driver): for MLP (199,210 params) the
3SFC payload is 28·28·1 + 10 + 1 = 795 floats -> compression ratio 250.6x,
the number in the paper's Table 2.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CompressorConfig, FLConfig
from repro.configs.run import RunConfig
from repro.core.baselines import compression_rate_bytes
from repro.core.strategy import make_strategy
from repro.core import flat
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_class_image_dataset
from repro.fl.budget import (matched_compressors, measured_wire_bytes,
                             payload_budget)
from repro.fl.engine import RoundEngine, device_pools, vision_batcher
from repro.fl.round import build_fl_round
from repro.models.build import vision_syn_spec
from repro.models.cnn import DATASETS, accuracy, make_paper_model

__all__ = ["DATASETS", "ExperimentResult", "payload_budget",
           "matched_compressors", "run_fl", "fmt_table"]


@dataclasses.dataclass
class ExperimentResult:
    name: str
    acc_curve: List[float]            # test accuracy per eval point
    loss_curve: List[float]
    cosine_curve: List[float]         # mean compression efficiency per round
    payload_floats: float             # per-client uplink floats per round
    model_params: int
    comp_rate: float                  # paper Eq. 1 (accounted floats)
    seconds: float
    # measured wire size (repro.comm codec frame, header included); None for
    # kinds without a wire codec. Reported NEXT TO the accounted floats —
    # the honest uplink bill vs the paper's convention.
    wire_bytes: Optional[float] = None
    comp_rate_bytes: Optional[float] = None
    # control-plane bytes (headers, acks, heartbeats, metric frames) billed
    # by a live transport's ledger — the part of the bill LinkStats data
    # buckets deliberately exclude. None for in-process runs (no control
    # plane); a socket run fills both via ``from_live_run``.
    overhead_up_bytes: Optional[float] = None
    overhead_down_bytes: Optional[float] = None

    @property
    def final_acc(self) -> float:
        return self.acc_curve[-1] if self.acc_curve else float("nan")

    @property
    def comp_ratio(self) -> float:
        return 1.0 / self.comp_rate if self.comp_rate else float("inf")

    @classmethod
    def from_live_run(cls, name: str, history: Sequence[dict], ledger: dict,
                      *, payload_floats: float, model_params: int,
                      seconds: float,
                      acc_curve: Sequence[float] = ()) -> "ExperimentResult":
        """Build a result from a ``LiveRoundLoop`` run: loss curve from the
        per-round worker-reported losses, byte columns from the transport's
        ledger — including the control-plane overhead the in-process path
        never has."""
        losses = [float(np.mean(list(rec["losses"].values())))
                  for rec in history if rec["losses"]]
        rounds = max(len(history), 1)
        return cls(
            name=name, acc_curve=list(acc_curve), loss_curve=losses,
            cosine_curve=[], payload_floats=float(payload_floats),
            model_params=int(model_params),
            comp_rate=float(payload_floats) / max(model_params, 1),
            seconds=float(seconds),
            wire_bytes=ledger["uplink"]["total_bytes"] / rounds,
            overhead_up_bytes=float(ledger.get("overhead_up", 0)),
            overhead_down_bytes=float(ledger.get("overhead_down", 0)))


def run_fl(
    model_name: str,
    dataset: str,
    comp: CompressorConfig,
    *,
    num_clients: int = 10,
    rounds: int = 40,
    local_steps: int = 5,
    local_batch: int = 32,
    local_lr: float = 0.01,
    train_size: int = 4000,
    test_size: int = 1000,
    alpha: float = 0.5,
    eval_every: int = 5,
    seed: int = 0,
    label: Optional[str] = None,
    sigma: float = 0.35,
    wire: str = "float",
) -> ExperimentResult:
    """``wire='codec'`` runs the round in serialized-bytes mode (only framed
    uint8 buffers cross the client/server boundary; see repro.comm) —
    bit-identical to float mode for every lossless codec, and the measured
    ``wire_bytes`` column is filled either way."""
    t_start = time.time()
    spec = DATASETS[dataset]
    key = jax.random.PRNGKey(seed)
    kd, kt, km, _ = jax.random.split(key, 4)

    train = make_class_image_dataset(kd, train_size, spec.input_shape,
                                     spec.num_classes, sigma=sigma)
    test = make_class_image_dataset(kt, test_size, spec.input_shape,
                                    spec.num_classes, sigma=sigma)
    parts = dirichlet_partition(train.y, num_clients, alpha=alpha, seed=seed,
                                min_per_client=local_batch)

    model = make_paper_model(model_name, spec)
    params = model.init(km)
    d = flat.tree_size(params)
    syn_spec = vision_syn_spec(spec, comp)
    strategy = make_strategy(comp, loss_fn=model.syn_loss,
                             syn_spec=syn_spec, local_lr=local_lr)
    fl_cfg = FLConfig(num_clients=num_clients, local_steps=local_steps,
                      local_lr=local_lr, local_batch=local_batch,
                      compressor=comp, seed=seed)
    run = RunConfig(fl=fl_cfg, wire=wire)   # validates the wire value too
    codec = strategy.wire_codec(params) if run.wire == "codec" else None
    engine = RoundEngine(
        build_fl_round(model.loss, strategy, run, codec=codec),
        vision_batcher(train.x, train.y, device_pools(parts),
                       local_steps, local_batch),
        seed=seed)
    state = engine.init_state(params, num_clients, strategy)

    test_x = jnp.asarray(test.x)
    test_y = jnp.asarray(test.y)

    @jax.jit
    def eval_acc(p):
        return accuracy(model.apply(p, test_x), test_y)

    payload = strategy.payload_floats(params)

    state, hist = engine.run(state, rounds, eval_every=eval_every,
                             eval_fn=lambda st, ms, r: float(eval_acc(st.params)))
    losses = [float(v) for v in hist.metrics.loss]
    cos = np.asarray(hist.metrics.cosine)          # (rounds, clients)
    coses = [float(v) for v in cos.reshape(len(losses), -1).mean(axis=1)]
    accs = [v for _, v in hist.evals]

    wb = measured_wire_bytes(comp, params, syn_spec=syn_spec)
    return ExperimentResult(
        name=label or f"{model_name}/{dataset}/{comp.kind}",
        acc_curve=accs, loss_curve=losses, cosine_curve=coses,
        payload_floats=float(payload), model_params=d,
        comp_rate=float(payload) / d, seconds=time.time() - t_start,
        wire_bytes=wb,
        comp_rate_bytes=None if wb is None
        else compression_rate_bytes(wb, d))


def fmt_table(rows: Sequence[Tuple], headers: Sequence[str]) -> str:
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
              for i, h in enumerate(headers)]
    def line(vals):
        return " | ".join(str(v).ljust(w) for v, w in zip(vals, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
