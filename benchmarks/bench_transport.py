"""Live socket transport: bytes, bitwise parity, conservation, straggle.

PR 6 made faults *first-class* but simulated; here the same round runs
over a real wire — ``repro.comm.transport`` sockets between this process
and worker subprocesses (``repro.launch.worker``) — and is gated against
the in-process oracle. Four gates:

* **bytes match**: data-frame bytes billed by the socket server equal
  ``N * codec.nbytes`` exactly on settled rounds (control traffic —
  heartbeats, ACKs, length prefixes — is accounted separately as
  overhead), at BOTH the tiny/stc scenario and the paper mlp/mnist
  3SFC config; the 8-client total must equal ``BENCH_wire.json``'s
  measured ``channel.uplink_bytes_per_round`` (same codec, so the live
  wire carries not one byte more than the accounted one);
* **socket bitwise**: a live multi-process run — including injected frame
  drops (``rx_filter``) and a SIGKILLed worker — produces params, per-
  client EF, and delivered masks bitwise equal to the in-process masked
  oracle (``build_fl_round`` + ``fault_schedule_fn``) on the identical
  fault pattern;
* **residual conservation**: for a round whose frame the wire ate, the
  EF identity ``e' = u - delivered`` holds exactly (``delivered = 0``,
  so ``e' == u``) — checked on the oracle at ``atol=0`` and transferred
  to the wire by the EF-bitwise gate;
* **straggle isolation**: with one worker sleeping ``STRAGGLE_S`` per
  round and a tight deadline, measured round wall clock stays bounded by
  the deadline (+ slack), NOT by the straggler — and the slow worker is
  marked undelivered, never dead (heartbeats flow during its sleep).

Worker round-0 jit compilation happens inside the live round, so every
scenario warms round 0 under a generous deadline and gates only the
settled rounds after it. Deterministic except the wall-clock gate
(slack-padded); ``--quick`` == ``--full``. Emits ``BENCH_transport.json``
(repo root) + ``experiments/results/transport.json`` for
``scripts/check_bench.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# -- tiny scenario (bitwise / faults / straggle) ----------------------------
TINY_N = 3
TINY_ROUNDS = 5                      # 0 = warm-up, 1 = settled null, 2-4 faulted
TINY_TRAIN = 120
DROPS = {(2, 1), (3, 0)}             # (round, cid) frames the wire eats
KILL_CID, KILL_AFTER_ROUND = 2, 3    # SIGKILL between rounds 3 and 4
CONS_ROUND, CONS_CID = 3, 0          # conservation checked on this drop

# -- paper-shape scenario (byte gate vs BENCH_wire) -------------------------
MLP_N = 2                            # live workers; scaled to the 8-client
MLP_MEASURED_ROUNDS = 2              # total by messages (frames are i.i.d.
MLP_TRAIN = 256                      # in size: codec.nbytes each)

# -- straggle scenario ------------------------------------------------------
STRAGGLE_CID, STRAGGLE_S = 1, 4.0
STRAGGLE_DEADLINE_S = 0.75
STRAGGLE_ROUNDS = 3                  # measured (after warm-up)
WALL_SLACK_S = 1.0                   # server-side decode/step overhead

WARM_DEADLINE_S = 600.0              # round-0 jit compile inside workers


def _ravel(tree) -> np.ndarray:
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree_util.tree_leaves(tree)])


def _ravel_row(tree, i) -> np.ndarray:
    return np.concatenate([np.asarray(l[i], np.float32).ravel()
                           for l in jax.tree_util.tree_leaves(tree)])


def _tiny_problem():
    from repro.configs.base import CompressorConfig, FLConfig
    from repro.models.cnn import VisionSpec

    spec = VisionSpec("tiny", (6, 6, 1), 3)
    comp = CompressorConfig(kind="stc", keep_ratio=0.1)
    fl = FLConfig(num_clients=TINY_N, local_steps=2, local_lr=0.05,
                  local_batch=4, compressor=comp, seed=0)
    return spec, fl


def _build(model_name, spec, fl, run):
    from repro.core.strategy import make_strategy
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import make_paper_model

    model = make_paper_model(model_name, spec)
    params = model.init(jax.random.PRNGKey(fl.seed))
    strategy = make_strategy(fl.compressor, loss_fn=model.syn_loss,
                             syn_spec=vision_syn_spec(spec, fl.compressor),
                             local_lr=fl.local_lr)
    codec = strategy.wire_codec(params, policy=run.wire_policy)
    return model, params, strategy, codec


def _socket_run(run, model_name, spec, train_size, params, strategy, codec,
                *, rounds: int, rx_filter=None, straggle=None, on_round=None,
                collect_ef: bool = True):
    """Spawn workers, warm round 0 generously, drive the measured rounds.

    Returns (final_params, efs, history, stats) where ``efs[i]`` is the
    worker's flat EF dump (None for dead workers / collect_ef=False) and
    ``stats`` carries the server's byte buckets.
    """
    from repro.comm.transport import SocketServer, spawn_local_workers
    from repro.fl.engine import LiveRoundLoop, RetryPolicy
    from repro.launch.worker import vision_setup

    N = run.fl.num_clients
    server = SocketServer(N, heartbeat_s=run.heartbeat_s,
                          liveness_timeout_s=run.liveness_timeout_s,
                          rx_filter=rx_filter)
    procs = spawn_local_workers(server.address, range(N))
    efs = [None] * N
    try:
        server.wait_ready(60)
        server.send_setup(vision_setup(run, model=model_name, spec=spec,
                                       train_size=train_size,
                                       straggle=straggle))
        loop = LiveRoundLoop(server, strategy, codec, run, params,
                             on_round=on_round)
        warm = RetryPolicy(max_retries=0, recv_timeout_s=WARM_DEADLINE_S,
                           max_timeout_s=WARM_DEADLINE_S)
        loop.run(1, deadline_s=WARM_DEADLINE_S, policy=warm)
        final = jax.device_get(loop.run(rounds - 1))
        if collect_ef:
            live = set(server.live_workers())
            efs = [server.request_ef(i, timeout=30) if i in live else None
                   for i in range(N)]
        stats = {"uplink_per_round": list(server.uplink.per_round),
                 "downlink_per_round": list(server.downlink.per_round),
                 "overhead_up": int(server.overhead_up),
                 "overhead_down": int(server.overhead_down)}
    finally:
        server.stop()
        for p in procs:
            try:
                p.wait(timeout=15)
            except Exception:
                p.kill()
    return final, efs, loop.history, stats


def _fault_plans():
    """(R, N) participate/delivered plans for the tiny fault scenario."""
    plan = np.ones((TINY_ROUNDS, TINY_N), bool)
    part = np.ones((TINY_ROUNDS, TINY_N), bool)
    for (r, c) in DROPS:
        plan[r, c] = False
    for r in range(KILL_AFTER_ROUND + 1, TINY_ROUNDS):
        plan[r, KILL_CID] = False
        part[r, KILL_CID] = False
    return plan, part


def _tiny_oracle(model, params, strategy, codec, fl, train, pools,
                 plan, part):
    """In-process masked pipeline under the identical fault pattern."""
    from repro.configs.run import RunConfig
    from repro.fl.engine import RoundEngine, vision_batcher
    from repro.fl.faults import null_schedule
    from repro.fl.round import build_fl_round

    plan_j, part_j = jnp.asarray(plan), jnp.asarray(part)

    def sched_fn(r, n):
        s = null_schedule(n)
        return s._replace(participate=part_j[r], delivered=plan_j[r])

    engine = RoundEngine(
        build_fl_round(model.loss, strategy, RunConfig(fl=fl, wire="codec"),
                       codec=codec, fault_schedule_fn=sched_fn),
        vision_batcher(train.x, train.y, pools, fl.local_steps,
                       fl.local_batch),
        seed=fl.seed)
    return engine


def _conservation(engine, model, params, strategy, fl, train, pools) -> Dict:
    """EF mass on the CONS_ROUND drop: replay the oracle to the round,
    recompute u = g + e on the engine-contract batch, run the round, and
    check e' == u exactly (the delivered payload is the zero tree)."""
    from repro.fl.client import local_train
    from repro.fl.faults import residual_mass_conserved

    state = engine.init_state(params, TINY_N, strategy)
    state, _ = engine.run_loop(state, CONS_ROUND)
    ef_before = jax.tree_util.tree_map(lambda l: l[CONS_CID], state.ef)
    data_key = jax.random.fold_in(jax.random.PRNGKey(fl.seed), 0)
    kr = jax.random.fold_in(data_key, jnp.int32(CONS_ROUND))
    k = jax.random.fold_in(kr, CONS_CID)
    pos = jax.random.randint(k, (fl.local_steps, fl.local_batch), 0,
                             pools.size[CONS_CID])
    idx = pools.index[CONS_CID, pos]
    batch = {"x": jnp.asarray(train.x)[idx], "y": jnp.asarray(train.y)[idx]}
    g, _ = local_train(model.loss, state.params, batch, fl.local_lr)
    u = jax.tree_util.tree_map(lambda a, b: a + b, g, ef_before)
    state, _ = engine.run_loop(state, 1)
    e_new = jax.tree_util.tree_map(lambda l: l[CONS_CID], state.ef)
    zero = jax.tree_util.tree_map(jnp.zeros_like, u)
    exact = bool(residual_mass_conserved(u, e_new, zero, atol=0.0))
    return {"round": CONS_ROUND, "cid": CONS_CID, "exact": exact,
            "max_abs_residual": float(max(
                float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(e_new),
                                jax.tree_util.tree_leaves(u))))}


def _tiny_scenarios() -> Dict:
    """Bitwise-vs-oracle under faults + conservation + tiny byte check."""
    from repro.configs.run import RunConfig
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.engine import device_pools

    spec, fl = _tiny_problem()
    run = RunConfig(fl=fl, wire="codec", transport="socket",
                    round_deadline_s=60.0, recv_timeout_s=1.0,
                    recv_backoff=1.5, transport_retries=1,
                    heartbeat_s=0.2, liveness_timeout_s=3.0)
    model, params, strategy, codec = _build("mlp", spec, fl, run)
    train = make_class_image_dataset(jax.random.PRNGKey(fl.seed), TINY_TRAIN,
                                     spec.input_shape, spec.num_classes)
    parts = dirichlet_partition(train.y, TINY_N, alpha=fl.dirichlet_alpha,
                                seed=fl.seed, min_per_client=fl.local_batch)
    pools = device_pools(parts)
    plan, part = _fault_plans()

    # oracle
    engine = _tiny_oracle(model, params, strategy, codec, fl, train, pools,
                          plan, part)
    state = engine.init_state(params, TINY_N, strategy)
    state, _ = engine.run_loop(state, TINY_ROUNDS)
    oracle_params, oracle_ef = jax.device_get((state.params, state.ef))

    # live: the wire eats DROPS frames; the worker dies mid-run
    def rx_filter(cid, rnd, buf):
        return None if (rnd, cid) in DROPS else buf

    killed = {"done": False}
    procs_box = {}

    def on_round(rec, rep):
        if rec["round"] == KILL_AFTER_ROUND and not killed["done"]:
            p = procs_box["procs"][KILL_CID]
            p.send_signal(signal.SIGKILL)
            p.wait()
            killed["done"] = True

    # _socket_run spawns procs internally; thread them out for the killer
    from repro.comm.transport import SocketServer, spawn_local_workers
    from repro.fl.engine import LiveRoundLoop, RetryPolicy
    from repro.launch.worker import vision_setup

    server = SocketServer(TINY_N, heartbeat_s=run.heartbeat_s,
                          liveness_timeout_s=run.liveness_timeout_s,
                          rx_filter=rx_filter)
    procs = spawn_local_workers(server.address, range(TINY_N))
    procs_box["procs"] = procs
    efs = [None] * TINY_N
    try:
        server.wait_ready(60)
        server.send_setup(vision_setup(run, model="mlp", spec=spec,
                                       train_size=TINY_TRAIN))
        loop = LiveRoundLoop(server, strategy, codec, run, params,
                             on_round=on_round)
        warm = RetryPolicy(max_retries=0, recv_timeout_s=WARM_DEADLINE_S,
                           max_timeout_s=WARM_DEADLINE_S)
        loop.run(1, deadline_s=WARM_DEADLINE_S, policy=warm)
        live_params = jax.device_get(loop.run(TINY_ROUNDS - 1))
        live = set(server.live_workers())
        efs = [server.request_ef(i, timeout=30) if i in live else None
               for i in range(TINY_N)]
        up_per_round = list(server.uplink.per_round)
        overhead = {"up": int(server.overhead_up),
                    "down": int(server.overhead_down)}
    finally:
        server.stop()
        for p in procs:
            try:
                p.wait(timeout=15)
            except Exception:
                p.kill()

    masks_ok = all(
        rec["delivered"].tolist() == plan[rec["round"]].tolist()
        for rec in loop.history)
    params_ok = bool((_ravel(oracle_params) == _ravel(live_params)).all())
    ef_ok, ef_detail = True, {}
    for i in range(TINY_N):
        if i == KILL_CID:
            ef_detail[str(i)] = "dead" if efs[i] is None else "unexpected"
            ef_ok &= efs[i] is None
        else:
            same = efs[i] is not None and bool(
                (efs[i] == _ravel_row(oracle_ef, i)).all())
            ef_detail[str(i)] = bool(same)
            ef_ok &= same
    cons = _conservation(engine, model, params, strategy, fl, train, pools)
    # conservation transfers to the wire because the dropped client's EF
    # (CONS_CID survives the run) is bitwise equal to the oracle's
    cons["wire_ef_bitwise"] = ef_detail[str(CONS_CID)] is True

    nbytes = int(codec.nbytes)
    settled_bytes = int(loop.history[1]["bytes_up"])    # round 1: null, warm
    return {
        "codec_nbytes": nbytes,
        "delivered_masks": [r["delivered"].tolist() for r in loop.history],
        "expected_masks": plan.tolist(),
        "masks_match": bool(masks_ok),
        "params_bitwise": params_ok,
        "ef_bitwise": ef_detail,
        "ef_all_ok": bool(ef_ok),
        "dead_at_end": sorted(loop.history[-1]["dead"]),
        "retries_per_round": [r["retries"] for r in loop.history],
        "uplink_bytes_per_round": up_per_round,
        "settled_null_round_bytes": settled_bytes,
        "settled_null_round_expected": TINY_N * nbytes,
        "overhead_bytes": overhead,
        "conservation": cons,
    }


def _mlp_bytes_scenario() -> Dict:
    """Paper-shape byte gate: live mlp/mnist 3SFC frames over the socket
    must bill exactly ``codec.nbytes`` per message — the same measured
    bytes BENCH_wire accounts — so the 8-client round total equals
    ``BENCH_wire.json``'s ``channel.uplink_bytes_per_round``."""
    from repro.configs.base import FLConfig
    from repro.configs.run import RunConfig
    from repro.core import flat
    from repro.fl.budget import matched_compressors
    from repro.models.cnn import MNIST_SPEC

    # the exact BENCH_wire codec config (syn_batch-matched 3SFC)
    from repro.models.cnn import make_paper_model
    model0 = make_paper_model("mlp", MNIST_SPEC)
    d = flat.tree_size(model0.init(jax.random.PRNGKey(0)))
    comp = matched_compressors("mlp", MNIST_SPEC, d)["threesfc"]
    fl = FLConfig(num_clients=MLP_N, local_steps=2, local_lr=0.05,
                  local_batch=8, compressor=comp, seed=0)
    run = RunConfig(fl=fl, wire="codec", transport="socket",
                    round_deadline_s=120.0, recv_timeout_s=60.0,
                    recv_backoff=1.5, transport_retries=0,
                    heartbeat_s=0.2, liveness_timeout_s=10.0)
    model, params, strategy, codec = _build("mlp", MNIST_SPEC, fl, run)
    nbytes = int(codec.nbytes)

    _, _, history, stats = _socket_run(
        run, "mlp", MNIST_SPEC, MLP_TRAIN, params, strategy, codec,
        rounds=1 + MLP_MEASURED_ROUNDS, collect_ef=False)

    measured = [int(r["bytes_up"]) for r in history[1:]]
    per_msg = measured[0] // MLP_N if measured else 0
    wire_ref: Optional[Dict] = None
    wire_path = os.path.join(REPO, "BENCH_wire.json")
    if os.path.exists(wire_path):
        with open(wire_path) as f:
            wire = json.load(f)
        wire_ref = dict(wire["measure"]["channel"])
        wire_ref["threesfc_measured_bytes"] = \
            wire["measure"]["methods"]["threesfc"]["measured_bytes"]
    return {
        "codec_nbytes": nbytes,
        "live_clients": MLP_N,
        "uplink_bytes_per_round": stats["uplink_per_round"],
        "measured_round_bytes": measured,
        "per_message_bytes": int(per_msg),
        "n8_round_bytes": int(8 * per_msg),
        "overhead_bytes": {"up": stats["overhead_up"],
                           "down": stats["overhead_down"]},
        "wire_reference": wire_ref,
        "retries_per_round": [r["retries"] for r in history],
    }


def _straggle_scenario() -> Dict:
    """One worker sleeps STRAGGLE_S per round; a tight deadline must bound
    the round's wall clock — slow means undelivered, never waited-on and
    never dead."""
    from repro.configs.run import RunConfig

    spec, fl = _tiny_problem()
    run = RunConfig(fl=fl, wire="codec", transport="socket",
                    round_deadline_s=STRAGGLE_DEADLINE_S,
                    recv_timeout_s=STRAGGLE_DEADLINE_S,
                    recv_backoff=1.5, transport_retries=0,
                    heartbeat_s=0.2, liveness_timeout_s=3.0)
    _, params, strategy, codec = _build("mlp", spec, fl, run)
    _, _, history, _ = _socket_run(
        run, "mlp", spec, TINY_TRAIN, params, strategy, codec,
        rounds=1 + STRAGGLE_ROUNDS,
        straggle={STRAGGLE_CID: STRAGGLE_S}, collect_ef=False)

    measured = history[1:]
    expect = [True] * TINY_N
    expect[STRAGGLE_CID] = False
    rounds = [{
        "round": r["round"],
        "wall_s": float(r["wall_s"]),
        "delivered": r["delivered"].tolist(),
        "dead": r["dead"],
        "wall_bounded": bool(r["wall_s"] <= STRAGGLE_DEADLINE_S
                             + WALL_SLACK_S),
        "wall_below_straggle": bool(r["wall_s"] <= 0.5 * STRAGGLE_S),
        "mask_ok": r["delivered"].tolist() == expect,
        "straggler_not_dead": STRAGGLE_CID not in r["dead"],
    } for r in measured]
    return {
        "straggle_cid": STRAGGLE_CID,
        "straggle_s": STRAGGLE_S,
        "deadline_s": STRAGGLE_DEADLINE_S,
        "wall_slack_s": WALL_SLACK_S,
        "warmup_wall_s": float(history[0]["wall_s"]),
        "rounds": rounds,
    }


def _gate(results: Dict) -> Dict:
    tiny, mlp, strag = (results["faulted"], results["bytes_mlp"],
                        results["straggle"])
    bytes_ok = (tiny["settled_null_round_bytes"]
                == tiny["settled_null_round_expected"])
    bytes_ok &= all(b == MLP_N * mlp["codec_nbytes"]
                    for b in mlp["measured_round_bytes"])
    if mlp["wire_reference"] is not None:
        bytes_ok &= (mlp["n8_round_bytes"]
                     == mlp["wire_reference"]["uplink_bytes_per_round"])
        bytes_ok &= (mlp["per_message_bytes"]
                     == mlp["wire_reference"]["threesfc_measured_bytes"])
    results["pass_bytes_match"] = bool(bytes_ok)
    results["pass_socket_bitwise"] = bool(
        tiny["masks_match"] and tiny["params_bitwise"] and tiny["ef_all_ok"])
    results["pass_residual_conservation"] = bool(
        tiny["conservation"]["exact"]
        and tiny["conservation"]["wire_ef_bitwise"])
    results["pass_straggle_isolation"] = bool(
        strag["rounds"]
        and all(r["wall_bounded"] and r["wall_below_straggle"]
                and r["mask_ok"] and r["straggler_not_dead"]
                for r in strag["rounds"]))
    results["pass"] = all(results[k] for k in (
        "pass_bytes_match", "pass_socket_bitwise",
        "pass_residual_conservation", "pass_straggle_isolation"))
    return results


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    # deterministic modulo wall clock: quick == full (orchestrator symmetry)
    del quick
    print("live tiny/stc rounds with injected drops + SIGKILL vs the "
          "in-process oracle...")
    faulted = _tiny_scenarios()
    print("live mlp/mnist 3SFC frames over the socket (byte gate vs "
          "BENCH_wire)...")
    bytes_mlp = _mlp_bytes_scenario()
    print(f"straggle: worker {STRAGGLE_CID} sleeps {STRAGGLE_S:.1f}s/round "
          f"under a {STRAGGLE_DEADLINE_S:.2f}s deadline...")
    straggle = _straggle_scenario()

    results = _gate({
        "config": {
            "tiny": {"clients": TINY_N, "rounds": TINY_ROUNDS,
                     "drops": sorted(list(DROPS)),
                     "kill_cid": KILL_CID,
                     "kill_after_round": KILL_AFTER_ROUND},
            "mlp": {"clients": MLP_N, "measured_rounds": MLP_MEASURED_ROUNDS},
            "straggle": {"cid": STRAGGLE_CID, "sleep_s": STRAGGLE_S,
                         "deadline_s": STRAGGLE_DEADLINE_S,
                         "rounds": STRAGGLE_ROUNDS},
        },
        "faulted": faulted,
        "bytes_mlp": bytes_mlp,
        "straggle": straggle,
    })

    t, m, s = faulted, bytes_mlp, straggle
    print("\n== Socket transport vs in-process oracle ==")
    print(f"  [{'PASS' if results['pass_bytes_match'] else 'FAIL'}] "
          f"wire bills exactly N*nbytes: tiny "
          f"{t['settled_null_round_bytes']}/{t['settled_null_round_expected']}"
          f" B, mlp {m['measured_round_bytes']} B "
          f"(n8 total {m['n8_round_bytes']} B == BENCH_wire "
          f"{(m['wire_reference'] or {}).get('uplink_bytes_per_round')})")
    print(f"  [{'PASS' if results['pass_socket_bitwise'] else 'FAIL'}] "
          f"live faulted run bitwise == oracle: masks "
          f"{t['masks_match']}, params {t['params_bitwise']}, "
          f"EF {t['ef_bitwise']}")
    print(f"  [{'PASS' if results['pass_residual_conservation'] else 'FAIL'}]"
          f" residual mass conserved on dropped frame (round "
          f"{CONS_ROUND}, cid {CONS_CID}): exact="
          f"{t['conservation']['exact']}, wire EF bitwise="
          f"{t['conservation']['wire_ef_bitwise']}")
    walls = [f"{r['wall_s']:.2f}" for r in s["rounds"]]
    print(f"  [{'PASS' if results['pass_straggle_isolation'] else 'FAIL'}] "
          f"straggler ({STRAGGLE_S:.1f}s sleep) bounded by the "
          f"{STRAGGLE_DEADLINE_S:.2f}s deadline: wall {walls} s, "
          f"undelivered-not-dead each round")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "transport.json"), "w") as f:
        json.dump(results, f, indent=2)
    with open(os.path.join(REPO, "BENCH_transport.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True,
                   help="accepted for orchestrator symmetry; quick == full")
    g.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
