"""Paper Table 1 + Fig. 2/3 — the FedSynth (multi-step L2) failure mode.

Claim C6: the L2-objective, K-step-unrolled distillation baseline is
unstable at high compression: gradients through the unroll grow with the
number of simulated steps (Fig. 3's explosion), and its final fit is worse
than 3SFC's single-evaluation similarity objective at the same budget.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CompressorConfig
from repro.core import fedsynth, flat, threesfc
from repro.data.synthetic import make_class_image_dataset
from repro.models.build import vision_syn_spec
from repro.models.cnn import MNIST_SPEC, make_paper_model


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    model = make_paper_model("mlp", MNIST_SPEC)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    ds = make_class_image_dataset(jax.random.PRNGKey(1), 512, (28, 28, 1), 10)

    # target update: K=5 real SGD steps
    p = params
    for i in range(5):
        g = jax.grad(model.loss)(p, {"x": jnp.asarray(ds.x[i*64:(i+1)*64]),
                                     "y": jnp.asarray(ds.y[i*64:(i+1)*64])})
        p = jax.tree.map(lambda a, b: a - 0.01*b, p, g)
    target = flat.tree_sub(params, p)

    spec = vision_syn_spec(MNIST_SPEC, CompressorConfig(syn_batch=1))
    results: Dict = {"fedsynth": {}, "threesfc": {}}

    # FedSynth at increasing unroll depth: grad-through-unroll norm + fit
    for unroll in ([1, 4, 16] if quick else [1, 4, 16, 64, 128]):
        syn0 = threesfc.init_syn(jax.random.PRNGKey(2), spec)
        res = fedsynth.encode(model.syn_loss, params, target, syn0,
                              unroll_steps=unroll, opt_steps=10,
                              lr=0.01, syn_lr=0.1)
        cos = float(flat.tree_cosine(res.recon, target))
        results["fedsynth"][unroll] = {
            "syn_grad_norm": float(res.syn_grad_norm),
            "l2": float(res.l2), "cosine": cos}
        print(f"  fedsynth unroll={unroll:4d}: grad-through-unroll norm="
              f"{float(res.syn_grad_norm):10.4g}  fit cos={cos:+.4f}")

    syn0 = threesfc.init_syn(jax.random.PRNGKey(2), spec)
    res3 = threesfc.encode(model.syn_loss, params, target, syn0,
                           steps=10, lr=0.1)
    results["threesfc"] = {"cosine": float(res3.cosine),
                           "objective": float(res3.objective)}
    print(f"  3sfc  (1 simulation step): fit cos={float(res3.cosine):+.4f}")

    norms = [results["fedsynth"][u]["syn_grad_norm"]
             for u in sorted(results["fedsynth"])]
    grows = norms[-1] > norms[0] * 2
    better = results["threesfc"]["cosine"] >= max(
        v["cosine"] for v in results["fedsynth"].values()) - 0.02
    print(f"  [{'PASS' if grows else 'FAIL'}] C6a: grad-through-unroll grows "
          f"with depth ({norms[0]:.3g} -> {norms[-1]:.3g})")
    print(f"  [{'PASS' if better else 'FAIL'}] C6b: 3SFC fit >= FedSynth fit at same budget")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fedsynth_collapse.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
