"""Measured wire bytes: the codec subsystem's end-to-end gate.

Everything the repo previously *accounted* as float counts is serialized
here into real framed ``uint8`` buffers (``repro.comm``) at the paper
MLP/MNIST shapes (d = 199,210) and measured. Gated:

* **round trip**: ``decode(encode(payload))`` is bit-exact for all five
  compressors on a realistic client update (one K=5 local-train), and the
  decoded server reconstruction equals the client's dequantized view
  bitwise (threesfc: the Eq. 10 server recompute, ≤ 1e-5);
* **signSGD budget**: measured uplink ≤ ceil(d/8) + per-leaf scales +
  header — ONE bit per coordinate actually on the wire;
* **3SFC budget**: measured uplink within 2% of the accounted
  4·(795+1) bytes + header;
* **measured vs accounted**: the ratio is recorded per method (DGC's
  ``ceil(log2 d)``-bit indices beat the "2k floats" convention; identity's
  header is the only overhead);
* **round parity**: 3 scanned engine rounds in ``wire='codec'`` mode equal
  float mode bitwise — params, EF, every shared metric — for
  fedavg/dgc/stc/threesfc (default AND fused 3SFC decode). signSGD is the
  documented exception: a 3-valued sign does not fit in the 1-bit wire, so
  coordinates that are *exactly* zero decode to +scale; the bench measures
  the zero fraction and the resulting divergence instead of pretending the
  float convention was serializable (the wire path itself is
  self-consistent: client EF uses the same ±1 view the server decodes).

Also exercises ``comm.channel.InProcessChannel``: one round's frames move
client->server through it and the uplink counters must bill exactly
N · nbytes. Deterministic end to end — ``--quick`` == ``--full``. Emits
``BENCH_wire.json`` (repo root) + ``experiments/results/wire.json`` for the
``scripts/check_bench.py`` trajectory gate.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_CLIENTS = 8
LOCAL_STEPS, LOCAL_BATCH = 5, 32       # paper MLP/MNIST round shape
PARITY_ROUNDS = 3
PARITY_K, PARITY_B = 2, 8
THREESFC_RECON_TOL = 1e-5
BITWISE_KINDS = ("fedavg", "dgc", "stc", "threesfc")


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _tree_maxdiff(a, b) -> float:
    diffs = [float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                   - y.astype(jnp.float32))))
             for x, y in zip(jax.tree_util.tree_leaves(a),
                             jax.tree_util.tree_leaves(b))]
    return max(diffs) if diffs else 0.0


def _measure(model, params, d, kinds, syn_specs) -> Dict:
    """Serialize one realistic client update per method and measure it."""
    from repro.comm import InProcessChannel, parse_header
    from repro.core import flat
    from repro.core.strategy import make_strategy
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.client import local_train
    from repro.models.cnn import MNIST_SPEC

    ds = make_class_image_dataset(jax.random.PRNGKey(11), 256,
                                  MNIST_SPEC.input_shape, 10)
    idx = jax.random.randint(jax.random.PRNGKey(12), (LOCAL_STEPS, LOCAL_BATCH),
                             0, 256)
    batches = {"x": jnp.asarray(ds.x)[idx], "y": jnp.asarray(ds.y)[idx]}
    u, _ = local_train(model.loss, params, batches, 0.01)

    per_method: Dict[str, Dict] = {}
    for name, ccfg in kinds.items():
        spec = syn_specs[name]
        strat = make_strategy(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                              local_lr=0.01)
        codec = strat.wire_codec(params)
        out = strat.client_encode(jax.random.PRNGKey(13), u, params)
        buf = jax.jit(lambda w: codec.encode(w, round_idx=3, client_idx=1))(
            out.wire)
        hdr = parse_header(np.asarray(buf))
        assert hdr["kind"] == ccfg.kind and hdr["round"] == 3 \
            and hdr["client"] == 1, hdr

        canon = codec.decode(buf)
        # THE round-trip gate: the decoded payload must equal the byte-free
        # canonical oracle bitwise (a symmetric pack/unpack bug cannot hide)
        roundtrip = _tree_equal(canon, codec.canonical(out.wire))
        # ... in eager as in jit, and encode must be deterministic
        canon2 = codec.decode(codec.encode(out.wire, 3, 1))   # eager
        jit_eager_stable = _tree_equal(canon, canon2)
        buf2 = codec.encode(out.wire, round_idx=3, client_idx=1)
        enc_deterministic = bool(np.array_equal(np.asarray(buf),
                                                np.asarray(buf2)))

        recon_dec = codec.recon_tree(canon, params)
        recon_cli, direction, scale = codec.client_view(out)
        if direction is not None:                 # threesfc: factored client
            recon_cli = flat.tree_scale(direction, scale)
            recon_diff = _tree_maxdiff(recon_cli, recon_dec)
            recon_ok = recon_diff <= THREESFC_RECON_TOL
        else:
            recon_diff = _tree_maxdiff(recon_cli, recon_dec)
            recon_ok = _tree_equal(recon_cli, recon_dec)

        accounted_floats = strat.payload_floats(params)
        # stc shares signsgd's 1-bit sign semantics: a kept value that is
        # exactly zero would decode to +mu where the float path writes 0.
        # Count them so a future parity divergence is attributable (today:
        # 0 — top-k only reaches zeros when a leaf has fewer than k
        # nonzeros, which the paper shapes never do).
        zero_kept = None
        if name == "stc":
            zero_kept = int(sum(int(jnp.sum(sgn == 0.0))
                                for sgn, _, _ in out.wire))
        per_method[name] = {
            "measured_bytes": int(codec.nbytes),
            "header_bytes": int(codec.header_bytes),
            "payload_bytes": int(codec.nbytes - codec.header_bytes),
            "header_overhead": codec.header_bytes / codec.nbytes,
            "accounted_floats": float(accounted_floats),
            "accounted_bytes": 4.0 * float(accounted_floats),
            "measured_over_accounted":
                codec.nbytes / (4.0 * float(accounted_floats)),
            "roundtrip_bitexact": bool(roundtrip and jit_eager_stable
                                       and enc_deterministic),
            "recon_consistent": bool(recon_ok),
            "recon_maxdiff": float(recon_diff),
        }
        if zero_kept is not None:
            per_method[name]["zero_kept_values"] = zero_kept

    # the channel bills exactly one frame per client
    ch = InProcessChannel()
    ch.begin_round()
    strat = make_strategy(kinds["threesfc"], loss_fn=model.syn_loss,
                          syn_spec=syn_specs["threesfc"], local_lr=0.01)
    codec = strat.wire_codec(params)
    out = strat.client_encode(jax.random.PRNGKey(14), u, params)
    for c in range(N_CLIENTS):
        ch.send_up(codec.encode(out.wire, round_idx=0, client_idx=c))
    channel = {
        "uplink_bytes_per_round": ch.uplink.per_round[0],
        "expected": N_CLIENTS * codec.nbytes,
        "messages": ch.uplink.messages,
    }

    # exact zeros in the realistic update: the signsgd 1-bit caveat, measured
    zeros = sum(int(jnp.sum(l == 0.0)) for l in jax.tree_util.tree_leaves(u))
    return {"methods": per_method, "channel": channel,
            "update_zero_coords": zeros, "update_zero_fraction": zeros / d}


def _parity(model, params, kinds, syn_specs) -> Dict:
    """wire='codec' engine rounds vs the float oracle, 3 scanned rounds."""
    from repro.configs.base import FLConfig
    from repro.configs.run import RunConfig
    from repro.core.strategy import make_strategy
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_class_image_dataset
    from repro.fl.engine import RoundEngine, device_pools, vision_batcher
    from repro.fl.round import build_fl_round
    from repro.models.cnn import MNIST_SPEC

    train = make_class_image_dataset(jax.random.PRNGKey(1), 400,
                                     MNIST_SPEC.input_shape, 10)
    parts = dirichlet_partition(train.y, N_CLIENTS, alpha=0.5, seed=0,
                                min_per_client=16)

    def run3(ccfg, spec, wire, fused=False):
        strat = make_strategy(ccfg, loss_fn=model.syn_loss, syn_spec=spec,
                              local_lr=0.05)
        cfg = FLConfig(num_clients=N_CLIENTS, local_steps=PARITY_K,
                       local_lr=0.05, local_batch=PARITY_B, compressor=ccfg)
        run = RunConfig(fl=cfg, wire=wire, fused_decode=fused)
        codec = strat.wire_codec(params) if wire == "codec" else None
        eng = RoundEngine(
            build_fl_round(model.loss, strat, run, codec=codec),
            vision_batcher(train.x, train.y, device_pools(parts),
                           PARITY_K, PARITY_B), seed=0)
        return eng.run_block(eng.init_state(params, N_CLIENTS), PARITY_ROUNDS)

    shared = ("loss", "cosine", "payload_floats", "update_norm")
    out: Dict[str, Dict] = {}
    for name, ccfg in kinds.items():
        spec = syn_specs[name]
        sf, mf = run3(ccfg, spec, "float")
        sw, mw = run3(ccfg, spec, "codec")
        rec = {
            "params_bitexact": _tree_equal(sf.params, sw.params),
            "ef_bitexact": _tree_equal(sf.ef, sw.ef),
            "metrics_bitexact": all(
                np.array_equal(np.asarray(getattr(mf, f)),
                               np.asarray(getattr(mw, f))) for f in shared),
            "max_abs_param_diff": _tree_maxdiff(sf.params, sw.params),
            "wire_bytes_up": float(np.asarray(mw.wire_bytes_up)[0]),
        }
        if name == "threesfc":
            s1, _ = run3(ccfg, spec, "float", fused=True)
            s2, m2 = run3(ccfg, spec, "codec", fused=True)
            rec["fused_params_bitexact"] = _tree_equal(s1.params, s2.params)
            rec["fused_ef_bitexact"] = _tree_equal(s1.ef, s2.ef)
            rec["fused_wire_bytes_up"] = float(np.asarray(m2.wire_bytes_up)[0])
        out[name] = rec
    return out


def _gate(results: Dict, d: int, n_leaves: int) -> Dict:
    m = results["measure"]["methods"]
    results["pass_roundtrip"] = bool(
        all(m[k]["roundtrip_bitexact"] for k in m))
    results["pass_recon_consistency"] = bool(
        all(m[k]["recon_consistent"] for k in m))
    sign_budget = -(-d // 8) + 4 * n_leaves + m["signsgd"]["header_bytes"]
    results["signsgd_byte_budget"] = sign_budget
    results["pass_signsgd_bytes"] = bool(
        m["signsgd"]["measured_bytes"] <= sign_budget)
    target = 4.0 * (795 + 1)                       # paper MLP/MNIST budget
    results["threesfc_byte_target"] = target + m["threesfc"]["header_bytes"]
    results["pass_threesfc_bytes"] = bool(
        abs(m["threesfc"]["measured_bytes"]
            - (target + m["threesfc"]["header_bytes"])) <= 0.02 * target)
    p = results["parity"]
    results["pass_round_parity"] = bool(
        all(p[k]["params_bitexact"] and p[k]["ef_bitexact"]
            and p[k]["metrics_bitexact"] for k in BITWISE_KINDS)
        and p["threesfc"]["fused_params_bitexact"]
        and p["threesfc"]["fused_ef_bitexact"])
    ch = results["measure"]["channel"]
    results["pass_channel_accounting"] = bool(
        ch["uplink_bytes_per_round"] == ch["expected"])
    results["pass"] = all(results[k] for k in (
        "pass_roundtrip", "pass_recon_consistency", "pass_signsgd_bytes",
        "pass_threesfc_bytes", "pass_round_parity",
        "pass_channel_accounting"))
    return results


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    # deterministic end to end: quick == full (orchestrator symmetry only)
    del quick
    from repro.core import flat
    from repro.fl.budget import matched_compressors
    from repro.models.build import vision_syn_spec
    from repro.models.cnn import MNIST_SPEC, make_paper_model

    model = make_paper_model("mlp", MNIST_SPEC)
    params = model.init(jax.random.PRNGKey(0))
    d = flat.tree_size(params)
    n_leaves = len(jax.tree_util.tree_leaves(params))
    kinds = matched_compressors("mlp", MNIST_SPEC, d)
    syn_specs = {k: vision_syn_spec(MNIST_SPEC, c) for k, c in kinds.items()}

    print("serializing one client-round per method (mlp/mnist, "
          f"d={d})...")
    measure = _measure(model, params, d, kinds, syn_specs)
    print("wire == float parity, 3 scanned rounds per method...")
    parity = _parity(model, params, kinds, syn_specs)

    results = _gate({
        "config": {
            "model": "mlp", "dataset": "mnist", "model_params": d,
            "num_leaves": n_leaves, "num_clients": N_CLIENTS,
            "local_steps": LOCAL_STEPS, "local_batch": LOCAL_BATCH,
            "parity_rounds": PARITY_ROUNDS,
        },
        "measure": measure,
        "parity": parity,
    }, d, n_leaves)

    m = measure["methods"]
    print(f"\n== Measured wire bytes per client-round (mlp/mnist, d={d}) ==")
    print(f"  {'method':9s} {'measured':>9s} {'accounted':>10s} "
          f"{'ratio':>6s} {'header':>7s}")
    for k, r in m.items():
        print(f"  {k:9s} {r['measured_bytes']:9d} "
              f"{r['accounted_bytes']:10.0f} "
              f"{r['measured_over_accounted']:6.3f} "
              f"{r['header_bytes']:5d} B")
    print(f"  [{'PASS' if results['pass_roundtrip'] else 'FAIL'}] "
          f"decode(encode(payload)) bit-exact for all five compressors")
    print(f"  [{'PASS' if results['pass_recon_consistency'] else 'FAIL'}] "
          f"decoded server recon == client dequantized view (threesfc "
          f"<= {THREESFC_RECON_TOL:.0e}, measured "
          f"{m['threesfc']['recon_maxdiff']:.1e})")
    print(f"  [{'PASS' if results['pass_signsgd_bytes'] else 'FAIL'}] "
          f"signsgd uplink {m['signsgd']['measured_bytes']} B <= "
          f"ceil(d/8) + scales + header = {results['signsgd_byte_budget']} B "
          f"(1 bit/coord, measured)")
    print(f"  [{'PASS' if results['pass_threesfc_bytes'] else 'FAIL'}] "
          f"threesfc uplink {m['threesfc']['measured_bytes']} B within 2% "
          f"of 4*(795+1) + header = {results['threesfc_byte_target']:.0f} B")
    pr = parity
    print(f"  [{'PASS' if results['pass_round_parity'] else 'FAIL'}] "
          f"wire-mode rounds == float-mode rounds over {PARITY_ROUNDS} "
          f"scanned rounds (bitwise: {', '.join(BITWISE_KINDS)} + fused "
          f"threesfc)")
    print(f"         signsgd (1-bit wire, documented): "
          f"max |dparams| = {pr['signsgd']['max_abs_param_diff']:.1e}, "
          f"update zero-coord fraction = "
          f"{measure['update_zero_fraction']:.2e}")
    print(f"  [{'PASS' if results['pass_channel_accounting'] else 'FAIL'}] "
          f"channel bills exactly N*nbytes "
          f"({measure['channel']['uplink_bytes_per_round']} B/round)")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "wire.json"), "w") as f:
        json.dump(results, f, indent=2)
    with open(os.path.join(REPO, "BENCH_wire.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--quick", dest="quick", action="store_true", default=True,
                   help="accepted for orchestrator symmetry; the measurement "
                        "is deterministic, quick == full")
    g.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
