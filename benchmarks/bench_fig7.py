"""Paper Fig. 7 — per-round cosine compression efficiency, 3SFC vs DGC.

Claim C5: at the same rate, 3SFC's compressed update has higher cosine
similarity to the true update, every round (more information per byte).
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from benchmarks.fl_harness import DATASETS, matched_compressors, run_fl


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    model_name, dataset = "mlp", "mnist"
    rounds = 30 if quick else 100
    import jax
    from repro.core import flat
    from repro.models.cnn import make_paper_model
    spec = DATASETS[dataset]
    d = flat.tree_size(make_paper_model(model_name, spec).init(jax.random.PRNGKey(0)))
    comps = matched_compressors(model_name, spec, d)
    results = {}
    for method in ("fedavg", "dgc", "threesfc"):
        r = run_fl(model_name, dataset, comps[method], num_clients=10,
                   rounds=rounds, train_size=2000 if quick else 6000,
                   eval_every=rounds, label=method)
        results[method] = r.cosine_curve
    m3 = float(np.mean(results["threesfc"]))
    md = float(np.mean(results["dgc"]))
    print("\n== Fig 7 (reduced): mean compression efficiency (cosine) ==")
    print(f"  fedavg   : {np.mean(results['fedavg']):.4f} (=1 by definition)")
    print(f"  dgc      : {md:.4f}")
    print(f"  threesfc : {m3:.4f}")
    print(f"  [{'PASS' if m3 > md else 'FAIL'}] C5: 3SFC efficiency > DGC at same rate")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig7.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
