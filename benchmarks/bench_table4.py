"""Paper Table 4 — ablation: error feedback, budget B, local iterations K.

Claims:
  C3: disabling EF collapses accuracy (the single largest factor).
  C4: accuracy increases with B (1x -> 2x -> 4x) and with K (1 -> 5 -> 10).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict

from benchmarks.fl_harness import (DATASETS, fmt_table, matched_compressors,
                                   run_fl)


def run(quick: bool = True, out_dir: str = "experiments/results") -> Dict:
    model_name, dataset = "mlp", "mnist"
    rounds = 30 if quick else 120
    train_size = 2000 if quick else 6000
    import jax
    from repro.core import flat
    from repro.models.cnn import make_paper_model
    spec = DATASETS[dataset]
    d = flat.tree_size(make_paper_model(model_name, spec).init(jax.random.PRNGKey(0)))
    base = matched_compressors(model_name, spec, d)["threesfc"]

    variants = {
        "base (1xB, K=5, EF)": (base, 5),
        "w/o EF": (dataclasses.replace(base, error_feedback=False), 5),
        "2xB": (dataclasses.replace(base, syn_batch=2), 5),
        "4xB": (dataclasses.replace(base, syn_batch=4), 5),
        "K=1": (base, 1),
        "K=10": (base, 10),
    }
    results, rows = {}, []
    for name, (comp, K) in variants.items():
        r = run_fl(model_name, dataset, comp, num_clients=10, rounds=rounds,
                   local_steps=K, train_size=train_size,
                   test_size=500 if quick else 1500,
                   eval_every=max(rounds // 6, 1), label=name)
        results[name] = {"acc": r.final_acc, "ratio": r.comp_ratio,
                         "curve": r.acc_curve}
        rows.append((name, f"{r.final_acc:.4f}", f"{r.comp_ratio:.1f}x"))
    print("\n== Table 4 (reduced): 3SFC ablation on MLP+MNIST ==")
    print(fmt_table(rows, ["variant", "final acc", "ratio"]))
    ok_ef = results["base (1xB, K=5, EF)"]["acc"] > results["w/o EF"]["acc"]
    ok_b = results["4xB"]["acc"] >= results["base (1xB, K=5, EF)"]["acc"] - 0.02
    ok_k = results["K=10"]["acc"] >= results["K=1"]["acc"]
    print(f"  [{'PASS' if ok_ef else 'FAIL'}] C3: EF >> no-EF")
    print(f"  [{'PASS' if ok_b else 'FAIL'}] C4a: acc grows with B")
    print(f"  [{'PASS' if ok_k else 'FAIL'}] C4b: acc grows with K")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "table4.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run(quick=True)
