"""Dirichlet non-i.i.d. client partitioning (paper Fig. 5 protocol)."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    num_clients: int,
    alpha: float = 0.5,
    seed: int = 0,
    min_per_client: int = 2,
) -> List[np.ndarray]:
    """Split sample indices over clients with Dir(alpha) label skew.

    Returns a list of index arrays, one per client. Lower alpha => more
    skewed (some clients see only a few labels), matching paper Fig. 5.
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: List[List[int]] = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(alpha * np.ones(num_clients))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            shards[i].extend(part.tolist())
    out = []
    for i, s in enumerate(shards):
        if len(s) < min_per_client:        # ensure every client can form a batch
            donor = int(np.argmax([len(t) for t in shards]))
            need = min_per_client - len(s)
            s = s + shards[donor][:need]
        arr = np.array(sorted(s), dtype=np.int64)
        out.append(arr)
    return out


def partition_stats(labels: np.ndarray, parts: List[np.ndarray]) -> Dict:
    """Per-client size + label histogram (for the Fig. 5-style printout)."""
    classes = np.unique(labels)
    hists = np.stack([
        np.bincount(labels[p], minlength=classes.max() + 1) for p in parts])
    return {"sizes": [len(p) for p in parts], "label_hist": hists}
