"""Synthetic datasets (the container is offline — see DESIGN.md §9).

``make_class_image_dataset`` builds a class-conditional image problem with
the paper's dataset shapes (28x28x1 MNIST-like, 32x32x3 CIFAR-like): each
class c gets a fixed random template T_c; samples are
``clip(T_c + sigma * noise)``. The task is genuinely learnable (linear probes
reach high accuracy at low sigma; difficulty is tunable), so convergence-rate
*orderings* between compressors — the paper's claims — are measurable.

``make_token_dataset`` builds an LM stream with a planted bigram structure
(next token = f(current) with noise) so CE decreases with learning.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ClassImageDataset(NamedTuple):
    x: np.ndarray          # (N, H, W, C) float32 in [0, 1]
    y: np.ndarray          # (N,) int32
    num_classes: int


def make_class_image_dataset(
    key: jax.Array,
    num_samples: int,
    input_shape: Tuple[int, int, int],
    num_classes: int,
    sigma: float = 0.35,
    template_scale: float = 1.0,
    template_seed: int = 7,
) -> ClassImageDataset:
    """Templates come from ``template_seed`` (NOT ``key``) so that train and
    test splits generated with different keys share the same class structure."""
    ky, kn = jax.random.split(key, 2)
    kt = jax.random.PRNGKey(template_seed)
    templates = template_scale * jax.random.normal(kt, (num_classes, *input_shape))
    y = jax.random.randint(ky, (num_samples,), 0, num_classes)
    noise = sigma * jax.random.normal(kn, (num_samples, *input_shape))
    x = jnp.clip(templates[y] * 0.5 + 0.5 + noise, 0.0, 1.0)
    return ClassImageDataset(np.asarray(x, np.float32), np.asarray(y, np.int32),
                             num_classes)


def make_token_dataset(
    key: jax.Array,
    num_seqs: int,
    seq_len: int,
    vocab: int,
    noise: float = 0.1,
) -> np.ndarray:
    """(num_seqs, seq_len) int32 with a planted random bigram map."""
    kp, k0, kn, km = jax.random.split(key, 4)
    bigram = jax.random.permutation(kp, vocab)
    t0 = jax.random.randint(k0, (num_seqs,), 0, vocab)

    def step(tok, k):
        nxt = bigram[tok]
        rnd = jax.random.randint(k, tok.shape, 0, vocab)
        use_rnd = jax.random.bernoulli(jax.random.fold_in(k, 1), noise, tok.shape)
        nxt = jnp.where(use_rnd, rnd, nxt)
        return nxt, nxt

    keys = jax.random.split(kn, seq_len - 1)
    _, rest = jax.lax.scan(step, t0, keys)
    seqs = jnp.concatenate([t0[None], rest], axis=0).T
    return np.asarray(seqs, np.int32)
