from repro.data.synthetic import (
    ClassImageDataset,
    make_class_image_dataset,
    make_token_dataset,
)
from repro.data.partition import dirichlet_partition, partition_stats
