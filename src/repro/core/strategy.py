"""CompressionStrategy: ONE protocol object per compression method.

This module is the single extension point for adding a compressor to the
repo. A strategy carries everything the runtime needs to host a method in
the paper's comparison — under identical FL rounds, fan-outs and wire
modes — behind one registered class:

* ``client_encode(key, u, params) -> TreeCompressed`` — the per-client
  encoder (3SFC's S-step synthesis, top-k selection, sign quantization...).
* ``server_decode(payload, params)`` — reconstruct one client's update from
  the *canonical wire payload* (what ``repro.comm`` codecs decode).
* ``server_aggregate(params, payloads)`` (optional, declared by
  ``supports_fused_aggregate``) — aggregate straight from the batched
  payloads without materializing per-client reconstructions; this is how
  3SFC's fused decode (one batched backward over the gathered ``(D_syn,
  s)``) is expressed as a *capability* instead of a special case inside
  ``fl/round.py``.
* ``wire_codec(params, policy=...)`` — the method's serialized byte format
  (``repro.comm.codec`` registry), raising ``KeyError`` for accounted-only
  methods.
* ``payload_floats(params)`` — the accounted uplink size (paper Eq. 1).
* ``init_ef_state(params)`` — the per-client error-feedback residual.

The base class also provides the three derived *steps* the FL round
pipeline consumes — ``step`` (float mode), ``payload_step`` (fused mode,
the wire payload is the message) and ``wire_step`` (codec mode, a framed
``uint8`` buffer is the message) — all sharing ONE copy of the Eq. 6 EF
algebra, so a new method only implements the protocol methods above.

Registering a new method is one class::

    from repro.core import strategy as S

    @S.register_strategy("meansign")
    class MeanSign(S.CompressionStrategy):
        def payload_floats(self, params):
            return 2.0 * len(jax.tree_util.tree_leaves(params))
        def client_encode(self, key, u, params):
            recon = jax.tree_util.tree_map(
                lambda l: jnp.mean(jnp.abs(l)) * jnp.sign(l), u)
            return S.TreeCompressed(
                recon, jnp.float32(self.payload_floats(params)),
                jnp.float32(0))

Duplicate kinds are rejected; ``make_strategy`` lists the registered kinds
on an unknown one, and ``strategy_kinds()`` is the introspection surface
used by the budget tables and the benchmark orchestrator.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, NamedTuple, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CompressorConfig
from repro.core import flat
from repro.kernels import ops

PyTree = Any


class CompressMetrics(NamedTuple):
    cosine: jax.Array                # compression efficiency (Fig. 7)
    payload_floats: jax.Array        # accounted wire size this round
    aux: jax.Array                   # method-specific (3SFC: objective; else 0)


class TreeCompressed(NamedTuple):
    """What a strategy's ``client_encode`` hands back to the shared steps.

    ``cosine`` (when not None) is the already-computed cos(recon, u), so the
    EF step skips its own ``tree_cosine`` pass; ``direction``/``scale``
    (when not None) factor ``recon = scale · direction``, letting the EF
    update run as one fused ``e' = u − s·direction`` stream
    (``kernels.ops.tree_ef_update``) instead of reading the materialized
    recon again. ``wire`` is the method-specific wire payload (what a
    ``repro.comm.codec`` codec serializes and what ``server_decode`` /
    ``server_aggregate`` consume — value/index streams, sign sources, the
    (D_syn, s) pair); ``None`` for kinds without a wire format. Unused
    fields cost nothing (dead-code eliminated under jit).
    """

    recon: Any
    floats: jax.Array
    aux: jax.Array
    cosine: Optional[jax.Array] = None
    direction: Any = None
    scale: Optional[jax.Array] = None
    wire: Any = None


def leaf_k(n: int, ratio: float) -> int:
    """Kept entries for a size-n leaf at ``keep_ratio`` — the single source
    of truth for per-leaf budgets (the wire codecs derive their static
    layouts from the same function)."""
    return max(1, int(round(ratio * n)))


def _leaf_k(leaf, ratio: float) -> int:
    return leaf_k(leaf.size, ratio)


# ---------------------------------------------------------------------------
# deprecation bookkeeping (shared by the compressor/round shims)
# ---------------------------------------------------------------------------

_DEPRECATION_SEEN: set = set()


def warn_deprecated_once(name: str, replacement: str) -> None:
    """Emit one DeprecationWarning per process per shim name."""
    if name in _DEPRECATION_SEEN:
        return
    _DEPRECATION_SEEN.add(name)
    warnings.warn(f"{name} is deprecated; use {replacement}",
                  DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


class CompressionStrategy:
    """Base class for registered compression methods (see module docstring).

    Instances are constructed by ``make_strategy(cfg, ...)`` with a uniform
    signature so third-party strategies plug in without touching the
    callers; ``loss_fn``/``syn_spec`` are the synthetic-payload hooks (3SFC
    family) and may stay None for methods that don't use them.
    """

    kind: str = ""
    # capability: server_aggregate can consume the batched wire payloads
    # directly (no per-client reconstruction, no O(d) collective)
    supports_fused_aggregate: bool = False

    def __init__(self, cfg: CompressorConfig, *, loss_fn=None, syn_spec=None,
                 local_lr: float = 0.01):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.syn_spec = syn_spec
        self.local_lr = local_lr

    # -- protocol ----------------------------------------------------------
    def init_ef_state(self, params: PyTree) -> PyTree:
        """EF residual pytree (zeros, f32) mirroring params."""
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def payload_floats(self, params: PyTree) -> float:
        """Accounted per-round uplink size in floats (paper Eq. 1)."""
        raise NotImplementedError

    def client_encode(self, key, u: PyTree, params: PyTree) -> TreeCompressed:
        """Compress one client's accumulated update ``u`` at ``params``."""
        raise NotImplementedError

    def server_decode(self, payload, params: PyTree) -> PyTree:
        """Canonical wire payload -> one client's reconstruction tree."""
        raise NotImplementedError(
            f"strategy {self.kind!r} has no payload decode")

    def server_aggregate(self, params: PyTree, payloads) -> PyTree:
        """Batched (leading client axis) payloads -> aggregated update.

        Only meaningful when ``supports_fused_aggregate``; the returned
        tree is what the server applies (mean semantics, matching
        ``fl.server.aggregate`` over the per-client reconstructions).
        """
        raise NotImplementedError(
            f"strategy {self.kind!r} does not support fused aggregation")

    def mask_payloads(self, payloads, w: jax.Array):
        """Weight the batched (leading client axis N) wire payloads by the
        (N,) f32 mask ``w`` so that ``server_aggregate`` of the masked batch
        equals the weighted sum / N of per-client contributions.

        The fault pipeline (``fl/round.py`` under ``run.has_faults``) uses
        this with ``w ∈ {0, 1}`` to zero out dropped clients inside the
        fused aggregate, then rescales by N/Σw. Only meaningful together
        with ``supports_fused_aggregate``; the default refuses so a fused
        strategy without fault support fails loudly at build time.
        """
        raise NotImplementedError(
            f"strategy {self.kind!r} does not support masked fused "
            f"aggregation (mask_payloads)")

    def wire_codec(self, params: PyTree, *, policy: Optional[str] = None):
        """Build this method's registered byte codec over a params template.

        Raises ``KeyError`` for kinds without a wire format (their budgets
        stay accounted-only).
        """
        from repro.comm.codec import CODECS  # lazy: keep core import-light
        if self.cfg.kind not in CODECS:
            raise KeyError(
                f"no wire codec registered for compressor kind "
                f"{self.cfg.kind!r} (have: {sorted(CODECS)})")
        policy = policy or getattr(self.cfg, "wire_dtype", "fp32")
        return CODECS[self.cfg.kind](self.cfg, params, policy, strategy=self)

    # -- shared EF algebra (Eq. 6) — the ONE copy every entry path uses ----
    def _accumulate(self, g_tree: PyTree, e_tree: PyTree) -> PyTree:
        return flat.tree_add(g_tree, e_tree) if self.cfg.error_feedback \
            else g_tree

    def _ef_update(self, u, e_tree, recon, direction, scale) -> PyTree:
        """Eq. 6 residual on a (recon | direction·scale) view — shared by
        the float path (the strategy's own recon) and the wire path (the
        codec's dequantized view)."""
        if not self.cfg.error_feedback:
            return e_tree
        if direction is not None:
            return ops.tree_ef_update(u, direction, scale)
        return flat.tree_sub(u, recon)

    @staticmethod
    def _efficiency_cosine(out: TreeCompressed, recon, u) -> jax.Array:
        """cos(recon, u) unless the method already computed it fused."""
        return out.cosine if out.cosine is not None \
            else flat.tree_cosine(recon, u)

    # -- derived steps (what fl.round's pipeline calls) --------------------
    def step(self, key, g_tree, e_tree, params):
        """Float mode: (recon_tree, new_e_tree, CompressMetrics)."""
        u = self._accumulate(g_tree, e_tree)
        out = self.client_encode(key, u, params)
        e_new = self._ef_update(u, e_tree, out.recon, out.direction, out.scale)
        cos = self._efficiency_cosine(out, out.recon, u)
        return out.recon, e_new, CompressMetrics(cos, out.floats, out.aux)

    def payload_step(self, key, g_tree, e_tree, params):
        """Fused mode: (wire payload, new_e_tree, CompressMetrics).

        The wire payload is the message that crosses the client/server
        boundary (``server_aggregate`` consumes the batch of them); the
        reconstruction never does — with a (direction, scale) factorization
        it is never materialized client-side either.
        """
        u = self._accumulate(g_tree, e_tree)
        out = self.client_encode(key, u, params)
        if out.wire is None:
            raise ValueError(
                f"compressor kind {self.cfg.kind!r} emits no wire payload")
        e_new = self._ef_update(u, e_tree, out.recon, out.direction, out.scale)
        cos = self._efficiency_cosine(out, out.recon, u)
        return out.wire, e_new, CompressMetrics(cos, out.floats, out.aux)

    def wire_step(self, key, g_tree, e_tree, params, *, codec,
                  round_idx=0, client_idx=0):
        """Codec mode: (framed uint8 buffer, new_e_tree, CompressMetrics).

        Same EF algebra as ``step`` but everything downstream of the
        strategy sees only the serialized frame; the reconstruction used
        for EF/cosine is the codec's *dequantized view*
        (``Codec.client_view``), so the client stays consistent with what
        the server will decode — identical to the float path wherever the
        codec is lossless.
        """
        u = self._accumulate(g_tree, e_tree)
        out = self.client_encode(key, u, params)
        if out.wire is None:
            raise ValueError(
                f"compressor kind {self.cfg.kind!r} emits no wire payload")
        buf = codec.encode(out.wire, round_idx=round_idx,
                           client_idx=client_idx)
        recon, direction, scale = codec.client_view(out)
        e_new = self._ef_update(u, e_tree, recon, direction, scale)
        cos = self._efficiency_cosine(out, recon, u)
        return buf, e_new, CompressMetrics(cos, out.floats, out.aux)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, Type[CompressionStrategy]] = {}


def register_strategy(kind: str):
    """Class decorator registering a ``CompressionStrategy`` under ``kind``.

    Third-party code calls this too — a new compressor is one registered
    class, not an edit to the runtime. Duplicate kinds are rejected so two
    packages can't silently shadow each other.
    """

    def deco(cls: Type[CompressionStrategy]) -> Type[CompressionStrategy]:
        if kind in STRATEGIES:
            raise ValueError(
                f"strategy kind {kind!r} already registered "
                f"(by {STRATEGIES[kind].__name__})")
        cls.kind = kind
        STRATEGIES[kind] = cls
        return cls

    return deco


def strategy_kinds():
    """Sorted registered kinds — the introspection surface for budget
    tables and the benchmark orchestrator."""
    return sorted(STRATEGIES)


def make_strategy(cfg: CompressorConfig, *, loss_fn=None, syn_spec=None,
                  local_lr: float = 0.01) -> CompressionStrategy:
    """Instantiate the registered strategy for ``cfg.kind``."""
    if cfg.kind not in STRATEGIES:
        raise ValueError(
            f"unknown compressor kind {cfg.kind!r} "
            f"(registered: {strategy_kinds()})")
    return STRATEGIES[cfg.kind](cfg, loss_fn=loss_fn, syn_spec=syn_spec,
                                local_lr=local_lr)


# ---------------------------------------------------------------------------
# the paper's methods, as registered strategies
# ---------------------------------------------------------------------------


@register_strategy("identity")
class IdentityStrategy(CompressionStrategy):
    """FedAvg: the update itself is the payload (4d wire bytes)."""

    def payload_floats(self, params) -> float:
        return float(sum(l.size for l in jax.tree_util.tree_leaves(params)))

    def client_encode(self, key, u, params):
        # recon == u exactly, so the efficiency cosine is 1 by identity —
        # no reduction pass needed. The wire payload is the tree itself.
        return TreeCompressed(u, jnp.float32(self.payload_floats(params)),
                              jnp.float32(0), cosine=jnp.float32(1.0),
                              wire=u)

    def server_decode(self, payload, params):
        return payload


@register_strategy("topk")
class TopKStrategy(CompressionStrategy):
    """DGC-style magnitude top-k per leaf: exact values + indices."""

    def payload_floats(self, params) -> float:
        return float(sum(2 * _leaf_k(l, self.cfg.keep_ratio)
                         for l in jax.tree_util.tree_leaves(params)))

    def client_encode(self, key, u, params):
        leaves, treedef = jax.tree_util.tree_flatten(u)
        recs, wires = [], []
        for l in leaves:
            k = _leaf_k(l, self.cfg.keep_ratio)
            v = l.ravel()
            _, idx = jax.lax.top_k(jnp.abs(v), k)
            vals = v[idx]
            recs.append(jnp.zeros_like(v).at[idx].set(vals)
                        .reshape(l.shape))
            wires.append((vals, idx))
        recon = jax.tree_util.tree_unflatten(treedef, recs)
        return TreeCompressed(recon, jnp.float32(self.payload_floats(params)),
                              jnp.float32(0), wire=tuple(wires))

    def server_decode(self, payload, params):
        leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for (vals, idx), leaf in zip(payload, leaves):
            shape = jnp.shape(leaf)
            n = int(np.prod(shape)) if len(shape) else 1
            out.append(jnp.zeros((n,), jnp.float32).at[idx].set(vals)
                       .reshape(shape))
        return jax.tree_util.tree_unflatten(treedef, out)


@register_strategy("randk")
class RandKStrategy(CompressionStrategy):
    """Random-k per leaf (accounted-only: no wire format registered)."""

    def payload_floats(self, params) -> float:
        leaves = jax.tree_util.tree_leaves(params)
        return float(sum(_leaf_k(l, self.cfg.keep_ratio)
                         for l in leaves) + 1)

    def client_encode(self, key, u, params):
        leaves, treedef = jax.tree_util.tree_flatten(u)
        keys = jax.random.split(key, len(leaves))
        out = []
        for l, k_i in zip(leaves, keys):
            k = _leaf_k(l, self.cfg.keep_ratio)
            v = l.ravel()
            idx = jax.random.choice(k_i, v.size, shape=(k,), replace=False)
            kept = jnp.zeros_like(v).at[idx].set(v[idx])
            out.append(kept.reshape(l.shape))
        recon = jax.tree_util.tree_unflatten(treedef, out)
        return TreeCompressed(recon, jnp.float32(self.payload_floats(params)),
                              jnp.float32(0))


@register_strategy("signsgd")
class SignSGDStrategy(CompressionStrategy):
    """signSGD with per-leaf mean-|x| scale; 1 bit/coordinate on the wire."""

    def payload_floats(self, params) -> float:
        leaves = jax.tree_util.tree_leaves(params)
        return sum(l.size for l in leaves) / 32.0 + len(leaves)

    def client_encode(self, key, u, params):
        leaves, treedef = jax.tree_util.tree_flatten(u)
        scales = [jnp.mean(jnp.abs(l)) for l in leaves]
        recon = jax.tree_util.tree_unflatten(
            treedef, [s * jnp.sign(l) for s, l in zip(scales, leaves)])
        # wire: the sign *source* tree + per-leaf scales; the codec packs
        # one bit per coordinate from it (bit = coord >= 0).
        return TreeCompressed(recon, jnp.float32(self.payload_floats(params)),
                              jnp.float32(0),
                              wire=(u, jnp.stack(scales)))

    def server_decode(self, payload, params):
        # the canonical payload is already the reconstructed tree (signs
        # re-scaled by the codec's unpack)
        return payload


@register_strategy("stc")
class STCStrategy(CompressionStrategy):
    """STC: ternary top-k (single magnitude mu per leaf + signs)."""

    def payload_floats(self, params) -> float:
        leaves = jax.tree_util.tree_leaves(params)
        ks = [_leaf_k(l, self.cfg.keep_ratio) for l in leaves]
        return float(sum(ks)) + sum(ks) / 32.0 + len(leaves)

    def client_encode(self, key, u, params):
        leaves, treedef = jax.tree_util.tree_flatten(u)
        recs, wires = [], []
        for l in leaves:
            k = _leaf_k(l, self.cfg.keep_ratio)
            v = l.ravel()
            _, idx = jax.lax.top_k(jnp.abs(v), k)
            vals = v[idx]
            mu = jnp.mean(jnp.abs(vals))
            sgn = jnp.sign(vals)
            recs.append(jnp.zeros_like(v).at[idx].set(mu * sgn)
                        .reshape(l.shape))
            wires.append((sgn, idx, mu))
        recon = jax.tree_util.tree_unflatten(treedef, recs)
        return TreeCompressed(recon, jnp.float32(self.payload_floats(params)),
                              jnp.float32(0), wire=tuple(wires))

    def server_decode(self, payload, params):
        leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for (pm1, idx, mu), leaf in zip(payload, leaves):
            shape = jnp.shape(leaf)
            n = int(np.prod(shape)) if len(shape) else 1
            out.append(jnp.zeros((n,), jnp.float32).at[idx].set(mu * pm1)
                       .reshape(shape))
        return jax.tree_util.tree_unflatten(treedef, out)


@register_strategy("threesfc")
class ThreeSFCStrategy(CompressionStrategy):
    """The paper's method: single-step synthetic-features compression.

    The (D_syn, s) payload is the wire; the server decode is one backward
    of the global model on the synthetic batch (Eq. 10), and — because
    every client encodes at the same w^t — the batched payloads aggregate
    in ONE replicated backward (``server_aggregate``), which is what makes
    the fused fan-out's O(N·payload) collective possible.
    """

    supports_fused_aggregate = True

    def __init__(self, cfg, *, loss_fn=None, syn_spec=None, local_lr=0.01):
        super().__init__(cfg, loss_fn=loss_fn, syn_spec=syn_spec,
                         local_lr=local_lr)
        assert syn_spec is not None, \
            f"{cfg.kind} strategy needs syn_spec (synthetic payload shapes)"

    def payload_floats(self, params) -> float:
        return self.syn_spec.floats + 1.0

    def client_encode(self, key, u, params):
        from repro.core import threesfc
        assert self.loss_fn is not None, \
            f"{self.cfg.kind} encode needs the model's syn loss_fn"
        syn0 = threesfc.init_syn(key, self.syn_spec)
        res = threesfc.encode(
            self.loss_fn, params, u, syn0,
            steps=self.cfg.syn_steps, lr=self.cfg.syn_lr,
            lam=self.cfg.l2_coef,
        )
        # encode's fused stats triple already carries cos(recon, u) and
        # the (gw, s) factorization — EF and metrics add no extra passes.
        return TreeCompressed(res.recon,
                              jnp.float32(self.payload_floats(params)),
                              res.objective, cosine=res.cosine,
                              direction=res.gw, scale=res.s,
                              wire=(res.syn, res.s))

    def server_decode(self, payload, params):
        assert self.loss_fn is not None, \
            "threesfc decode-side reconstruction needs syn_loss_fn"
        syn, s = payload
        gw = jax.grad(self.loss_fn)(params, syn)
        return flat.tree_scale(gw, s)

    def server_aggregate(self, params, payloads):
        """ONE replicated batched backward over the gathered (D_syn, s):

            G(ĝ_1..ĝ_N) = ∇_w (1/N) Σ_i s_i F(D_syn,i, w^t)
        """
        assert self.loss_fn is not None, \
            "threesfc fused aggregation needs syn_loss_fn"
        syns, ss = payloads

        def total_loss(w):
            per = jax.vmap(lambda sy: self.loss_fn(w, sy))(syns)   # (N,)
            return jnp.mean(jax.lax.stop_gradient(ss) * per)

        return jax.grad(total_loss)(params)

    def mask_payloads(self, payloads, w):
        """(D_syn, s) is linear in s, so masking a client is exactly
        ``s_i <- w_i * s_i`` — a dropped payload contributes a zero term to
        the batched backward; ``w == 1`` everywhere is ``s * 1.0``, bitwise
        the unmasked payload (the zero-fault gate's fused leg)."""
        syns, ss = payloads
        return syns, ss * w


@register_strategy("fedsynth")
class FedSynthStrategy(ThreeSFCStrategy):
    """FedSynth baseline: K-step unrolled synthesis (accounted-only wire)."""

    supports_fused_aggregate = False

    def client_encode(self, key, u, params):
        from repro.core import fedsynth, threesfc
        assert self.loss_fn is not None, \
            f"{self.cfg.kind} encode needs the model's syn loss_fn"
        syn0 = threesfc.init_syn(key, self.syn_spec)
        res = fedsynth.encode(
            self.loss_fn, params, u, syn0,
            unroll_steps=self.cfg.unroll_steps,
            opt_steps=max(self.cfg.syn_steps, 10),
            lr=self.local_lr, syn_lr=self.cfg.syn_lr,
        )
        return TreeCompressed(res.recon,
                              jnp.float32(self.payload_floats(params)),
                              res.l2)

    def server_decode(self, payload, params):
        raise NotImplementedError(
            "fedsynth has no payload decode (unrolled recon is client-side)")

    def server_aggregate(self, params, payloads):
        raise NotImplementedError(
            "strategy 'fedsynth' does not support fused aggregation")
