"""Error feedback (EF) — paper Eq. 6, generic over any compressor.

EF maintains a per-client residual ``e`` (same shape as the flat gradient).
Each round the client compresses ``u = g + e`` and keeps the part the
compressor dropped: ``e' = u - decode(encode(u))``.

The key invariant (tested property): the *telescoped* sum of reconstructions
equals the telescoped sum of true updates minus the final residual:

    sum_t recon_t = sum_t g_t + e_0 - e_T

so no gradient mass is ever lost, only delayed.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def ef_init(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


def ef_step(
    compress_fn: Callable[[jax.Array], Tuple[object, jax.Array]],
    g: jax.Array,
    e: jax.Array,
    enabled: bool = True,
) -> Tuple[object, jax.Array, jax.Array]:
    """One EF round. Returns (payload, recon, new_residual).

    With ``enabled=False`` the residual is pinned to zero (paper's w/o-EF
    ablation row).
    """
    u = g + e if enabled else g
    payload, recon = compress_fn(u)
    e_new = u - recon if enabled else e
    return payload, recon, e_new
