"""3SFC — Single-Step Synthetic Features Compressor (the paper's method).

Encoder (client, Eq. 7-9): compress the accumulated local update
``g + e`` into a tiny synthetic dataset ``D_syn = (x_syn, y_syn)`` plus one
scalar ``s`` by maximizing the |cosine| between ``∇_w F(D_syn, w^t)`` and the
target. The scale is factored out analytically (Eq. 8):

    s = <g+e, ∇F> / ||∇F||²         (least-squares optimal coefficient)

so the synthetic-data objective (Eq. 9) only cares about *direction*:

    min_{D_syn}  1 - |cos(∇_w F(D_syn, w^t), g+e)| + λ ||D_syn||²

optimized for S steps (paper: S=1 suffices — hence "single-step") of GD via
grad-of-grad. Decoder (server, Eq. 10): one backward of the *global* model on
``D_syn`` scaled by ``s``. Both sides evaluate at the same ``w^t`` so the
reconstruction is exact on the server.

Synthetic features generalize beyond the paper's image classifiers:
* classifier:  x (n, *input_shape) raw pixels, y (n, C) soft-label logits
* LM family:   x (n, L, d_model) *soft input embeddings*, y soft labels over
  the vocab — optionally low-rank factored (u (n,L,r) @ v (r,V)) so the
  payload stays tiny for 100k+ vocabs (beyond-paper extension).

Budget: ||D_syn||₀ + 1 ≤ B, counting every transmitted float.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flat


class SynData(NamedTuple):
    """The transmitted synthetic dataset. ``y_rank`` empty => dense labels."""

    x: jax.Array                     # synthetic inputs (or soft embeddings)
    y: jax.Array                     # soft label logits, dense or factor u
    y_rank: jax.Array                # low-rank factor v (r, C); shape (0,0) if dense

    @property
    def floats(self) -> float:
        return float(self.x.size + self.y.size + self.y_rank.size)

    def labels(self) -> jax.Array:
        """Dense soft-label logits."""
        if self.y_rank.size == 0:
            return self.y
        return jnp.einsum("...r,rc->...c", self.y, self.y_rank)


@dataclasses.dataclass(frozen=True)
class SynSpec:
    """Static description of the synthetic payload's shapes."""

    x_shape: Tuple[int, ...]         # e.g. (n, 28, 28, 1) or (n, L, d_model)
    num_classes: int                 # C (classifier) or vocab (LM)
    label_rank: int = 0              # 0 => dense (n, ..., C) labels
    label_lead: Tuple[int, ...] = () # leading label dims, default x_shape[:-1]

    @property
    def floats(self) -> float:
        import numpy as np

        lead = self.label_lead or self.x_shape[:1]
        x = float(np.prod(self.x_shape))
        if self.label_rank:
            return x + float(np.prod(lead)) * self.label_rank + self.label_rank * self.num_classes
        return x + float(np.prod(lead)) * self.num_classes


def init_syn(key: jax.Array, spec: SynSpec, scale: float = 0.1) -> SynData:
    kx, ky, kv = jax.random.split(key, 3)
    x = scale * jax.random.normal(kx, spec.x_shape, jnp.float32)
    lead = spec.label_lead or spec.x_shape[:1]
    if spec.label_rank:
        y = scale * jax.random.normal(ky, (*lead, spec.label_rank), jnp.float32)
        v = scale * jax.random.normal(kv, (spec.label_rank, spec.num_classes), jnp.float32)
    else:
        y = scale * jax.random.normal(ky, (*lead, spec.num_classes), jnp.float32)
        v = jnp.zeros((0, 0), jnp.float32)
    return SynData(x, y, v)


# ``loss_fn(params, syn: SynData) -> scalar`` — the model's empirical risk on
# the synthetic batch (soft-label cross-entropy for every model family here).
LossFn = Callable[[flat.PyTree, SynData], jax.Array]


def soft_xent(logits: jax.Array, label_logits: jax.Array) -> jax.Array:
    """Cross-entropy against softmax(label_logits); mean over leading dims."""
    target = jax.nn.softmax(label_logits, axis=-1)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(target * logp, axis=-1))


_EPS = 1e-12


def _objective(
    loss_fn: LossFn, params: flat.PyTree, syn: SynData, target: flat.PyTree, lam: float
) -> Tuple[jax.Array, Tuple[flat.PyTree, jax.Array]]:
    """Eq. 9 value plus aux ``(gw, stats)``.

    ``stats = (⟨gw,t⟩, ||gw||², ||t||²)`` comes from ONE fused HBM pass
    (``flat.tree_stats``); Eq. 9's cosine, Eq. 8's scale and the reported
    compression efficiency are all scalar algebra on this same triple, so
    each objective evaluation reads the gradient trees exactly once.
    """
    gw = jax.grad(loss_fn)(params, syn)
    stats = flat.tree_stats(gw, target)
    dot, gg, tt = stats[0], stats[1], stats[2]
    cos = dot / (jnp.sqrt(gg) * jnp.sqrt(tt) + _EPS)
    reg = lam * (flat.tree_sqnorm([syn.x, syn.y, syn.y_rank]))
    return 1.0 - jnp.abs(cos) + reg, (gw, stats)


class EncodeResult(NamedTuple):
    syn: SynData
    s: jax.Array                     # scaling coefficient (Eq. 8)
    gw: flat.PyTree                  # ∇_w F(D_syn, w^t) at the final D_syn
    cosine: jax.Array                # compression efficiency (Fig. 7 metric)
    objective: jax.Array             # final Eq. 9 value
    stats: jax.Array                 # (⟨gw,t⟩, ||gw||², ||t||²) fused triple

    @property
    def recon(self) -> flat.PyTree:
        """s · ∇_w F(D_syn, w^t) — what the server sees (Eq. 10).

        Materialized on demand: EF paths that only need ``e' = u − s·gw``
        (``kernels.ops.tree_ef_update``) never instantiate this tree.
        """
        return flat.tree_scale(self.gw, self.s)


def encode(
    loss_fn: LossFn,
    params: flat.PyTree,
    target: flat.PyTree,
    syn0: SynData,
    *,
    steps: int = 1,
    lr: float = 0.1,
    lam: float = 0.0,
    normalize_updates: bool = True,
) -> EncodeResult:
    """Run S optimization steps on D_syn (Algorithm 1 lines 7-9), then Eq. 8.

    ``normalize_updates=True`` rescales each GD step by the syn-grad RMS —
    a per-tensor Adam-like normalization that makes one step land at a useful
    distance regardless of model scale. The paper's plain-GD update is
    recovered with ``normalize_updates=False``; both are exposed because the
    normalized variant is markedly more robust across the 10 assigned
    architectures (recorded as a beyond-paper change in DESIGN.md).

    Perf: every objective evaluation reduces the gradient trees exactly once
    (the fused ``flat.tree_stats`` triple); s, the efficiency cosine and the
    Eq. 9 value are scalar algebra on that triple, and the reconstruction is
    returned factored as (gw, s) so EF consumers can stream
    ``e' = u − s·gw`` without materializing s·gw (see ``EncodeResult.recon``).
    """

    def obj_aux(syn: SynData):
        return _objective(loss_fn, params, syn, target, lam)

    vag = jax.value_and_grad(obj_aux, has_aux=True)

    def update(syn: SynData, g: SynData) -> SynData:
        if normalize_updates:
            def upd(p, gi):
                rms = jnp.sqrt(jnp.mean(gi * gi) + 1e-12)
                return p - lr * gi / rms
            return SynData(*[upd(p, gi) for p, gi in zip(syn, g)])
        return SynData(*[p - lr * gi for p, gi in zip(syn, g)])

    # One scan of steps+1 evaluations: iterations 0..S-1 run grad-of-grad
    # and apply the GD update; the final iteration evaluates (obj, gw, stats)
    # at the *returned* D_syn with a plain inner backward (cond keeps the
    # outer backward off that step — the predicate is the unbatched scan
    # index, so vmap'd clients keep the branch, not a select). The last
    # carry therefore already holds everything Eq. 8/9 need — no separate
    # `_objective` recompute after the loop, and since the final branch's
    # zero gradient makes `update` the identity, the carry's syn is exactly
    # the one gw was evaluated at (decode exactness, Eq. 10).
    def step(carry, i):
        syn = carry[0]

        def eval_and_grad(syn):
            (val, (gw, st)), g = vag(syn)
            return val, gw, st, g

        def eval_only(syn):
            val, (gw, st) = obj_aux(syn)
            return val, gw, st, jax.tree_util.tree_map(jnp.zeros_like, syn)

        val, gw, st, g = jax.lax.cond(i < steps, eval_and_grad, eval_only, syn)
        return (update(syn, g), val, gw, st), None

    gw0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.result_type(p)), params)
    init = (syn0, jnp.zeros((), jnp.float32), gw0, jnp.zeros((3,), jnp.float32))
    (syn, obj_val, gw, stats), _ = jax.lax.scan(step, init, jnp.arange(steps + 1))

    dot, gg = stats[0], stats[1]
    s = dot / (gg + _EPS)                                    # Eq. 8
    # cos(s·gw, target) = sign(s) · cos(gw, target), from the same triple
    cos = jnp.sign(s) * dot / (jnp.sqrt(gg) * jnp.sqrt(stats[2]) + _EPS)
    return EncodeResult(syn, s, gw, cos, obj_val, stats)


def decode(loss_fn: LossFn, params: flat.PyTree, syn: SynData, s: jax.Array) -> flat.PyTree:
    """Server-side reconstruction (Eq. 10): s · ∇_w F(D_syn, w^t)."""
    gw = jax.grad(loss_fn)(params, syn)
    return flat.tree_scale(gw, s)
