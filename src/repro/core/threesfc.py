"""3SFC — Single-Step Synthetic Features Compressor (the paper's method).

Encoder (client, Eq. 7-9): compress the accumulated local update
``g + e`` into a tiny synthetic dataset ``D_syn = (x_syn, y_syn)`` plus one
scalar ``s`` by maximizing the |cosine| between ``∇_w F(D_syn, w^t)`` and the
target. The scale is factored out analytically (Eq. 8):

    s = <g+e, ∇F> / ||∇F||²         (least-squares optimal coefficient)

so the synthetic-data objective (Eq. 9) only cares about *direction*:

    min_{D_syn}  1 - |cos(∇_w F(D_syn, w^t), g+e)| + λ ||D_syn||²

optimized for S steps (paper: S=1 suffices — hence "single-step") of GD via
grad-of-grad. Decoder (server, Eq. 10): one backward of the *global* model on
``D_syn`` scaled by ``s``. Both sides evaluate at the same ``w^t`` so the
reconstruction is exact on the server.

Synthetic features generalize beyond the paper's image classifiers:
* classifier:  x (n, *input_shape) raw pixels, y (n, C) soft-label logits
* LM family:   x (n, L, d_model) *soft input embeddings*, y soft labels over
  the vocab — optionally low-rank factored (u (n,L,r) @ v (r,V)) so the
  payload stays tiny for 100k+ vocabs (beyond-paper extension).

Budget: ||D_syn||₀ + 1 ≤ B, counting every transmitted float.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flat


class SynData(NamedTuple):
    """The transmitted synthetic dataset. ``y_rank`` empty => dense labels."""

    x: jax.Array                     # synthetic inputs (or soft embeddings)
    y: jax.Array                     # soft label logits, dense or factor u
    y_rank: jax.Array                # low-rank factor v (r, C); shape (0,0) if dense

    @property
    def floats(self) -> float:
        return float(self.x.size + self.y.size + self.y_rank.size)

    def labels(self) -> jax.Array:
        """Dense soft-label logits."""
        if self.y_rank.size == 0:
            return self.y
        return jnp.einsum("...r,rc->...c", self.y, self.y_rank)


@dataclasses.dataclass(frozen=True)
class SynSpec:
    """Static description of the synthetic payload's shapes."""

    x_shape: Tuple[int, ...]         # e.g. (n, 28, 28, 1) or (n, L, d_model)
    num_classes: int                 # C (classifier) or vocab (LM)
    label_rank: int = 0              # 0 => dense (n, ..., C) labels
    label_lead: Tuple[int, ...] = () # leading label dims, default x_shape[:-1]

    @property
    def floats(self) -> float:
        import numpy as np

        lead = self.label_lead or self.x_shape[:1]
        x = float(np.prod(self.x_shape))
        if self.label_rank:
            return x + float(np.prod(lead)) * self.label_rank + self.label_rank * self.num_classes
        return x + float(np.prod(lead)) * self.num_classes


def init_syn(key: jax.Array, spec: SynSpec, scale: float = 0.1) -> SynData:
    kx, ky, kv = jax.random.split(key, 3)
    x = scale * jax.random.normal(kx, spec.x_shape, jnp.float32)
    lead = spec.label_lead or spec.x_shape[:1]
    if spec.label_rank:
        y = scale * jax.random.normal(ky, (*lead, spec.label_rank), jnp.float32)
        v = scale * jax.random.normal(kv, (spec.label_rank, spec.num_classes), jnp.float32)
    else:
        y = scale * jax.random.normal(ky, (*lead, spec.num_classes), jnp.float32)
        v = jnp.zeros((0, 0), jnp.float32)
    return SynData(x, y, v)


# ``loss_fn(params, syn: SynData) -> scalar`` — the model's empirical risk on
# the synthetic batch (soft-label cross-entropy for every model family here).
LossFn = Callable[[flat.PyTree, SynData], jax.Array]


def soft_xent(logits: jax.Array, label_logits: jax.Array) -> jax.Array:
    """Cross-entropy against softmax(label_logits); mean over leading dims."""
    target = jax.nn.softmax(label_logits, axis=-1)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(target * logp, axis=-1))


def _objective(
    loss_fn: LossFn, params: flat.PyTree, syn: SynData, target: flat.PyTree, lam: float
) -> Tuple[jax.Array, flat.PyTree]:
    """Eq. 9 value and the synthetic gradient ∇_w F(D_syn, w) (aux)."""
    gw = jax.grad(loss_fn)(params, syn)
    cos = flat.tree_cosine(gw, target)
    reg = lam * (flat.tree_sqnorm([syn.x, syn.y, syn.y_rank]))
    return 1.0 - jnp.abs(cos) + reg, gw


class EncodeResult(NamedTuple):
    syn: SynData
    s: jax.Array                     # scaling coefficient (Eq. 8)
    recon: flat.PyTree               # s * ∇_w F(D_syn, w^t) — what the server sees
    cosine: jax.Array                # compression efficiency (Fig. 7 metric)
    objective: jax.Array             # final Eq. 9 value


def encode(
    loss_fn: LossFn,
    params: flat.PyTree,
    target: flat.PyTree,
    syn0: SynData,
    *,
    steps: int = 1,
    lr: float = 0.1,
    lam: float = 0.0,
    normalize_updates: bool = True,
) -> EncodeResult:
    """Run S optimization steps on D_syn (Algorithm 1 lines 7-9), then Eq. 8.

    ``normalize_updates=True`` rescales each GD step by the syn-grad RMS —
    a per-tensor Adam-like normalization that makes one step land at a useful
    distance regardless of model scale. The paper's plain-GD update is
    recovered with ``normalize_updates=False``; both are exposed because the
    normalized variant is markedly more robust across the 10 assigned
    architectures (recorded as a beyond-paper change in DESIGN.md).
    """

    def obj_only(syn: SynData) -> jax.Array:
        val, _ = _objective(loss_fn, params, syn, target, lam)
        return val

    grad_obj = jax.grad(obj_only)

    def step(syn: SynData, _):
        g = grad_obj(syn)
        if normalize_updates:
            def upd(p, gi):
                rms = jnp.sqrt(jnp.mean(gi * gi) + 1e-12)
                return p - lr * gi / rms
            syn = SynData(*[upd(p, gi) for p, gi in zip(syn, g)])
        else:
            syn = SynData(*[p - lr * gi for p, gi in zip(syn, g)])
        return syn, None

    syn, _ = jax.lax.scan(step, syn0, None, length=steps)

    obj_val, gw = _objective(loss_fn, params, syn, target, lam)
    num = flat.tree_dot(target, gw)
    den = flat.tree_sqnorm(gw) + 1e-12
    s = num / den                                            # Eq. 8
    recon = flat.tree_scale(gw, s)
    cos = flat.tree_cosine(recon, target)
    return EncodeResult(syn, s, recon, cos, obj_val)


def decode(loss_fn: LossFn, params: flat.PyTree, syn: SynData, s: jax.Array) -> flat.PyTree:
    """Server-side reconstruction (Eq. 10): s · ∇_w F(D_syn, w^t)."""
    gw = jax.grad(loss_fn)(params, syn)
    return flat.tree_scale(gw, s)
