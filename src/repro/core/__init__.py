"""Core: the paper's contribution — 3SFC + EF + baseline compressors.

Method dispatch lives in ``repro.core.strategy``: one registered
``CompressionStrategy`` per compression method (``make_strategy``,
``register_strategy``); ``compressor`` keeps the historical
``make_compressor`` facade over it.
"""
from repro.core import (baselines, error_feedback, fedsynth, flat,  # noqa: F401
                        strategy, threesfc)
from repro.core.strategy import (CompressionStrategy, make_strategy,  # noqa: F401
                                 register_strategy, strategy_kinds)
