"""Core: the paper's contribution — 3SFC + EF + baseline compressors."""
from repro.core import baselines, error_feedback, fedsynth, flat, threesfc  # noqa: F401
