"""FedSynth-style multi-step distillation baseline (what 3SFC fixes).

The method of Goetz & Tewari / Hu et al.: synthesize data such that *K
unrolled SGD steps* on the synthetic batch, starting from ``w^t``, land near
the true local weights ``w_i^t``. The objective is the ℓ₂ distance between
simulated and real weights — differentiated through the whole unroll
(grad-through-K-grads).

The paper shows (Fig. 2/3, Table 1) this collapses at high compression on
non-trivial models: gradients through the unroll explode as they
backpropagate to the early simulation steps. We reproduce that failure mode
as a benchmark (``benchmarks.fedsynth_collapse``) — per-unroll-step syn-grad
norms are surfaced so the explosion is observable, mirroring Fig. 3.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import flat
from repro.core.threesfc import LossFn, SynData


class FedSynthResult(NamedTuple):
    syn: SynData
    recon: flat.PyTree               # w^t - simulate(syn) : the decoded update
    l2: jax.Array                    # final objective value
    syn_grad_norm: jax.Array         # grad-through-unroll norm (explosion metric)


def _simulate(loss_fn: LossFn, params: flat.PyTree, syn: SynData, k: int, lr: float):
    """K unrolled SGD steps on the synthetic batch from ``params``."""

    def step(w, _):
        g = jax.grad(loss_fn)(w, syn)
        return flat.tree_axpy(-lr, g, w), None

    w_sim, _ = jax.lax.scan(step, params, None, length=k)
    return w_sim


def encode(
    loss_fn: LossFn,
    params: flat.PyTree,
    target: flat.PyTree,             # g_i^t = w^t - w_i^t
    syn0: SynData,
    *,
    unroll_steps: int = 5,
    opt_steps: int = 10,
    lr: float = 0.01,
    syn_lr: float = 0.1,
) -> FedSynthResult:
    """Optimize syn data so the K-step simulated update matches ``target``."""

    def objective(syn: SynData) -> jax.Array:
        w_sim = _simulate(loss_fn, params, syn, unroll_steps, lr)
        sim_update = flat.tree_sub(params, w_sim)            # w^t - w_sim
        return flat.tree_sqnorm(flat.tree_sub(sim_update, target))

    grad_obj = jax.grad(objective)

    def step(syn, _):
        g = grad_obj(syn)
        gn = flat.tree_norm(g)
        syn = SynData(*[p - syn_lr * gi for p, gi in zip(syn, g)])
        return syn, gn

    syn, gnorms = jax.lax.scan(step, syn0, None, length=opt_steps)

    w_sim = _simulate(loss_fn, params, syn, unroll_steps, lr)
    recon = flat.tree_sub(params, w_sim)
    l2 = flat.tree_sqnorm(flat.tree_sub(recon, target))
    return FedSynthResult(syn, recon, l2, gnorms[-1])


def decode(loss_fn: LossFn, params: flat.PyTree, syn: SynData, k: int, lr: float) -> flat.PyTree:
    w_sim = _simulate(loss_fn, params, syn, k, lr)
    return flat.tree_sub(params, w_sim)
