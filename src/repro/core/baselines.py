"""Baseline gradient compressors the paper compares against.

All operate on *flat* float32 vectors (see ``flat.Flattener``) and return
``(payload, recon)`` where ``recon`` is the server-side reconstruction —
exactly what the decoder would produce from the payload. Budget accounting
(``payload_floats``) follows the paper's conventions:

* top-k (DGC):  k values + k indices  -> 2k float-equivalents
* rand-k:       k values + 1 seed     -> k + 1 (indices regenerable from seed)
* signSGD(+EF): 1 bit/coord + 1 scale -> d/32 + 1
* STC:          top-k + binarized values -> k (indices) + k/32 (signs) + 1 (mu)
* identity (FedAvg): d

These float counts are *conventions*, not measurements. The real wire
format — each payload serialized into one framed ``uint8`` buffer
(bit-packed signs, ``ceil(log2 d)``-bit index streams, dtype-policied
synthetic payloads) with a measured byte size — lives in ``repro.comm``;
``compression_rate_bytes`` below is the bytes-based sibling of Eq. 1 that
the FL harness reports next to the accounted-float rate.

On TPU, exact global top-k over O(d) is sort-bound; we use the Pallas
threshold-select kernel (``repro.kernels.topk_mask``) when available and fall
back to ``jax.lax.top_k`` here. Reconstruction semantics are identical.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Payload(NamedTuple):
    """Accounted-size stand-in, NOT the wire format. ``floats`` is the
    paper-convention payload size; the serialized frame (actual bytes on
    the wire, header included) is produced by ``repro.comm.codec``."""

    data: tuple
    floats: float


# ---------------------------------------------------------------------------
# identity (FedAvg)
# ---------------------------------------------------------------------------


def identity_compress(vec: jax.Array) -> Tuple[Payload, jax.Array]:
    return Payload((vec,), float(vec.size)), vec


# ---------------------------------------------------------------------------
# top-k (DGC)
# ---------------------------------------------------------------------------


def topk_compress(vec: jax.Array, k: int) -> Tuple[Payload, jax.Array]:
    """Keep the k largest-magnitude coordinates (DGC sparsifier)."""
    k = max(1, min(int(k), vec.size))
    mag = jnp.abs(vec)
    _, idx = jax.lax.top_k(mag, k)
    vals = vec[idx]
    recon = jnp.zeros_like(vec).at[idx].set(vals)
    return Payload((vals, idx), 2.0 * k), recon


# ---------------------------------------------------------------------------
# rand-k
# ---------------------------------------------------------------------------


def randk_compress(key: jax.Array, vec: jax.Array, k: int) -> Tuple[Payload, jax.Array]:
    k = max(1, min(int(k), vec.size))
    idx = jax.random.choice(key, vec.size, shape=(k,), replace=False)
    vals = vec[idx]
    recon = jnp.zeros_like(vec).at[idx].set(vals)
    return Payload((vals, idx), float(k) + 1.0), recon


# ---------------------------------------------------------------------------
# signSGD (with mean-|x| scale, as in EF-signSGD)
# ---------------------------------------------------------------------------


def signsgd_compress(vec: jax.Array) -> Tuple[Payload, jax.Array]:
    scale = jnp.mean(jnp.abs(vec))
    signs = jnp.sign(vec)
    # 0-sign coords reconstruct to 0 (sign(0) == 0): harmless and exact.
    recon = scale * signs
    return Payload((signs, scale), vec.size / 32.0 + 1.0), recon


# ---------------------------------------------------------------------------
# STC: sparse ternary compression = top-k + binarize kept values to mean
# ---------------------------------------------------------------------------


def stc_compress(vec: jax.Array, k: int) -> Tuple[Payload, jax.Array]:
    k = max(1, min(int(k), vec.size))
    mag = jnp.abs(vec)
    _, idx = jax.lax.top_k(mag, k)
    vals = vec[idx]
    mu = jnp.mean(jnp.abs(vals))
    tern = mu * jnp.sign(vals)
    recon = jnp.zeros_like(vec).at[idx].set(tern)
    return Payload((jnp.sign(vals), idx, mu), k + k / 32.0 + 1.0), recon


# ---------------------------------------------------------------------------
# reconstruction quality (fused single-pass accounting)
# ---------------------------------------------------------------------------


def reconstruction_stats(vec: jax.Array, recon: jax.Array,
                         eps: float = 1e-12) -> Tuple[jax.Array, jax.Array]:
    """(cosine, relative L2 error) of a reconstruction in two fused passes.

    The cosine is scalar algebra on the ``(⟨r,v⟩, ||r||², ||v||²)`` triple
    the Pallas ``fused_cosine`` kernel returns in a single HBM sweep. The
    error term is deliberately NOT derived from that triple —
    ``||r−v||² = ||r||² − 2⟨r,v⟩ + ||v||²`` cancels catastrophically in f32
    once the error drops below ~3e-4 relative — but from a direct sum over
    the streamed difference (XLA fuses it into one more pass, nothing
    materialized). Two passes total vs the naive route's four.
    """
    from repro.kernels import ops

    d, rr, vv = ops.fused_cosine(recon, vec)
    cos = d / (jnp.sqrt(rr) * jnp.sqrt(vv) + eps)
    sq = jnp.sum(jnp.square(recon.astype(jnp.float32) - vec.astype(jnp.float32)))
    return cos, jnp.sqrt(sq) / (jnp.sqrt(vv) + eps)


# ---------------------------------------------------------------------------
# budget helpers
# ---------------------------------------------------------------------------


def keep_k_for_budget(d: int, budget_floats: float) -> int:
    """k such that a top-k payload (2k floats) fits the budget."""
    return max(1, int(budget_floats // 2))


def compression_rate(payload_floats: float, d: int) -> float:
    """Paper Eq. 1: compressed size / uncompressed size (accounted floats)."""
    return payload_floats / float(d)


def compression_rate_bytes(payload_bytes: float, d: int,
                           bytes_per_param: int = 4) -> float:
    """Eq. 1 on *measured* wire bytes (``repro.comm.wire_bytes``): encoded
    frame size (header included) over the raw f32 tree size."""
    return payload_bytes / (bytes_per_param * float(d))
