"""Pytree <-> flat-vector utilities and tree algebra.

Compressors in this package operate on *flat* float32 vectors — the
concatenation of every leaf of the gradient pytree. ``Flattener`` records
shapes/dtypes once so compress/decompress round-trips are exact.
"""
from __future__ import annotations

from typing import Any, Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class Flattener:
    """Round-trippable pytree <-> 1-D float32 vector mapping.

    The mapping is static (shapes/dtypes/treedef captured at construction),
    so ``flatten``/``unflatten`` are jit-safe closures.
    """

    def __init__(self, tree: PyTree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        self.treedef = treedef
        self.shapes: List[Tuple[int, ...]] = [jnp.shape(l) for l in leaves]
        self.dtypes = [jnp.result_type(l) for l in leaves]
        self.sizes = [int(np.prod(s)) if len(s) else 1 for s in self.shapes]
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).tolist()
        self.total = int(self.offsets[-1])

    def flatten(self, tree: PyTree) -> jax.Array:
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32) for l in leaves]
        ) if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(self, vec: jax.Array) -> PyTree:
        leaves = []
        for shape, dtype, off, size in zip(
            self.shapes, self.dtypes, self.offsets[:-1], self.sizes
        ):
            chunk = jax.lax.dynamic_slice_in_dim(vec, off, size)
            leaves.append(chunk.reshape(shape).astype(dtype))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


# --- tree algebra (used where flattening would force a big concat) ---------


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, leafwise."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def _tree_stats_naive(a: PyTree, b: PyTree) -> jax.Array:
    """Single-traversal leafwise triple (a·b, ||a||², ||b||²), f32."""
    def leaf(x, y):
        xf = jnp.ravel(x).astype(jnp.float32)
        yf = jnp.ravel(y).astype(jnp.float32)
        return jnp.stack([jnp.sum(xf * yf), jnp.sum(xf * xf), jnp.sum(yf * yf)])

    parts = jax.tree_util.tree_leaves(jax.tree_util.tree_map(leaf, a, b))
    return sum(parts) if parts else jnp.zeros((3,), jnp.float32)


try:  # Pallas engine; gated so flat algebra survives a missing toolchain.
    # ImportError ONLY: any other error in the kernels package must surface,
    # not silently downgrade every reduction to the naive path.
    from repro.kernels import ops as _kernel_ops
except ImportError:  # pragma: no cover - exercised only without jax.experimental
    _kernel_ops = None


def tree_stats(a: PyTree, b: PyTree) -> jax.Array:
    """(3,) f32 = [a·b, ||a||², ||b||²] over whole pytrees, ONE HBM pass.

    The primitive every reduction below dispatches through: one streamed
    read of each tree yields all three partials (see ``kernels.ops.
    tree_fused_stats`` for the HBM-pass accounting), instead of the 2×
    traffic of a separate dot + two norms. Differentiable to arbitrary
    order (custom JVP) and safe under jit/vmap.
    """
    if _kernel_ops is not None:
        return _kernel_ops.tree_fused_stats(a, b)
    return _tree_stats_naive(a, b)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    """Sum of elementwise products over all leaves, accumulated in f32."""
    return tree_stats(a, b)[0]


def tree_sqnorm(a: PyTree) -> jax.Array:
    # Deliberately NOT routed through the pair kernel: a single-tree sum of
    # squares is already one pass; feeding a as both operands would read it
    # twice from HBM.
    parts = jax.tree_util.tree_map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a
    )
    leaves = jax.tree_util.tree_leaves(parts)
    return sum(leaves) if leaves else jnp.zeros((), jnp.float32)


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sqnorm(a))


def tree_cosine(a: PyTree, b: PyTree, eps: float = 1e-12) -> jax.Array:
    """cos(a, b) from the fused stats triple — one pass over each tree
    (the naive dot + norm + norm route reads each tree twice)."""
    d, aa, bb = tree_stats(a, b)
    return d / (jnp.sqrt(aa) * jnp.sqrt(bb) + eps)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_size(a: PyTree) -> int:
    """Total number of scalars in the tree (static)."""
    return sum(int(np.prod(jnp.shape(l)) or 1) for l in jax.tree_util.tree_leaves(a))
