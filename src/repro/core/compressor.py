"""Back-compat facade over the ``repro.core.strategy`` registry.

Since PR 5 every compression method lives as ONE registered
``CompressionStrategy`` object (``repro.core.strategy``): per-method
encode, server-side decode/aggregate, wire codec and payload accounting
travel together, and method dispatch is a registry lookup — not the
``kind``-keyed if/elif chains that used to live here. This module keeps the
two seed-era entry points alive for existing callers:

* ``TreeCompressor`` — a thin delegator exposing the strategy's derived
  steps under the historical names (``step``, ``wire_step``,
  ``compress_tree``, ``payload_floats``, ``init_state``). Everything is
  jit/vmap-safe: payload sizes are static, EF residuals live as pytrees
  mirroring the parameters (never a global concat — at production scale a
  flat concat would destroy GSPMD sharding; per-leaf operation keeps every
  collective on the leaf's own mesh axes).
* ``make_compressor(cfg, ...)`` — deprecated shim: builds the registered
  strategy and wraps it. New code should call
  ``repro.core.strategy.make_strategy`` and hand the strategy to
  ``repro.fl.round.build_fl_round`` directly.

Baselines run *per-leaf* (per-layer), matching how DGC/STC are deployed;
the global compression rate equals the per-leaf rate. 3SFC/FedSynth operate
on the tree directly (their reductions are per-leaf + scalar all-reduce).
Adding a method is one ``@register_strategy("kind")`` class — see the
strategy module docstring and README.md §"Writing a new compressor".
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import CompressorConfig
from repro.core import flat, threesfc
# re-exported for back-compat: these types moved to repro.core.strategy
from repro.core.strategy import (CompressMetrics, CompressionStrategy,
                                 TreeCompressed, leaf_k, make_strategy,
                                 warn_deprecated_once)

__all__ = ["CompressMetrics", "TreeCompressed", "TreeCompressor",
           "leaf_k", "make_compressor"]


class TreeCompressor:
    """Historical facade: the strategy's derived steps under the old names."""

    def __init__(self, strategy: CompressionStrategy):
        self.strategy = strategy
        self.cfg = strategy.cfg
        # (key, u_tree, params) -> TreeCompressed; exposed for the wire path
        # and benchmarks that need the raw payload.
        self.compress_tree = strategy.client_encode

    def init_state(self, params: flat.PyTree) -> flat.PyTree:
        """EF residual pytree (zeros, f32) mirroring params."""
        return self.strategy.init_ef_state(params)

    def payload_floats(self, params: flat.PyTree) -> float:
        return self.strategy.payload_floats(params)

    def step(self, key, g_tree, e_tree, params):
        """Returns (recon_tree, new_e_tree, CompressMetrics)."""
        return self.strategy.step(key, g_tree, e_tree, params)

    def wire_step(self, key, g_tree, e_tree, params, *, codec,
                  round_idx=0, client_idx=0):
        """Codec-mode step: (encoded uint8 buffer, new_e_tree, metrics)."""
        return self.strategy.wire_step(key, g_tree, e_tree, params,
                                       codec=codec, round_idx=round_idx,
                                       client_idx=client_idx)


def make_compressor(
    cfg: CompressorConfig,
    *,
    loss_fn: Optional[threesfc.LossFn] = None,
    syn_spec: Optional[threesfc.SynSpec] = None,
    local_lr: float = 0.01,
) -> TreeCompressor:
    """Deprecated: ``make_strategy`` + ``TreeCompressor`` in one call."""
    warn_deprecated_once(
        "make_compressor",
        "repro.core.strategy.make_strategy(cfg, ...)")
    return TreeCompressor(make_strategy(cfg, loss_fn=loss_fn,
                                        syn_spec=syn_spec,
                                        local_lr=local_lr))
