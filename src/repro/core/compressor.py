"""Unified, tree-based compressor interface for the FL runtime.

``make_compressor(cfg, ...)`` returns a ``TreeCompressor`` whose ``step`` maps
(per-client) ``(key, g_tree, e_tree, params) -> (recon_tree, e_tree',
metrics)``. Everything is jit/vmap-safe: payload sizes are static, EF
residuals live as pytrees mirroring the parameters (never a global concat —
at production scale a flat concat would destroy GSPMD sharding; per-leaf
operation keeps every collective on the leaf's own mesh axes).

Baselines run *per-leaf* (per-layer), matching how DGC/STC are deployed; the
global compression rate equals the per-leaf rate. 3SFC/FedSynth operate on
the tree directly (their reductions are per-leaf + scalar all-reduce).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fedsynth, flat, threesfc
from repro.configs.base import CompressorConfig
from repro.kernels import ops


class CompressMetrics(NamedTuple):
    cosine: jax.Array                # compression efficiency (Fig. 7)
    payload_floats: jax.Array        # accounted wire size this round
    aux: jax.Array                   # method-specific (3SFC: objective; else 0)


class TreeCompressed(NamedTuple):
    """What a per-method ``compress_tree`` hands back to the EF wrapper.

    ``cosine`` (when not None) is the already-computed cos(recon, u), so the
    wrapper skips its own ``tree_cosine`` pass; ``direction``/``scale`` (when
    not None) factor ``recon = scale · direction``, letting the EF update run
    as one fused ``e' = u − s·direction`` stream (``kernels.ops.
    tree_ef_update``) instead of reading the materialized recon again.
    ``wire`` is the method-specific wire payload (the quantities a
    ``repro.comm.codec`` codec serializes — value/index streams, sign
    sources, the (D_syn, s) pair); ``None`` for kinds without a wire format.
    Unused in float mode, so it costs nothing there (dead-code eliminated).
    """

    recon: Any
    floats: jax.Array
    aux: jax.Array
    cosine: Optional[jax.Array] = None
    direction: Any = None
    scale: Optional[jax.Array] = None
    wire: Any = None


class TreeCompressor:
    def __init__(self, cfg: CompressorConfig, step_fn, payload_floats_fn,
                 compress_tree=None):
        self.cfg = cfg
        self._step = step_fn
        self._payload = payload_floats_fn
        # (key, u_tree, params) -> TreeCompressed; exposed for the wire path
        # and benchmarks that need the raw payload.
        self.compress_tree = compress_tree

    def init_state(self, params: flat.PyTree) -> flat.PyTree:
        """EF residual pytree (zeros, f32) mirroring params."""
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def payload_floats(self, params: flat.PyTree) -> float:
        return self._payload(params)

    def step(self, key, g_tree, e_tree, params):
        """Returns (recon_tree, new_e_tree, CompressMetrics)."""
        return self._step(key, g_tree, e_tree, params)

    def wire_step(self, key, g_tree, e_tree, params, *, codec,
                  round_idx=0, client_idx=0):
        """Codec-mode step: (encoded uint8 buffer, new_e_tree, metrics).

        Same EF algebra as ``step`` but everything downstream of the
        compressor sees only the serialized frame; the reconstruction used
        for EF/cosine is the codec's *dequantized view* (``Codec.
        client_view``), so the client stays consistent with what the server
        will decode — identical to the float path wherever the codec is
        lossless (identity/topk; threesfc at the fp32 policy), and the
        documented 1-bit sign convention for signsgd/stc.
        """
        cfg = self.cfg
        if self.compress_tree is None:
            raise ValueError(f"compressor kind {cfg.kind!r} has no wire path")
        if cfg.error_feedback:
            u = flat.tree_add(g_tree, e_tree)
        else:
            u = g_tree
        out = self.compress_tree(key, u, params)
        if out.wire is None:
            raise ValueError(
                f"compressor kind {cfg.kind!r} emits no wire payload")
        buf = codec.encode(out.wire, round_idx=round_idx,
                           client_idx=client_idx)
        recon, direction, scale = codec.client_view(out)
        e_new = _ef_update(cfg, u, e_tree, recon, direction, scale)
        cos = _efficiency_cosine(out, recon, u)
        return buf, e_new, CompressMetrics(cos, out.floats, out.aux)


def leaf_k(n: int, ratio: float) -> int:
    """Kept entries for a size-n leaf at ``keep_ratio`` — the single source
    of truth for per-leaf budgets (the wire codecs derive their static
    layouts from the same function)."""
    return max(1, int(round(ratio * n)))


def _leaf_k(leaf, ratio: float) -> int:
    return leaf_k(leaf.size, ratio)


def _ef_update(cfg, u, e_tree, recon, direction, scale):
    """Eq. 6 residual on a (recon | direction·scale) view — the ONE copy of
    the EF algebra, shared by the float path (the compressor's own recon)
    and the wire path (the codec's dequantized view)."""
    if not cfg.error_feedback:
        return e_tree
    if direction is not None:
        return ops.tree_ef_update(u, direction, scale)
    return flat.tree_sub(u, recon)


def _efficiency_cosine(out, recon, u):
    """cos(recon, u) unless the method already computed it fused."""
    return out.cosine if out.cosine is not None \
        else flat.tree_cosine(recon, u)


def _ef_wrap(cfg, compress_tree):
    """Generic tree EF (Eq. 6) around a (key, u_tree, params)->TreeCompressed
    closure. Reuses the method's own stats where offered (see TreeCompressed)
    so the wrapper adds zero extra O(d) reduction passes for 3SFC."""

    def step(key, g_tree, e_tree, params):
        if cfg.error_feedback:
            u = flat.tree_add(g_tree, e_tree)
        else:
            u = g_tree
        out = compress_tree(key, u, params)
        e_new = _ef_update(cfg, u, e_tree, out.recon, out.direction, out.scale)
        cos = _efficiency_cosine(out, out.recon, u)
        return out.recon, e_new, CompressMetrics(cos, out.floats, out.aux)

    return step


def make_compressor(
    cfg: CompressorConfig,
    *,
    loss_fn: Optional[threesfc.LossFn] = None,
    syn_spec: Optional[threesfc.SynSpec] = None,
    local_lr: float = 0.01,
) -> TreeCompressor:
    kind = cfg.kind

    # ---- payload accounting (static) -------------------------------------
    def payload_floats_fn(params) -> float:
        leaves = jax.tree_util.tree_leaves(params)
        d = sum(l.size for l in leaves)
        if kind == "identity":
            return float(d)
        if kind == "topk":
            return float(sum(2 * _leaf_k(l, cfg.keep_ratio) for l in leaves))
        if kind == "randk":
            return float(sum(_leaf_k(l, cfg.keep_ratio) for l in leaves) + 1)
        if kind == "signsgd":
            return d / 32.0 + len(leaves)
        if kind == "stc":
            ks = [_leaf_k(l, cfg.keep_ratio) for l in leaves]
            return float(sum(ks)) + sum(ks) / 32.0 + len(leaves)
        if kind in ("threesfc", "fedsynth"):
            assert syn_spec is not None
            return syn_spec.floats + 1.0
        raise ValueError(f"unknown compressor kind {kind!r}")

    # ---- per-method tree compression --------------------------------------
    if kind == "identity":
        def compress_tree(key, u, params):
            # recon == u exactly, so the efficiency cosine is 1 by identity —
            # no reduction pass needed. The wire payload is the tree itself.
            return TreeCompressed(u, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0), cosine=jnp.float32(1.0),
                                  wire=u)

    elif kind == "topk":
        def compress_tree(key, u, params):
            leaves, treedef = jax.tree_util.tree_flatten(u)
            recs, wires = [], []
            for l in leaves:
                k = _leaf_k(l, cfg.keep_ratio)
                v = l.ravel()
                _, idx = jax.lax.top_k(jnp.abs(v), k)
                vals = v[idx]
                recs.append(jnp.zeros_like(v).at[idx].set(vals)
                            .reshape(l.shape))
                wires.append((vals, idx))
            recon = jax.tree_util.tree_unflatten(treedef, recs)
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0), wire=tuple(wires))

    elif kind == "randk":
        def compress_tree(key, u, params):
            leaves, treedef = jax.tree_util.tree_flatten(u)
            keys = jax.random.split(key, len(leaves))
            out = []
            for l, k_i in zip(leaves, keys):
                k = _leaf_k(l, cfg.keep_ratio)
                v = l.ravel()
                idx = jax.random.choice(k_i, v.size, shape=(k,), replace=False)
                kept = jnp.zeros_like(v).at[idx].set(v[idx])
                out.append(kept.reshape(l.shape))
            recon = jax.tree_util.tree_unflatten(treedef, out)
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0))

    elif kind == "signsgd":
        def compress_tree(key, u, params):
            leaves, treedef = jax.tree_util.tree_flatten(u)
            scales = [jnp.mean(jnp.abs(l)) for l in leaves]
            recon = jax.tree_util.tree_unflatten(
                treedef, [s * jnp.sign(l) for s, l in zip(scales, leaves)])
            # wire: the sign *source* tree + per-leaf scales; the codec packs
            # one bit per coordinate from it (bit = coord >= 0).
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0),
                                  wire=(u, jnp.stack(scales)))

    elif kind == "stc":
        def compress_tree(key, u, params):
            leaves, treedef = jax.tree_util.tree_flatten(u)
            recs, wires = [], []
            for l in leaves:
                k = _leaf_k(l, cfg.keep_ratio)
                v = l.ravel()
                _, idx = jax.lax.top_k(jnp.abs(v), k)
                vals = v[idx]
                mu = jnp.mean(jnp.abs(vals))
                sgn = jnp.sign(vals)
                recs.append(jnp.zeros_like(v).at[idx].set(mu * sgn)
                            .reshape(l.shape))
                wires.append((sgn, idx, mu))
            recon = jax.tree_util.tree_unflatten(treedef, recs)
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0), wire=tuple(wires))

    elif kind == "threesfc":
        assert loss_fn is not None and syn_spec is not None

        def compress_tree(key, u, params):
            syn0 = threesfc.init_syn(key, syn_spec)
            res = threesfc.encode(
                loss_fn, params, u, syn0,
                steps=cfg.syn_steps, lr=cfg.syn_lr, lam=cfg.l2_coef,
            )
            # encode's fused stats triple already carries cos(recon, u) and
            # the (gw, s) factorization — EF and metrics add no extra passes.
            return TreeCompressed(res.recon, jnp.float32(payload_floats_fn(params)),
                                  res.objective, cosine=res.cosine,
                                  direction=res.gw, scale=res.s,
                                  wire=(res.syn, res.s))

    elif kind == "fedsynth":
        assert loss_fn is not None and syn_spec is not None

        def compress_tree(key, u, params):
            syn0 = threesfc.init_syn(key, syn_spec)
            res = fedsynth.encode(
                loss_fn, params, u, syn0,
                unroll_steps=cfg.unroll_steps, opt_steps=max(cfg.syn_steps, 10),
                lr=local_lr, syn_lr=cfg.syn_lr,
            )
            return TreeCompressed(res.recon, jnp.float32(payload_floats_fn(params)),
                                  res.l2)

    else:
        raise ValueError(f"unknown compressor kind {kind!r}")

    return TreeCompressor(cfg, _ef_wrap(cfg, compress_tree), payload_floats_fn,
                          compress_tree=compress_tree)
