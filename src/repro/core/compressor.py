"""Unified, tree-based compressor interface for the FL runtime.

``make_compressor(cfg, ...)`` returns a ``TreeCompressor`` whose ``step`` maps
(per-client) ``(key, g_tree, e_tree, params) -> (recon_tree, e_tree',
metrics)``. Everything is jit/vmap-safe: payload sizes are static, EF
residuals live as pytrees mirroring the parameters (never a global concat —
at production scale a flat concat would destroy GSPMD sharding; per-leaf
operation keeps every collective on the leaf's own mesh axes).

Baselines run *per-leaf* (per-layer), matching how DGC/STC are deployed; the
global compression rate equals the per-leaf rate. 3SFC/FedSynth operate on
the tree directly (their reductions are per-leaf + scalar all-reduce).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fedsynth, flat, threesfc
from repro.configs.base import CompressorConfig
from repro.kernels import ops


class CompressMetrics(NamedTuple):
    cosine: jax.Array                # compression efficiency (Fig. 7)
    payload_floats: jax.Array        # accounted wire size this round
    aux: jax.Array                   # method-specific (3SFC: objective; else 0)


class TreeCompressed(NamedTuple):
    """What a per-method ``compress_tree`` hands back to the EF wrapper.

    ``cosine`` (when not None) is the already-computed cos(recon, u), so the
    wrapper skips its own ``tree_cosine`` pass; ``direction``/``scale`` (when
    not None) factor ``recon = scale · direction``, letting the EF update run
    as one fused ``e' = u − s·direction`` stream (``kernels.ops.
    tree_ef_update``) instead of reading the materialized recon again.
    """

    recon: Any
    floats: jax.Array
    aux: jax.Array
    cosine: Optional[jax.Array] = None
    direction: Any = None
    scale: Optional[jax.Array] = None


class TreeCompressor:
    def __init__(self, cfg: CompressorConfig, step_fn, payload_floats_fn):
        self.cfg = cfg
        self._step = step_fn
        self._payload = payload_floats_fn

    def init_state(self, params: flat.PyTree) -> flat.PyTree:
        """EF residual pytree (zeros, f32) mirroring params."""
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def payload_floats(self, params: flat.PyTree) -> float:
        return self._payload(params)

    def step(self, key, g_tree, e_tree, params):
        """Returns (recon_tree, new_e_tree, CompressMetrics)."""
        return self._step(key, g_tree, e_tree, params)


def _leaf_k(leaf, ratio: float) -> int:
    return max(1, int(round(ratio * leaf.size)))


def _ef_wrap(cfg, compress_tree):
    """Generic tree EF (Eq. 6) around a (key, u_tree, params)->TreeCompressed
    closure. Reuses the method's own stats where offered (see TreeCompressed)
    so the wrapper adds zero extra O(d) reduction passes for 3SFC."""

    def step(key, g_tree, e_tree, params):
        if cfg.error_feedback:
            u = flat.tree_add(g_tree, e_tree)
        else:
            u = g_tree
        out = compress_tree(key, u, params)
        if cfg.error_feedback:
            if out.direction is not None:
                e_new = ops.tree_ef_update(u, out.direction, out.scale)
            else:
                e_new = flat.tree_sub(u, out.recon)
        else:
            e_new = e_tree
        cos = out.cosine if out.cosine is not None \
            else flat.tree_cosine(out.recon, u)
        return out.recon, e_new, CompressMetrics(cos, out.floats, out.aux)

    return step


def make_compressor(
    cfg: CompressorConfig,
    *,
    loss_fn: Optional[threesfc.LossFn] = None,
    syn_spec: Optional[threesfc.SynSpec] = None,
    local_lr: float = 0.01,
) -> TreeCompressor:
    kind = cfg.kind

    # ---- payload accounting (static) -------------------------------------
    def payload_floats_fn(params) -> float:
        leaves = jax.tree_util.tree_leaves(params)
        d = sum(l.size for l in leaves)
        if kind == "identity":
            return float(d)
        if kind == "topk":
            return float(sum(2 * _leaf_k(l, cfg.keep_ratio) for l in leaves))
        if kind == "randk":
            return float(sum(_leaf_k(l, cfg.keep_ratio) for l in leaves) + 1)
        if kind == "signsgd":
            return d / 32.0 + len(leaves)
        if kind == "stc":
            ks = [_leaf_k(l, cfg.keep_ratio) for l in leaves]
            return float(sum(ks)) + sum(ks) / 32.0 + len(leaves)
        if kind in ("threesfc", "fedsynth"):
            assert syn_spec is not None
            return syn_spec.floats + 1.0
        raise ValueError(f"unknown compressor kind {kind!r}")

    # ---- per-method tree compression --------------------------------------
    if kind == "identity":
        def compress_tree(key, u, params):
            # recon == u exactly, so the efficiency cosine is 1 by identity —
            # no reduction pass needed.
            return TreeCompressed(u, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0), cosine=jnp.float32(1.0))

    elif kind == "topk":
        def compress_tree(key, u, params):
            def leaf(l):
                k = _leaf_k(l, cfg.keep_ratio)
                v = l.ravel()
                vals, idx = jax.lax.top_k(jnp.abs(v), k)
                kept = jnp.zeros_like(v).at[idx].set(v[idx])
                return kept.reshape(l.shape)
            recon = jax.tree_util.tree_map(leaf, u)
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0))

    elif kind == "randk":
        def compress_tree(key, u, params):
            leaves, treedef = jax.tree_util.tree_flatten(u)
            keys = jax.random.split(key, len(leaves))
            out = []
            for l, k_i in zip(leaves, keys):
                k = _leaf_k(l, cfg.keep_ratio)
                v = l.ravel()
                idx = jax.random.choice(k_i, v.size, shape=(k,), replace=False)
                kept = jnp.zeros_like(v).at[idx].set(v[idx])
                out.append(kept.reshape(l.shape))
            recon = jax.tree_util.tree_unflatten(treedef, out)
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0))

    elif kind == "signsgd":
        def compress_tree(key, u, params):
            def leaf(l):
                scale = jnp.mean(jnp.abs(l))
                return scale * jnp.sign(l)
            recon = jax.tree_util.tree_map(leaf, u)
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0))

    elif kind == "stc":
        def compress_tree(key, u, params):
            def leaf(l):
                k = _leaf_k(l, cfg.keep_ratio)
                v = l.ravel()
                _, idx = jax.lax.top_k(jnp.abs(v), k)
                vals = v[idx]
                mu = jnp.mean(jnp.abs(vals))
                kept = jnp.zeros_like(v).at[idx].set(mu * jnp.sign(vals))
                return kept.reshape(l.shape)
            recon = jax.tree_util.tree_map(leaf, u)
            return TreeCompressed(recon, jnp.float32(payload_floats_fn(params)),
                                  jnp.float32(0))

    elif kind == "threesfc":
        assert loss_fn is not None and syn_spec is not None

        def compress_tree(key, u, params):
            syn0 = threesfc.init_syn(key, syn_spec)
            res = threesfc.encode(
                loss_fn, params, u, syn0,
                steps=cfg.syn_steps, lr=cfg.syn_lr, lam=cfg.l2_coef,
            )
            # encode's fused stats triple already carries cos(recon, u) and
            # the (gw, s) factorization — EF and metrics add no extra passes.
            return TreeCompressed(res.recon, jnp.float32(payload_floats_fn(params)),
                                  res.objective, cosine=res.cosine,
                                  direction=res.gw, scale=res.s)

    elif kind == "fedsynth":
        assert loss_fn is not None and syn_spec is not None

        def compress_tree(key, u, params):
            syn0 = threesfc.init_syn(key, syn_spec)
            res = fedsynth.encode(
                loss_fn, params, u, syn0,
                unroll_steps=cfg.unroll_steps, opt_steps=max(cfg.syn_steps, 10),
                lr=local_lr, syn_lr=cfg.syn_lr,
            )
            return TreeCompressed(res.recon, jnp.float32(payload_floats_fn(params)),
                                  res.l2)

    else:
        raise ValueError(f"unknown compressor kind {kind!r}")

    return TreeCompressor(cfg, _ef_wrap(cfg, compress_tree), payload_floats_fn)
