"""Layer 3: static transport-protocol analysis.

Two analyses over the socket layer's *source* (no process is started):

**Message grammar.** The ``MSG_*`` constants in ``comm/transport.py`` are
the wire vocabulary. This module rebuilds the transition table from the
AST: a ``MSG_X`` reference inside a comparison (``mtype == MSG_X``) is a
*handler* for that message on that side; any other reference (an argument
to ``send_msg``, a tuple element in a send list) is a *send*. Sides are
classes: ``SocketServer`` is the server, ``ServerLink`` and everything in
``launch/worker.py`` is the worker. Three rules:

* every message is sent by at least one side (no dead vocabulary);
* every sent message has a handler on the peer side (no black-hole
  sends — the bug class where a new message type lands in the peer's
  ``else: raise ProtocolError`` arm);
* every handler corresponds to a message its peer actually sends (no
  unreachable transitions rotting in the dispatch chain).

**Race-detector-lite.** ``SocketServer`` mutates shared dicts/counters
from the accept thread, the per-client recv threads, and the main round
thread. The analyzer extracts the thread entry points
(``threading.Thread(target=self._x)``), assigns each method its execution
contexts (main, and each entry's transitive ``self.*()`` closure), and
requires every write to an attribute touched from ≥2 contexts to sit
under a ``with self._lock``-style guard. Attributes that are themselves
locks, are only written in ``__init__``, or are thread-safe by type
(``queue.Queue``, ``threading.Event``/``Lock``/``Condition`` inferred
from the ``__init__`` RHS) are exempt. ``LiveRoundLoop`` is analyzed too
— it spawns no threads today, so it passes trivially, but the gate is
what keeps that true.
"""
from __future__ import annotations

import ast
import os
from typing import Any, Dict, List, Optional, Set, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

TRANSPORT_PATH = os.path.join(REPO, "src", "repro", "comm", "transport.py")
WORKER_PATH = os.path.join(REPO, "src", "repro", "launch", "worker.py")
ENGINE_PATH = os.path.join(REPO, "src", "repro", "fl", "engine.py")

# transport.py class -> protocol side
_TRANSPORT_SIDES = {"SocketServer": "server", "ServerLink": "worker"}

# method calls that mutate their receiver in place
MUTATORS = {"append", "extend", "insert", "add", "discard", "remove",
            "pop", "popitem", "clear", "update", "setdefault"}
# constructors whose instances are internally synchronized
THREADSAFE_CTORS = {"Queue", "LifoQueue", "PriorityQueue", "SimpleQueue",
                    "Lock", "RLock", "Condition", "Event", "Semaphore",
                    "BoundedSemaphore", "Barrier"}
LOCK_CTORS = {"Lock", "RLock", "Condition"}


def _read(path: str) -> str:
    with open(path, "r") as f:
        return f.read()


# ---------------------------------------------------------------------------
# message grammar
# ---------------------------------------------------------------------------


def message_table(transport_src: Optional[str] = None) -> Dict[str, int]:
    """``MSG_*`` name -> wire id, from transport.py's module constants."""
    tree = ast.parse(transport_src if transport_src is not None
                     else _read(TRANSPORT_PATH))
    out: Dict[str, int] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id.startswith("MSG_")
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)):
            out[node.targets[0].id] = node.value.value
    return out


def _msg_refs(node: ast.AST, messages: Set[str]
              ) -> Tuple[Set[str], Set[str]]:
    """(handled, sent) message names referenced under ``node``.

    A reference inside any ``ast.Compare`` is a handler-side use; every
    other ``Name`` load of a MSG constant is a send-side use.
    """
    compared: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Compare):
            for m in ast.walk(n):
                if isinstance(m, ast.Name) and m.id in messages:
                    compared.add(m.id)
    all_refs: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in messages:
            all_refs.add(n.id)
    return compared, all_refs - compared


def build_transitions(transport_src: Optional[str] = None,
                      worker_src: Optional[str] = None) -> Dict[str, Any]:
    """The explicit transition table: per side, which messages it sends
    and which it handles."""
    t_src = transport_src if transport_src is not None \
        else _read(TRANSPORT_PATH)
    w_src = worker_src if worker_src is not None else _read(WORKER_PATH)
    msgs = set(message_table(t_src))
    sends: Dict[str, Set[str]] = {"server": set(), "worker": set()}
    handles: Dict[str, Set[str]] = {"server": set(), "worker": set()}

    for node in ast.parse(t_src).body:
        if isinstance(node, ast.ClassDef) and node.name in _TRANSPORT_SIDES:
            side = _TRANSPORT_SIDES[node.name]
            h, s = _msg_refs(node, msgs)
            handles[side] |= h
            sends[side] |= s
    h, s = _msg_refs(ast.parse(w_src), msgs)
    handles["worker"] |= h
    sends["worker"] |= s
    return {"messages": message_table(t_src),
            "sends": {k: sorted(v) for k, v in sends.items()},
            "handles": {k: sorted(v) for k, v in handles.items()}}


def check_protocol(transport_src: Optional[str] = None,
                   worker_src: Optional[str] = None) -> Tuple[int, List[str]]:
    """The three grammar rules over the transition table."""
    table = build_transitions(transport_src, worker_src)
    msgs = table["messages"]
    sends = {k: set(v) for k, v in table["sends"].items()}
    handles = {k: set(v) for k, v in table["handles"].items()}
    peer = {"server": "worker", "worker": "server"}
    viol: List[str] = []
    for name in sorted(msgs):
        if not any(name in sends[s] for s in sends):
            viol.append(f"{name} (id {msgs[name]}): dead vocabulary — "
                        f"no side ever sends it")
    for side in ("server", "worker"):
        for name in sorted(sends[side]):
            if name not in handles[peer[side]]:
                viol.append(f"{name}: sent by {side} but {peer[side]} has "
                            f"no handler (black-hole send)")
        for name in sorted(handles[side]):
            if name not in sends[peer[side]]:
                viol.append(f"{name}: handled by {side} but {peer[side]} "
                            f"never sends it (unreachable transition)")
    evaluated = len(msgs) + sum(len(v) for v in sends.values()) \
        + sum(len(v) for v in handles.values())
    return evaluated, viol


# ---------------------------------------------------------------------------
# race-detector-lite
# ---------------------------------------------------------------------------


def _ctor_name(call: ast.expr) -> Optional[str]:
    if not isinstance(call, ast.Call):
        return None
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _self_attr(node: ast.expr) -> Optional[str]:
    """'X' if node is ``self.X`` (possibly through a subscript)."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class _MethodScan(ast.NodeVisitor):
    """Accesses to ``self.*`` in one method, with lock-guard tracking."""

    def __init__(self, lock_attrs: Set[str]):
        self.lock_attrs = lock_attrs
        self.depth = 0
        self.reads: Set[str] = set()
        self.writes: List[Tuple[str, int, bool]] = []   # attr, line, guarded
        self.self_calls: Set[str] = set()
        self.thread_targets: Set[str] = set()

    def visit_With(self, node: ast.With) -> None:
        locked = any(_self_attr(item.context_expr) in self.lock_attrs
                     or (_ctor_name(item.context_expr) or "") in LOCK_CTORS
                     for item in node.items)
        for item in node.items:
            self.visit(item)
        self.depth += 1 if locked else 0
        for stmt in node.body:
            self.visit(stmt)
        self.depth -= 1 if locked else 0

    def _write(self, target: ast.expr) -> None:
        attr = _self_attr(target)
        if attr is not None:
            self.writes.append((attr, target.lineno, self.depth > 0))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._write(t)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._write(node.target)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._write(node.target)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._write(t)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute):
            recv = _self_attr(f.value)
            if recv is not None and f.attr in MUTATORS:
                self.writes.append((recv, node.lineno, self.depth > 0))
            if isinstance(f.value, ast.Name) and f.value.id == "self":
                self.self_calls.add(f.attr)
        if _ctor_name(node) == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    t = _self_attr(kw.value)
                    if t is not None:
                        self.thread_targets.add(t)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is not None:
            self.reads.add(attr)
        self.generic_visit(node)


def analyze_class_races(tree: ast.Module, class_name: str
                        ) -> Tuple[int, List[str]]:
    """Race rules for one class; returns (attributes examined, violations).

    Raises ``ValueError`` if the class is missing — a silently-skipped
    class would green-light exactly the code this layer exists to check.
    """
    cls = next((n for n in ast.walk(tree)
                if isinstance(n, ast.ClassDef) and n.name == class_name),
               None)
    if cls is None:
        raise ValueError(f"class {class_name} not found")
    methods = {n.name: n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}

    # pass 1: find lock attributes + thread-safe-by-type attributes
    lock_attrs: Set[str] = set()
    safe_attrs: Set[str] = set()
    init = methods.get("__init__")
    if init is not None:
        for n in ast.walk(init):
            if isinstance(n, ast.Assign) and len(n.targets) == 1:
                attr = _self_attr(n.targets[0])
                ctor = _ctor_name(n.value)
                if attr and ctor:
                    if ctor in LOCK_CTORS:
                        lock_attrs.add(attr)
                    if ctor in THREADSAFE_CTORS:
                        safe_attrs.add(attr)

    # pass 2: per-method access scan
    scans: Dict[str, _MethodScan] = {}
    for name, node in methods.items():
        s = _MethodScan(lock_attrs)
        for stmt in node.body:
            s.visit(stmt)
        scans[name] = s

    # pass 3: execution contexts (main + one per thread entry)
    entries = sorted({t for s in scans.values() for t in s.thread_targets
                      if t in methods})

    def closure(roots: Set[str]) -> Set[str]:
        seen: Set[str] = set()
        stack = [r for r in roots if r in methods]
        while stack:
            m = stack.pop()
            if m in seen:
                continue
            seen.add(m)
            stack.extend(c for c in scans[m].self_calls if c in methods)
        return seen

    main_roots = {m for m in methods
                  if m not in entries and not m.startswith("__")}
    contexts: Dict[str, Set[str]] = {m: set() for m in methods}
    for m in closure(main_roots):
        contexts[m].add("main")
    for e in entries:
        for m in closure({e}):
            contexts[m].add(f"thread:{e}")

    # pass 4: the rule
    attrs: Dict[str, Dict[str, Any]] = {}
    for mname, s in scans.items():
        ctxs = contexts.get(mname, set())
        for a in s.reads | {w[0] for w in s.writes}:
            rec = attrs.setdefault(a, {"ctxs": set(), "writes": []})
            if mname != "__init__":
                rec["ctxs"] |= ctxs
                rec["writes"] += [(mname, ln, g) for w, ln, g in s.writes
                                  if w == a]
    viol: List[str] = []
    for a, rec in sorted(attrs.items()):
        if a in lock_attrs or a in safe_attrs:
            continue
        if len(rec["ctxs"]) < 2 or not rec["writes"]:
            continue
        for mname, ln, guarded in rec["writes"]:
            if not guarded:
                viol.append(
                    f"{class_name}.{a}: written in {mname}():{ln} without "
                    f"holding the lock, but touched from "
                    f"{sorted(rec['ctxs'])}")
    return len(attrs), viol


def check_races(transport_src: Optional[str] = None,
                engine_src: Optional[str] = None) -> Tuple[int, List[str]]:
    t_tree = ast.parse(transport_src if transport_src is not None
                       else _read(TRANSPORT_PATH))
    e_tree = ast.parse(engine_src if engine_src is not None
                       else _read(ENGINE_PATH))
    n1, v1 = analyze_class_races(t_tree, "SocketServer")
    n2, v2 = analyze_class_races(e_tree, "LiveRoundLoop")
    return n1 + n2, v1 + v2


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_protocol(transport_src: Optional[str] = None,
                 worker_src: Optional[str] = None,
                 engine_src: Optional[str] = None) -> Dict[str, Any]:
    """Both analyses; returns the ``BENCH_static.json`` protocol stanza."""
    g_eval, g_viol = check_protocol(transport_src, worker_src)
    r_eval, r_viol = check_races(transport_src, engine_src)
    table = build_transitions(transport_src, worker_src)
    return {
        "transitions": table,
        "rules": {
            "message-grammar": {"evaluated": g_eval, "violations": g_viol},
            "shared-state-locking": {"evaluated": r_eval,
                                     "violations": r_viol},
        },
        "rules_evaluated": g_eval + r_eval,
        "violations": len(g_viol) + len(r_viol),
    }
