"""Layer 2: repo-specific AST lints over ``src/``.

Four rules, each tuned to a guarantee the generic linters can't state:

* ``untyped-except`` — no bare ``except:`` / ``except Exception`` /
  ``except BaseException`` without an explicit ``# noqa`` on the handler
  line. Swallowing everything hides trace-time shape bugs (the
  ``models/shard.py`` incident this rule was written for).
* ``host-call-in-round-path`` — no *impure* host calls (``time.*``,
  stdlib ``random.*``, ``np.random.*``) reachable from the round-path
  roots (``build_fl_round``, the ``CompressionStrategy`` methods,
  local-train / aggregate / server-update helpers). Static ``np`` shape
  and header math folds into constants at trace time and is allowed —
  what the rule bans is wall-clock and host RNG, which would make a
  jitted round nondeterministic between trace and execution. Reachability
  is a name-based over-approximation pruned by module imports: a call
  edge from a function in module M resolves to every same-named
  definition in M or a module M imports (dunder names excluded —
  ``super().__init__`` would otherwise edge to every constructor in the
  repo).
* ``registry-kind-ids`` — every ``@register_strategy("k")`` kind has a
  wire kind-id in ``comm/frame.py``'s ``KIND_IDS`` literal (a strategy
  without a kind id cannot cross the socket transport).
* ``public-api-exports`` — package ``__all__`` literals match the GOLDEN
  pins in ``tests/test_public_api.py`` (the export surface is governed by
  the test; an ``__all__`` drifting from it is a silent API break).

Everything operates on a ``{path: source}`` mapping so the negative tests
(``tests/test_analysis.py``) can lint synthetic snippets without touching
disk.
"""
from __future__ import annotations

import ast
import os
from typing import Any, Dict, List, Optional, Set, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

# round-path roots: the functions/classes whose transitive callees must
# stay host-free (they run under jit every round)
ROUND_PATH_ROOTS = ("build_fl_round", "local_train", "aggregate",
                    "server_update")
ROUND_PATH_BASE_CLASSES = ("CompressionStrategy",)

# impure host modules: wall-clock and host RNG have no place under jit
BANNED_MODULES = {"time", "random"}
# call names that never resolve through the name index: super().__init__
# (and dunders generally) would edge to every same-named method in the repo
_SKIP_CALL_NAMES = {n for n in dir(object)} | {"__init__", "__call__"}


def collect_sources(root: Optional[str] = None) -> Dict[str, str]:
    """``{relpath: source}`` for every ``.py`` under ``src/``."""
    root = root or os.path.join(REPO, "src")
    out: Dict[str, str] = {}
    for dirpath, _, names in sorted(os.walk(root)):
        for n in sorted(names):
            if not n.endswith(".py"):
                continue
            p = os.path.join(dirpath, n)
            with open(p, "r") as f:
                out[os.path.relpath(p, REPO)] = f.read()
    return out


def _parse_all(files: Dict[str, str]) -> Dict[str, ast.Module]:
    trees = {}
    for path, src in files.items():
        try:
            trees[path] = ast.parse(src)
        except SyntaxError as e:
            raise SyntaxError(f"{path}: {e}") from e
    return trees


# ---------------------------------------------------------------------------
# rule: untyped-except
# ---------------------------------------------------------------------------


def _is_broad(h: ast.ExceptHandler) -> bool:
    t = h.type
    if t is None:
        return True
    names = []
    for node in ([t.elts] if isinstance(t, ast.Tuple) else [[t]])[0]:
        if isinstance(node, ast.Name):
            names.append(node.id)
        elif isinstance(node, ast.Attribute):
            names.append(node.attr)
    return any(n in ("Exception", "BaseException") for n in names)


def check_untyped_except(files: Dict[str, str],
                         trees: Dict[str, ast.Module]) -> Tuple[int, List[str]]:
    evaluated = 0
    viol: List[str] = []
    for path, tree in trees.items():
        lines = files[path].splitlines()
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            evaluated += 1
            if not _is_broad(node):
                continue
            line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if "# noqa" in line:
                continue
            viol.append(f"{path}:{node.lineno}: broad except "
                        f"({ast.unparse(node.type) if node.type else 'bare'})"
                        f" without a # noqa justification")
    return evaluated, viol


# ---------------------------------------------------------------------------
# rule: host-call-in-round-path
# ---------------------------------------------------------------------------


def _module_name(path: str) -> str:
    """'src/repro/comm/frame.py' -> 'repro.comm.frame'."""
    parts = path.replace(os.sep, "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    else:
        parts[-1] = parts[-1][:-3]
    return ".".join(parts)


def _imports_of(tree: ast.Module, mod_name: str, is_pkg: bool) -> Set[str]:
    """Fully-qualified module names this module imports (repo + external),
    relative imports resolved against ``mod_name``."""
    mods: Set[str] = set()
    parts = mod_name.split(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mods.add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                drop = node.level - (1 if is_pkg else 0)
                base = parts[:len(parts) - drop] if drop else parts
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            mods.add(mod)
            for a in node.names:           # `from pkg import submodule`
                mods.add(f"{mod}.{a.name}")
    return mods


def _banned_import_names(tree: ast.Module) -> Dict[str, str]:
    """Local aliases that ARE banned host calls: ``import time``,
    ``from time import monotonic``, ``from numpy import random``."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and not node.level:
            mod = node.module or ""
            if mod in BANNED_MODULES:
                for a in node.names:
                    out[a.asname or a.name] = f"{mod}.{a.name}"
            elif mod == "numpy.random":
                for a in node.names:
                    out[a.asname or a.name] = f"np.random.{a.name}"
    return out


def _alias_map(tree: ast.Module) -> Dict[str, str]:
    """local name -> top module ('np' -> 'numpy', 'time' -> 'time')."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name.split(".")[0]
    return out


class _FnInfo:
    __slots__ = ("path", "mod", "node", "aliases", "banned_names", "calls")

    def __init__(self, path: str, mod: str, node: ast.AST,
                 aliases: Dict[str, str], banned_names: Dict[str, str]):
        self.path = path
        self.mod = mod
        self.node = node
        self.aliases = aliases
        self.banned_names = banned_names
        self.calls: Set[str] = set()
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                f = n.func
                if isinstance(f, ast.Name):
                    self.calls.add(f.id)
                elif isinstance(f, ast.Attribute):
                    self.calls.add(f.attr)


def _function_index(trees: Dict[str, ast.Module]
                    ) -> Tuple[Dict[str, List[_FnInfo]], List[_FnInfo],
                               Dict[str, Set[str]]]:
    """Name -> defs index, the root set, and the module import graph."""
    index: Dict[str, List[_FnInfo]] = {}
    roots: List[_FnInfo] = []
    imports: Dict[str, Set[str]] = {}
    for path, tree in trees.items():
        mod = _module_name(path)
        imports[mod] = _imports_of(tree, mod, path.endswith("__init__.py"))
        aliases = _alias_map(tree)
        banned = _banned_import_names(tree)

        def add(node, *, is_root):
            info = _FnInfo(path, mod, node, aliases, banned)
            index.setdefault(node.name, []).append(info)
            if is_root:
                roots.append(info)

        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                add(node, is_root=node.name in ROUND_PATH_ROOTS)
            elif isinstance(node, ast.ClassDef):
                bases = {b.id if isinstance(b, ast.Name) else
                         getattr(b, "attr", "") for b in node.bases}
                strategic = (node.name in ROUND_PATH_BASE_CLASSES
                             or bool(bases & set(ROUND_PATH_BASE_CLASSES))
                             or any(any(r.node.name == b for r in roots
                                        if isinstance(r.node, ast.ClassDef))
                                    for b in bases))
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        add(sub, is_root=(strategic
                                          or sub.name in ROUND_PATH_ROOTS))
                if strategic:   # keep subclass chains resolvable by name
                    roots.append(_FnInfo(path, mod, node, aliases, banned))
    return index, roots, imports


def _reachable(index: Dict[str, List[_FnInfo]], roots: List[_FnInfo],
               imports: Dict[str, Set[str]]) -> List[_FnInfo]:
    seen: Set[int] = set()
    out: List[_FnInfo] = []
    stack = [r for r in roots if not isinstance(r.node, ast.ClassDef)]
    while stack:
        fn = stack.pop()
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        out.append(fn)
        visible = imports.get(fn.mod, set()) | {fn.mod}
        for name in fn.calls:
            if name in _SKIP_CALL_NAMES:
                continue
            for callee in index.get(name, ()):
                if isinstance(callee.node, ast.ClassDef):
                    continue
                if callee.mod in visible:
                    stack.append(callee)
    return out


def _host_calls_in(fn: _FnInfo) -> List[Tuple[int, str]]:
    hits: List[Tuple[int, str]] = []
    for n in ast.walk(fn.node):
        if not isinstance(n, ast.Call):
            continue
        f = n.func
        if isinstance(f, ast.Name) and f.id in fn.banned_names:
            hits.append((n.lineno, fn.banned_names[f.id]))
            continue
        # np.random.X(...) — nested attribute off the numpy alias
        if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Attribute)
                and isinstance(f.value.value, ast.Name)
                and fn.aliases.get(f.value.value.id) == "numpy"
                and f.value.attr == "random"):
            hits.append((n.lineno, f"np.random.{f.attr}"))
            continue
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            mod = fn.aliases.get(f.value.id)
            if mod in BANNED_MODULES:
                hits.append((n.lineno, f"{mod}.{f.attr}"))
            elif mod == "numpy" and f.attr == "random":
                hits.append((n.lineno, "np.random"))
    return hits


def check_host_calls(files: Dict[str, str],
                     trees: Dict[str, ast.Module]) -> Tuple[int, List[str]]:
    index, roots, imports = _function_index(trees)
    reach = _reachable(index, roots, imports)
    viol: List[str] = []
    for fn in reach:
        for lineno, what in _host_calls_in(fn):
            name = getattr(fn.node, "name", "?")
            viol.append(f"{fn.path}:{lineno}: host call {what} reachable "
                        f"from the round path (via {name})")
    return len(reach), viol


# ---------------------------------------------------------------------------
# rule: registry-kind-ids
# ---------------------------------------------------------------------------


def _registered_kinds(trees: Dict[str, ast.Module]) -> Dict[str, str]:
    """kind string -> defining path, from @register_strategy decorators."""
    kinds: Dict[str, str] = {}
    for path, tree in trees.items():
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                if (isinstance(dec, ast.Call)
                        and isinstance(dec.func, ast.Name)
                        and dec.func.id == "register_strategy"
                        and dec.args
                        and isinstance(dec.args[0], ast.Constant)):
                    kinds[dec.args[0].value] = path
    return kinds


def _dict_literal(trees: Dict[str, ast.Module], path_suffix: str,
                  name: str) -> Optional[Dict[Any, Any]]:
    for path, tree in trees.items():
        if not path.endswith(path_suffix):
            continue
        for node in tree.body:
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Name) and t.id == name:
                    try:
                        return ast.literal_eval(
                            node.value)  # type: ignore[arg-type]
                    except (ValueError, TypeError):
                        return None
    return None


def check_registry_kinds(files: Dict[str, str],
                         trees: Dict[str, ast.Module]) -> Tuple[int, List[str]]:
    kinds = _registered_kinds(trees)
    kind_ids = _dict_literal(trees, os.path.join("comm", "frame.py"),
                             "KIND_IDS")
    viol: List[str] = []
    if kind_ids is None:
        viol.append("comm/frame.py: KIND_IDS dict literal not found")
        return len(kinds), viol
    for kind, path in sorted(kinds.items()):
        if kind not in kind_ids:
            viol.append(f"{path}: strategy kind {kind!r} registered but has "
                        f"no wire kind-id in comm/frame.py KIND_IDS "
                        f"(have: {sorted(kind_ids)})")
    return len(kinds), viol


# ---------------------------------------------------------------------------
# rule: public-api-exports
# ---------------------------------------------------------------------------


def _golden_pins(test_path: str) -> Optional[Dict[str, List[str]]]:
    if not os.path.exists(test_path):
        return None
    with open(test_path, "r") as f:
        tree = ast.parse(f.read())
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "GOLDEN"
                        for t in node.targets)):
            try:
                return ast.literal_eval(node.value)
            except (ValueError, TypeError):
                return None
    return None


def check_public_exports(files: Dict[str, str],
                         trees: Dict[str, ast.Module],
                         golden: Optional[Dict[str, List[str]]] = None,
                         ) -> Tuple[int, List[str]]:
    if golden is None:
        golden = _golden_pins(
            os.path.join(REPO, "tests", "test_public_api.py"))
    if golden is None:
        return 0, ["tests/test_public_api.py: GOLDEN pins not found"]
    evaluated = 0
    viol: List[str] = []
    for mod, pinned in sorted(golden.items()):
        rel = os.path.join("src", *mod.split("."), "__init__.py")
        tree = trees.get(rel)
        if tree is None:
            viol.append(f"{rel}: GOLDEN-pinned module has no source file")
            continue
        declared = _list_literal(tree, "__all__")
        if declared is None:
            continue          # no __all__: surface governed by the test only
        evaluated += 1
        if sorted(declared) != sorted(pinned):
            extra = sorted(set(declared) - set(pinned))
            missing = sorted(set(pinned) - set(declared))
            viol.append(f"{rel}: __all__ disagrees with the GOLDEN pin "
                        f"(extra: {extra}, missing: {missing})")
    return evaluated, viol


def _list_literal(tree: ast.Module, name: str) -> Optional[List[str]]:
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == name
                        for t in node.targets)):
            try:
                v = ast.literal_eval(node.value)
            except (ValueError, TypeError):
                return None
            return list(v) if isinstance(v, (list, tuple)) else None
    return None


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

RULES = (
    ("untyped-except", check_untyped_except),
    ("host-call-in-round-path", check_host_calls),
    ("registry-kind-ids", check_registry_kinds),
    ("public-api-exports", check_public_exports),
)


def run_lint(files: Optional[Dict[str, str]] = None) -> Dict[str, Any]:
    """Run every lint rule; returns the ``BENCH_static.json`` lint stanza."""
    if files is None:
        files = collect_sources()
    trees = _parse_all(files)
    per: Dict[str, Any] = {}
    total_eval = 0
    total_viol = 0
    for name, fn in RULES:
        evaluated, violations = fn(files, trees)
        per[name] = {"evaluated": evaluated, "violations": violations}
        total_eval += evaluated
        total_viol += len(violations)
    return {"files": len(files), "rules": per,
            "rules_evaluated": total_eval, "violations": total_viol}
