"""Layer-1 driver: compile the FULL round matrix at tiny shapes.

``iter_round_configs()`` enumerates every *valid* point of
``strategy_kinds()`` × {vmap, shard_map} × {float, codec} × {fused,
default} × {faulted, null} — capability-filtered exactly the way
``build_fl_round`` itself filters (codec only for kinds with a registered
wire format, fused only for ``supports_fused_aggregate`` strategies,
fused×faulted only with a real ``mask_payloads``), so the checker covers
precisely the space a user can construct, no more and no less.

``build_round_artifact`` compiles one point at deliberately tiny shapes
(4 clients, 1 local step, batch 4, a 4×4×1 3-class vision spec) with the
EF state donated, and packages the optimized HLO plus the config-derived
expectations into a ``contracts.RoundArtifact``. shard_map points need a
≥4-device runtime, so ``python -m repro.analysis.ir`` is run as a child
under ``benchmarks.bench_collectives.multidev_env()`` (the forced-8-device
host-CPU recipe) and prints the ``contracts.run_contracts`` report as JSON
— the driver (``scripts/check_static.py``) never ships HLO text across the
process boundary, only the verdicts.
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Optional

from repro.analysis import contracts

# tiny-but-real round shape: 4 clients over a (4, 1) data×model mesh,
# one local step, batch 4, 4x4x1 inputs, 3 classes
TINY_N, TINY_K, TINY_B = 4, 1, 4
TINY_MESH_SHAPE = (4, 1)
TINY_INPUT = (4, 4, 1)
TINY_CLASSES = 3


def iter_round_configs() -> List[Dict[str, Any]]:
    """Every constructible (kind, fanout, wire, fused, faulted) point."""
    from repro.comm.codec import CODECS
    from repro.core.strategy import (CompressionStrategy, STRATEGIES,
                                     strategy_kinds)
    cfgs: List[Dict[str, Any]] = []
    for kind in strategy_kinds():
        cls = STRATEGIES[kind]
        wires = ["float"] + (["codec"] if kind in CODECS else [])
        fuseds = [False, True] if cls.supports_fused_aggregate else [False]
        masked = cls.mask_payloads is not CompressionStrategy.mask_payloads
        for fanout in ("vmap", "shard_map"):
            for wire in wires:
                for fused in fuseds:
                    for faulted in (False, True):
                        if fused and faulted and not masked:
                            continue
                        cfgs.append({"kind": kind, "fanout": fanout,
                                     "wire": wire, "fused": fused,
                                     "faulted": faulted})
    return cfgs


def build_context() -> Dict[str, Any]:
    """Shared compile context: tiny model/params, mesh + shardings when the
    runtime has ≥4 devices (else shard_map points must be skipped by the
    caller), abstract batch/key avals."""
    import jax
    import jax.numpy as jnp

    from repro.fl.sharding import make_fl_shardings
    from repro.models.cnn import VisionSpec, make_paper_model

    spec = VisionSpec("tiny", TINY_INPUT, TINY_CLASSES)
    model = make_paper_model("mlp", spec)
    params = model.init(jax.random.PRNGKey(0))
    mesh = sh = None
    client_shards = 1
    if len(jax.devices()) >= TINY_MESH_SHAPE[0]:
        mesh = jax.make_mesh(TINY_MESH_SHAPE, ("data", "model"))
        sh = make_fl_shardings(mesh)
        client_shards = sh.client_shards
    batches = {
        "x": jax.ShapeDtypeStruct(
            (TINY_N, TINY_K, TINY_B, *TINY_INPUT), jnp.float32),
        "y": jax.ShapeDtypeStruct((TINY_N, TINY_K, TINY_B), jnp.int32),
    }
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return {"spec": spec, "model": model, "params": params, "mesh": mesh,
            "sh": sh, "client_shards": client_shards, "batches": batches,
            "key": key}


def build_round_artifact(config: Dict[str, Any],
                         ctx: Optional[Dict[str, Any]] = None,
                         ) -> contracts.RoundArtifact:
    """Compile one matrix point (EF donated) into a contract-checkable
    artifact."""
    import jax

    from repro.comm.codec import make_codec
    from repro.configs.base import CompressorConfig, FLConfig
    from repro.configs.run import RunConfig
    from repro.core.strategy import make_strategy
    from repro.fl import faults as F
    from repro.fl.round import build_fl_round, fl_init
    from repro.models.build import vision_syn_spec

    if ctx is None:
        ctx = build_context()
    kind = config["kind"]
    shard = config["fanout"] == "shard_map"
    if shard and ctx["mesh"] is None:
        raise RuntimeError(
            "shard_map config needs a >=4-device runtime "
            "(run via benchmarks.bench_collectives.multidev_env())")

    ccfg = CompressorConfig(kind=kind, keep_ratio=0.25, syn_steps=2,
                            syn_lr=0.1,
                            error_feedback=(kind != "identity"))
    spec = vision_syn_spec(ctx["spec"], ccfg)
    strat = make_strategy(ccfg, loss_fn=ctx["model"].syn_loss,
                          syn_spec=spec, local_lr=0.05)
    fl = FLConfig(num_clients=TINY_N, local_steps=TINY_K, local_lr=0.05,
                  local_batch=TINY_B, compressor=ccfg)
    run = RunConfig(fl=fl, wire=config["wire"],
                    fused_decode=config["fused"],
                    client_parallel=config["fanout"],
                    mesh=ctx["mesh"] if shard else None)
    codec = None
    if config["wire"] == "codec":
        codec = make_codec(ccfg, ctx["params"], syn_spec=spec,
                           syn_loss_fn=ctx["model"].syn_loss)
    sched = (lambda r, n: F.null_schedule(n)) if config["faulted"] else None
    rf = build_fl_round(ctx["model"].loss, strat, run,
                        codec=codec, fault_schedule_fn=sched)
    state = fl_init(ctx["params"], TINY_N, strat)

    jit_kwargs: Dict[str, Any] = {"donate_argnums": (0,)}
    if shard:
        sh = ctx["sh"]
        jit_kwargs.update(
            in_shardings=(sh.state, sh.client, sh.replicated),
            out_shardings=(sh.state, sh.replicated))
    compiled = jax.jit(rf, **jit_kwargs).lower(
        state, ctx["batches"], ctx["key"]).compile()

    n_p = len(jax.tree_util.tree_leaves(state.params))
    n_e = len(jax.tree_util.tree_leaves(state.ef))
    shards = ctx["client_shards"] if shard else 1
    payload = None
    if config["fused"]:
        payload = (4.0 * float(strat.payload_floats(ctx["params"]))
                   * (TINY_N // shards))
    return contracts.RoundArtifact(
        config=dict(config),
        hlo_text=compiled.as_text(),
        ef_param_indices=tuple(range(n_p, n_p + n_e)),
        payload_bytes_local=payload,
        codec_nbytes=(codec.nbytes if codec is not None else None),
        codec_policy=(codec.policy if codec is not None else None),
        num_clients=TINY_N,
        client_shards=shards)


def run_matrix(configs: Optional[List[Dict[str, Any]]] = None,
               verbose: bool = True) -> Dict[str, Any]:
    """Compile every matrix point and evaluate the contracts in-process."""
    if configs is None:
        configs = iter_round_configs()
    ctx = build_context()
    artifacts: List[contracts.RoundArtifact] = []
    for i, cfg in enumerate(configs):
        a = build_round_artifact(cfg, ctx)
        artifacts.append(a)
        if verbose:
            print(f"  [{i + 1}/{len(configs)}] compiled {a.label}",
                  file=sys.stderr)
    return contracts.run_contracts(artifacts)


def main() -> None:
    report = run_matrix()
    json.dump(report, sys.stdout)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
