"""Compile-time contract checking: IR invariants, repo lints, protocol.

Three layers, one driver (``scripts/check_static.py`` →
``BENCH_static.json``):

* ``contracts``/``ir`` — declarative ``Contract`` rules over the compiled
  HLO of every constructible ``build_fl_round`` configuration;
* ``lint`` — repo-specific AST rules over ``src/``;
* ``protocol`` — the ``MSG_*`` transition table + a race-detector-lite
  for the socket server's shared state.

Benches and tests import the extraction API from here
(``collective_summary``, ``encode_region_collectives``) so each invariant
has exactly one definition.
"""
from repro.analysis.contracts import (CLIENT_SCOPE, CONTRACTS, Contract,
                                      RoundArtifact, aliased_param_indices,
                                      collective_summary,
                                      encode_region_collectives,
                                      host_callbacks, run_contracts)

__all__ = [
    "CLIENT_SCOPE",
    "CONTRACTS",
    "Contract",
    "RoundArtifact",
    "aliased_param_indices",
    "collective_summary",
    "encode_region_collectives",
    "host_callbacks",
    "run_contracts",
]
