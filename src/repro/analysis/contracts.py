"""Layer 1 of the static-analysis subsystem: declarative IR contracts.

A ``Contract`` is one compiled-HLO invariant, stated once, checked against
every configuration of the round matrix (``repro.analysis.ir`` builds the
``RoundArtifact`` per configuration). The five contracts encode the repo's
hardest-won guarantees:

* ``client-scope-clean`` — zero collectives inside the per-client
  local-train + encode region (the ``CLIENT_SCOPE`` named scope); on the
  vmap fan-out (no mesh) the whole module must be collective-free.
* ``fused-gather-bounded`` — the fused 3SFC decode's all_gather carries
  only the tiny ``(D_syn, s)`` payloads: total gather bytes bounded by
  ``FUSED_GATHER_FACTOR × local payload bytes + FUSED_GATHER_SLACK``.
  This is THE definition ``benchmarks/bench_collectives.py`` gates with.
* ``no-host-callbacks`` — no ``pure_callback`` / ``io_callback`` /
  ``debug.print`` lowered into a jitted round body (they all become
  ``*callback*`` custom-calls in the optimized HLO).
* ``ef-donation-aliased`` — the donated ``FLState`` EF buffers are
  actually input→output aliased in the compiled executable
  (``input_output_alias`` in the module header), so the N×d residual
  never doubles in memory.
* ``wire-dtype-policy`` — in codec mode what crosses the boundary is the
  framed ``uint8`` stream (u8 all_gather operands sized in whole frames);
  float-typed gather traffic is metrics-only (≤ the metadata slack), and
  the codec's declared dtype policy is a registered one.

``encode_region_collectives`` / ``collective_summary`` are the shared
extraction API — benches and tests go through them instead of re-deriving
scope filters from ``utils.hlo_analyzer`` (one definition per rule).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.fl.round import CLIENT_SCOPE
from repro.utils import hlo_analyzer as H

# fused-decode gather bound: total gathered bytes per device must stay
# within FACTOR × the local clients' payload bytes plus SLACK for the
# (N,)-shaped metrics gathers — the O(N·payload) claim, as a constant
FUSED_GATHER_FACTOR = 2.0
FUSED_GATHER_SLACK_BYTES = 1024.0

# codec mode: non-u8 gather traffic (losses/cosines/payload-float metrics)
# allowed before it counts as a float tree leaking onto the wire
WIRE_METADATA_SLACK_BYTES = 1024.0

# custom-call targets that mean "host round-trip inside the jitted body":
# jax lowers pure_callback / io_callback / debug.print / debug.callback to
# per-backend python-callback custom-calls
_CALLBACK_TARGET_RE = re.compile(
    r'custom_call_target="([^"]*callback[^"]*)"')


# ---------------------------------------------------------------------------
# HLO extraction helpers (the API benches/tests consume)
# ---------------------------------------------------------------------------


def encode_region_collectives(hlo_text: str) -> List[H.CollectiveInstr]:
    """Collectives inside the per-client encode region — the single
    definition of the CLIENT_SCOPE rule's extraction."""
    return H.collectives_in_scope(hlo_text, CLIENT_SCOPE)


def collective_summary(hlo_text: str) -> Dict[str, Any]:
    """Per-module collective bill + encode-region census, the record shape
    ``BENCH_collectives.json`` carries per compiled round."""
    cols = H.collectives(hlo_text)
    by_kind: Dict[str, float] = {}
    for c in cols:
        by_kind[c.kind] = by_kind.get(c.kind, 0.0) + c.total_bytes
    scoped = encode_region_collectives(hlo_text)
    return {
        "collective_bytes_per_round": sum(c.total_bytes for c in cols),
        "collective_count": len(cols),
        "bytes_by_kind": by_kind,
        "encode_region_collectives": len(scoped),
        "encode_region_ops": [c.kind for c in scoped],
    }


def host_callbacks(hlo_text: str) -> List[str]:
    """Custom-call targets in the module that are host python callbacks."""
    return _CALLBACK_TARGET_RE.findall(hlo_text)


def aliased_param_indices(hlo_text: str) -> frozenset:
    """Parameter numbers input→output aliased in the module header.

    The header carries ``input_output_alias={ {out}: (param, {sub}, kind),
    ... }``; the donation contract only needs the set of aliased parameter
    positions, read from the second element of each pair.
    """
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return frozenset()
    i = start + len("input_output_alias=")
    depth = 0
    end = i
    for j in range(i, min(len(hlo_text), i + 1_000_000)):
        ch = hlo_text[j]
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                end = j
                break
    block = hlo_text[i:end + 1]
    return frozenset(int(m) for m in re.findall(r"\(\s*(\d+)\s*,", block))


# ---------------------------------------------------------------------------
# the artifact + the rule engine
# ---------------------------------------------------------------------------


@dataclass
class RoundArtifact:
    """One compiled round configuration, everything a Contract may probe.

    ``config`` is the matrix point (kind/fanout/wire/fused/faulted);
    ``hlo_text`` the optimized per-device module. The remaining fields are
    config-derived expectations: the entry-parameter positions of the EF
    leaves (donation), the local payload byte budget (fused gather bound)
    and the codec's declared layout (wire dtype).
    """

    config: Dict[str, Any]
    hlo_text: str
    ef_param_indices: Tuple[int, ...] = ()
    payload_bytes_local: Optional[float] = None
    codec_nbytes: Optional[int] = None
    codec_policy: Optional[str] = None
    num_clients: int = 0
    client_shards: int = 1

    @property
    def label(self) -> str:
        c = self.config
        return (f"{c.get('kind')}/{c.get('fanout')}/{c.get('wire')}"
                + ("/fused" if c.get("fused") else "")
                + ("/faulted" if c.get("faulted") else ""))


@dataclass(frozen=True)
class Contract:
    """One declarative IR rule: ``applies`` scopes it to the matrix points
    it is meaningful for, ``check`` returns violation messages (empty =
    clean). Adding a rule for a new strategy is appending one of these to
    ``CONTRACTS`` (see README §Static analysis)."""

    name: str
    description: str
    applies: Callable[[RoundArtifact], bool]
    check: Callable[[RoundArtifact], List[str]]


def _check_client_scope(a: RoundArtifact) -> List[str]:
    if a.config.get("fanout") == "shard_map":
        scoped = encode_region_collectives(a.hlo_text)
        return [f"{a.label}: {c.kind} ({c.total_bytes:.0f} B) inside "
                f"{CLIENT_SCOPE} (op_name={c.op_name!r})" for c in scoped]
    # vmap fan-out compiles mesh-free: the whole module is collective-free
    cols = H.collectives(a.hlo_text)
    return [f"{a.label}: {c.kind} ({c.total_bytes:.0f} B) in a mesh-free "
            f"vmap round" for c in cols]


def _check_fused_gather(a: RoundArtifact) -> List[str]:
    assert a.payload_bytes_local is not None, \
        f"{a.label}: fused artifact missing payload_bytes_local"
    gathered = sum(c.total_bytes for c in H.collectives(a.hlo_text)
                   if c.kind == "all-gather")
    bound = (FUSED_GATHER_FACTOR * a.payload_bytes_local
             + FUSED_GATHER_SLACK_BYTES)
    if gathered > bound:
        return [f"{a.label}: fused gather moves {gathered:.0f} B > bound "
                f"{bound:.0f} B ({FUSED_GATHER_FACTOR}x local payload "
                f"{a.payload_bytes_local:.0f} B + "
                f"{FUSED_GATHER_SLACK_BYTES:.0f} B slack)"]
    return []


def _check_host_callbacks(a: RoundArtifact) -> List[str]:
    return [f"{a.label}: host callback custom-call {t!r} in the jitted "
            f"round body" for t in host_callbacks(a.hlo_text)]


def _check_ef_donation(a: RoundArtifact) -> List[str]:
    aliased = aliased_param_indices(a.hlo_text)
    missing = [i for i in a.ef_param_indices if i not in aliased]
    if missing:
        return [f"{a.label}: EF leaf parameter(s) {missing} not "
                f"input->output aliased (donated buffers not reused; "
                f"aliased set: {sorted(aliased)})"]
    return []


def _check_wire_dtype(a: RoundArtifact) -> List[str]:
    from repro.comm.frame import HEADER_BYTES, POLICY_IDS
    probs: List[str] = []
    if a.codec_policy not in POLICY_IDS:
        probs.append(f"{a.label}: codec declares unregistered dtype policy "
                     f"{a.codec_policy!r} (registered: {sorted(POLICY_IDS)})")
    if a.codec_nbytes is None or a.codec_nbytes <= HEADER_BYTES:
        probs.append(f"{a.label}: codec frame size {a.codec_nbytes} must "
                     f"exceed the {HEADER_BYTES} B header")
        return probs
    if a.config.get("fanout") != "shard_map":
        return probs        # no boundary collective to inspect mesh-free
    gathers = [c for c in H.collectives(a.hlo_text)
               if c.kind == "all-gather"]
    u8 = sum(b for c in gathers for dt, b in c.operands if dt == "u8")
    other = sum(b for c in gathers for dt, b in c.operands if dt != "u8")
    local = a.num_clients // max(a.client_shards, 1)
    want = float(a.codec_nbytes * local)
    if u8 < want:
        probs.append(f"{a.label}: u8 gather carries {u8:.0f} B, expected at "
                     f"least {want:.0f} B ({local} local frames x "
                     f"{a.codec_nbytes} B)")
    elif u8 % a.codec_nbytes:
        probs.append(f"{a.label}: u8 gather bytes {u8:.0f} are not whole "
                     f"{a.codec_nbytes} B frames")
    if other > WIRE_METADATA_SLACK_BYTES:
        probs.append(f"{a.label}: {other:.0f} B of non-u8 gather traffic in "
                     f"codec mode (> {WIRE_METADATA_SLACK_BYTES:.0f} B "
                     f"metrics slack) — a float tree is crossing the wire")
    return probs


CONTRACTS: Tuple[Contract, ...] = (
    Contract(
        "client-scope-clean",
        "zero collectives inside the per-client encode region "
        f"({CLIENT_SCOPE}); mesh-free vmap rounds are collective-free",
        lambda a: True,
        _check_client_scope),
    Contract(
        "fused-gather-bounded",
        "fused-decode all_gather bytes bounded by "
        f"{FUSED_GATHER_FACTOR}x local payload + "
        f"{FUSED_GATHER_SLACK_BYTES:.0f} B",
        lambda a: bool(a.config.get("fused"))
        and a.config.get("fanout") == "shard_map",
        _check_fused_gather),
    Contract(
        "no-host-callbacks",
        "no pure_callback/io_callback/debug.print custom-calls in the "
        "compiled round",
        lambda a: True,
        _check_host_callbacks),
    Contract(
        "ef-donation-aliased",
        "donated FLState EF buffers are input->output aliased in the "
        "executable",
        lambda a: True,
        _check_ef_donation),
    Contract(
        "wire-dtype-policy",
        "codec-mode boundary traffic is whole u8 frames under a registered "
        "dtype policy; float gathers are metrics-sized",
        lambda a: a.config.get("wire") == "codec",
        _check_wire_dtype),
)


def run_contracts(artifacts: List[RoundArtifact],
                  contracts: Tuple[Contract, ...] = CONTRACTS,
                  ) -> Dict[str, Any]:
    """Evaluate every contract against every artifact it applies to.

    Returns the ``BENCH_static.json`` IR stanza: per-contract evaluation
    counts + violation messages, the covered config labels, and totals.
    """
    per: Dict[str, Dict[str, Any]] = {}
    total_eval = 0
    total_viol = 0
    for c in contracts:
        evaluated = 0
        violations: List[str] = []
        for a in artifacts:
            if not c.applies(a):
                continue
            evaluated += 1
            violations.extend(c.check(a))
        per[c.name] = {"description": c.description,
                       "evaluated": evaluated,
                       "violations": violations}
        total_eval += evaluated
        total_viol += len(violations)
    return {
        "configs": [a.label for a in artifacts],
        "configs_evaluated": len(artifacts),
        "contracts": per,
        "rules_evaluated": total_eval,
        "violations": total_viol,
    }
