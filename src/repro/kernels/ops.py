"""Public jit'd wrappers around the Pallas kernels.

Each wrapper handles flattening/padding to the (rows, 1024)-lane layout the
kernels tile over, dispatches interpret mode off-TPU, and reduces kernel
partials to the user-facing result. ``on_tpu()`` flips interpret mode
automatically, so the same call sites run compiled on real hardware.

HBM-pass accounting
-------------------
The 3SFC encoder is memory-bound end to end (arithmetic intensity ~0.25
FLOP/byte), so the unit of cost here is *passes over the gradient tree*
(d floats, f32):

* ``tree_fused_stats(a, b)`` — ONE pass: reads a once and b once (2d·4
  bytes) and returns all three partials ``(a·b, ||a||², ||b||²)``. The
  naive route (``tree_dot`` + two ``tree_sqnorm``/norms, as in a separate
  dot + norm + norm cosine) reads each tree twice — 4d·4 bytes, i.e. 2×
  the traffic — and a dot/sqnorm/cosine *sequence* as in the seed encoder
  totalled ~8 passes plus a materialized s·∇F tree.
* ``tree_ef_update(u, d, s)`` — ONE streaming pass for ``e' = u − s·d``
  (read u, read d, write e'): never materializes ``s·d`` or the recon tree.

Both stream pytree *leaves* through the kernels in lockstep chunks — there
is no monolithic ``jnp.concatenate`` of the whole tree, only bounded
per-chunk concats of adjacent small leaves (large leaves are sliced, never
copied whole), with the tail tile zero-padded (zeros are exact identities
for every partial).
"""
from __future__ import annotations

import functools
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ef_update import ef_update_2d
from repro.kernels.fused_cosine import fused_cosine_2d
from repro.kernels.sign_quant import sign_quant_2d
from repro.kernels.ssd_chunk import ssd_chunk_call
from repro.kernels.topk_mask import topk_mask_2d

PyTree = Any

LANES = 1024

# Per-chunk element budget for the tree-streaming reductions: 4 Mi elems =
# 16 MiB f32 per operand — big enough to amortize kernel launches, small
# enough that the lockstep chunk concat never approaches a whole-tree copy.
TREE_CHUNK_ELEMS = 1 << 22


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _plan_rows(n: int, block_rows: int) -> Tuple[int, int]:
    """(block_rows', rows) covering n elems with minimal zero padding.

    Scans the 8-row-aligned block sizes (f32 sublane alignment for TPU) up
    to the requested ``block_rows`` and picks the one whose row count pads
    least, tie-breaking toward the largest block (fewer grid steps, bigger
    DMAs). The br=8 candidate caps padding at <8 rows (<32 KiB/operand) per
    call, so the accounting stays within ~1 tile of the 2d·4-byte ideal.
    """
    rows_needed = max(1, -(-n // LANES))
    if rows_needed <= 8:
        return 8, 8   # f32 min tile is (8, 128) sublanes×lanes — never go below
    best_br, best_rows = 8, -(-rows_needed // 8) * 8
    for br in range(16, block_rows + 1, 8):
        rows = -(-rows_needed // br) * br
        if rows <= best_rows:
            best_br, best_rows = br, rows
    return best_br, best_rows


def _to_2d(v: jax.Array, block_rows: int) -> Tuple[jax.Array, int]:
    """Flatten + zero-pad to (rows, LANES), rows % block_rows == 0."""
    n = v.size
    tile = block_rows * LANES
    rows = max(1, -(-n // tile)) * block_rows
    pad = rows * LANES - n
    v2 = jnp.pad(v.reshape(-1), (0, pad)).reshape(rows, LANES)
    return v2, n


# ---------------------------------------------------------------------------
# fused_cosine
# ---------------------------------------------------------------------------


def fused_cosine(x: jax.Array, y: jax.Array, block_rows: int = 128) -> jax.Array:
    """(3,) f32 = [x·y, ||x||², ||y||²] over flat views of x, y."""
    br, _ = _plan_rows(x.size, block_rows)
    x2, _ = _to_2d(x, br)
    y2, _ = _to_2d(y, br)
    return fused_cosine_2d(x2, y2, block_rows=br, interpret=_interpret())


# ---------------------------------------------------------------------------
# tree_fused_stats — the fused tree-reduction engine
# ---------------------------------------------------------------------------


def _ravel_f32(leaf: jax.Array) -> jax.Array:
    return jnp.ravel(leaf).astype(jnp.float32)


def _cat(parts: List[jax.Array]) -> jax.Array:
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def _check_lockstep(a_tree: PyTree, b_tree: PyTree) -> Tuple[list, list]:
    """Trace-time guard: lockstep streaming silently mis-pairs trees whose
    structures or leaf shapes differ (zero padding hides length mismatches),
    so reject both loudly — matching the old tree_map-based reductions'
    behavior. Returns the two leaf lists."""
    a_leaves, a_def = jax.tree_util.tree_flatten(a_tree)
    b_leaves, b_def = jax.tree_util.tree_flatten(b_tree)
    if a_def != b_def:
        raise ValueError(
            f"lockstep tree mismatch: treedefs {a_def} vs {b_def}")
    a_shapes = [jnp.shape(l) for l in a_leaves]
    b_shapes = [jnp.shape(l) for l in b_leaves]
    if a_shapes != b_shapes:
        raise ValueError(
            f"lockstep tree mismatch: leaf shapes {a_shapes} vs {b_shapes}")
    return a_leaves, b_leaves


def _chunk_plan(sizes: List[int], chunk_elems: int) -> List[List[Tuple[int, int, int]]]:
    """Chunking plan: a list of chunks, each a list of (leaf_idx, off, take).

    Leaf sizes are static, so the plan is resolved at trace time: small
    adjacent leaves are packed into one chunk (bounded concat), leaves
    larger than ``chunk_elems`` are walked by static slices (no whole-leaf
    copy). The SINGLE source of truth for how the tree streamers below pack
    leaves — ``tree_stats_hbm_bytes`` accounts from this same plan, so the
    benchmark's byte numbers cannot drift from the kernels' actual tiling.
    """
    plan: List[List[Tuple[int, int, int]]] = []
    cur: List[Tuple[int, int, int]] = []
    n = 0
    for i, size in enumerate(sizes):
        off = 0
        while size - off > 0:
            take = min(chunk_elems - n, size - off)
            cur.append((i, off, take))
            n += take
            off += take
            if n == chunk_elems:
                plan.append(cur)
                cur, n = [], 0
    if cur:
        plan.append(cur)
    return plan


def _gather_chunk(leaves_1d: List[jax.Array],
                  chunk: List[Tuple[int, int, int]]) -> jax.Array:
    parts = []
    for i, off, take in chunk:
        v = leaves_1d[i]
        parts.append(v if (off == 0 and take == v.size)
                     else jax.lax.slice_in_dim(v, off, off + take))
    return _cat(parts)


def _tree_dot_naive(a: PyTree, b: PyTree) -> jax.Array:
    """Leafwise f32 dot (differentiable; used only in the stats JVP rule)."""
    parts = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    leaves = jax.tree_util.tree_leaves(parts)
    return sum(leaves) if leaves else jnp.zeros((), jnp.float32)


@jax.custom_jvp
def tree_fused_stats(a_tree: PyTree, b_tree: PyTree) -> jax.Array:
    """(3,) f32 = [a·b, ||a||², ||b||²] over whole pytrees in ONE HBM pass.

    Streams lockstep leaf chunks through the ``fused_cosine_2d`` Pallas
    kernel (interpret mode off-TPU) and accumulates the (3,) partials in
    f32. Zero-padding of each chunk's tail tile is exact (zeros contribute
    nothing to any of the three sums). Mixed-dtype trees are cast to f32
    leaf-by-leaf; a/b must share treedef and leaf shapes.

    Differentiable to arbitrary order: the custom JVP routes tangents
    through plain leafwise reductions (the Pallas primal has no AD rule),
    so ``jax.grad``-of-``jax.grad`` encoder objectives work unchanged.
    """
    a_leaves, b_leaves = _check_lockstep(a_tree, b_tree)
    ra = [_ravel_f32(l) for l in a_leaves]
    rb = [_ravel_f32(l) for l in b_leaves]
    total = jnp.zeros((3,), jnp.float32)
    for chunk in _chunk_plan([v.size for v in ra], TREE_CHUNK_ELEMS):
        total = total + fused_cosine(_gather_chunk(ra, chunk),
                                     _gather_chunk(rb, chunk))
    return total


@tree_fused_stats.defjvp
def _tree_fused_stats_jvp(primals, tangents):
    a, b = primals
    da, db = tangents
    out = tree_fused_stats(a, b)
    tan = jnp.stack([
        _tree_dot_naive(da, b) + _tree_dot_naive(a, db),
        2.0 * _tree_dot_naive(a, da),
        2.0 * _tree_dot_naive(b, db),
    ])
    return out, tan


def tree_stats_hbm_bytes(tree: PyTree, block_rows: int = 128) -> int:
    """Static HBM bytes ``tree_fused_stats`` touches for this tree pair.

    Not a measurement: the Pallas grid DMAs exactly two (block_rows, LANES)
    f32 tiles per step plus the (1, 3) accumulator — the traffic is fixed by
    the BlockSpecs, so it can be accounted from the chunk plan alone. Used
    by ``benchmarks/bench_kernels.py``; XLA ``cost_analysis`` cannot see
    through the interpret-mode callback, and on CPU it charges every
    unfused elementwise intermediate, so this is the apples-to-apples
    "bytes the kernel reads on TPU" number.
    """
    sizes = [int(np.prod(jnp.shape(l))) for l in jax.tree_util.tree_leaves(tree)]
    total = 0
    for chunk in _chunk_plan(sizes, TREE_CHUNK_ELEMS):
        n = sum(take for _, _, take in chunk)
        _, rows = _plan_rows(n, block_rows)
        total += 2 * rows * LANES * 4 + 3 * 4   # two operand tiles + (1,3) acc
    return total


def tree_ef_update(u_tree: PyTree, d_tree: PyTree, s: jax.Array) -> PyTree:
    """EF residual e' = u − s·d over whole pytrees, one streaming pass.

    Streams the same lockstep leaf chunks as ``tree_fused_stats`` through
    the ``ef_update_2d`` Pallas kernel (one launch per ~16 MiB chunk, not
    per leaf — bias/scale leaves don't each pay a padded tile) and slices
    the outputs back into leaves. Never materializes the scaled ``s·d``
    (= recon) tree. Output leaves are f32 in u's shapes. Not differentiable
    (EF state updates sit outside autodiff).
    """
    u_leaves, d_leaves = _check_lockstep(u_tree, d_tree)
    treedef = jax.tree_util.tree_structure(u_tree)
    ru = [_ravel_f32(l) for l in u_leaves]
    rd = [_ravel_f32(l) for l in d_leaves]
    pieces: List[List[jax.Array]] = [[] for _ in u_leaves]
    for chunk in _chunk_plan([v.size for v in ru], TREE_CHUNK_ELEMS):
        out = ef_update(_gather_chunk(ru, chunk), _gather_chunk(rd, chunk), s)
        pos = 0
        for i, off, take in chunk:
            pieces[i].append(jax.lax.slice_in_dim(out, pos, pos + take))
            pos += take
    new_leaves = [
        (_cat(ps) if ps else jnp.zeros((0,), jnp.float32)).reshape(jnp.shape(l))
        for ps, l in zip(pieces, u_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def cosine_similarity(x: jax.Array, y: jax.Array, eps: float = 1e-12) -> jax.Array:
    d, xx, yy = fused_cosine(x, y)
    return d / (jnp.sqrt(xx) * jnp.sqrt(yy) + eps)


def optimal_scale(target: jax.Array, direction: jax.Array, eps: float = 1e-12) -> jax.Array:
    """3SFC Eq. 8: s = <target, dir> / ||dir||² in one pass."""
    d, _, yy = fused_cosine(target, direction)
    return d / (yy + eps)


# ---------------------------------------------------------------------------
# ef_update
# ---------------------------------------------------------------------------


def ef_update(u: jax.Array, d: jax.Array, s: jax.Array,
              block_rows: int = 256) -> jax.Array:
    """e' = u - s·d, elementwise fused; returns u's shape, f32."""
    br, _ = _plan_rows(u.size, block_rows)
    u2, n = _to_2d(u, br)
    d2, _ = _to_2d(d, br)
    out = ef_update_2d(u2, d2, s, block_rows=br, interpret=_interpret())
    return out.reshape(-1)[:n].reshape(u.shape)


# ---------------------------------------------------------------------------
# sign_quant
# ---------------------------------------------------------------------------


def sign_quant(x: jax.Array, block_rows: int = 256) -> Tuple[jax.Array, jax.Array]:
    """(signs int8 of x's shape, scale = mean|x|)."""
    x2, n = _to_2d(x, block_rows)
    signs2, asum = sign_quant_2d(x2, block_rows=block_rows, interpret=_interpret())
    signs = signs2.reshape(-1)[:n].reshape(x.shape)
    return signs, asum[0, 0] / n


# ---------------------------------------------------------------------------
# topk_mask (threshold select)
# ---------------------------------------------------------------------------


def topk_threshold(x: jax.Array, k: int, sample: int = 65536) -> jax.Array:
    """Sampled threshold estimate: |x| of the ~k-th largest (DGC-style)."""
    v = jnp.abs(x.reshape(-1))
    n = v.size
    if n <= sample:
        kk = max(1, min(k, n))
        return jax.lax.top_k(v, kk)[0][-1]
    stride = n // sample
    sub = v[:: stride][:sample]
    kk = max(1, min(int(round(k * sub.size / n)), sub.size))
    return jax.lax.top_k(sub, kk)[0][-1]


def topk_mask(x: jax.Array, threshold: jax.Array,
              block_rows: int = 256) -> Tuple[jax.Array, jax.Array]:
    """(masked f32 of x's shape, kept count)."""
    x2, n = _to_2d(x, block_rows)
    # guard: padding zeros must never pass the threshold
    t = jnp.maximum(threshold, jnp.float32(1e-38))
    out2, cnt = topk_mask_2d(x2, t, block_rows=block_rows, interpret=_interpret())
    return out2.reshape(-1)[:n].reshape(x.shape), cnt[0, 0]


# ---------------------------------------------------------------------------
# ssd_chunk (used by models.ssm when use_pallas=True; oracle: models.ssm.ssd_scan)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def ssd_chunked_ad(xdt: jax.Array, dA: jax.Array, Bc: jax.Array, Cc: jax.Array,
                   chunk: int, h0: jax.Array):
    """Differentiable wrapper: forward through the Pallas kernel, backward
    through the jnp oracle's VJP (forward parity is asserted in
    tests/test_kernels.py, so the cotangents are consistent). NOTE:
    ``custom_vjp`` has no JVP rule — the 3SFC grad-of-grad encoder must use
    the pure-jnp path (use_pallas_ssd stays False for training entries)."""
    return ssd_chunked(xdt, dA, Bc, Cc, chunk, h0)


def _ssd_ad_fwd(xdt, dA, Bc, Cc, chunk, h0):
    out = ssd_chunked(xdt, dA, Bc, Cc, chunk, h0)
    return out, (xdt, dA, Bc, Cc, h0)


def _ssd_ad_bwd(chunk, res, ct):
    from repro.models.ssm import ssd_scan
    xdt, dA, Bc, Cc, h0 = res
    _, vjp = jax.vjp(lambda a, b, c, d, h: ssd_scan(a, b, c, d, chunk, h),
                     xdt, dA, Bc, Cc, h0)
    return vjp(ct)


ssd_chunked_ad.defvjp(_ssd_ad_fwd, _ssd_ad_bwd)


def ssd_chunked(xdt: jax.Array, dA: jax.Array, Bc: jax.Array, Cc: jax.Array,
                chunk: int, h0: jax.Array = None):
    """Same contract as models.ssm.ssd_scan, but the intra-chunk math runs in
    the Pallas kernel. xdt (b,s,h,p); dA (b,s,h); B,C (b,s,n)."""
    b, s, h, pdim = xdt.shape
    n = Bc.shape[-1]
    Q = min(chunk, s)
    assert s % Q == 0
    nc = s // Q
    # kernel layout: (b, h, nc, Q, ...)
    xk = jnp.moveaxis(xdt.reshape(b, nc, Q, h, pdim), 3, 1)       # (b,h,nc,Q,P)
    dAk = jnp.moveaxis(dA.reshape(b, nc, Q, h), 3, 1)             # (b,h,nc,Q)
    Bk = Bc.reshape(b, nc, Q, n)
    Ck = Cc.reshape(b, nc, Q, n)
    y_diag, states, decay = ssd_chunk_call(
        xk.astype(jnp.float32), dAk.astype(jnp.float32),
        Bk.astype(jnp.float32), Ck.astype(jnp.float32), interpret=_interpret())
    # inter-chunk recurrence (tiny, sequential)
    chunk_decay = decay[..., -1]                                   # (b,h,nc)
    if h0 is None:
        h0 = jnp.zeros((b, h, pdim, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp
        return st + dec[..., None, None] * carry, carry

    sts = jnp.moveaxis(states, 2, 0)                               # (nc,b,h,P,N)
    dcs = jnp.moveaxis(chunk_decay, 2, 0)                          # (nc,b,h)
    final, prev = jax.lax.scan(step, h0.astype(jnp.float32), (sts, dcs))
    prev = jnp.moveaxis(prev, 0, 2)                                # (b,h,nc,P,N)
    y_off = jnp.einsum("bcqn,bhcpn,bhcq->bhcqp",
                       Ck.astype(jnp.float32), prev, decay)
    y = y_diag + y_off                                             # (b,h,nc,Q,P)
    y = jnp.moveaxis(y, 1, 3).reshape(b, s, h, pdim)
    return y.astype(xdt.dtype), final.astype(xdt.dtype)
