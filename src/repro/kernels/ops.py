"""Public jit'd wrappers around the Pallas kernels.

Each wrapper handles flattening/padding to the (rows, 1024)-lane layout the
kernels tile over, dispatches interpret mode off-TPU, and reduces kernel
partials to the user-facing result. ``on_tpu()`` flips interpret mode
automatically, so the same call sites run compiled on real hardware.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ef_update import ef_update_2d
from repro.kernels.fused_cosine import fused_cosine_2d
from repro.kernels.sign_quant import sign_quant_2d
from repro.kernels.ssd_chunk import ssd_chunk_call
from repro.kernels.topk_mask import topk_mask_2d

LANES = 1024


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _to_2d(v: jax.Array, block_rows: int) -> Tuple[jax.Array, int]:
    """Flatten + zero-pad to (rows, LANES), rows % block_rows == 0."""
    n = v.size
    tile = block_rows * LANES
    rows = max(1, -(-n // tile)) * block_rows
    pad = rows * LANES - n
    v2 = jnp.pad(v.reshape(-1), (0, pad)).reshape(rows, LANES)
    return v2, n


# ---------------------------------------------------------------------------
# fused_cosine
# ---------------------------------------------------------------------------


def fused_cosine(x: jax.Array, y: jax.Array, block_rows: int = 128) -> jax.Array:
    """(3,) f32 = [x·y, ||x||², ||y||²] over flat views of x, y."""
    x2, _ = _to_2d(x, block_rows)
    y2, _ = _to_2d(y, block_rows)
    return fused_cosine_2d(x2, y2, block_rows=block_rows, interpret=_interpret())


def cosine_similarity(x: jax.Array, y: jax.Array, eps: float = 1e-12) -> jax.Array:
    d, xx, yy = fused_cosine(x, y)
    return d / (jnp.sqrt(xx) * jnp.sqrt(yy) + eps)


def optimal_scale(target: jax.Array, direction: jax.Array, eps: float = 1e-12) -> jax.Array:
    """3SFC Eq. 8: s = <target, dir> / ||dir||² in one pass."""
    d, _, yy = fused_cosine(target, direction)
    return d / (yy + eps)


# ---------------------------------------------------------------------------
# ef_update
# ---------------------------------------------------------------------------


def ef_update(u: jax.Array, d: jax.Array, s: jax.Array,
              block_rows: int = 256) -> jax.Array:
    """e' = u - s·d, elementwise fused; returns u's shape, f32."""
    u2, n = _to_2d(u, block_rows)
    d2, _ = _to_2d(d, block_rows)
    out = ef_update_2d(u2, d2, s, block_rows=block_rows, interpret=_interpret())
    return out.reshape(-1)[:n].reshape(u.shape)


# ---------------------------------------------------------------------------
# sign_quant
# ---------------------------------------------------------------------------


def sign_quant(x: jax.Array, block_rows: int = 256) -> Tuple[jax.Array, jax.Array]:
    """(signs int8 of x's shape, scale = mean|x|)."""
    x2, n = _to_2d(x, block_rows)
    signs2, asum = sign_quant_2d(x2, block_rows=block_rows, interpret=_interpret())
    signs = signs2.reshape(-1)[:n].reshape(x.shape)
    return signs, asum[0, 0] / n


# ---------------------------------------------------------------------------
# topk_mask (threshold select)
# ---------------------------------------------------------------------------


def topk_threshold(x: jax.Array, k: int, sample: int = 65536) -> jax.Array:
    """Sampled threshold estimate: |x| of the ~k-th largest (DGC-style)."""
    v = jnp.abs(x.reshape(-1))
    n = v.size
    if n <= sample:
        kk = max(1, min(k, n))
        return jax.lax.top_k(v, kk)[0][-1]
    stride = n // sample
    sub = v[:: stride][:sample]
    kk = max(1, min(int(round(k * sub.size / n)), sub.size))
    return jax.lax.top_k(sub, kk)[0][-1]


def topk_mask(x: jax.Array, threshold: jax.Array,
              block_rows: int = 256) -> Tuple[jax.Array, jax.Array]:
    """(masked f32 of x's shape, kept count)."""
    x2, n = _to_2d(x, block_rows)
    # guard: padding zeros must never pass the threshold
    t = jnp.maximum(threshold, jnp.float32(1e-38))
    out2, cnt = topk_mask_2d(x2, t, block_rows=block_rows, interpret=_interpret())
    return out2.reshape(-1)[:n].reshape(x.shape), cnt[0, 0]


# ---------------------------------------------------------------------------
# ssd_chunk (used by models.ssm when use_pallas=True; oracle: models.ssm.ssd_scan)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def ssd_chunked_ad(xdt: jax.Array, dA: jax.Array, Bc: jax.Array, Cc: jax.Array,
                   chunk: int, h0: jax.Array):
    """Differentiable wrapper: forward through the Pallas kernel, backward
    through the jnp oracle's VJP (forward parity is asserted in
    tests/test_kernels.py, so the cotangents are consistent). NOTE:
    ``custom_vjp`` has no JVP rule — the 3SFC grad-of-grad encoder must use
    the pure-jnp path (use_pallas_ssd stays False for training entries)."""
    return ssd_chunked(xdt, dA, Bc, Cc, chunk, h0)


def _ssd_ad_fwd(xdt, dA, Bc, Cc, chunk, h0):
    out = ssd_chunked(xdt, dA, Bc, Cc, chunk, h0)
    return out, (xdt, dA, Bc, Cc, h0)


def _ssd_ad_bwd(chunk, res, ct):
    from repro.models.ssm import ssd_scan
    xdt, dA, Bc, Cc, h0 = res
    _, vjp = jax.vjp(lambda a, b, c, d, h: ssd_scan(a, b, c, d, chunk, h),
                     xdt, dA, Bc, Cc, h0)
    return vjp(ct)


ssd_chunked_ad.defvjp(_ssd_ad_fwd, _ssd_ad_bwd)


def ssd_chunked(xdt: jax.Array, dA: jax.Array, Bc: jax.Array, Cc: jax.Array,
                chunk: int, h0: jax.Array = None):
    """Same contract as models.ssm.ssd_scan, but the intra-chunk math runs in
    the Pallas kernel. xdt (b,s,h,p); dA (b,s,h); B,C (b,s,n)."""
    b, s, h, pdim = xdt.shape
    n = Bc.shape[-1]
    Q = min(chunk, s)
    assert s % Q == 0
    nc = s // Q
    # kernel layout: (b, h, nc, Q, ...)
    xk = jnp.moveaxis(xdt.reshape(b, nc, Q, h, pdim), 3, 1)       # (b,h,nc,Q,P)
    dAk = jnp.moveaxis(dA.reshape(b, nc, Q, h), 3, 1)             # (b,h,nc,Q)
    Bk = Bc.reshape(b, nc, Q, n)
    Ck = Cc.reshape(b, nc, Q, n)
    y_diag, states, decay = ssd_chunk_call(
        xk.astype(jnp.float32), dAk.astype(jnp.float32),
        Bk.astype(jnp.float32), Ck.astype(jnp.float32), interpret=_interpret())
    # inter-chunk recurrence (tiny, sequential)
    chunk_decay = decay[..., -1]                                   # (b,h,nc)
    if h0 is None:
        h0 = jnp.zeros((b, h, pdim, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp
        return st + dec[..., None, None] * carry, carry

    sts = jnp.moveaxis(states, 2, 0)                               # (nc,b,h,P,N)
    dcs = jnp.moveaxis(chunk_decay, 2, 0)                          # (nc,b,h)
    final, prev = jax.lax.scan(step, h0.astype(jnp.float32), (sts, dcs))
    prev = jnp.moveaxis(prev, 0, 2)                                # (b,h,nc,P,N)
    y_off = jnp.einsum("bcqn,bhcpn,bhcq->bhcqp",
                       Ck.astype(jnp.float32), prev, decay)
    y = y_diag + y_off                                             # (b,h,nc,Q,P)
    y = jnp.moveaxis(y, 1, 3).reshape(b, s, h, pdim)
    return y.astype(xdt.dtype), final.astype(xdt.dtype)
