"""bitpack — 32→1 sign bit-packing kernel pair for the wire codec.

``repro.comm`` serializes signSGD's uplink as an actual bit stream (the
paper's "1 bit per coordinate" accounting, measured instead of assumed).
The hot operation is packing ``d`` float signs into ``ceil(d/32)`` uint32
words — a pure streaming transform, so it gets the same Pallas treatment as
the reduction engine: one read of the float tile, one write of the 32×
smaller word tile, no intermediate bool tensor in HBM.

Layout: each kernel block reads ``(block_rows, 4096)`` f32 lanes and writes
``(block_rows, 128)`` uint32 words — output lane ``w`` packs input lanes
``[32w, 32w+32)`` LSB-first, so flat element ``n`` lands in word ``n // 32``
bit ``n % 32``. Both tiles respect the (8, 128) f32/u32 TPU min-tile; off
TPU the kernels run in interpret mode (``ops.on_tpu()`` convention).

Sign convention (the wire contract, shared with ``comm.codec``): bit =
``x >= 0``; unpacking yields ±1, never 0. Exact zeros therefore decode to
+1 — the codec documents this as the 1-bit wire semantics (a 3-valued sign
does not fit in 1 bit; see ``comm.codec.SignCodec``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK_LANES = 4096                    # f32 lanes per packed row
WORD_LANES = PACK_LANES // 32        # = 128, uint32 lanes per packed row
BLOCK_ROWS = 8                       # f32/u32 min sublane tile


def _pack_kernel(x_ref, out_ref):
    x = x_ref[...]                                       # (br, 4096) f32
    br = x.shape[0]
    bits = (x >= 0).astype(jnp.uint32).reshape(br, WORD_LANES, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    out_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def _unpack_kernel(w_ref, out_ref):
    w = w_ref[...]                                       # (br, 128) uint32
    br = w.shape[0]
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (w[:, :, None] >> shifts) & jnp.uint32(1)
    pm1 = bits.astype(jnp.float32) * 2.0 - 1.0
    out_ref[...] = pm1.reshape(br, PACK_LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_signs_2d(x2: jax.Array, *, interpret: bool = True) -> jax.Array:
    """(rows, 4096) f32 -> (rows, 128) uint32; bit = (x >= 0), LSB-first."""
    rows = x2.shape[0]
    assert rows % BLOCK_ROWS == 0 and x2.shape[1] == PACK_LANES, x2.shape
    return pl.pallas_call(
        _pack_kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, PACK_LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, WORD_LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, WORD_LANES), jnp.uint32),
        interpret=interpret,
    )(x2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_signs_2d(w2: jax.Array, *, interpret: bool = True) -> jax.Array:
    """(rows, 128) uint32 -> (rows, 4096) f32 in {-1, +1}."""
    rows = w2.shape[0]
    assert rows % BLOCK_ROWS == 0 and w2.shape[1] == WORD_LANES, w2.shape
    return pl.pallas_call(
        _unpack_kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, WORD_LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, PACK_LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, PACK_LANES), jnp.float32),
        interpret=interpret,
    )(w2)


# ---------------------------------------------------------------------------
# flat-vector wrappers (padding + interpret dispatch)
# ---------------------------------------------------------------------------


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_signs(x: jax.Array) -> jax.Array:
    """Flat f32 (n,) -> uint32 (ceil(n/32),) sign words.

    The tail is padded with +1.0 (bit 1) up to the tile grid; padded bits
    live only in the final word(s) the caller slices away by byte count.
    """
    n = x.size
    words = -(-n // 32)
    tile = BLOCK_ROWS * PACK_LANES
    rows = max(1, -(-n // tile)) * BLOCK_ROWS
    x2 = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, rows * PACK_LANES - n),
                 constant_values=1.0).reshape(rows, PACK_LANES)
    packed = pack_signs_2d(x2, interpret=_interpret())
    return packed.reshape(-1)[:words]


def unpack_signs(words: jax.Array, n: int) -> jax.Array:
    """uint32 (ceil(n/32),) -> f32 (n,) in {-1, +1} (inverse of pack_signs)."""
    w = words.size
    assert w == -(-n // 32), (w, n)
    tile = BLOCK_ROWS * WORD_LANES
    rows = max(1, -(-w // tile)) * BLOCK_ROWS
    w2 = jnp.pad(words.reshape(-1), (0, rows * WORD_LANES - w)) \
        .reshape(rows, WORD_LANES)
    pm1 = unpack_signs_2d(w2, interpret=_interpret())
    return pm1.reshape(-1)[:n]
