"""Pure-jnp oracles for every kernel (the correctness contract)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def fused_cosine(x: jax.Array, y: jax.Array) -> jax.Array:
    """(3,) f32: [x·y, ||x||², ||y||²]."""
    xf, yf = x.astype(jnp.float32), y.astype(jnp.float32)
    return jnp.stack([jnp.sum(xf * yf), jnp.sum(xf * xf), jnp.sum(yf * yf)])


def ef_update(u: jax.Array, d: jax.Array, s: jax.Array) -> jax.Array:
    """e' = u - s·d."""
    return (u.astype(jnp.float32) - s.astype(jnp.float32) * d.astype(jnp.float32))


def sign_quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(signs int8, scale = mean|x| f32)."""
    xf = x.astype(jnp.float32)
    return jnp.sign(xf).astype(jnp.int8), jnp.mean(jnp.abs(xf))


def topk_mask(x: jax.Array, threshold: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(masked vector keeping |x| >= threshold, kept count f32)."""
    xf = x.astype(jnp.float32)
    keep = jnp.abs(xf) >= threshold
    return jnp.where(keep, xf, 0.0), jnp.sum(keep.astype(jnp.float32))


def ssd_chunk(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Intra-chunk SSD for ONE chunk and ONE head.

    xdt (Q, P) = dt·x;  dA (Q,);  B, C (Q, N).
    Returns (y_diag (Q, P), state (P, N), state_decay_out (Q,)):
      y_diag   = (C B^T ⊙ L) xdt           with L_ij = exp(sum_{j<m<=i} dA_m)
      state    = sum_k exp(cs[-1] - cs[k]) B_k ⊗ xdt_k   (end-of-chunk state)
      decay    = exp(cs)  (per-position multiplier for the incoming state)
    """
    Q = xdt.shape[0]
    cs = jnp.cumsum(dA)
    diff = cs[:, None] - cs[None, :]
    L = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), jnp.exp(diff), 0.0)
    scores = (C @ B.T) * L                                   # (Q, Q)
    y_diag = scores @ xdt                                    # (Q, P)
    decay_states = jnp.exp(cs[-1] - cs)                      # (Q,)
    state = (xdt * decay_states[:, None]).T @ B              # (P, N)
    return y_diag, state, jnp.exp(cs)
