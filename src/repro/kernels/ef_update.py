"""ef_update — fused EF residual axpy: e' = u - s·d (paper Eq. 6 line 2).

One streaming pass: reads u, d tiles from HBM, writes e' tiles. Fusing the
scale-and-subtract avoids materializing s·d (one full extra HBM round-trip
over an O(d) buffer). The scalar s rides along as a (1, 1) block mapped to
every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
BLOCK_ROWS = 256


def _kernel(u_ref, d_ref, s_ref, o_ref):
    s = s_ref[0, 0]
    o_ref[...] = u_ref[...].astype(jnp.float32) - s * d_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ef_update_2d(u2: jax.Array, d2: jax.Array, s: jax.Array, *,
                 block_rows: int = BLOCK_ROWS, interpret: bool = True) -> jax.Array:
    rows = u2.shape[0]
    assert rows % block_rows == 0 and u2.shape == d2.shape
    s2 = jnp.reshape(s.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(u2.shape, jnp.float32),
        interpret=interpret,
    )(u2, d2, s2)
