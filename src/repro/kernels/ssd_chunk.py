"""ssd_chunk — Mamba2 SSD intra-chunk kernel.

Per (batch, chunk, head) grid cell, all the dense intra-chunk work runs on
one VMEM-resident tile set:

    L      = exp(segsum(dA))            (Q, Q)   causal decay matrix
    y_diag = ((C Bᵀ) ⊙ L) · xdt         (Q, Q)·(Q, P)  — MXU matmuls
    state  = (xdt ⊙ decay)ᵀ · B         (P, N)   end-of-chunk state
    decay  = exp(cumsum(dA))            (Q,)     incoming-state multiplier

Q = chunk = 128, N = state = 128, P = head_dim = 64 — every matmul dim is
MXU-aligned (multiples of 64/128). The O(S) inter-chunk recurrence and the
rank-1 state->output combine stay outside (ops.ssd_chunked): they are tiny
and sequential, exactly the split the SSD paper prescribes.

B/C are shared across heads (n_groups=1): their BlockSpec index_map ignores
the head coordinate, so the same (Q, N) tile is reused for all H head steps
— VMEM traffic for B/C is 1/H of the naive layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dA_ref, B_ref, C_ref, y_ref, st_ref, dec_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    dA = dA_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    B = B_ref[0, 0].astype(jnp.float32)           # (Q, N)
    C = C_ref[0, 0].astype(jnp.float32)           # (Q, N)
    Q = x.shape[0]

    cs = jnp.cumsum(dA)
    diff = cs[:, None] - cs[None, :]
    L = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), jnp.exp(diff), 0.0)
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32) * L
    y_ref[0, 0, 0] = jnp.dot(scores, x, preferred_element_type=jnp.float32)
    decay_states = jnp.exp(cs[-1] - cs)
    st_ref[0, 0, 0] = jnp.dot((x * decay_states[:, None]).T, B,
                              preferred_element_type=jnp.float32)
    dec_ref[0, 0, 0] = jnp.exp(cs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_call(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                   *, interpret: bool = True):
    """xdt (b,h,nc,Q,P);  dA (b,h,nc,Q);  B,C (b,nc,Q,N).

    Returns (y_diag (b,h,nc,Q,P), states (b,h,nc,P,N), decay (b,h,nc,Q)).
    """
    b, h, nc, Q, P = xdt.shape
    N = B.shape[-1]
    grid = (b, h, nc)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda ib, ih, ic: (ib, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda ib, ih, ic: (ib, ih, ic, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nc, P, N), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nc, Q), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, dA, B, C)
