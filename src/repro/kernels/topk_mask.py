"""topk_mask — DGC sparsifier, TPU-native threshold-select form.

Exact global top-k is a sort (O(d log d), serial) — GPU-idiomatic, hostile
to the TPU. The DGC paper itself samples a threshold; we do the same
(ops.topk_threshold estimates tau from a strided sample with lax.top_k),
then this kernel does the single streaming pass: keep |x| >= tau, zero the
rest, count survivors (the count feeds budget accounting / tau refinement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
BLOCK_ROWS = 256


def _kernel(x_ref, t_ref, out_ref, cnt_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = x_ref[...].astype(jnp.float32)
    keep = jnp.abs(x) >= t_ref[0, 0]
    out_ref[...] = jnp.where(keep, x, 0.0)
    cnt_ref[0, 0] += jnp.sum(keep.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def topk_mask_2d(x2: jax.Array, threshold: jax.Array, *,
                 block_rows: int = BLOCK_ROWS, interpret: bool = True):
    rows = x2.shape[0]
    assert rows % block_rows == 0
    t2 = jnp.reshape(threshold.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2, t2)
