"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §7).

TPU is the *target*; on CPU every kernel runs in ``interpret=True`` mode and
is validated against the pure-jnp oracles in ``ref.py``. ``ops.py`` holds the
jit'd public wrappers (padding, dtype plumbing, interpret-mode dispatch).

  fused_cosine — one-HBM-pass (x·y, ||x||², ||y||²) for 3SFC Eq. 8/9
  ef_update    — fused EF residual axpy  e' = u - s·d
  sign_quant   — signSGD sign+scale extraction, int8 wire format
  topk_mask    — DGC threshold-select sparsifier (TPU-native top-k)
  ssd_chunk    — Mamba2 SSD intra-chunk kernel (MXU matmuls per chunk)
"""
