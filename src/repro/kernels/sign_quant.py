"""sign_quant — signSGD compression: signs (int8 wire format) + mean-|x| scale.

TPU has no efficient 1-bit type; the wire format is *accounted* as
1 bit/coord (budget math in core/baselines.py) while the on-chip payload is
int8 — matching how an ICI/NCCL implementation would pack before the wire.
One pass emits the sign tile and accumulates sum|x| for the scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
BLOCK_ROWS = 256


def _kernel(x_ref, sign_ref, acc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    sign_ref[...] = jnp.sign(x).astype(jnp.int8)
    acc_ref[0, 0] += jnp.sum(jnp.abs(x))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sign_quant_2d(x2: jax.Array, *, block_rows: int = BLOCK_ROWS,
                  interpret: bool = True):
    """Returns (signs int8 (rows, LANES), sum|x| (1,1) f32)."""
    rows = x2.shape[0]
    assert rows % block_rows == 0
    return pl.pallas_call(
        _kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, jnp.int8),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
