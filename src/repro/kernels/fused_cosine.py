"""fused_cosine — one-HBM-pass (x·y, ||x||², ||y||²).

The 3SFC encoder's Eq. 8/9 needs three O(d) reductions over the same two
flat vectors. Done naively that is three HBM passes over 2·d floats; the
gradient trees here are up to ~10^10 elements, so the pass count IS the cost
(arithmetic intensity ≈ 0.25 FLOP/byte — deeply memory-bound). This kernel
computes all three partial sums per VMEM tile in a single pass.

Tiling: inputs are padded/reshaped to (rows, 1024) lanes (8·128-aligned);
each grid step streams a (BLOCK_ROWS, 1024) tile of x and y through VMEM
(2 × 512 KB) and accumulates into a (1, 3) f32 accumulator that lives in the
output block (same block every step — the TPU grid is sequential, so this is
the standard Pallas reduction idiom).

HBM-pass accounting
-------------------
Per call over d-element operands (f32):

    fused (this kernel) : read x once + read y once          = 2d·4 bytes
    unfused dot+norms   : x·y (2d), ||x||² (d), ||y||² (d)   = 4d·4 bytes
    seed encoder total  : dot + sqnorm + 2×cosine + recon    ≈ 8 passes

``benchmarks/bench_kernels.py`` measures this structurally via XLA
``cost_analysis`` bytes-accessed on the lowered reductions and records the
before/after numbers in ``BENCH_kernels.json``; ``ops.tree_fused_stats``
extends the same single-pass contract to whole gradient pytrees (chunked
leaf streaming, no monolithic concatenate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
BLOCK_ROWS = 128


def _kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(x * y)
    o_ref[0, 1] += jnp.sum(x * x)
    o_ref[0, 2] += jnp.sum(y * y)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_cosine_2d(x2: jax.Array, y2: jax.Array, *, block_rows: int = BLOCK_ROWS,
                    interpret: bool = True) -> jax.Array:
    """x2, y2: (rows, LANES) with rows % block_rows == 0. Returns (3,) f32."""
    rows = x2.shape[0]
    assert rows % block_rows == 0 and x2.shape == y2.shape
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 3), jnp.float32),
        interpret=interpret,
    )(x2, y2)
    return out[0]
