"""The paper's five small vision models, reproduced in JAX.

* MLP        — 784-200-200-10 (199,210 params, matching the paper's count)
* MnistNet   — 2 conv + 2 linear (classic MNIST net)
* ConvNet    — 4 conv + 1 linear
* ResNet     — BN/dropout-free residual net (paper §5 deletes BN/dropout)
* RegNet     — BN-free simplified RegNet stem+stages

All share the facade: ``init(key)``, ``apply(params, x) -> logits``,
``loss(params, batch)`` (softmax CE on int labels), ``syn_loss(params, syn)``
(soft-label CE on synthetic pixels — the 3SFC payload for classifiers).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.threesfc import SynData, soft_xent
from repro.models import layers
from repro.models import params as P_

PyTree = Any


class VisionSpec(NamedTuple):
    name: str
    input_shape: Tuple[int, int, int]     # (H, W, C)
    num_classes: int


def xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


class VisionModel:
    """Facade wrapping an (init_fn, apply_fn) pair."""

    def __init__(self, spec: VisionSpec, init_fn, apply_fn):
        self.spec = spec
        self._init = init_fn
        self._apply = apply_fn

    def init(self, key) -> PyTree:
        return self._init(key)

    def apply(self, params: PyTree, x: jax.Array) -> jax.Array:
        return self._apply(params, x)

    def loss(self, params: PyTree, batch: Dict[str, jax.Array]) -> jax.Array:
        return xent(self._apply(params, batch["x"]), batch["y"])

    def syn_loss(self, params: PyTree, syn: SynData) -> jax.Array:
        return soft_xent(self._apply(params, syn.x), syn.labels())


# ---------------------------------------------------------------------------
# MLP — 784-200-200-10 = 199,210 params (paper Fig. 1)
# ---------------------------------------------------------------------------


def make_mlp(spec: VisionSpec, hidden: int = 200) -> VisionModel:
    d_in = int(np.prod(spec.input_shape))

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "l1": {"w": P_.dense_init(k1, d_in, (d_in, hidden)), "b": jnp.zeros((hidden,))},
            "l2": {"w": P_.dense_init(k2, hidden, (hidden, hidden)), "b": jnp.zeros((hidden,))},
            "l3": {"w": P_.dense_init(k3, hidden, (hidden, spec.num_classes)),
                   "b": jnp.zeros((spec.num_classes,))},
        }

    def apply(p, x):
        h = x.reshape(x.shape[0], -1)
        h = jax.nn.relu(h @ p["l1"]["w"] + p["l1"]["b"])
        h = jax.nn.relu(h @ p["l2"]["w"] + p["l2"]["b"])
        return h @ p["l3"]["w"] + p["l3"]["b"]

    return VisionModel(spec, init, apply)


# ---------------------------------------------------------------------------
# MnistNet — conv(10,5x5) conv(20,5x5) fc(50) fc(C)
# ---------------------------------------------------------------------------


def make_mnistnet(spec: VisionSpec) -> VisionModel:
    H, W, C = spec.input_shape

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        flat = (H // 4) * (W // 4) * 20
        return {
            "c1": layers.conv2d_init(k1, C, 10, 5),
            "c2": layers.conv2d_init(k2, 10, 20, 5),
            "f1": {"w": P_.dense_init(k3, flat, (flat, 50)), "b": jnp.zeros((50,))},
            "f2": {"w": P_.dense_init(k4, 50, (50, spec.num_classes)),
                   "b": jnp.zeros((spec.num_classes,))},
        }

    def apply(p, x):
        h = jax.nn.relu(layers.conv2d(p["c1"], x))
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = jax.nn.relu(layers.conv2d(p["c2"], h))
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p["f1"]["w"] + p["f1"]["b"])
        return h @ p["f2"]["w"] + p["f2"]["b"]

    return VisionModel(spec, init, apply)


# ---------------------------------------------------------------------------
# ConvNet — 4 conv + 1 linear
# ---------------------------------------------------------------------------


def make_convnet(spec: VisionSpec, widths=(32, 64, 128, 256)) -> VisionModel:
    H, W, C = spec.input_shape

    def init(key):
        ks = jax.random.split(key, 5)
        p = {}
        cin = C
        for i, w in enumerate(widths):
            p[f"c{i}"] = layers.conv2d_init(ks[i], cin, w, 3)
            cin = w
        p["fc"] = {"w": P_.dense_init(ks[4], cin, (cin, spec.num_classes)),
                   "b": jnp.zeros((spec.num_classes,))}
        return p

    def apply(p, x):
        h = x
        for i in range(len(widths)):
            h = jax.nn.relu(layers.conv2d(p[f"c{i}"], h, stride=2 if i else 1))
        h = jnp.mean(h, axis=(1, 2))
        return h @ p["fc"]["w"] + p["fc"]["b"]

    return VisionModel(spec, init, apply)


# ---------------------------------------------------------------------------
# ResNet (BN-free) — basic blocks, widths scalable for CPU runtime
# ---------------------------------------------------------------------------


def make_resnet(spec: VisionSpec, widths=(16, 32, 64), blocks_per_stage: int = 1) -> VisionModel:
    H, W, C = spec.input_shape

    def init(key):
        keys = jax.random.split(key, 2 + 3 * len(widths) * blocks_per_stage + len(widths))
        it = iter(keys)
        p = {"stem": layers.conv2d_init(next(it), C, widths[0], 3)}
        cin = widths[0]
        for s, w in enumerate(widths):
            for b in range(blocks_per_stage):
                blk = {
                    "c1": layers.conv2d_init(next(it), cin if b == 0 else w, w, 3),
                    "c2": layers.conv2d_init(next(it), w, w, 3),
                }
                if b == 0 and cin != w:
                    blk["proj"] = layers.conv2d_init(next(it), cin, w, 1)
                p[f"s{s}b{b}"] = blk
            cin = w
        p["fc"] = {"w": P_.dense_init(next(it), cin, (cin, spec.num_classes)),
                   "b": jnp.zeros((spec.num_classes,))}
        return p

    def apply(p, x):
        h = jax.nn.relu(layers.conv2d(p["stem"], x))
        for s, w in enumerate(widths):
            for b in range(blocks_per_stage):
                blk = p[f"s{s}b{b}"]
                stride = 2 if (s > 0 and b == 0) else 1
                r = jax.nn.relu(layers.conv2d(blk["c1"], h, stride=stride))
                r = layers.conv2d(blk["c2"], r)
                sc = h
                if "proj" in blk:
                    sc = layers.conv2d(blk["proj"], h, stride=stride)
                elif stride != 1:
                    sc = h[:, ::stride, ::stride, :]
                h = jax.nn.relu(r + sc)
        h = jnp.mean(h, axis=(1, 2))
        return h @ p["fc"]["w"] + p["fc"]["b"]

    return VisionModel(spec, init, apply)


# ---------------------------------------------------------------------------
# RegNet (BN-free, simplified X-block: group conv -> 1x1)
# ---------------------------------------------------------------------------


def make_regnet(spec: VisionSpec, widths=(24, 56, 152), depths=(1, 1, 2)) -> VisionModel:
    H, W, C = spec.input_shape

    def init(key):
        n = 1 + sum(depths) * 3 + 1
        keys = iter(jax.random.split(key, n + 8))
        p = {"stem": layers.conv2d_init(next(keys), C, widths[0], 3)}
        cin = widths[0]
        for s, (w, dep) in enumerate(zip(widths, depths)):
            for b in range(dep):
                blk = {
                    "c1": layers.conv2d_init(next(keys), cin if b == 0 else w, w, 1),
                    "c3": layers.conv2d_init(next(keys), w, w, 3),
                    "c2": layers.conv2d_init(next(keys), w, w, 1),
                }
                if b == 0 and cin != w:
                    blk["proj"] = layers.conv2d_init(next(keys), cin, w, 1)
                p[f"s{s}b{b}"] = blk
            cin = w
        p["fc"] = {"w": P_.dense_init(next(keys), cin, (cin, spec.num_classes)),
                   "b": jnp.zeros((spec.num_classes,))}
        return p

    def apply(p, x):
        h = jax.nn.relu(layers.conv2d(p["stem"], x, stride=1))
        for s, (w, dep) in enumerate(zip(widths, depths)):
            for b in range(dep):
                blk = p[f"s{s}b{b}"]
                stride = 2 if (s > 0 and b == 0) else 1
                r = jax.nn.relu(layers.conv2d(blk["c1"], h))
                r = jax.nn.relu(layers.conv2d(blk["c3"], r, stride=stride))
                r = layers.conv2d(blk["c2"], r)
                sc = h
                if "proj" in blk:
                    sc = layers.conv2d(blk["proj"], h, stride=stride)
                elif stride != 1:
                    sc = h[:, ::stride, ::stride, :]
                h = jax.nn.relu(r + sc)
        h = jnp.mean(h, axis=(1, 2))
        return h @ p["fc"]["w"] + p["fc"]["b"]

    return VisionModel(spec, init, apply)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

MNIST_SPEC = VisionSpec("mnist", (28, 28, 1), 10)
EMNIST_SPEC = VisionSpec("emnist", (28, 28, 1), 47)
FMNIST_SPEC = VisionSpec("fmnist", (28, 28, 1), 10)
CIFAR10_SPEC = VisionSpec("cifar10", (32, 32, 3), 10)
CIFAR100_SPEC = VisionSpec("cifar100", (32, 32, 3), 100)

DATASETS = {
    "mnist": MNIST_SPEC,
    "emnist": EMNIST_SPEC,
    "fmnist": FMNIST_SPEC,
    "cifar10": CIFAR10_SPEC,
    "cifar100": CIFAR100_SPEC,
}


def make_paper_model(name: str, spec: VisionSpec) -> VisionModel:
    return {
        "mlp": make_mlp,
        "mnistnet": make_mnistnet,
        "convnet": make_convnet,
        "resnet": make_resnet,
        "regnet": make_regnet,
    }[name](spec)
