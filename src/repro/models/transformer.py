"""Decoder-only LM covering the dense / MoE / SSM / hybrid families.

Layers are grouped into *periods* = one repetition of ``cfg.block_pattern``
(uniform archs: pattern ("attn",) -> period == layer). Period params carry a
leading ``n_periods`` axis and the whole stack is ONE ``lax.scan`` (remat'd),
so even 48-layer multi-billion-param configs lower to a compact HLO. A
non-divisible remainder becomes unrolled ``tail`` blocks (recurrentgemma:
26 = 3*8 + 2).

Big-vocab discipline: the (B, S, V) logits tensor is never materialized.
Training CE scans the sequence in chunks (remat'd), projecting each chunk's
hidden states and accumulating the loss; prefill projects only the last
position; decode projects a single token.

Multimodal (vlm / audio stubs): ``prefix_embeds`` (B, T_mm, d) are
concatenated in front of the token embeddings; the loss masks them out.

Synthetic features (3SFC): ``syn_loss`` consumes soft input embeddings
(n, L, d) + soft labels (dense or low-rank over the vocab) — the model-
agnostic payload the paper transmits, generalized to the LM families.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.threesfc import SynData, soft_xent
from repro.models import attention as attn_mod
from repro.models import layers, moe as moe_mod, rglru as rglru_mod, ssm as ssm_mod
from repro.models import params as P_

PyTree = Any
LOSS_CHUNK = 512          # sequence-chunked CE block size


# ---------------------------------------------------------------------------
# pattern helpers
# ---------------------------------------------------------------------------


def pattern_layout(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(pattern, n_periods, tail_pattern)."""
    pat = tuple(cfg.block_pattern)
    n_periods = cfg.num_layers // len(pat)
    tail = pat[: cfg.num_layers % len(pat)]
    return pat, n_periods, tail


# ---------------------------------------------------------------------------
# block init / forward / decode
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, btype: str, dtype) -> Dict:
    d = cfg.d_model
    if btype == "attn":
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": layers.rmsnorm_init(d, dtype),
            "attn": attn_mod.attn_init(k1, d, cfg.num_heads, cfg.num_kv_heads,
                                       cfg.resolved_head_dim, cfg.qkv_bias, dtype),
            "ln2": layers.rmsnorm_init(d, dtype),
        }
        if cfg.num_experts:
            p["moe"] = moe_mod.moe_init(k2, d, cfg.d_ff, cfg.num_experts,
                                        cfg.shared_experts, dtype)
        else:
            p["ffn"] = layers.ffn_init(k2, d, cfg.d_ff, dtype)
        return p
    if btype == "ssm":
        dims = ssm_mod.SSMDims.from_cfg(cfg)
        return {"ln1": layers.rmsnorm_init(d, dtype),
                "ssm": ssm_mod.ssm_init(key, dims, dtype)}
    if btype == "rec":
        k1, k2 = jax.random.split(key)
        width = cfg.rnn_width or cfg.d_model
        return {
            "ln1": layers.rmsnorm_init(d, dtype),
            "rglru": rglru_mod.rglru_init(k1, d, width, cfg.conv_width, dtype),
            "ln2": layers.rmsnorm_init(d, dtype),
            "ffn": layers.ffn_init(k2, d, cfg.d_ff, dtype),
        }
    raise ValueError(f"unknown block type {btype!r}")


def _block_forward(cfg: ModelConfig, btype: str, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    if btype == "attn":
        h = attn_mod.attention(p["attn"], layers.rmsnorm(p["ln1"], x, eps),
                               theta=cfg.rope_theta, window=cfg.attn_window)
        x = x + h
        z = layers.rmsnorm(p["ln2"], x, eps)
        if cfg.num_experts:
            out = moe_mod.moe_ffn(p["moe"], z, experts_per_token=cfg.experts_per_token,
                                  capacity_factor=cfg.capacity_factor,
                                  aux_coef=cfg.moe_aux_coef)
            x = x + out.y
            aux = aux + out.aux_loss
        else:
            x = x + layers.ffn(p["ffn"], z)
    elif btype == "ssm":
        dims = ssm_mod.SSMDims.from_cfg(cfg)
        y, _ = ssm_mod.ssm_forward(p["ssm"], layers.rmsnorm(p["ln1"], x, eps), dims)
        x = x + y
    elif btype == "rec":
        y, _ = rglru_mod.rglru_forward(p["rglru"], layers.rmsnorm(p["ln1"], x, eps))
        x = x + y
        x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln2"], x, eps))
    return x, aux


def _block_cache(cfg: ModelConfig, btype: str, batch: int, cache_len: int, dtype):
    if btype == "attn":
        eff = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        return attn_mod.init_cache(batch, eff, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, dtype)
    if btype == "ssm":
        return ssm_mod.init_ssm_cache(batch, ssm_mod.SSMDims.from_cfg(cfg), dtype)
    if btype == "rec":
        width = cfg.rnn_width or cfg.d_model
        return rglru_mod.init_rglru_cache(batch, width, cfg.conv_width, dtype)
    raise ValueError(btype)


def _block_prefill(cfg: ModelConfig, btype: str, p: Dict, x: jax.Array, cache_len: int):
    """Full forward + populated cache for this block."""
    eps = cfg.norm_eps
    if btype == "attn":
        eff = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        h, kv = attn_mod.prefill_cache(p["attn"], layers.rmsnorm(p["ln1"], x, eps),
                                       eff, theta=cfg.rope_theta, window=cfg.attn_window)
        x = x + h
        z = layers.rmsnorm(p["ln2"], x, eps)
        if cfg.num_experts:
            out = moe_mod.moe_ffn(p["moe"], z, experts_per_token=cfg.experts_per_token,
                                  capacity_factor=cfg.capacity_factor,
                                  aux_coef=cfg.moe_aux_coef)
            x = x + out.y
        else:
            x = x + layers.ffn(p["ffn"], z)
        return x, kv
    if btype == "ssm":
        dims = ssm_mod.SSMDims.from_cfg(cfg)
        xin = layers.rmsnorm(p["ln1"], x, eps)
        y, final = ssm_mod.ssm_forward(p["ssm"], xin, dims)
        # conv buffer = last (width-1) conv inputs
        z_, xc, Bc, Cc, _ = ssm_mod._split_proj(p["ssm"], xin[:, -(dims.conv_width - 1):, :], dims)
        buf = jnp.concatenate([xc, Bc, Cc], axis=-1).astype(final.dtype)
        return x + y, ssm_mod.SSMCache(buf, final)
    if btype == "rec":
        width = cfg.rnn_width or cfg.d_model
        xin = layers.rmsnorm(p["ln1"], x, eps)
        y, hfin = rglru_mod.rglru_forward(p["rglru"], xin)
        xconv = jnp.einsum("...d,dw->...w", xin[:, -(cfg.conv_width - 1):, :],
                           p["rglru"]["w_in"].astype(x.dtype))
        x = x + y
        x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln2"], x, eps))
        return x, rglru_mod.RGLRUCache(xconv, hfin)
    raise ValueError(btype)


def _block_decode(cfg: ModelConfig, btype: str, p: Dict, x_t: jax.Array, cache, t):
    eps = cfg.norm_eps
    if btype == "attn":
        h, cache = attn_mod.decode_attention(
            p["attn"], layers.rmsnorm(p["ln1"], x_t, eps), cache, t,
            theta=cfg.rope_theta, window=cfg.attn_window)
        x_t = x_t + h
        z = layers.rmsnorm(p["ln2"], x_t, eps)
        if cfg.num_experts:
            out = moe_mod.moe_ffn(p["moe"], z[:, None, :],
                                  experts_per_token=cfg.experts_per_token,
                                  capacity_factor=cfg.capacity_factor,
                                  aux_coef=cfg.moe_aux_coef)
            x_t = x_t + out.y[:, 0, :]
        else:
            x_t = x_t + layers.ffn(p["ffn"], z)
        return x_t, cache
    if btype == "ssm":
        dims = ssm_mod.SSMDims.from_cfg(cfg)
        y, cache = ssm_mod.ssm_decode_step(p["ssm"], layers.rmsnorm(p["ln1"], x_t, eps),
                                           cache, dims)
        return x_t + y, cache
    if btype == "rec":
        y, cache = rglru_mod.rglru_decode_step(p["rglru"], layers.rmsnorm(p["ln1"], x_t, eps),
                                               cache)
        x_t = x_t + y
        x_t = x_t + layers.ffn(p["ffn"], layers.rmsnorm(p["ln2"], x_t, eps))
        return x_t, cache
    raise ValueError(btype)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


class LM:
    """Functional decoder-only LM facade bound to a ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern, self.n_periods, self.tail = pattern_layout(cfg)
        self.param_dtype = P_.dtype_of(cfg.param_dtype)
        self.dtype = P_.dtype_of(cfg.dtype)

    # ---- init -------------------------------------------------------------

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        ke, kl, kt, kh = jax.random.split(key, 4)

        def period_init(k):
            ks = jax.random.split(k, len(self.pattern))
            return {str(i): _block_init(ks[i], cfg, bt, self.param_dtype)
                    for i, bt in enumerate(self.pattern)}

        params = {
            "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, self.param_dtype),
            "layers": P_.stack_init(period_init, kl, self.n_periods),
            "final_norm": layers.rmsnorm_init(cfg.d_model, self.param_dtype),
        }
        if self.tail:
            kts = jax.random.split(kt, len(self.tail))
            params["tail"] = {str(i): _block_init(kts[i], cfg, bt, self.param_dtype)
                              for i, bt in enumerate(self.tail)}
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.lm_head_init(kh, cfg.d_model, cfg.vocab_size,
                                                    self.param_dtype)
        return params

    # ---- shared trunk -----------------------------------------------------

    def _trunk(self, params: PyTree, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(B, S, d) -> (hidden (B, S, d), aux). One scan over periods."""
        cfg = self.cfg

        def period_fn(carry, pp):
            x, aux = carry
            for i, bt in enumerate(self.pattern):
                x, a = _block_forward(cfg, bt, pp[str(i)], x)
                aux = aux + a
            return (x, aux), None

        fn = jax.checkpoint(period_fn) if cfg.remat else period_fn
        (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        for i, bt in enumerate(self.tail):
            x, a = _block_forward(cfg, bt, params["tail"][str(i)], x)
            aux = aux + a
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux

    def _logits(self, params: PyTree, h: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            return layers.unembed(params["embed"], h)
        return layers.lm_head(params["lm_head"], h)

    def embed_tokens(self, params: PyTree, tokens: jax.Array) -> jax.Array:
        return layers.embed(params["embed"], tokens, self.dtype)

    # ---- training ---------------------------------------------------------

    def forward_hidden(self, params: PyTree, tokens: jax.Array,
                       prefix_embeds: Optional[jax.Array] = None):
        x = self.embed_tokens(params, tokens)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        return self._trunk(params, x)

    def loss(self, params: PyTree, batch: Dict[str, jax.Array]) -> jax.Array:
        """Next-token CE, sequence-chunked so (B,S,V) never materializes.

        batch: tokens (B,S) int32, optional prefix_embeds (B,T,d),
        optional mask (B,S) f32.
        """
        tokens = batch["tokens"]
        B, S = tokens.shape
        h, aux = self.forward_hidden(params, tokens, batch.get("prefix_embeds"))
        T = h.shape[1] - S
        h = h[:, T:, :]                                   # token positions only
        targets = tokens[:, 1:]
        mask = batch.get("mask")
        mask = jnp.ones_like(targets, jnp.float32) if mask is None else mask[:, 1:]
        hs = h[:, :-1, :]
        chunk = min(LOSS_CHUNK, S - 1)
        n_chunks = (S - 1) // chunk
        rem = (S - 1) % chunk

        def ce(hc, tc, mc):
            logits = self._logits(params, hc)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
            return jnp.sum(nll * mc), jnp.sum(mc)

        ce = jax.checkpoint(ce)
        if n_chunks > 0:
            hcs = hs[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, -1)
            tcs = targets[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)
            mcs = mask[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)

            def body(acc, xs):
                hc, tc, mc = xs
                s, c = ce(hc, tc, mc)
                return (acc[0] + s, acc[1] + c), None

            (tot, cnt), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                (jnp.moveaxis(hcs, 1, 0), jnp.moveaxis(tcs, 1, 0), jnp.moveaxis(mcs, 1, 0)))
        else:
            tot = cnt = jnp.zeros((), jnp.float32)
        if rem:
            s, c = ce(hs[:, n_chunks * chunk:], targets[:, n_chunks * chunk:],
                      mask[:, n_chunks * chunk:])
            tot, cnt = tot + s, cnt + c
        return tot / jnp.maximum(cnt, 1.0) + aux

    # ---- synthetic features (3SFC payload) ---------------------------------

    def syn_loss(self, params: PyTree, syn: SynData) -> jax.Array:
        """Soft-embedding inputs -> soft-label CE (the compressor's F)."""
        h, aux = self._trunk(params, syn.x.astype(self.dtype))
        logits = self._logits(params, h)
        return soft_xent(logits, syn.labels()) + aux

    # ---- serving ----------------------------------------------------------

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16) -> PyTree:
        cfg = self.cfg

        def one_period():
            return {str(i): _block_cache(cfg, bt, batch, cache_len, dtype)
                    for i, bt in enumerate(self.pattern)}

        period = one_period()
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (self.n_periods, *x.shape)), period)
        cache = {"layers": stacked}
        if self.tail:
            cache["tail"] = {str(i): _block_cache(cfg, bt, batch, cache_len, dtype)
                             for i, bt in enumerate(self.tail)}
        return cache

    def prefill(self, params: PyTree, tokens: jax.Array, cache_len: int,
                prefix_embeds: Optional[jax.Array] = None):
        """Returns (last-token logits (B, V), cache, t0)."""
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)

        def period_fn(x, pp):
            caches = {}
            for i, bt in enumerate(self.pattern):
                x, c = _block_prefill(cfg, bt, pp[str(i)], x, cache_len)
                caches[str(i)] = c
            return x, caches

        fn = jax.checkpoint(period_fn) if cfg.remat else period_fn
        x, stacked = jax.lax.scan(fn, x, params["layers"])
        cache = {"layers": stacked}
        if self.tail:
            cache["tail"] = {}
            for i, bt in enumerate(self.tail):
                x, c = _block_prefill(cfg, bt, params["tail"][str(i)], x, cache_len)
                cache["tail"][str(i)] = c
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x[:, -1, :])
        return logits, cache, jnp.asarray(x.shape[1], jnp.int32)

    def decode_step(self, params: PyTree, cache: PyTree, token: jax.Array, t):
        """token (B,) int32, t scalar position. Returns (logits (B,V), cache)."""
        cfg = self.cfg
        x_t = layers.embed(params["embed"], token, self.dtype)

        def period_fn(carry, xs):
            x_t, t = carry
            pp, pc = xs
            new_c = {}
            for i, bt in enumerate(self.pattern):
                x_t, c = _block_decode(cfg, bt, pp[str(i)], x_t, pc[str(i)], t)
                new_c[str(i)] = c
            return (x_t, t), new_c

        (x_t, _), new_stacked = jax.lax.scan(
            period_fn, (x_t, jnp.asarray(t, jnp.int32)),
            (params["layers"], cache["layers"]))
        new_cache = {"layers": new_stacked}
        if self.tail:
            new_cache["tail"] = {}
            for i, bt in enumerate(self.tail):
                x_t, c = _block_decode(cfg, bt, params["tail"][str(i)], x_t,
                                       cache["tail"][str(i)], t)
                new_cache["tail"][str(i)] = c
        x_t = layers.rmsnorm(params["final_norm"], x_t, cfg.norm_eps)
        return self._logits(params, x_t), new_cache
