"""Shared primitive layers: RMSNorm, dense FFN (SwiGLU), embedding, conv."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as P_


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Dict:
    return {"table": P_.embed_init(key, vocab, d, dtype)}


def embed(p: Dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Dict, x: jax.Array) -> jax.Array:
    """Tied-embedding logits: x (.., d) @ table.T (d, V), f32 accumulate."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), p["table"].astype(jnp.float32)
    )


def lm_head_init(key, d: int, vocab: int, dtype=jnp.float32) -> Dict:
    return {"w": P_.dense_init(key, d, (d, vocab), dtype)}


def lm_head(p: Dict, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32), p["w"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def ffn_init(key, d: int, ff: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": P_.dense_init(k1, d, (d, ff), dtype),
        "w_gate": P_.dense_init(k2, d, (d, ff), dtype),
        "w_out": P_.dense_init(k3, ff, (ff, d), dtype),
    }


def ffn(p: Dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(dt))
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dt))


# ---------------------------------------------------------------------------
# Conv (paper CNN models + SSM/RG-LRU temporal conv)
# ---------------------------------------------------------------------------


def conv2d_init(key, cin: int, cout: int, k: int, dtype=jnp.float32) -> Dict:
    kw, kb = jax.random.split(key)
    w = P_.dense_init(kw, cin * k * k, (k, k, cin, cout), dtype)
    return {"w": w, "b": jnp.zeros((cout,), dtype)}


def conv2d(p: Dict, x: jax.Array, stride: int = 1, padding: str = "SAME") -> jax.Array:
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"].astype(x.dtype)


def causal_conv1d_init(key, channels: int, width: int, dtype=jnp.float32) -> Dict:
    w = P_.dense_init(key, width, (width, channels), dtype)
    return {"conv_w": w, "conv_b": jnp.zeros((channels,), dtype)}


def causal_conv1d(p: Dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: (B, S, C)."""
    width = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    # depthwise: stack width shifted copies (small width => cheap, fusable)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
    return out + p["conv_b"].astype(x.dtype)


def causal_conv1d_step(p: Dict, x_t: jax.Array, buf: jax.Array):
    """Single decode step. x_t: (B, C); buf: (B, width-1, C) past inputs.

    Returns (y_t, new_buf).
    """
    width = p["conv_w"].shape[0]
    full = jnp.concatenate([buf, x_t[:, None, :]], axis=1)       # (B, width, C)
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32))
    y = (y + p["conv_b"].astype(jnp.float32)).astype(x_t.dtype)
    return y, full[:, 1:, :]
