"""Opt-in activation sharding constraints (§Perf lever).

Baseline lowering relies purely on GSPMD propagation from parameter/input
shardings; the SPMD partitioner then emits "involuntary full
rematerialization" copies around attention (kv-head-sharded tensors flowing
into batch-sharded consumers). ``enable(True, mesh)`` turns on explicit
``with_sharding_constraint`` pins (NamedSharding on the concrete mesh) at
the attention/MoE hot spots so the partitioner keeps the head axis on
'model' through the block.

Constraints are applied only when (a) enabled, (b) the registered mesh has a
'model' axis, and (c) the constrained dim divides the axis — so the same
model code lowers unchanged in tests and single-device runs.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_ENABLED = False
_MESH: Optional[jax.sharding.Mesh] = None


def enable(value: bool = True, mesh: Optional[jax.sharding.Mesh] = None) -> None:
    global _ENABLED, _MESH
    _ENABLED = value
    if mesh is not None:
        _MESH = mesh


def enabled() -> bool:
    return _ENABLED


def _model_axis_size() -> Optional[int]:
    if _MESH is None or "model" not in _MESH.axis_names:
        return None
    return dict(zip(_MESH.axis_names, _MESH.devices.shape))["model"]


def heads(x: jax.Array, axis: int = -2) -> jax.Array:
    """Pin the heads axis of (..., H, hd)-shaped activations to 'model'."""
    if not _ENABLED or _MESH is None:
        return x
    msize = _model_axis_size()
    ax = axis % x.ndim
    if not msize or x.shape[ax] % msize:
        return x
    spec = [None] * x.ndim
    spec[ax] = "model"
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_MESH, P(*spec)))
    except (ValueError, TypeError):
        # constraint rejected (mesh/aval mismatch): unsharded is correct,
        # just slower — anything else (tracer leaks etc.) should surface
        return x


def last(x: jax.Array) -> jax.Array:
    """Pin the last (feature) axis to 'model' (MoE expert-parallel h)."""
    return heads(x, axis=-1)
