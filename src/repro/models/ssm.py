"""Mamba2 mixer — SSD (state-space duality) with chunked scan.

The chunked formulation splits the sequence into chunks of length Q:
intra-chunk terms are dense matmuls (MXU work — this is the part the
``ssd_chunk`` Pallas kernel targets), the inter-chunk recurrence is a short
``lax.scan`` over Nc = S/Q chunk states. Decode is the O(1) recurrent update
h' = exp(dt·A)·h + dt·(B ⊗ x).

Layer layout (n_groups = 1):
  in_proj (d, 2·d_inner + 2·N + H)  -> z, x, B, C, dt
  conv    depthwise causal width-4 over concat(x, B, C)
  A_log, dt_bias, D : (H,)
  norm    gated RMSNorm (d_inner,)
  out_proj (d_inner, d)
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import params as P_
from repro.models import layers


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int
    heads: int
    head_dim: int
    state: int
    conv_width: int
    chunk: int
    use_pallas: bool = False

    @classmethod
    def from_cfg(cls, cfg):
        d_inner = cfg.ssm_expand * cfg.d_model
        heads = d_inner // cfg.ssm_head_dim
        return cls(cfg.d_model, d_inner, heads, cfg.ssm_head_dim,
                   cfg.ssm_state, cfg.conv_width, cfg.ssm_chunk,
                   getattr(cfg, "use_pallas_ssd", False))

    @property
    def conv_dim(self):
        return self.d_inner + 2 * self.state

    @property
    def in_proj_dim(self):
        return 2 * self.d_inner + 2 * self.state + self.heads


def ssm_init(key, dims: SSMDims, dtype=jnp.float32) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": P_.dense_init(k1, dims.d_model, (dims.d_model, dims.in_proj_dim), dtype),
        **layers.causal_conv1d_init(k2, dims.conv_dim, dims.conv_width, dtype),
        "A_log": jnp.zeros((dims.heads,), jnp.float32),
        "dt_bias": jnp.zeros((dims.heads,), jnp.float32),
        "D": jnp.ones((dims.heads,), jnp.float32),
        "norm": jnp.ones((dims.d_inner,), dtype),
        "out_proj": P_.dense_init(k4, dims.d_inner, (dims.d_inner, dims.d_model), dtype),
    }


def _split_proj(p: Dict, u: jax.Array, dims: SSMDims):
    zx = jnp.einsum("...d,de->...e", u, p["in_proj"].astype(u.dtype))
    z, x, Bc, Cc, dt = jnp.split(
        zx, [dims.d_inner, 2 * dims.d_inner,
             2 * dims.d_inner + dims.state,
             2 * dims.d_inner + 2 * dims.state], axis=-1)
    return z, x, Bc, Cc, dt


def _gated_norm(p: Dict, y: jax.Array, z: jax.Array, eps: float = 1e-6) -> jax.Array:
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps) * p["norm"].astype(jnp.float32)).astype(y.dtype)


def segsum(x: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q) lower-triangular pairwise cumulative sums."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    # element (i, j): sum_{j < m <= i} x_m  for i >= j; diag = 0
    d = cs[..., :, None] - cs[..., None, :]
    return jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), d, -jnp.inf)


def ssd_scan(xdt: jax.Array, dA: jax.Array, Bc: jax.Array, Cc: jax.Array,
             chunk: int, h0: jax.Array = None):
    """Chunked SSD. xdt (b,s,h,p) = dt·x;  dA (b,s,h);  B,C (b,s,n).

    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, pdim = xdt.shape
    n = Bc.shape[-1]
    Q = min(chunk, s)
    pad = (-s) % Q
    if pad:
        # zero-pad the tail: xdt=0 contributes nothing and dA=0 -> decay 1,
        # so y[:s] and the final state are exact
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // Q
    xc = xdt.reshape(b, nc, Q, h, pdim)
    dAc = dA.reshape(b, nc, Q, h)
    Bq = Bc.reshape(b, nc, Q, n)
    Cq = Cc.reshape(b, nc, Q, n)

    dA_cs = jnp.cumsum(dAc, axis=2)                                   # (b,c,Q,h)
    L = jnp.exp(segsum(jnp.moveaxis(dAc, -1, -2)))                    # (b,c,h,Q,Q)
    # intra-chunk (the ssd_chunk kernel computes this fused on TPU)
    y_diag = jnp.einsum("bcqn,bckn,bchqk,bckhp->bcqhp", Cq, Bq, L.astype(xdt.dtype), xc)
    # per-chunk input -> end-of-chunk state
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)               # (b,c,Q,h)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", Bq,
                        decay_states.astype(xdt.dtype), xc)           # (b,c,h,p,n)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                          # (b,c,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, pdim, n), xdt.dtype)

    def step(carry, inp):
        st, dec = inp
        new = st + dec[..., None, None].astype(st.dtype) * carry
        return new, carry                                              # emit state *entering* the chunk

    states_t = jnp.moveaxis(states, 1, 0)                              # (c,b,h,p,n)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                          # (c,b,h)
    final, prev_states = jax.lax.scan(step, h0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                      # (b,c,h,p,n)
    # contribution of the incoming state to each position
    state_decay = jnp.exp(dA_cs)                                       # (b,c,Q,h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cq, prev_states,
                       state_decay.astype(xdt.dtype))
    y = (y_diag + y_off).reshape(b, s, h, pdim)
    if pad:
        y = y[:, :s_orig]
    return y, final


class SSMCache(NamedTuple):
    conv_buf: jax.Array     # (B, width-1, conv_dim)
    state: jax.Array        # (B, H, P, N)


def init_ssm_cache(batch: int, dims: SSMDims, dtype=jnp.bfloat16) -> SSMCache:
    return SSMCache(
        conv_buf=jnp.zeros((batch, dims.conv_width - 1, dims.conv_dim), dtype),
        state=jnp.zeros((batch, dims.heads, dims.head_dim, dims.state), dtype),
    )


def ssm_forward(p: Dict, u: jax.Array, dims: SSMDims,
                h0: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence mixer. u: (B, S, d) -> (y (B, S, d), final_state)."""
    z, x, Bc, Cc, dt = _split_proj(p, u, dims)
    xbc = jnp.concatenate([x, Bc, Cc], axis=-1)
    xbc = jax.nn.silu(layers.causal_conv1d(p, xbc))
    x, Bc, Cc = jnp.split(xbc, [dims.d_inner, dims.d_inner + dims.state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,S,H)
    A = -jnp.exp(p["A_log"])                                           # (H,)
    xh = x.reshape(*x.shape[:-1], dims.heads, dims.head_dim)
    xdt = xh * dt[..., None].astype(xh.dtype)
    dA = dt * A
    if dims.use_pallas and xdt.shape[1] % min(dims.chunk, xdt.shape[1]) == 0:
        from repro.kernels import ops as kops
        if h0 is None:
            h0 = jnp.zeros((xdt.shape[0], dims.heads, dims.head_dim,
                            dims.state), xdt.dtype)
        y, final = kops.ssd_chunked_ad(xdt, dA, Bc, Cc, dims.chunk, h0)
    else:
        y, final = ssd_scan(xdt, dA, Bc, Cc, dims.chunk, h0)
    y = y + p["D"].astype(y.dtype)[:, None] * xh
    y = y.reshape(*u.shape[:-1], dims.d_inner)
    y = _gated_norm(p, y, z)
    return jnp.einsum("...e,ed->...d", y, p["out_proj"].astype(u.dtype)), final


def ssm_decode_step(p: Dict, u_t: jax.Array, cache: SSMCache,
                    dims: SSMDims) -> Tuple[jax.Array, SSMCache]:
    """One-token recurrent update. u_t: (B, d)."""
    z, x, Bc, Cc, dt = _split_proj(p, u_t, dims)
    xbc = jnp.concatenate([x, Bc, Cc], axis=-1)
    xbc, conv_buf = layers.causal_conv1d_step(p, xbc, cache.conv_buf)
    xbc = jax.nn.silu(xbc)
    x, Bc, Cc = jnp.split(xbc, [dims.d_inner, dims.d_inner + dims.state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                               # (B,H)
    xh = x.reshape(x.shape[0], dims.heads, dims.head_dim)
    dBx = jnp.einsum("bn,bhp->bhpn", Bc, xh * dt[..., None].astype(xh.dtype))
    state = cache.state * dA[..., None, None].astype(cache.state.dtype) + dBx.astype(cache.state.dtype)
    y = jnp.einsum("bhpn,bn->bhp", state, Cc.astype(state.dtype))
    y = y + p["D"].astype(y.dtype)[:, None] * xh.astype(y.dtype)
    y = y.reshape(u_t.shape[0], dims.d_inner).astype(u_t.dtype)
    y = _gated_norm(p, y, z)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"].astype(u_t.dtype))
    return out, SSMCache(conv_buf, state)
