"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = a^(c·r_t)   with a = sigmoid(a_param), c = 8
    h_t = a_t · h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

The block wraps the LRU in the Griffin recurrent-block layout:
linear-in -> temporal conv(width 4) -> RG-LRU -> gated linear-out.
Full-sequence form uses an associative scan over time (log-depth —
the TPU-friendly formulation); decode is the O(1) update.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import params as P_
from repro.models import layers

_C = 8.0


class RGLRUCache(NamedTuple):
    conv_buf: jax.Array     # (B, width-1, W)
    h: jax.Array            # (B, W)


def rglru_init(key, d: int, width: int, conv_width: int = 4, dtype=jnp.float32) -> Dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # a_param init so that a = sigmoid(a_param)^c spans ~[0.9, 0.999]
    a0 = jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, width) ** (1.0 / _C)
                           / (1 - jnp.linspace(0.9, 0.999, width) ** (1.0 / _C))))
    return {
        "w_in": P_.dense_init(k1, d, (d, width), dtype),        # branch input
        "w_gate_lin": P_.dense_init(k2, d, (d, width), dtype),  # multiplicative gate branch
        **layers.causal_conv1d_init(k3, width, conv_width, dtype),
        "w_gate_in": P_.dense_init(k4, width, (width, width), dtype),
        "b_gate_in": jnp.zeros((width,), dtype),
        "w_gate_a": P_.dense_init(k5, width, (width, width), dtype),
        "b_gate_a": jnp.zeros((width,), dtype),
        "a_param": a0.astype(jnp.float32),
        "w_y": P_.dense_init(k6, width, (width, d), dtype),
    }


def _lru_coeffs(p: Dict, x: jax.Array):
    """x: (..., W) conv output. Returns (a, gx) both f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_gate_a"].astype(jnp.float32) + p["b_gate_a"])
    i = jax.nn.sigmoid(xf @ p["w_gate_in"].astype(jnp.float32) + p["b_gate_in"])
    log_a = _C * r * jax.nn.log_sigmoid(p["a_param"])            # log a_t
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * xf)
    return a, gx


def rglru_forward(p: Dict, u: jax.Array, h0: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """u: (B, S, d) -> (y (B, S, d), final hidden (B, W))."""
    x = jnp.einsum("...d,dw->...w", u, p["w_in"].astype(u.dtype))
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", u, p["w_gate_lin"].astype(u.dtype)))
    x = layers.causal_conv1d(p, x)
    a, gx = _lru_coeffs(p, x)                                    # (B,S,W) f32
    if h0 is not None:
        gx = gx.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))
    # associative scan: (a1,b1) ∘ (a2,b2) = (a1·a2, b2 + a2·b1)
    def comb(l, r):
        return (l[0] * r[0], r[1] + r[0] * l[1])
    _, h = jax.lax.associative_scan(comb, (a, gx), axis=1)
    y = (h.astype(u.dtype) * gate)
    return jnp.einsum("...w,wd->...d", y, p["w_y"].astype(u.dtype)), h[:, -1, :]


def init_rglru_cache(batch: int, width: int, conv_width: int = 4,
                     dtype=jnp.bfloat16) -> RGLRUCache:
    return RGLRUCache(
        conv_buf=jnp.zeros((batch, conv_width - 1, width), dtype),
        h=jnp.zeros((batch, width), jnp.float32),
    )


def rglru_decode_step(p: Dict, u_t: jax.Array, cache: RGLRUCache) -> Tuple[jax.Array, RGLRUCache]:
    """u_t: (B, d)."""
    x = jnp.einsum("bd,dw->bw", u_t, p["w_in"].astype(u_t.dtype))
    gate = jax.nn.gelu(jnp.einsum("bd,dw->bw", u_t, p["w_gate_lin"].astype(u_t.dtype)))
    x, conv_buf = layers.causal_conv1d_step(p, x, cache.conv_buf)
    a, gx = _lru_coeffs(p, x)
    h = a * cache.h + gx
    y = h.astype(u_t.dtype) * gate
    out = jnp.einsum("bw,wd->bd", y, p["w_y"].astype(u_t.dtype))
    return out, RGLRUCache(conv_buf, h)
