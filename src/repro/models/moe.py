"""Mixture-of-Experts FFN: top-k router + capacity-based einsum dispatch.

Dispatch is the GSPMD-friendly one-hot einsum formulation (MaxText/GShard
style): dispatch (B,S,E,C) routes tokens to per-expert capacity slots, the
expert SwiGLU runs as three (E, ...) batched matmuls (experts sharded over
the 'model' mesh axis -> all-to-all appears in the lowered HLO exactly where
a real expert-parallel deployment has it), and combine scatters weighted
outputs back. Tokens beyond capacity are dropped (residual carries them).

Optional shared experts (llama4-scout: 1, moonshot/moonlight: 2) run as an
always-on dense SwiGLU added to the routed output.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import params as P_
from repro.models import layers, shard


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array       # load-balance loss (Switch-style)


def moe_init(key, d: int, ff: int, num_experts: int, shared_experts: int = 0,
             dtype=jnp.float32) -> Dict:
    kr, ki, kg, ko, ks = jax.random.split(key, 5)
    p = {
        "router": P_.dense_init(kr, d, (d, num_experts), jnp.float32),
        "w_in": P_.dense_init(ki, d, (num_experts, d, ff), dtype),
        "w_gate": P_.dense_init(kg, d, (num_experts, d, ff), dtype),
        "w_out": P_.dense_init(ko, ff, (num_experts, ff, d), dtype),
    }
    if shared_experts:
        p["shared"] = layers.ffn_init(ks, d, ff * shared_experts, dtype)
    return p


def _router(p: Dict, x: jax.Array, k: int):
    """Returns (topk weights (B,S,k), topk expert ids (B,S,k), aux loss)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)
    # Switch aux loss: E * sum_e fraction_tokens(e) * mean_prob(e)
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=(0, 1))                                # (E,)
    onehot = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)     # top-1 assign
    ce = jnp.mean(onehot, axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return top_w, top_e, aux


def moe_ffn(p: Dict, x: jax.Array, *, experts_per_token: int,
            capacity_factor: float = 1.25, aux_coef: float = 0.01) -> MoEOut:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E = p["router"].shape[-1]
    k = experts_per_token
    C = max(1, int(capacity_factor * k * S / E))
    top_w, top_e, aux = _router(p, x, k)

    # position of each token within its expert's queue, per routing slot
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)             # (B,S,k,E)
    flat = onehot.reshape(B, S * k, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat) * flat              # (B,S*k,E)
    keep = pos_in_e < C
    cap_oh = jax.nn.one_hot(pos_in_e.astype(jnp.int32), C, dtype=jnp.float32)
    dispatch = (flat * keep)[..., None] * cap_oh                      # (B,S*k,E,C)
    weights = top_w.reshape(B, S * k)
    combine = dispatch * weights[..., None, None]                     # (B,S*k,E,C)
    # fold the k routing slots back onto tokens
    dispatch = dispatch.reshape(B, S, k, E, C).sum(axis=2)
    combine = combine.reshape(B, S, k, E, C).sum(axis=2)

    dt = x.dtype
    xe = jnp.einsum("bsd,bsec->ebcd", x, dispatch.astype(dt))        # (E,B,C,d)
    xe = shard.heads(xe, axis=0)       # §Perf: experts stay on 'model'
    h = jnp.einsum("ebcd,edf->ebcf", xe, p["w_in"].astype(dt))
    g = jnp.einsum("ebcd,edf->ebcf", xe, p["w_gate"].astype(dt))
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["w_out"].astype(dt))      # (E,B,C,d)
    y = jnp.einsum("ebcd,bsec->bsd", ye, combine.astype(dt))

    if "shared" in p:
        y = y + layers.ffn(p["shared"], x)
    return MoEOut(y, aux_coef * aux)
