"""Model substrate: every assigned architecture family, in pure JAX.

Entry points:
  * ``transformer.py``  — decoder-only LM (dense / MoE / SSM / hybrid blocks)
  * ``encdec.py``       — encoder-decoder (seamless-m4t family)
  * ``cnn.py``          — the paper's five small vision models
  * ``build.py``        — ``build_model(cfg)`` returning a ``Model`` facade
"""
