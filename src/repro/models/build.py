"""``build_model(cfg)`` + synthetic-feature spec helpers.

The returned object is an ``LM``, ``EncDec`` or ``VisionModel`` facade; all
expose ``init``, ``loss(params, batch)`` and a 3SFC-compatible
``syn_loss(params, syn)`` (for EncDec the encoder length is bound here so the
compressor sees the uniform ``LossFn`` signature).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import numpy as np

from repro.configs.base import CompressorConfig, ModelConfig
from repro.core.threesfc import SynSpec
from repro.models.encdec import EncDec
from repro.models.transformer import LM

# encoder-side synthetic frames for enc-dec syn payloads
ENC_SYN_LEN = 8


def build_model(cfg: ModelConfig):
    if cfg.enc_layers > 0:
        return EncDec(cfg)
    return LM(cfg)


def syn_spec_for(cfg: ModelConfig, comp: CompressorConfig) -> SynSpec:
    """Shapes of the 3SFC payload for this architecture."""
    n, L = comp.syn_batch, comp.syn_seq
    if cfg.enc_layers > 0:
        return SynSpec(
            x_shape=(n, ENC_SYN_LEN + L, cfg.d_model),
            num_classes=cfg.vocab_size,
            label_rank=comp.soft_label_rank,
            label_lead=(n, L),
        )
    return SynSpec(
        x_shape=(n, L, cfg.d_model),
        num_classes=cfg.vocab_size,
        label_rank=comp.soft_label_rank,
        label_lead=(n, L),
    )


def syn_loss_fn(model) -> Callable:
    """Uniform ``loss_fn(params, syn)`` for the compressor."""
    if isinstance(model, EncDec):
        return functools.partial(
            lambda m, p, s: m.syn_loss(p, s, ENC_SYN_LEN), model)
    return model.syn_loss


def vision_syn_spec(spec, comp: CompressorConfig) -> SynSpec:
    """Classifier payload: raw synthetic pixels + soft labels (paper's form)."""
    return SynSpec(
        x_shape=(comp.syn_batch, *spec.input_shape),
        num_classes=spec.num_classes,
        label_rank=0,
        label_lead=(comp.syn_batch,),
    )
