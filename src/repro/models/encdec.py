"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend (mel + conformer feature extractor) is a STUB per the
assignment carve-out: the encoder consumes precomputed frame embeddings
(B, T_frames, d_model). Everything downstream — 12L bidirectional encoder,
12L causal decoder with cross-attention, 256k-vocab head — is real.

Serving: ``prefill`` encodes the frames + teacher-forces the prompt through
the decoder, caching decoder self-attn KV (ring buffer) and the *projected*
encoder memory K/V (computed once). ``decode_step`` is one decoder token.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.threesfc import SynData, soft_xent
from repro.models import attention as attn_mod
from repro.models import layers
from repro.models import params as P_

PyTree = Any
LOSS_CHUNK = 512


def _enc_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attn_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, cfg.qkv_bias, dtype),
        "ln2": layers.rmsnorm_init(cfg.d_model, dtype),
        "ffn": layers.ffn_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attn_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, cfg.qkv_bias, dtype),
        "lnx": layers.rmsnorm_init(cfg.d_model, dtype),
        "xattn": attn_mod.attn_init(k2, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                    cfg.resolved_head_dim, cfg.qkv_bias, dtype),
        "ln2": layers.rmsnorm_init(cfg.d_model, dtype),
        "ffn": layers.ffn_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


class EncDec:
    def __init__(self, cfg: ModelConfig):
        assert cfg.enc_layers > 0
        self.cfg = cfg
        self.param_dtype = P_.dtype_of(cfg.param_dtype)
        self.dtype = P_.dtype_of(cfg.dtype)

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        ke, kenc, kdec, kh = jax.random.split(key, 4)
        return {
            "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, self.param_dtype),
            "enc_layers": P_.stack_init(
                lambda k: _enc_block_init(k, cfg, self.param_dtype), kenc, cfg.enc_layers),
            "enc_norm": layers.rmsnorm_init(cfg.d_model, self.param_dtype),
            "dec_layers": P_.stack_init(
                lambda k: _dec_block_init(k, cfg, self.param_dtype), kdec, cfg.num_layers),
            "final_norm": layers.rmsnorm_init(cfg.d_model, self.param_dtype),
            "lm_head": layers.lm_head_init(kh, cfg.d_model, cfg.vocab_size, self.param_dtype),
        }

    # ---- encoder ----------------------------------------------------------

    def encode(self, params: PyTree, frames: jax.Array) -> jax.Array:
        """frames: (B, T, d) stub embeddings -> encoder memory (B, T, d)."""
        cfg = self.cfg
        x = frames.astype(self.dtype)

        def block(x, p):
            h = attn_mod.attention(p["attn"], layers.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                   theta=cfg.rope_theta, causal=False)
            x = x + h
            x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
            return x, None

        fn = jax.checkpoint(block) if cfg.remat else block
        x, _ = jax.lax.scan(fn, x, params["enc_layers"])
        return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # ---- decoder (teacher-forced) ------------------------------------------

    def _decoder_hidden(self, params: PyTree, x: jax.Array, memory: jax.Array) -> jax.Array:
        cfg = self.cfg

        def block(x, p):
            h = attn_mod.attention(p["attn"], layers.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                   theta=cfg.rope_theta, window=cfg.attn_window)
            x = x + h
            h = attn_mod.attention(p["xattn"], layers.rmsnorm(p["lnx"], x, cfg.norm_eps),
                                   theta=cfg.rope_theta, xkv=memory, causal=False)
            x = x + h
            x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
            return x, None

        fn = jax.checkpoint(block) if cfg.remat else block
        x, _ = jax.lax.scan(fn, x, params["dec_layers"])
        return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)

    def loss(self, params: PyTree, batch: Dict[str, jax.Array]) -> jax.Array:
        """batch: frames (B,T,d), tokens (B,S). Chunked CE (256k vocab)."""
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = layers.embed(params["embed"], tokens, self.dtype)
        h = self._decoder_hidden(params, x, memory)
        hs, targets = h[:, :-1, :], tokens[:, 1:]
        chunk = min(LOSS_CHUNK, S - 1)
        n_chunks, rem = (S - 1) // chunk, (S - 1) % chunk

        def ce(hc, tc):
            logits = layers.lm_head(params["lm_head"], hc)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
            return jnp.sum(nll)

        ce = jax.checkpoint(ce)
        tot = jnp.zeros((), jnp.float32)
        if n_chunks > 0:
            hcs = hs[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, -1)
            tcs = targets[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)

            def body(acc, xs):
                return acc + ce(*xs), None

            tot, _ = jax.lax.scan(body, tot, (jnp.moveaxis(hcs, 1, 0),
                                              jnp.moveaxis(tcs, 1, 0)))
        if rem:
            tot = tot + ce(hs[:, n_chunks * chunk:], targets[:, n_chunks * chunk:])
        return tot / jnp.float32(B * (S - 1))

    # ---- synthetic features -------------------------------------------------

    def syn_loss(self, params: PyTree, syn: SynData, enc_len: int) -> jax.Array:
        """syn.x = (n, Le + Ld, d): first ``enc_len`` are encoder frames,
        rest are decoder soft embeddings. Labels cover the Ld positions."""
        xe = syn.x[:, :enc_len, :]
        xd = syn.x[:, enc_len:, :].astype(self.dtype)
        memory = self.encode(params, xe)
        h = self._decoder_hidden(params, xd, memory)
        logits = layers.lm_head(params["lm_head"], h)
        return soft_xent(logits, syn.labels())

    # ---- serving ------------------------------------------------------------

    def init_cache(self, batch: int, cache_len: int, enc_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
        cfg = self.cfg
        kv = attn_mod.init_cache(batch, cache_len, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, dtype)
        self_kv = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers, *x.shape)), kv)
        mem_kv = {
            "k": jnp.zeros((cfg.num_layers, batch, enc_len, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, enc_len, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype),
        }
        return {"self": self_kv, "mem": mem_kv}

    def prefill(self, params: PyTree, frames: jax.Array, tokens: jax.Array,
                cache_len: int):
        """Encode frames, teacher-force tokens, build decode caches."""
        cfg = self.cfg
        memory = self.encode(params, frames)
        x = layers.embed(params["embed"], tokens, self.dtype)

        def block(x, p):
            h, kv = attn_mod.prefill_cache(p["attn"],
                                           layers.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                           cache_len, theta=cfg.rope_theta,
                                           window=cfg.attn_window)
            x = x + h
            # project encoder memory K/V once for this layer
            _, mk, mv = attn_mod._project_qkv(p["xattn"], memory[:, :1, :], memory)
            h = attn_mod.attention(p["xattn"], layers.rmsnorm(p["lnx"], x, cfg.norm_eps),
                                   theta=cfg.rope_theta, xkv=memory, causal=False)
            x = x + h
            x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
            return x, (kv, {"k": mk, "v": mv})

        fn = jax.checkpoint(block) if cfg.remat else block
        x, (self_kv, mem_kv) = jax.lax.scan(fn, x, params["dec_layers"])
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.lm_head(params["lm_head"], x[:, -1, :])
        return logits, {"self": self_kv, "mem": mem_kv}, jnp.asarray(tokens.shape[1], jnp.int32)

    def decode_step(self, params: PyTree, cache: PyTree, token: jax.Array, t):
        cfg = self.cfg
        x_t = layers.embed(params["embed"], token, self.dtype)

        def block(carry, xs):
            x_t, t = carry
            p, sc, mem = xs
            h, sc = attn_mod.decode_attention(
                p["attn"], layers.rmsnorm(p["ln1"], x_t, cfg.norm_eps), sc, t,
                theta=cfg.rope_theta, window=cfg.attn_window)
            x_t = x_t + h
            # cross-attn against cached projected memory
            z = layers.rmsnorm(p["lnx"], x_t, cfg.norm_eps)
            q = jnp.einsum("bd,dhk->bhk", z, p["xattn"]["wq"].astype(z.dtype))
            out = attn_mod._sdpa(q[:, None], mem["k"], mem["v"], None)[:, 0]
            x_t = x_t + jnp.einsum("bhk,hkd->bd", out, p["xattn"]["wo"].astype(z.dtype))
            x_t = x_t + layers.ffn(p["ffn"], layers.rmsnorm(p["ln2"], x_t, cfg.norm_eps))
            return (x_t, t), sc

        (x_t, _), new_self = jax.lax.scan(
            block, (x_t, jnp.asarray(t, jnp.int32)),
            (params["dec_layers"], cache["self"], cache["mem"]))
        x_t = layers.rmsnorm(params["final_norm"], x_t, cfg.norm_eps)
        logits = layers.lm_head(params["lm_head"], x_t)
        return logits, {"self": new_self, "mem": cache["mem"]}
