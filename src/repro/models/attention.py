"""GQA attention: full/sliding-window causal, cross-attention, ring-buffer
KV-cache decode.

Weight layout (chosen for tensor-parallel sharding, see params.py rules):
  wq (d, H, hd)   wk/wv (d, KV, hd)   wo (H, hd, d)   [+ optional biases]

Decode cache is a ring buffer of ``cache_len`` slots holding (k, v, abs_pos).
``cache_len == seq_len`` gives exact full attention; ``cache_len == window``
gives exact sliding-window attention with O(window) memory — that is the
sub-quadratic serving mode used by long_500k.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import params as P_
from repro.models import shard
from repro.models.rope import apply_rope

NEG_INF = -1e30


def attn_init(key, d: int, num_heads: int, num_kv: int, head_dim: int,
              qkv_bias: bool = False, dtype=jnp.float32) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": P_.dense_init(kq, d, (d, num_heads, head_dim), dtype),
        "wk": P_.dense_init(kk, d, (d, num_kv, head_dim), dtype),
        "wv": P_.dense_init(kv, d, (d, num_kv, head_dim), dtype),
        "wo": P_.dense_init(ko, num_heads * head_dim, (num_heads, head_dim, d), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((num_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((num_kv, head_dim), dtype)
    return p


def _project_qkv(p: Dict, x: jax.Array, xkv: Optional[jax.Array] = None):
    dt = x.dtype
    xkv = x if xkv is None else xkv
    q = jnp.einsum("...sd,dhk->...shk", x, p["wq"].astype(dt))
    k = jnp.einsum("...sd,dgk->...sgk", xkv, p["wk"].astype(dt))
    v = jnp.einsum("...sd,dgk->...sgk", xkv, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    # §Perf: pin heads (fallback head_dim) to 'model' through the block
    q = shard.heads(q) if q.shape[-2] % (shard._model_axis_size() or 1) == 0 \
        else shard.heads(q, axis=-1)
    k = shard.heads(k) if k.shape[-2] % (shard._model_axis_size() or 1) == 0 \
        else shard.heads(k, axis=-1)
    v = shard.heads(v) if v.shape[-2] % (shard._model_axis_size() or 1) == 0 \
        else shard.heads(v, axis=-1)
    return q, k, v


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array]):
    """q (..,Sq,H,hd)  k/v (..,Sk,KV,hd) grouped attention, f32 softmax."""
    H = q.shape[-2]
    KV = k.shape[-2]
    G = H // KV
    Bsh = q.shape[:-3]
    q = q.reshape(*Bsh, q.shape[-3], KV, G, q.shape[-1])
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("...qgrk,...sgk->...grqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("...grqs,...sgk->...qgrk", probs, v)
    return out.reshape(*Bsh, out.shape[-4], H, out.shape[-1])


def causal_mask(sq: int, sk: int, window: int = 0, offset: int = 0) -> jax.Array:
    """(sq, sk) bool mask. ``offset`` = absolute position of query 0 minus
    absolute position of key 0 (for chunked prefill)."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m


def attention(p: Dict, x: jax.Array, *, theta: float, window: int = 0,
              positions: Optional[jax.Array] = None,
              xkv: Optional[jax.Array] = None, causal: bool = True) -> jax.Array:
    """Full-sequence attention. x: (B, S, d). Cross-attn: pass xkv, causal=False."""
    S = x.shape[-2]
    q, k, v = _project_qkv(p, x, xkv)
    if positions is None:
        positions = jnp.arange(S)
    if xkv is None:  # self-attention: rope on both
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    mask = causal_mask(S, k.shape[-3], window) if causal else None
    out = _sdpa(q, k, v, mask)
    return jnp.einsum("...shk,hkd->...sd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV cache (ring buffer)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array          # (B, cache_len, KV, hd)
    v: jax.Array          # (B, cache_len, KV, hd)
    pos: jax.Array        # (B, cache_len) int32 absolute positions, -1 = empty


def init_cache(batch: int, cache_len: int, num_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, cache_len, num_kv, head_dim), dtype),
        v=jnp.zeros((batch, cache_len, num_kv, head_dim), dtype),
        pos=-jnp.ones((batch, cache_len), jnp.int32),
    )


def prefill_cache(p: Dict, x: jax.Array, cache_len: int, *, theta: float,
                  window: int = 0) -> Tuple[jax.Array, KVCache]:
    """Run full self-attention over x and return output + populated cache.

    When ``cache_len < S`` only the trailing window is kept (ring semantics).
    """
    B, S = x.shape[0], x.shape[-2]
    q, k, v = _project_qkv(p, x)
    positions = jnp.arange(S)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    eff_window = window if window > 0 else (0 if cache_len >= S else cache_len)
    out = _sdpa(q, k, v, causal_mask(S, S, eff_window))
    y = jnp.einsum("...shk,hkd->...sd", out, p["wo"].astype(x.dtype))
    if cache_len >= S:
        pad = cache_len - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pc = jnp.pad(positions, (0, pad), constant_values=-1)
    else:
        kc, vc, pc = k[:, -cache_len:], v[:, -cache_len:], positions[-cache_len:]
        # ring layout: slot = pos % cache_len
        slot = pc % cache_len
        order = jnp.argsort(slot)
        kc, vc, pc = kc[:, order], vc[:, order], pc[order]
    pc = jnp.broadcast_to(pc, (B, cache_len)).astype(jnp.int32)
    return y, KVCache(kc, vc, pc)


def decode_attention(p: Dict, x_t: jax.Array, cache: KVCache, t: jax.Array, *,
                     theta: float, window: int = 0) -> Tuple[jax.Array, KVCache]:
    """One decode step. x_t: (B, d); t: scalar absolute position of the new
    token. Returns (y_t (B, d), new cache)."""
    dt_ = x_t.dtype
    B = x_t.shape[0]
    cache_len = cache.k.shape[1]
    q = jnp.einsum("bd,dhk->bhk", x_t, p["wq"].astype(dt_))
    k = jnp.einsum("bd,dgk->bgk", x_t, p["wk"].astype(dt_))
    v = jnp.einsum("bd,dgk->bgk", x_t, p["wv"].astype(dt_))
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt_), k + p["bk"].astype(dt_), v + p["bv"].astype(dt_)
    tpos = jnp.asarray(t, jnp.int32)
    q = apply_rope(q[:, None], tpos[None], theta)[:, 0]
    k = apply_rope(k[:, None], tpos[None], theta)[:, 0]
    slot = tpos % cache_len
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k[:, None].astype(cache.k.dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v[:, None].astype(cache.v.dtype), slot, axis=1)
    pc = jax.lax.dynamic_update_slice_in_dim(
        cache.pos, jnp.full((B, 1), tpos, jnp.int32), slot, axis=1)
    # grouped attention over the whole ring buffer, masked by validity/window
    valid = (pc >= 0) & (pc <= tpos)
    if window > 0:
        valid = valid & (pc > tpos - window)
    mask = valid[:, None, None, None, :]                       # (B,1,1,1,L)
    out = _sdpa(q[:, None], kc, vc, mask)[:, 0]                # (B, H, hd)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(dt_))
    return y, KVCache(kc, vc, pc)
