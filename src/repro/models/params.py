"""Parameter init / dtype / sharding-rule helpers.

Params are nested dicts of jnp arrays. Layer stacks carry a leading
``num_layers`` axis (populated with ``jax.vmap`` over the layer index) so the
whole stack is one ``lax.scan`` — keeping the HLO small enough that 48-layer
multi-billion-parameter configs lower on a single CPU host.

Sharding is *path based*: ``sharding_rules`` maps a param path (joined dict
keys) to a ``PartitionSpec`` via substring rules, applied with
``tree_map_with_path``. Rules are mesh-shape aware: an axis is only sharded
when its size divides by the mesh axis, otherwise the rule falls through to
the next candidate (e.g. kv-heads -> head_dim -> replicate).
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

PyTree = Any


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, shape: Tuple[int, ...], dtype=jnp.float32):
    """Truncated-normal fan-in init (1/sqrt(in_dim))."""
    scale = 1.0 / np.sqrt(max(in_dim, 1))
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def stack_init(init_fn: Callable[[jax.Array], PyTree], key, n: int) -> PyTree:
    """vmap ``init_fn`` over ``n`` layer keys -> stacked params (leading n)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# path utilities
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# Each rule: (regex, spec_fn(leaf_shape, mesh_axis_sizes) -> PartitionSpec).
Rule = Tuple[str, Callable[[Tuple[int, ...], Dict[str, int]], P]]


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


# §Perf H2 toggle (see _heads_then_hd): default keeps the baseline behavior
_QK_HD_FALLBACK = True


def set_qk_hd_fallback(value: bool) -> None:
    global _QK_HD_FALLBACK
    _QK_HD_FALLBACK = value


def make_sharding_rules(model_axis: str = "model") -> Sequence[Rule]:
    """Default tensor-parallel rules for the LM families.

    Conventions (see layer defs): weights are stored so that the sharded
    logical axis is recognizable by name; ``stacked`` leading layer axis is
    never sharded.
    """
    m = model_axis

    def _shard_last(shape, sizes):
        return P(*([None] * (len(shape) - 1) + [m])) if _div(shape[-1], sizes[m]) else P()

    def _shard_dim(i):
        def f(shape, sizes):
            j = i if i >= 0 else len(shape) + i
            if 0 <= j < len(shape) and _div(shape[j], sizes[m]):
                spec = [None] * len(shape)
                spec[j] = m
                return P(*spec)
            return P()
        return f

    def _heads_then_hd(shape, sizes):
        # (..., H, hd): prefer heads, fall back to head_dim, else replicate.
        # head_dim fallback is a FOOTGUN for q/k: hd is the QK^T contraction
        # dim, so sharding it makes GSPMD all-reduce the (S, S) logits —
        # 320 GiB/layer for llama4 prefill_32k (§Perf H2). Disable via
        # set_qk_hd_fallback(False) to replicate q/k instead.
        if len(shape) >= 2 and _div(shape[-2], sizes[m]):
            return P(*([None] * (len(shape) - 2) + [m, None]))
        if _QK_HD_FALLBACK and _div(shape[-1], sizes[m]):
            return P(*([None] * (len(shape) - 1) + [m]))
        return P()

    def _embed_table(shape, sizes):
        # (V, d): shard the vocab rows.
        return P(m, None) if len(shape) == 2 and _div(shape[0], sizes[m]) else P()

    def _wo(shape, sizes):
        # (H, hd, d): shard heads; fall back to head_dim.
        if _div(shape[0], sizes[m]):
            return P(*([m] + [None] * (len(shape) - 1)))
        if len(shape) > 2 and _div(shape[1], sizes[m]):
            return P(*([None, m] + [None] * (len(shape) - 2)))
        return P()

    return [
        # embeddings / logits: shard vocab (dim 0 for embed table, last for head)
        (r"embed/table$", _embed_table),
        (r"lm_head/w$", _shard_last),
        # attention
        (r"attn/wq$", _heads_then_hd),       # (d, H, hd)
        (r"attn/wk$", _heads_then_hd),       # (d, KV, hd)
        (r"attn/wv$", _heads_then_hd),
        (r"attn/wo$", _wo),                  # (H, hd, d): shard H, fallback hd
        (r"attn/bq$", _heads_then_hd),
        (r"attn/bk$", _heads_then_hd),
        (r"attn/bv$", _heads_then_hd),
        # FFN
        (r"ffn/w_in$", _shard_last),          # (d, ff)
        (r"ffn/w_gate$", _shard_last),
        (r"ffn/w_out$", _shard_dim(-2)),      # (ff, d)
        # MoE: shard experts; if E doesn't divide the model axis (e.g. 16
        # experts on a 64-way axis after a mesh reshape), shard the per-
        # expert ffn dim instead so expert weights never replicate
        (r"moe/(w_in|w_gate)$", lambda s, z: (
            _shard_dim(0)(s, z) if _div(s[0], z[m]) else _shard_dim(2)(s, z))),
        (r"moe/w_out$", lambda s, z: (
            _shard_dim(0)(s, z) if _div(s[0], z[m]) else _shard_dim(1)(s, z))),
        (r"moe/router$", lambda s, z: P()),
        # SSM (mamba2): shard the inner/heads axis
        (r"ssm/in_proj$", _shard_last),       # (d, inner_total)
        (r"ssm/out_proj$", _shard_dim(-2)),   # (inner, d)
        (r"ssm/(A_log|D|dt_bias)$", lambda s, z: P(m) if _div(s[-1], z[m]) else P()),
        (r"ssm/conv_w$", _shard_last),        # (width, conv_dim)
        (r"ssm/conv_b$", _shard_last),
        (r"ssm/norm$", _shard_last),
        # RG-LRU: recurrent width sharded over model
        (r"rglru/(w_in|w_gate_lin|w_gate_in|w_gate_a)$", _shard_last),
        (r"rglru/(a_param|b_gate_in|b_gate_a)$", _shard_last),
        (r"rglru/w_y$", _shard_dim(-2)),
        (r"rglru/conv_w$", _shard_last),
        (r"rglru/conv_b$", _shard_last),
        # norms & everything else: replicate
        (r".*", lambda s, z: P()),
    ]


def sharding_specs(
    params: PyTree,
    mesh: jax.sharding.Mesh,
    rules: Optional[Sequence[Rule]] = None,
    stacked_paths: Tuple[str, ...] = ("layers/", "blocks/", "enc_layers/", "dec_layers/"),
    client_axis: Optional[Tuple[str, ...]] = None,
) -> PyTree:
    """PartitionSpec pytree for ``params`` on ``mesh``.

    * stacked layer params get their leading layer axis unsharded (specs are
      shifted right by one None).
    * ``client_axis``: if given (e.g. ``('pod','data')``), every leaf gets an
      extra *leading* client axis sharded over those mesh axes (FL client
      stacking).
    """
    rules = rules or make_sharding_rules()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if "model" not in sizes:
        sizes["model"] = 1

    def spec_for(path, leaf):
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        stacked = any(s in ps for s in stacked_paths)
        core_shape = shape
        n_lead = 0
        if client_axis:
            core_shape = core_shape[1:]
            n_lead += 1
        if stacked:
            core_shape = core_shape[1:]
            n_lead += 1
        for pat, fn in rules:
            if re.search(pat, ps):
                core = fn(core_shape, sizes)
                break
        else:
            core = P()
        lead = []
        if client_axis:
            lead.append(client_axis)
        if stacked:
            lead.append(None)
        full = list(lead) + list(core)
        # pad to rank
        while len(full) < len(shape):
            full.append(None)
        return P(*full[: len(shape)])

    return jax.tree_util.tree_map_with_path(spec_for, params)


def param_count(params: PyTree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))


def cast_tree(params: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), params)
