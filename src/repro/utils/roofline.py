"""Roofline terms from a compiled (AOT) artifact — no hardware required.

``compiled.cost_analysis()`` reports the *per-device* (post-SPMD-partition)
program, so the three terms come out per chip directly:

    compute    = HLO_FLOPs(per-dev)  / peak_FLOP/s
    memory     = HLO_bytes(per-dev)  / HBM_bw
    collective = coll_bytes(per-dev) / link_bw

Collective bytes are NOT in cost_analysis: ``collective_bytes`` parses the
optimized per-device HLO and sums the *operand* sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(two passes: result-shape symbol table, then operand resolution — modern HLO
printing omits operand type literals).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

# v5e per-chip constants
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([\w\-]+)")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_text):
        nb = _DTYPE_BYTES.get(dtype)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes summed over instructions (per-device
    HLO => per-device bytes). ``-start`` variants counted, ``-done`` not."""
    sizes: Dict[str, int] = {}
    entries = []                      # (op_base, operand_names)
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, type_text, op = m.group(1), m.group(2), m.group(3)
        sizes[name] = _shape_bytes(type_text)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            idx = line.find(op + "(")
            if idx < 0:
                continue
            depth = 0
            start = idx + len(op)
            end = start
            for j in range(start, len(line)):
                if line[j] == "(":
                    depth += 1
                elif line[j] == ")":
                    depth -= 1
                    if depth == 0:
                        end = j
                        break
            operands = _NAME_RE.findall(line[start + 1 : end])
            entries.append((base, operands))
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for base, operands in entries:
        out[base] += sum(sizes.get(n, 0) for n in operands)
    return out


@dataclass
class Roofline:
    flops: float                     # per-device HLO flops
    hbm_bytes: float                 # per-device bytes accessed
    coll_bytes: Dict[str, int]       # per-device collective operand bytes
    chips: int
    model_flops: float = 0.0         # 6·N·D useful-math estimate (global)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste probe."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": dict(self.coll_bytes), "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }


def from_compiled(compiled, chips: int, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Primary source: the trip-count-aware HLO analyzer (cost_analysis()
    counts while bodies once — see utils/hlo_analyzer.py). The raw
    cost_analysis numbers are kept alongside for cross-checking."""
    from repro.utils import hlo_analyzer

    text = hlo_text if hlo_text is not None else compiled.as_text()
    tot = hlo_analyzer.analyze(text)
    return Roofline(tot.flops, tot.bytes,
                    {k: int(v) for k, v in tot.coll_bytes.items()},
                    chips, model_flops)


def model_flops_estimate(cfg, tokens: float, mode: str = "train") -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) rule of thumb."""
    d, L, ff, V = cfg.d_model, cfg.num_layers, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim
    per_layer = 0.0
    pattern = cfg.block_pattern
    n_attn = sum(1 for b in pattern if b == "attn") / len(pattern)
    n_ssm = sum(1 for b in pattern if b == "ssm") / len(pattern)
    n_rec = sum(1 for b in pattern if b == "rec") / len(pattern)
    if n_attn:
        qkvo = d * hd * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)
        if cfg.num_experts:
            ffw = 3 * d * ff * (cfg.experts_per_token + cfg.shared_experts)
        else:
            ffw = 3 * d * ff
        per_layer += n_attn * (qkvo + ffw)
    if n_ssm:
        dims_inner = cfg.ssm_expand * d
        per_layer += n_ssm * (d * (2 * dims_inner + 2 * cfg.ssm_state
                                   + dims_inner // cfg.ssm_head_dim)
                              + dims_inner * d)
    if n_rec:
        w = cfg.rnn_width or d
        per_layer += n_rec * (3 * d * w + 2 * w * w + w * d + 3 * d * ff)
    n_active = L * per_layer + 2 * d * V  # embed+head
    if cfg.enc_layers:
        n_active += cfg.enc_layers * per_layer
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_active * tokens
