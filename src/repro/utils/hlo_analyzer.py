"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified empirically — a length-10 scan reports 1/10th of the
unrolled FLOPs), which silently undercounts every scanned layer stack /
local-step loop by its trip count. XLA *does* annotate
``backend_config={"known_trip_count":{"n":...}}`` on while ops after loop
analysis, so this module re-derives the three roofline inputs from the
optimized HLO text with loop multipliers applied:

  * flops       — 2·prod(result_dims)·prod(contracting_dims) per dot
                  (+ rough conv accounting), × enclosing trip counts
  * hbm bytes   — per instruction: operand + result bytes, skipping
                  register-level ops and fusion *internals* (a fusion's own
                  operands/result are the real HBM traffic), × trip counts
  * collectives — operand bytes per kind, × trip counts

This is a cost MODEL, not a simulator: it assumes every loop iteration
re-touches its operands (true for scanned layer stacks, where weights stream
from HBM each layer). Parsed totals are validated against cost_analysis()
on loop-free programs in tests.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# every op whose called computations the cost/collective walks descend into —
# ONE constant shared by _comp_cost and _collect_collectives so the two
# accountings always visit the same call graph
_CALLERS = ("while", "conditional", "call", "map", "reduce", "reduce-window",
            "scatter", "sort", "all-reduce", "reduce-scatter",
            "select-and-scatter", "custom-call", "fusion")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([\w\-]+)")
_CALLED = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)="
                     r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _n_elems(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(type_text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(type_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_text: str
    op: str
    line: str
    called: List[str]
    trip: int = 1


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)    # %name -> type text


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in _COLLECTIVES:
            self.coll_bytes[k] += mult * other.coll_bytes[k]


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "opt-barrier", "partition-id",
    "replica-id", "iota", "while", "conditional", "call",
}


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        # strip /*index=N*/-style comments: their '=' breaks the type regexes
        line = _COMMENT_RE.sub("", raw).strip()
        if not line:
            continue
        if line.endswith("{") and ("->" in line) and not line.startswith("%param"):
            m = _COMP_HDR.match(line[:-1].strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_text, op = m.group(1), m.group(2), m.group(3)
        called = []
        for g1, g2 in _CALLED.findall(line):
            if g1:
                called += [c.strip().lstrip("%") for c in g1.split(",")]
            elif g2:
                called.append(g2)
        ins = Instr(name, type_text, op, line, called)
        if op == "while":
            t = _TRIP.search(line)
            ins.trip = int(t.group(1)) if t else 1
        cur.instrs.append(ins)
        cur.types[name] = type_text
    return comps, entry


def _operand_names(line: str, op: str) -> List[str]:
    idx = line.find(op + "(")
    if idx < 0:
        return []
    depth = 0
    start = idx + len(op)
    end = start
    for j in range(start, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    return re.findall(r"%([\w.\-]+)", line[start + 1:end])


def _dot_flops(ins: Instr, comp: Computation) -> float:
    result_elems = 0
    for _, dims in _shapes_in(ins.type_text):
        n = 1
        for d in dims:
            n *= d
        result_elems += n
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    ops = _operand_names(ins.line, ins.op)
    contract = 1
    if m and ops:
        lhs_type = comp.types.get(ops[0], "")
        shp = _shapes_in(lhs_type)
        if shp:
            dims = shp[0][1]
            for i in (int(x) for x in m.group(1).split(",") if x):
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * result_elems * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    result_elems = 0
    for _, dims in _shapes_in(ins.type_text):
        n = 1
        for d in dims:
            n *= d
        result_elems += n
    ops = _operand_names(ins.line, ins.op)
    kernel_elems = 1
    if len(ops) >= 2:
        shp = _shapes_in(comp.types.get(ops[1], ""))
        if shp:
            for d in shp[0][1]:
                kernel_elems *= d
            out_feat = shp[0][1][-1] if shp[0][1] else 1
            kernel_elems = kernel_elems // max(out_feat, 1)
    return 2.0 * result_elems * kernel_elems


def _instr_bytes(ins: Instr, comp: Computation) -> float:
    if ins.op in _SKIP_BYTES_OPS:
        return 0.0
    total = _bytes_of(ins.type_text)
    for name in _operand_names(ins.line, ins.op):
        total += _bytes_of(comp.types.get(name, ""))
    return float(total)


def _collective_of(ins: Instr, comp: Computation) -> Optional[Tuple[str, float]]:
    """(kind, operand bytes) if this instruction is a collective — the ONE
    detection rule shared by ``_comp_cost`` totals and the per-op
    ``collectives()`` extraction, so the two accountings cannot drift.
    ``-start`` counts, its ``-done`` half does not (one transfer)."""
    base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
    if base not in _COLLECTIVES or ins.op.endswith("-done"):
        return None
    b = sum(_bytes_of(comp.types.get(n, ""))
            for n in _operand_names(ins.line, ins.op))
    return base, float(b)


def _comp_cost(comps: Dict[str, Computation], name: str,
               memo: Dict[str, CostTotals], fused: bool = False) -> CostTotals:
    key = name + ("#f" if fused else "")
    if key in memo:
        return memo[key]
    memo[key] = CostTotals()          # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return memo[key]
    tot = CostTotals()
    for ins in comp.instrs:
        if ins.op == "dot":
            tot.flops += _dot_flops(ins, comp)
        elif ins.op == "convolution":
            tot.flops += _conv_flops(ins, comp)
        coll = _collective_of(ins, comp)
        if coll is not None:
            tot.coll_bytes[coll[0]] += coll[1]
        if not fused:
            tot.bytes += _instr_bytes(ins, comp)
        if ins.op == "fusion":
            for c in ins.called:
                tot.add(_comp_cost(comps, c, memo, fused=True))
        elif ins.op in _CALLERS:       # fusion handled above (fused=True)
            for c in ins.called:
                tot.add(_comp_cost(comps, c, memo, fused=fused), mult=ins.trip)
    memo[key] = tot
    return tot


def analyze(hlo_text: str) -> CostTotals:
    """Trip-count-aware totals for the per-device module."""
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return CostTotals()
    return _comp_cost(comps, entry, {})


# ---------------------------------------------------------------------------
# per-collective extraction (wire-bytes accounting, benchmarks/bench_collectives)
# ---------------------------------------------------------------------------

_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclass
class CollectiveInstr:
    """One collective op in the optimized HLO, with its loop context.

    ``bytes`` is the per-execution operand footprint (the same accounting
    ``CostTotals.coll_bytes`` uses); ``trip`` is the product of enclosing
    ``known_trip_count`` multipliers, so ``bytes * trip`` is the per-module
    wire bill. ``op_name`` is the jax name-stack metadata — ``named_scope``
    regions (e.g. the per-client encode region) are identified by substring
    on it. ``operands`` are ``(dtype, bytes)`` pairs in operand order — the
    wire-dtype contract (``repro.analysis``) reads them to prove that what
    crosses the boundary in codec mode is the framed ``u8`` stream, not a
    float tree."""

    kind: str
    bytes: float
    trip: float
    op_name: str
    operands: Tuple[Tuple[str, float], ...] = ()

    @property
    def total_bytes(self) -> float:
        return self.bytes * self.trip

    @property
    def dtypes(self) -> Tuple[str, ...]:
        return tuple(dt for dt, _ in self.operands)


def _collect_collectives(comps: Dict[str, Computation], name: str,
                         mult: float, out: List[CollectiveInstr],
                         stack: Tuple[str, ...]) -> None:
    comp = comps.get(name)
    if comp is None or name in stack:          # break cycles defensively
        return
    stack = stack + (name,)
    for ins in comp.instrs:
        coll = _collective_of(ins, comp)
        if coll is not None:
            m = _OP_NAME_RE.search(ins.line)
            ops = tuple(
                (dt, float(_DTYPE_BYTES[dt] * _n_elems(dims)))
                for n in _operand_names(ins.line, ins.op)
                for dt, dims in _shapes_in(comp.types.get(n, "")))
            out.append(CollectiveInstr(coll[0], coll[1], mult,
                                       m.group(1) if m else "", ops))
        if ins.op in _CALLERS:
            for c in ins.called:
                _collect_collectives(comps, c, mult * ins.trip, out, stack)


def collectives(hlo_text: str) -> List[CollectiveInstr]:
    """Every collective reachable from the entry, trip-count annotated."""
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return []
    out: List[CollectiveInstr] = []
    _collect_collectives(comps, entry, 1.0, out, ())
    return out


def collective_bytes(hlo_text: str) -> float:
    """Total per-module collective operand bytes (trip counts applied)."""
    return sum(c.total_bytes for c in collectives(hlo_text))


def collectives_in_scope(hlo_text: str, scope: str) -> List[CollectiveInstr]:
    """Collectives whose name-stack metadata mentions ``scope`` — the gate
    for 'the per-client encode region contains zero collectives'."""
    return [c for c in collectives(hlo_text) if scope in c.op_name]
