"""Wire frame: a versioned fixed-layout header making every buffer
self-describing.

Every encoded uplink message is one contiguous ``uint8`` buffer::

    [ header (24 B) | section table (4 B x n_sections) | sections ... ]

The header layout (all multi-byte fields little-endian, the native order of
every platform this repo targets):

    offset  size  field
    0       2     magic  b"3W"
    2       1     version (WIRE_VERSION)
    3       1     kind id        (KIND_IDS — CompressorConfig.kind)
    4       1     dtype policy id (POLICY_IDS — 3SFC payload dtype)
    5       1     n_sections
    6       2     reserved (0)
    8       4     round   (uint32, dynamic)
    12      4     client  (uint32, dynamic)
    16      4     payload bytes (sum of section lengths)
    20      4     reserved (0)

The *layout* is static per ``(CompressorConfig, params template)`` — that is
what makes ``wire_bytes`` a static-size function usable under jit: section
lengths live in the ``FrameSpec`` (and are also written into the buffer so a
receiver without the config can still walk it). Only ``round`` and
``client`` are dynamic; they are spliced in with a bitcast, so header
construction is jit/vmap-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MAGIC = b"3W"
WIRE_VERSION = 1
HEADER_BYTES = 24


class FrameError(ValueError):
    """A buffer that is not a valid wire frame.

    Base of every typed rejection ``parse_header`` can raise — transport
    drivers catch THIS (one except clause) and map it to client dropout
    plus the retry/give-up policy (``repro.fl.engine.RetryPolicy``). A
    ``ValueError`` subclass so pre-existing callers keep working.
    """


class TruncatedFrameError(FrameError):
    """Buffer ends before the fixed header or its section table does."""


class BadMagicError(FrameError):
    """First two bytes are not the frame magic — not one of our frames."""


class BadVersionError(FrameError):
    """Unsupported wire version byte."""


class CorruptHeaderError(FrameError):
    """Header fields decode to nothing registered (kind/policy id)."""


class FrameSizeError(FrameError):
    """Internal sizes disagree: payload sum vs header, or buffer length
    vs the frame's self-description (e.g. truncated mid-payload)."""

# Stable on-the-wire ids; append only, never renumber.
KIND_IDS: Dict[str, int] = {
    "identity": 0, "topk": 1, "randk": 2, "signsgd": 3, "stc": 4,
    "threesfc": 5, "fedsynth": 6,
}
KIND_NAMES = {v: k for k, v in KIND_IDS.items()}

# Third-party codec kinds (comm.codec.register_codec) get ids in the
# extension range so they can never collide with a future built-in.
EXTENSION_KIND_BASE = 128


def _extension_id(kind: str) -> int:
    """Deterministic extension-range id from the kind NAME, so the same
    kind maps to the same on-the-wire byte in every process regardless of
    registration order (frames stay parseable across processes/restarts)."""
    import hashlib

    h = hashlib.sha256(kind.encode()).digest()
    return EXTENSION_KIND_BASE + h[0] % (256 - EXTENSION_KIND_BASE)


def register_kind_id(kind: str, kind_id: int = None) -> int:
    """Assign an on-the-wire id to a codec kind (idempotent for known ones).

    Without an explicit ``kind_id`` a name-derived extension-range id is
    used; a (rare) hash collision or an explicitly taken id is rejected —
    pass an explicit free id then. Ids must fit the 1-byte header field.
    """
    if kind in KIND_IDS:
        return KIND_IDS[kind]
    if kind_id is None:
        kind_id = _extension_id(kind)
    if not 0 <= kind_id <= 255:
        raise ValueError(f"kind id {kind_id} does not fit the 1-byte field")
    if kind_id in KIND_NAMES:
        raise ValueError(
            f"kind id {kind_id} for {kind!r} already taken by "
            f"{KIND_NAMES[kind_id]!r}; pass an explicit free kind_id")
    KIND_IDS[kind] = kind_id
    KIND_NAMES[kind_id] = kind
    return kind_id

# 3SFC payload dtype policies (see comm.codec.POLICY_DTYPES).
POLICY_IDS: Dict[str, int] = {"fp32": 0, "fp16": 1, "bf16": 2}
POLICY_NAMES = {v: k for k, v in POLICY_IDS.items()}


@dataclasses.dataclass(frozen=True)
class FrameSpec:
    """Static layout of one message: everything but round/client."""

    kind: str
    policy: str
    section_bytes: Tuple[int, ...]

    @property
    def header_bytes(self) -> int:
        return HEADER_BYTES + 4 * len(self.section_bytes)

    @property
    def payload_bytes(self) -> int:
        return int(sum(self.section_bytes))

    @property
    def nbytes(self) -> int:
        return self.header_bytes + self.payload_bytes

    @property
    def section_offsets(self) -> Tuple[int, ...]:
        """Absolute byte offset of each section inside the buffer."""
        offs, o = [], self.header_bytes
        for n in self.section_bytes:
            offs.append(o)
            o += n
        return tuple(offs)


def _static_header(spec: FrameSpec) -> np.ndarray:
    """The constant part of header + section table (round/client zeroed)."""
    h = np.zeros(spec.header_bytes, np.uint8)
    h[0:2] = np.frombuffer(MAGIC, np.uint8)
    h[2] = WIRE_VERSION
    h[3] = KIND_IDS[spec.kind]
    h[4] = POLICY_IDS[spec.policy]
    h[5] = len(spec.section_bytes)
    h[16:20] = np.frombuffer(
        np.uint32(spec.payload_bytes).tobytes(), np.uint8)
    table = np.asarray(spec.section_bytes, np.uint32)
    h[HEADER_BYTES:] = np.frombuffer(table.tobytes(), np.uint8)
    return h


def _u32_bytes(x) -> jax.Array:
    """uint32 scalar -> 4 uint8 (native/little-endian), jit-safe."""
    return jax.lax.bitcast_convert_type(
        jnp.asarray(x, jnp.uint32).reshape(()), jnp.uint8).reshape(4)


def encode_header(spec: FrameSpec, round_idx=0, client_idx=0) -> jax.Array:
    """Full header + section table as a uint8 vector (jit/vmap-safe)."""
    h = jnp.asarray(_static_header(spec))
    h = jax.lax.dynamic_update_slice(h, _u32_bytes(round_idx), (8,))
    return jax.lax.dynamic_update_slice(h, _u32_bytes(client_idx), (12,))


def parse_header(buf) -> Dict:
    """Host-side: validate and read back a buffer's self-description.

    Every rejection is a typed ``FrameError`` subclass — never a cryptic
    unpack/KeyError — so a transport driver can catch one exception class
    and treat the sender as dropped (fuzz-tested in tests/test_faults.py).
    """
    b = np.asarray(buf, np.uint8)
    if b.ndim != 1 or b.size < HEADER_BYTES:
        raise TruncatedFrameError(f"frame too short: {b.shape}")
    if bytes(b[0:2].tobytes()) != MAGIC:
        raise BadMagicError(f"bad magic {b[:2]!r}")
    if int(b[2]) != WIRE_VERSION:
        raise BadVersionError(f"unsupported wire version {int(b[2])}")
    kind_id, policy_id = int(b[3]), int(b[4])
    if kind_id not in KIND_NAMES:
        raise CorruptHeaderError(f"unknown kind id {kind_id}")
    if policy_id not in POLICY_NAMES:
        raise CorruptHeaderError(f"unknown dtype policy id {policy_id}")
    n_sections = int(b[5])
    header_bytes = HEADER_BYTES + 4 * n_sections
    if b.size < header_bytes:
        raise TruncatedFrameError("frame shorter than its section table")
    u32 = lambda o: int(np.frombuffer(b[o:o + 4].tobytes(), np.uint32)[0])
    sections = tuple(
        u32(HEADER_BYTES + 4 * i) for i in range(n_sections))
    out = {
        "kind": KIND_NAMES[kind_id],
        "policy": POLICY_NAMES[policy_id],
        "round": u32(8),
        "client": u32(12),
        "payload_bytes": u32(16),
        "section_bytes": sections,
        "header_bytes": header_bytes,
        "nbytes": header_bytes + sum(sections),
    }
    if out["payload_bytes"] != sum(sections):
        raise FrameSizeError(
            f"payload size {out['payload_bytes']} != section sum {sum(sections)}")
    if b.size != out["nbytes"]:
        raise FrameSizeError(
            f"buffer is {b.size} B, frame says {out['nbytes']} B")
    return out
