"""Socket transport: framed rounds between a server and N worker processes.

This is the ``Channel`` interface over real sockets — the same
``comm/frame.py`` frames that ``InProcessChannel`` hands between two Python
halves here cross a TCP connection between a server process and N client
worker processes (``repro.launch.worker``). Workers are spawned locally by
``spawn_local_workers``, but every connection is address-based: pointing a
worker at another host's ``host:port`` is a config change, not a code
change.

Message protocol
----------------
Every message is length-prefixed::

    [ u32 LE body length | u8 type | body ... ]

Codec frames travel as ``MSG_FRAME`` bodies unchanged — the frame's own
header (``comm.frame``) still carries kind/round/client, so the transport
layer never interprets payloads. Control messages (HELLO, ROUND, ACK,
RESEND, heartbeats, metrics, EF dumps) are protocol overhead, billed into
``overhead_up``/``overhead_down`` counters; only data-frame bytes land in
the ``LinkStats`` buckets, so "uplink bytes per round" means exactly what
it means on the in-process channel: serialized codec frames
(``BENCH_transport`` gates the two equal).

Round lifecycle (server side, driven by ``repro.fl.engine.LiveRoundLoop``)
--------------------------------------------------------------------------
1. ``broadcast_round``: ROUND(round, participate flag, params frame) to
   every live worker.
2. ``collect``: drain uplink frames under a per-round deadline. Each
   expected client has a receive timer with exponential backoff
   (``RetryPolicy.timeout(attempt)``); a timeout or a corrupt frame
   (typed ``FrameError``, wrong client id) triggers a RESEND, up to
   ``max_retries`` times — re-sent frames are billed again (retransmission
   is not free). A client whose retries are exhausted, whose process died
   (EOF on its connection), or who stayed silent past the liveness window
   is marked undelivered — exactly the ``delivered=False`` branch of the
   PR 6 fault model. Stale frames (header round != current) are discarded.
3. ``send_acks``: ACK(round, delivered bit) tells each worker which EF
   branch to commit (``e' = u - r`` on delivery, ``e' = u`` on drop), so
   EF residual-mass conservation holds verbatim over the wire.

Liveness: workers heartbeat from a daemon thread even while computing, so
a *slow* worker (straggler) is alive-but-late (timeout/backoff path) while
a *dead* one (killed process) is EOF — detected immediately, excluded,
never hung on. A silent-but-connected worker (e.g. SIGSTOP) trips the
``liveness_timeout_s`` window instead.

Elastic membership (JOIN / REJOIN)
----------------------------------
The worker set is no longer frozen at HELLO time. After each EF commit a
worker pushes its residual, tagged with the committed round (MSG_EF_PUSH),
and the server banks the latest push per client (``ef_bank``) — so the
server always holds every client's last-committed EF slice, which is the
ONLY state a worker process owns. A worker that connects after SETUP was
broadcast (a fresh joiner, or one whose process was killed and restarted)
receives SETUP + MSG_EF_SYNC(its banked slice) back-to-back under one send
lock, rebuilds its computation, installs the residual, and re-enters the
round set at the next broadcast. Its missed rounds were ordinary
``delivered=False`` rounds on the server (dead workers are excluded, EF
frozen in the bank), so residual-mass conservation holds bitwise across
the death — the rejoin gate of ``benchmarks/bench_recovery``. The same
bank, snapshotted into full-state checkpoints (``seed_ef_bank`` on
restore), is what makes a *server* restart bitwise-resumable: re-synced
workers restart from exactly the residual the checkpointed round left
them with.
"""
from __future__ import annotations

import json
import os
import queue
import socket
import struct
import subprocess
import sys
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm.channel import Channel
from repro.comm.frame import FrameError, parse_header
from repro.obs import get_registry, get_tracer

# message types (u8 on the wire; append only, never renumber)
MSG_HELLO = 0        # worker -> server: u32 client id
MSG_SETUP = 1        # server -> worker: JSON setup blob
MSG_ROUND = 2        # server -> worker: u32 round | u8 flags | params frame
MSG_FRAME = 3        # worker -> server: one codec frame
MSG_HEARTBEAT = 4    # worker -> server: liveness tick; body is empty (legacy)
#                      or u64 LE worker monotonic_ns (clock-offset estimation)
MSG_RESEND = 5       # server -> worker: u32 round — re-send that frame
MSG_ACK = 6          # server -> worker: u32 round | u8 delivered
MSG_EF_REQ = 7       # server -> worker: dump your EF residual (empty body)
MSG_EF_DUMP = 8      # worker -> server: raw f32 EF leaf stream
MSG_METRIC = 9       # worker -> server: u32 round | f32 local loss, then
#                      optionally a JSON span batch (see repro.obs.trace)
MSG_STOP = 10        # server -> worker: shut down (empty body)
MSG_EF_PUSH = 11     # worker -> server: u32 committed round | f32 EF stream
MSG_EF_SYNC = 12     # server -> worker: u32 banked round | f32 EF stream

FLAG_PARTICIPATE = 1  # ROUND flags bit 0: train this round (vs. sit out)

_HDR = struct.Struct("<IB")          # body length, message type
MAX_MSG = 1 << 30                    # sanity bound on any single message


class ProtocolError(ConnectionError):
    """A peer that is not speaking this protocol (oversized length prefix,
    malformed control message). A ``ConnectionError`` subclass so transport
    loops handle 'broken peer' and 'dead peer' with one except clause."""


# ---------------------------------------------------------------------------
# framing primitives
# ---------------------------------------------------------------------------


def send_msg(sock: socket.socket, mtype: int, body: bytes = b"") -> int:
    """Write one length-prefixed message; returns total bytes written."""
    if not isinstance(body, (bytes, bytearray, memoryview)):
        body = np.asarray(body, np.uint8).tobytes()
    if len(body) > MAX_MSG:
        raise ProtocolError(f"message body {len(body)} B exceeds {MAX_MSG}")
    msg = _HDR.pack(len(body), mtype) + bytes(body)
    sock.sendall(msg)
    return len(msg)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ``ConnectionError`` — a peer that
    closes mid-message (killed worker) surfaces here, including a partial
    read at the length-prefix boundary itself."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError(
                f"peer closed after {len(buf)}/{n} bytes of a message")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket) -> Tuple[int, bytes]:
    """Read one message -> (type, body). Typed errors only: short reads are
    ``ConnectionError``, an insane length prefix is ``ProtocolError``."""
    length, mtype = _HDR.unpack(recv_exact(sock, _HDR.size))
    if length > MAX_MSG:
        raise ProtocolError(f"length prefix {length} exceeds {MAX_MSG}")
    return mtype, recv_exact(sock, length)


# ---------------------------------------------------------------------------
# server half
# ---------------------------------------------------------------------------


class SocketServer(Channel):
    """Accepts N workers and runs framed rounds with deadline / backoff /
    liveness semantics (module docstring). ``rx_filter(cid, round, buf) ->
    buf | None`` is the deterministic fault-injection seam the transport
    bench and tests use: it sees every *billed* uplink frame and may
    corrupt it or eat it (None), exactly like a lossy wire."""

    def __init__(self, num_clients: int, *,
                 address: Tuple[str, int] = ("127.0.0.1", 0),
                 heartbeat_s: float = 0.5, liveness_timeout_s: float = 5.0,
                 rx_filter: Optional[Callable] = None):
        super().__init__()
        self.num_clients = num_clients
        self.heartbeat_s = heartbeat_s
        self.liveness_timeout_s = liveness_timeout_s
        self.rx_filter = rx_filter
        # overhead_up/overhead_down (control-message bytes, never LinkStats)
        # live on the Channel base so they ride in ledger() with the rest
        self._lsock = socket.create_server(address)
        self._conns: Dict[int, socket.socket] = {}
        self._send_locks: Dict[int, threading.Lock] = {}
        self._last_seen: Dict[int, float] = {}
        self._dead: set = set()
        self._rx: "queue.Queue" = queue.Queue()
        self._ef: Dict[int, bytes] = {}
        self._ef_evt: Dict[int, threading.Event] = {}
        # cid -> (last committed round, flat f32 EF stream): the newest
        # MSG_EF_PUSH per client — the recovery source for worker rejoin
        # and the slice full-state checkpoints carry
        self._ef_bank: Dict[int, Tuple[int, bytes]] = {}
        self._setup: Optional[bytes] = None
        self._metrics: Dict[Tuple[int, int], float] = {}
        # spans piggybacked on MSG_METRIC, still on each worker's own clock
        self._worker_spans: Dict[int, List[dict]] = {}
        # cid -> min(server_mono_ns_at_recv - worker_heartbeat_ts): the
        # tightest heartbeat bounds offset + one-way latency from above,
        # so min over samples ≈ the clock offset (latency inflates, never
        # deflates, the estimate)
        self._clock_offset_ns: Dict[int, int] = {}
        self._hb_prev: Dict[int, float] = {}
        self._meters = get_registry()
        self._meters.register_source("transport.ledger", self.ledger)
        self._lock = threading.Lock()
        self._bank_cv = threading.Condition(self._lock)
        self._stopping = False
        self._threads: List[threading.Thread] = []
        t = threading.Thread(target=self._accept_loop, daemon=True)
        t.start()
        self._threads.append(t)

    @property
    def address(self) -> Tuple[str, int]:
        return self._lsock.getsockname()[:2]

    # -- liveness ----------------------------------------------------------
    def _is_dead(self, cid: int) -> bool:
        with self._lock:
            if cid in self._dead:
                return True
            seen = self._last_seen.get(cid)
        if seen is None:
            return True              # never connected
        return time.monotonic() - seen > self.liveness_timeout_s

    def _mark_dead(self, cid: int):
        with self._lock:
            was_dead = cid in self._dead
            self._dead.add(cid)
        if not was_dead:
            self._meters.counter("transport.liveness.dead").inc()
            get_tracer().event("liveness.dead", client=cid)

    def live_workers(self) -> List[int]:
        """Clients currently connected, not EOF'd, and heartbeating within
        the liveness window."""
        return [cid for cid in sorted(self._conns)
                if not self._is_dead(cid)]

    # -- connection plumbing ----------------------------------------------
    def _accept_loop(self):
        while not self._stopping:
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return               # listener closed by stop()
            try:
                mtype, body = recv_msg(conn)
                if mtype != MSG_HELLO or len(body) != 4:
                    raise ProtocolError("expected HELLO")
                cid = struct.unpack("<I", body)[0]
            except (ConnectionError, OSError):
                conn.close()
                continue
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._recv_loop, args=(cid, conn),
                                 daemon=True)
            with self._lock:
                self.overhead_up += _HDR.size + 4
                self._conns[cid] = conn
                self._send_locks[cid] = threading.Lock()
                self._last_seen[cid] = time.monotonic()
                self._dead.discard(cid)
                self._threads.append(t)
            t.start()
            if self._setup is not None:
                # mid-run joiner (fresh, or a killed worker's restarted
                # process): hand it the session state it missed — SETUP plus
                # its banked EF slice — and it re-enters at the next round
                self._send_join_state(cid)

    def _recv_loop(self, cid: int, conn: socket.socket):
        try:
            while True:
                mtype, body = recv_msg(conn)
                with self._lock:
                    self._last_seen[cid] = time.monotonic()
                if mtype == MSG_HEARTBEAT:
                    with self._lock:
                        self.overhead_up += _HDR.size + len(body)
                    now_mono = time.monotonic()
                    if len(body) >= 8:
                        # timestamped heartbeat: tighten the clock-offset
                        # estimate (min over samples, see _clock_offset_ns)
                        (wts,) = struct.unpack_from("<Q", body)
                        off = time.monotonic_ns() - wts
                        with self._lock:
                            prev = self._clock_offset_ns.get(cid)
                            if prev is None or off < prev:
                                self._clock_offset_ns[cid] = off
                    prev_beat = self._hb_prev.get(cid)
                    self._hb_prev[cid] = now_mono
                    if prev_beat is not None:
                        self._meters.histogram(
                            "transport.heartbeat_interval_s").observe(
                                now_mono - prev_beat)
                elif mtype == MSG_EF_DUMP:
                    with self._lock:
                        self.overhead_up += _HDR.size + len(body)
                        self._ef[cid] = body
                        evt = self._ef_evt.get(cid)
                    if evt is not None:
                        evt.set()
                elif mtype == MSG_EF_PUSH and len(body) >= 4:
                    with self._lock:
                        self.overhead_up += _HDR.size + len(body)
                    (rnd,) = struct.unpack_from("<I", body)
                    with self._bank_cv:
                        self._ef_bank[cid] = (rnd, body[4:])
                        self._bank_cv.notify_all()
                elif mtype == MSG_METRIC and len(body) >= 8:
                    with self._lock:
                        self.overhead_up += _HDR.size + len(body)
                    rnd, loss = struct.unpack_from("<If", body)
                    spans: List[dict] = []
                    if len(body) > 8:
                        # piggybacked span batch (worker-local clock); a
                        # malformed batch loses spans, never the metric
                        try:
                            spans = json.loads(body[8:].decode("utf-8"))
                        except (UnicodeDecodeError, ValueError):
                            spans = []
                    with self._lock:
                        self._metrics[(rnd, cid)] = loss
                        if spans:
                            self._worker_spans.setdefault(
                                cid, []).extend(spans)
                elif mtype == MSG_FRAME:
                    with self._lock:
                        self.overhead_up += _HDR.size
                    self._rx.put((cid, body))
                else:
                    raise ProtocolError(
                        f"unexpected message type {mtype} from client {cid}")
        except (ConnectionError, OSError):
            pass
        finally:
            self._mark_dead(cid)
            self._rx.put((cid, None))        # wake collect(): peer is gone
            try:
                conn.close()
            except OSError:
                pass

    def _send(self, cid: int, mtype: int, body: bytes = b"") -> int:
        conn = self._conns.get(cid)
        if conn is None:
            raise ConnectionError(f"client {cid} never connected")
        with self._send_locks[cid]:
            return send_msg(conn, mtype, body)

    def _send_or_bury(self, cid: int, mtype: int, body: bytes = b"") -> int:
        """Send, mapping any transport failure onto worker death (the
        graceful-degradation contract: a broken pipe is a dead peer, not an
        exception up the round loop). Returns bytes written (0 if dead)."""
        try:
            return self._send(cid, mtype, body)
        except (ConnectionError, OSError):
            self._mark_dead(cid)
            return 0

    # -- session setup -----------------------------------------------------
    def wait_ready(self, timeout: float = 60.0) -> None:
        """Block until all N workers have said HELLO (or raise)."""
        end = time.monotonic() + timeout
        while time.monotonic() < end:
            with self._lock:
                if len(self._conns) >= self.num_clients:
                    return
            time.sleep(0.01)
        with self._lock:
            got = sorted(self._conns)
        raise TimeoutError(
            f"only {len(got)}/{self.num_clients} workers connected within "
            f"{timeout}s (have: {got})")

    def send_setup(self, setup: Dict) -> None:
        """Broadcast the JSON setup blob every worker rebuilds its model /
        data / strategy from (see ``repro.launch.worker``). The blob is
        retained so late joiners get it too (``_send_join_state``); any
        pre-seeded EF bank entry (a resumed server) rides along."""
        with self._lock:
            self._setup = json.dumps(setup).encode("utf-8")
        for cid in sorted(self._conns):
            self._send_join_state(cid)

    def _send_join_state(self, cid: int) -> None:
        """SETUP + (banked) EF_SYNC to one worker, back-to-back under one
        send lock — a concurrently-broadcast ROUND can never interleave
        between them, so the worker always installs its residual BEFORE it
        computes anything."""
        conn = self._conns.get(cid)
        if conn is None or self._setup is None:
            return
        msgs = [(MSG_SETUP, self._setup)]
        with self._lock:
            bank = self._ef_bank.get(cid)
        if bank is not None:
            rnd, stream = bank
            msgs.append((MSG_EF_SYNC, struct.pack("<I", rnd) + stream))
        try:
            with self._send_locks[cid]:
                for mtype, body in msgs:
                    n = send_msg(conn, mtype, body)
                    with self._lock:
                        self.overhead_down += n
        except (ConnectionError, OSError):
            self._mark_dead(cid)

    # -- EF bank (elastic membership / recovery) ---------------------------
    def ef_bank(self) -> Dict[int, Tuple[int, np.ndarray]]:
        """Every client's last pushed EF slice: cid -> (committed round,
        flat f32 stream) — what full-state checkpoints carry."""
        with self._lock:
            items = dict(self._ef_bank)
        return {cid: (rnd, np.frombuffer(b, np.float32).copy())
                for cid, (rnd, b) in items.items()}

    def seed_ef_bank(self, bank: Dict[int, Tuple[int, np.ndarray]]) -> None:
        """Pre-load the bank (a resumed server, from its checkpoint) so
        every worker — they all rejoin a restarted server — is re-synced to
        exactly the residual the checkpointed round left it with."""
        with self._bank_cv:
            for cid, (rnd, arr) in bank.items():
                self._ef_bank[int(cid)] = (
                    int(rnd), np.asarray(arr, np.float32).tobytes())
            self._bank_cv.notify_all()

    def wait_ef_bank(self, round_idx: int, cids, timeout: float = 30.0) -> bool:
        """Block until every listed client's banked EF is tagged with a
        commit >= ``round_idx`` (False on timeout). The checkpoint hook
        calls this before snapshotting so the banked slices are exactly the
        post-round residuals — the settle point that makes a resumed run
        bitwise."""
        end = time.monotonic() + timeout
        with self._bank_cv:
            while True:
                if all(self._ef_bank.get(c, (-1, b""))[0] >= round_idx
                       for c in cids):
                    return True
                left = end - time.monotonic()
                if left <= 0:
                    return False
                self._bank_cv.wait(left)

    # -- the round ---------------------------------------------------------
    def broadcast_round(self, round_idx: int, down_frame,
                        participate=None) -> np.ndarray:
        """ROUND to every live worker: the framed params broadcast plus the
        per-client participate flag. Params-frame bytes are downlink data
        (``LinkStats``); the 5-byte round prefix is overhead."""
        b = np.asarray(down_frame, np.uint8).tobytes()
        if participate is None:
            participate = np.ones((self.num_clients,), bool)
        participate = np.asarray(participate, bool)
        for cid in range(self.num_clients):
            if cid not in self._conns or self._is_dead(cid):
                continue
            flags = FLAG_PARTICIPATE if participate[cid] else 0
            n = self._send_or_bury(
                cid, MSG_ROUND, struct.pack("<IB", round_idx, flags) + b)
            if n:
                self.downlink._record(len(b))
                with self._lock:
                    self.overhead_down += n - len(b)
                get_tracer().event("tx_frame", round=round_idx, client=cid,
                                   bytes=len(b))
        return participate

    def collect(self, round_idx: int, expected, *, policy,
                deadline_s: float):
        """Drain this round's uplink under the deadline; returns the same
        ``DeliveryReport`` shape as ``RoundEngine.deliver`` so the live
        round loop and the in-process oracle consume one structure.

        ``expected`` is the (N,) bool mask of clients a frame is owed from
        (participating AND live at broadcast time). Timer/corruption/death
        handling per the module docstring; every received frame is billed
        on receipt, before filtering or validation — the bytes crossed the
        wire even when they turn out to be garbage.
        """
        from repro.fl.engine import DeliveryReport  # lazy: fl sits above comm

        N = self.num_clients
        expected = np.asarray(expected, bool)
        frames: List[Optional[np.ndarray]] = [None] * N
        delivered = np.zeros((N,), bool)
        retries = 0
        start = time.monotonic()
        deadline = start + deadline_s
        # cid -> [attempt, due]; resolved clients leave the dict
        pending = {i: [0, start + policy.timeout(0)]
                   for i in range(N) if expected[i] and not self._is_dead(i)}

        tracer = get_tracer()

        def bump(cid: int, now: float):
            nonlocal retries
            attempt = pending[cid][0]
            if attempt >= policy.max_retries:
                del pending[cid]                     # give up: undelivered
                self._meters.counter("transport.give_up").inc()
                tracer.event("retry.give_up", round=round_idx, client=cid,
                             attempts=attempt)
                return
            retries += 1
            self._meters.counter("transport.resend").inc()
            tracer.event("retry.resend", round=round_idx, client=cid,
                         attempt=attempt + 1)
            self._send_or_bury(cid, MSG_RESEND, struct.pack("<I", round_idx))
            with self._lock:
                self.overhead_down += _HDR.size + 4
            pending[cid] = [attempt + 1, now + policy.timeout(attempt + 1)]

        while pending:
            now = time.monotonic()
            if now >= deadline:
                break
            for cid in [c for c in pending if self._is_dead(c)]:
                del pending[cid]                     # dead: never hang on it
            for cid in [c for c, (_, d) in pending.items() if d <= now]:
                bump(cid, now)                       # timer expired: retry
            if not pending:
                break
            due = min(d for _, d in pending.values())
            wait = max(min(due, deadline) - now, 0.001)
            try:
                cid, body = self._rx.get(timeout=wait)
            except queue.Empty:
                continue
            now = time.monotonic()
            if body is None:
                continue                             # death sentinel
            # bill on receipt, then trace with the final outcome tag: every
            # uplink._record has exactly one rx_frame event carrying the
            # billed byte count, so trace sums reconcile with the ledger
            self.uplink._record(len(body))
            nbytes = len(body)
            buf = np.frombuffer(body, np.uint8)
            if self.rx_filter is not None:
                buf = self.rx_filter(cid, round_idx, buf)
                if buf is None:
                    tracer.event("rx_frame", round=round_idx, client=cid,
                                 bytes=nbytes, outcome="filtered")
                    continue                         # eaten: timer will fire
            ok, stale = False, False
            try:
                hdr = parse_header(buf)
                stale = hdr["round"] != round_idx
                ok = not stale and hdr["client"] == cid
            except FrameError:
                ok = False
            if stale or cid not in pending:
                tracer.event("rx_frame", round=round_idx, client=cid,
                             bytes=nbytes,
                             outcome="stale" if stale else "late")
                continue                 # late/duplicate: billed, discarded
            if ok:
                frames[cid] = np.array(buf, np.uint8)
                delivered[cid] = True
                del pending[cid]
                tracer.event("rx_frame", round=round_idx, client=cid,
                             bytes=nbytes, outcome="ok")
            else:
                tracer.event("rx_frame", round=round_idx, client=cid,
                             bytes=nbytes, outcome="corrupt")
                bump(cid, now)                       # corrupt: retry now
        return DeliveryReport(frames, delivered, retries)

    def send_acks(self, round_idx: int, delivered) -> None:
        """ACK each live worker its delivered verdict — the signal that
        commits the worker's EF branch (``e' = u - r`` vs ``e' = u``)."""
        delivered = np.asarray(delivered, bool)
        for cid in range(self.num_clients):
            if cid not in self._conns or self._is_dead(cid):
                continue
            n = self._send_or_bury(
                cid, MSG_ACK,
                struct.pack("<IB", round_idx, int(delivered[cid])))
            with self._lock:
                self.overhead_down += n

    # -- diagnostics -------------------------------------------------------
    def pop_metrics(self, round_idx: int) -> Dict[int, float]:
        with self._lock:
            keys = [k for k in self._metrics if k[0] == round_idx]
            return {cid: self._metrics.pop((rnd, cid)) for rnd, cid in keys}

    def clock_offsets(self) -> Dict[str, int]:
        """Per-worker ``server_clock - worker_clock`` estimates (ns), keyed
        by the worker's trace proc label — feed :func:`~repro.obs.merge_traces`
        together with :meth:`pop_worker_spans`."""
        with self._lock:
            return {f"client-{cid}": off
                    for cid, off in self._clock_offset_ns.items()}

    def pop_worker_spans(self) -> Dict[str, List[dict]]:
        """Drain the spans workers piggybacked on MSG_METRIC, keyed by
        trace proc label, still on each worker's own clock."""
        with self._lock:
            out = {f"client-{cid}": spans
                   for cid, spans in self._worker_spans.items()}
            self._worker_spans = {}
        return out

    def request_ef(self, cid: int, timeout: float = 30.0) -> Optional[np.ndarray]:
        """Ask one worker for its committed EF residual (flat f32 leaf
        stream) — the observability hook the conservation gates read. None
        for a dead/silent worker."""
        if cid not in self._conns or self._is_dead(cid):
            return None
        evt = threading.Event()
        with self._lock:
            self._ef.pop(cid, None)
            self._ef_evt[cid] = evt
        n = self._send_or_bury(cid, MSG_EF_REQ)
        with self._lock:
            self.overhead_down += n
        if not evt.wait(timeout):
            return None
        with self._lock:
            body = self._ef.pop(cid, None)
            self._ef_evt.pop(cid, None)
        if body is None:
            return None
        return np.frombuffer(body, np.float32).copy()

    def stop(self) -> None:
        """STOP every worker and tear the sockets down (idempotent)."""
        with self._lock:
            if self._stopping:
                return
            self._stopping = True
        self._meters.unregister_source("transport.ledger")
        for cid in list(self._conns):
            self._send_or_bury(cid, MSG_STOP)
        try:
            self._lsock.close()
        except OSError:
            pass
        for conn in list(self._conns.values()):
            try:
                conn.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


# ---------------------------------------------------------------------------
# worker half (the socket side; the FL compute lives in repro.launch.worker)
# ---------------------------------------------------------------------------


class ServerLink:
    """A worker's connection to the server: HELLO handshake, a heartbeat
    daemon that ticks even while the main thread computes (so a busy or
    sleeping worker stays *alive*, just late), and lock-serialized sends."""

    def __init__(self, sock: socket.socket, client_id: int):
        self.sock = sock
        self.client_id = client_id
        self._send_lock = threading.Lock()
        self._closed = False

    @classmethod
    def connect(cls, address: Tuple[str, int], client_id: int, *,
                timeout: float = 30.0) -> "ServerLink":
        end = time.monotonic() + timeout
        last: Exception = None
        while time.monotonic() < end:
            try:
                sock = socket.create_connection(address, timeout=timeout)
                sock.settimeout(None)
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                link = cls(sock, client_id)
                link.send(MSG_HELLO, struct.pack("<I", client_id))
                return link
            except OSError as e:
                last = e
                time.sleep(0.1)
        raise ConnectionError(
            f"could not reach server at {address}: {last}")

    def start_heartbeat(self, heartbeat_s: float) -> None:
        def beat():
            while not self._closed:
                time.sleep(heartbeat_s)
                try:
                    # timestamped tick: the server turns these into a
                    # clock-offset estimate for cross-process trace merge
                    self.send(MSG_HEARTBEAT,
                              struct.pack("<Q", time.monotonic_ns()))
                except (ConnectionError, OSError):
                    return
        threading.Thread(target=beat, daemon=True).start()

    def send(self, mtype: int, body: bytes = b"") -> None:
        with self._send_lock:
            send_msg(self.sock, mtype, body)

    def recv(self) -> Tuple[int, bytes]:
        return recv_msg(self.sock)

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.close()
        except OSError:
            pass


def spawn_local_workers(address: Tuple[str, int],
                        client_ids: Sequence[int], *,
                        env: Optional[Dict[str, str]] = None,
                        ) -> List[subprocess.Popen]:
    """Spawn one ``repro.launch.worker`` process per client id, pointed at
    ``address``. Local spawning is a convenience — the workers themselves
    only know a ``host:port``, so running them on other hosts is a config
    change. The child env gets ``src/`` on PYTHONPATH (derived from this
    package's location) and defaults to the CPU backend for determinism."""
    host, port = address
    src_root = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    e = dict(os.environ if env is None else env)
    old = e.get("PYTHONPATH")
    e["PYTHONPATH"] = src_root + ((os.pathsep + old) if old else "")
    e.setdefault("JAX_PLATFORMS", "cpu")
    return [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.worker",
         "--connect", f"{host}:{port}", "--client-id", str(cid)], env=e)
        for cid in client_ids]
