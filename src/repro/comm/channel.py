"""In-process channel: only encoded buffers move between the two halves.

``InProcessChannel`` is the transport stand-in for the codec subsystem: the
client half may hand it nothing but framed ``uint8`` buffers (anything else
is a type error — that is the point: no float trees on the wire), and the
server half receives host copies, with per-round uplink/downlink byte
counters. It is deliberately host-level — the jitted round keeps buffers on
device; this channel is how the *driver* layer (benchmarks, future async /
multi-process transports on the ROADMAP) moves and bills them.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class LinkStats:
    """Byte counters for one direction of the link."""

    total_bytes: int = 0
    messages: int = 0
    per_round: List[int] = dataclasses.field(default_factory=list)

    def _record(self, nbytes: int):
        self.total_bytes += nbytes
        self.messages += 1
        if not self.per_round:
            self.per_round.append(0)
        self.per_round[-1] += nbytes

    def _new_round(self):
        self.per_round.append(0)


class InProcessChannel:
    """Moves encoded uint8 buffers client->server (uplink) and
    server->client (downlink), billing every byte."""

    def __init__(self):
        self.uplink = LinkStats()
        self.downlink = LinkStats()
        self._round = 0

    @property
    def round(self) -> int:
        return self._round

    def begin_round(self) -> int:
        """Open a new per-round accounting bucket; returns its index."""
        self.uplink._new_round()
        self.downlink._new_round()
        self._round = len(self.uplink.per_round) - 1
        return self._round

    @staticmethod
    def _as_wire(buf) -> np.ndarray:
        b = np.asarray(buf)
        if b.dtype != np.uint8 or b.ndim != 1:
            raise TypeError(
                f"channel carries 1-D uint8 frames only, got "
                f"{b.dtype}{list(b.shape)} — encode first (repro.comm.codec)")
        return b.copy()                  # the wire: a detached host copy

    def send_up(self, buf) -> np.ndarray:
        """Client -> server. Returns the host copy the server receives."""
        b = self._as_wire(buf)
        self.uplink._record(b.nbytes)
        return b

    def send_down(self, buf) -> np.ndarray:
        """Server -> client (e.g. a framed model broadcast)."""
        b = self._as_wire(buf)
        self.downlink._record(b.nbytes)
        return b
