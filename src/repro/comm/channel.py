"""Channel interface + in-process transport: only encoded buffers move.

``Channel`` is the interface every transport implements — per-direction
``LinkStats`` byte accounting opened in explicit per-round buckets by
``begin_round()``. Two transports live behind it today:
``InProcessChannel`` (below, the host-side stand-in the codec benchmarks
bill against) and ``repro.comm.transport.SocketServer`` (a real
length-prefixed socket transport between processes). Both bill *data*
frames into ``LinkStats`` only, so "bytes per round" means the same thing
— serialized codec frames — regardless of what carries them.

``InProcessChannel``'s client half may hand it nothing but framed
``uint8`` buffers (anything else is a type error — that is the point: no
float trees on the wire), and the server half receives host copies. It is
deliberately host-level — the jitted round keeps buffers on device; this
channel is how the *driver* layer (benchmarks, the live round loop) moves
and bills them.

``FaultyChannel`` wraps any channel with seeded transport-fault injection
(frame drop / truncation / bit flips) for the fault harness: corrupted
frames reach the receiver, whose ``frame.parse_header`` rejects them with a
typed ``FrameError`` that the driver maps to dropout via the retry policy
(``repro.fl.engine.RoundEngine.deliver``). Faults are attributed per round
(``dropped_per_round``/``corrupted_per_round``, LinkStats-style) on top of
the running totals.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.obs import get_registry


@dataclasses.dataclass
class LinkStats:
    """Byte counters for one direction of the link."""

    total_bytes: int = 0
    messages: int = 0
    per_round: List[int] = dataclasses.field(default_factory=list)

    def _record(self, nbytes: int):
        # a send must land in an explicitly opened per-round bucket —
        # an implicit round-0 bucket would silently skew per_round
        # accounting (begin_round() opens one)
        if not self.per_round:
            raise RuntimeError(
                "send before begin_round(): open a per-round accounting "
                "bucket first")
        self.total_bytes += nbytes
        self.messages += 1
        self.per_round[-1] += nbytes

    def _new_round(self):
        self.per_round.append(0)

    def snapshot(self) -> dict:
        """JSON-serializable ledger state (what a checkpoint carries)."""
        return {"total_bytes": int(self.total_bytes),
                "messages": int(self.messages),
                "per_round": [int(b) for b in self.per_round]}

    def restore(self, d: dict) -> None:
        """Reinstate a ``snapshot()``: a resumed run keeps billing into the
        same buckets, so round numbering (``begin_round`` indexes off the
        bucket count) continues from where the checkpoint left off."""
        self.total_bytes = int(d["total_bytes"])
        self.messages = int(d["messages"])
        self.per_round = [int(b) for b in d["per_round"]]


class Channel:
    """Transport interface: uplink/downlink byte accounting in per-round
    buckets. Subclasses move the bytes however they like (in-process hand-
    off, sockets, ...) but bill every data frame through ``LinkStats`` so
    per-round byte numbers are transport-independent."""

    def __init__(self):
        self.uplink = LinkStats()
        self.downlink = LinkStats()
        # Control-plane bytes (headers, acks, heartbeats, metric frames):
        # billed here, NOT into LinkStats — "bytes per round" stays pure
        # data-frame bytes, but the overhead is still part of the ledger
        # so reports can surface it instead of dropping it.
        self.overhead_up = 0
        self.overhead_down = 0
        self._round = 0

    @property
    def round(self) -> int:
        return self._round

    def begin_round(self) -> int:
        """Open a new per-round accounting bucket; returns its index."""
        self.uplink._new_round()
        self.downlink._new_round()
        self._round = len(self.uplink.per_round) - 1
        return self._round

    def ledger(self) -> dict:
        """Both directions' ``LinkStats.snapshot()`` — the byte ledger a
        full-state checkpoint carries."""
        return {"uplink": self.uplink.snapshot(),
                "downlink": self.downlink.snapshot(),
                "overhead_up": int(self.overhead_up),
                "overhead_down": int(self.overhead_down)}

    def restore_ledger(self, d: dict) -> None:
        """Reinstate a ``ledger()`` snapshot; the next ``begin_round``
        continues the restored round numbering. Overhead keys default to 0
        for ledgers written before they existed."""
        self.uplink.restore(d["uplink"])
        self.downlink.restore(d["downlink"])
        self.overhead_up = int(d.get("overhead_up", 0))
        self.overhead_down = int(d.get("overhead_down", 0))
        self._round = max(len(self.uplink.per_round) - 1, 0)


class InProcessChannel(Channel):
    """Moves encoded uint8 buffers client->server (uplink) and
    server->client (downlink), billing every byte."""

    @staticmethod
    def _as_wire(buf) -> np.ndarray:
        b = np.asarray(buf)
        if b.dtype != np.uint8 or b.ndim != 1:
            raise TypeError(
                f"channel carries 1-D uint8 frames only, got "
                f"{b.dtype}{list(b.shape)} — encode first (repro.comm.codec)")
        return b.copy()                  # the wire: a detached host copy

    def send_up(self, buf) -> np.ndarray:
        """Client -> server. Returns the host copy the server receives."""
        b = self._as_wire(buf)
        self.uplink._record(b.nbytes)
        return b

    def send_down(self, buf) -> np.ndarray:
        """Server -> client (e.g. a framed model broadcast)."""
        b = self._as_wire(buf)
        self.downlink._record(b.nbytes)
        return b


class FaultyChannel:
    """Seeded transport-fault injector over an inner channel.

    Each send first pays the inner channel's billing (the bytes were
    transmitted — corruption happens on the wire, not before it), then the
    frame is independently dropped (returns ``None``), truncated to a
    random prefix, or hit with single-bit flips, with the configured
    probabilities. Faults are deterministic from ``seed`` and the send
    sequence, so a fuzz failure replays exactly.

    Faults are counted both as running totals (``dropped``/``corrupted``)
    and per round (``dropped_per_round``/``corrupted_per_round``, buckets
    opened by ``begin_round()`` like ``LinkStats.per_round``) so a fault
    bench can attribute every injected fault to the round it hit. Rounds
    must therefore be opened on THIS wrapper, not its inner channel —
    bypassing it would desynchronize the fault buckets from the byte
    buckets and is rejected.
    """

    def __init__(self, inner: Optional[InProcessChannel] = None, *,
                 drop_prob: float = 0.0, truncate_prob: float = 0.0,
                 bitflip_prob: float = 0.0, max_bitflips: int = 8,
                 seed: int = 0):
        for name, p in (("drop_prob", drop_prob),
                        ("truncate_prob", truncate_prob),
                        ("bitflip_prob", bitflip_prob)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        self.inner = InProcessChannel() if inner is None else inner
        self.drop_prob = drop_prob
        self.truncate_prob = truncate_prob
        self.bitflip_prob = bitflip_prob
        self.max_bitflips = max_bitflips
        self._rng = np.random.default_rng(seed)
        self.dropped = 0
        self.corrupted = 0
        self.dropped_per_round: List[int] = []
        self.corrupted_per_round: List[int] = []
        # pull-model meters: /metrics and metrics.jsonl render the live
        # fault buckets without shadow-counting them
        get_registry().register_source("channel.faults", self.fault_stats)

    def fault_stats(self) -> dict:
        return {"dropped": int(self.dropped),
                "corrupted": int(self.corrupted),
                "dropped_per_round": [int(x) for x in self.dropped_per_round],
                "corrupted_per_round":
                    [int(x) for x in self.corrupted_per_round]}

    # accounting passthrough
    @property
    def uplink(self) -> LinkStats:
        return self.inner.uplink

    @property
    def downlink(self) -> LinkStats:
        return self.inner.downlink

    @property
    def round(self) -> int:
        return self.inner.round

    def begin_round(self) -> int:
        self.dropped_per_round.append(0)
        self.corrupted_per_round.append(0)
        return self.inner.begin_round()

    def _corrupt(self, b: np.ndarray) -> Optional[np.ndarray]:
        if not self.dropped_per_round:
            raise RuntimeError(
                "send before begin_round() on the FaultyChannel: open the "
                "round on the wrapper (not its inner channel) so per-round "
                "fault attribution stays aligned with the byte buckets")
        r = self._rng
        if r.random() < self.drop_prob:
            self.dropped += 1
            self.dropped_per_round[-1] += 1
            return None
        if r.random() < self.truncate_prob and b.size > 0:
            self.corrupted += 1
            self.corrupted_per_round[-1] += 1
            return b[: int(r.integers(0, b.size))].copy()
        if r.random() < self.bitflip_prob and b.size > 0:
            self.corrupted += 1
            self.corrupted_per_round[-1] += 1
            b = b.copy()
            for _ in range(int(r.integers(1, self.max_bitflips + 1))):
                pos = int(r.integers(0, b.size))
                b[pos] ^= np.uint8(1 << int(r.integers(0, 8)))
            return b
        return b

    def send_up(self, buf) -> Optional[np.ndarray]:
        """Client -> server through the faulty wire: the delivered frame,
        possibly corrupted, or ``None`` when the wire ate it."""
        return self._corrupt(self.inner.send_up(buf))

    def send_down(self, buf) -> Optional[np.ndarray]:
        return self._corrupt(self.inner.send_down(buf))
