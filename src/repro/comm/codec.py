"""Codec registry: each compressor's payload as actual serialized bytes.

Every codec turns the method-specific wire payload a compressor emits
(``core.compressor.TreeCompressed.wire``) into ONE contiguous ``uint8``
buffer — framed by ``comm.frame`` — and decodes it back bit-exactly. This is
the repo's honest answer to "how many bytes cross the network": the
accounted float conventions in ``core.baselines`` (signSGD = d/32 + 1
floats, DGC = 2k floats, ...) become *measured* sizes:

* **identity** (FedAvg): the raw f32 leaf stream — 4d bytes.
* **topk** (DGC): per leaf, a f32 value stream (4k) plus the kept indices
  bit-packed at ``ceil(log2 n_leaf)`` bits each.
* **signsgd**: ONE bit per coordinate — the whole tree's sign stream packed
  32→1 through the Pallas kernel pair (``kernels.bitpack``) — plus one f32
  scale per leaf. ``ceil(d/8)`` payload bytes, the paper's 32x limit made
  real. 1-bit semantics: bit = (x >= 0), so exact zeros decode to +scale
  (a 3-valued sign does not fit in 1 bit; ``client_view`` applies the same
  convention on the client so EF and the server stay consistent).
* **stc**: per leaf, ternary = 1 sign bit per kept entry + packed indices
  + one f32 mu.
* **threesfc**: the ``(D_syn, s)`` synthetic payload under a dtype policy
  (fp32 lossless / fp16 / bf16), ``s`` always f32. The server-side
  ``recon_tree`` is Eq. 10's one backward on the decoded payload.

Decode round-trip contract: ``decode(encode(wire))`` equals the canonical
payload bit-exactly, where canonical means "after the policy cast" (fp32
policies are strictly lossless). ``wire_bytes(cfg, params)`` exposes the
static frame size, so byte accounting works under jit without touching data.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import frame
from repro.configs.base import CompressorConfig
from repro.core.strategy import TreeCompressed, leaf_k, make_strategy
from repro.core.threesfc import SynData, SynSpec
from repro.kernels import bitpack

PyTree = Any

POLICY_DTYPES = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}
POLICY_ITEMBYTES = {"fp32": 4, "fp16": 2, "bf16": 2}


# ---------------------------------------------------------------------------
# byte/bit stream primitives (jit/vmap-safe, static shapes)
# ---------------------------------------------------------------------------


def array_to_bytes(x: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Flat little-endian byte view of ``x`` cast to ``dtype``."""
    v = jnp.asarray(x, dtype).reshape(-1)
    if v.size == 0:
        return jnp.zeros((0,), jnp.uint8)
    return jax.lax.bitcast_convert_type(v, jnp.uint8).reshape(-1)


def bytes_to_array(b: jax.Array, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    """Inverse of ``array_to_bytes`` (``shape``/``dtype`` static)."""
    n = int(np.prod(shape)) if len(shape) else 1
    if n == 0:
        return jnp.zeros(shape, dtype)
    item = jnp.dtype(dtype).itemsize
    return jax.lax.bitcast_convert_type(
        b.reshape(n, item), dtype).reshape(shape)


def index_width(n: int) -> int:
    """Bits per index into a size-``n`` leaf: ceil(log2 n), min 1."""
    return max(1, int(n - 1).bit_length())


def stream_bytes(count: int, width: int) -> int:
    return -(-count * width // 8)


def pack_uint_stream(vals: jax.Array, width: int) -> jax.Array:
    """(k,) uint -> ceil(k*width/8) uint8, LSB-first within the stream."""
    k = vals.size
    v = jnp.asarray(vals, jnp.uint32)
    bit_idx = jnp.arange(width, dtype=jnp.uint32)
    bits = ((v[:, None] >> bit_idx) & 1).reshape(-1)         # k*width bits
    nbytes = stream_bytes(k, width)
    bits = jnp.pad(bits, (0, nbytes * 8 - bits.size))
    return jnp.sum(
        bits.reshape(nbytes, 8) << jnp.arange(8, dtype=jnp.uint32),
        axis=-1, dtype=jnp.uint32).astype(jnp.uint8)


def unpack_uint_stream(b: jax.Array, count: int, width: int) -> jax.Array:
    """Inverse of ``pack_uint_stream`` -> (count,) uint32."""
    bits = ((b[:, None].astype(jnp.uint32)
             >> jnp.arange(8, dtype=jnp.uint32)) & 1).reshape(-1)
    bits = bits[: count * width].reshape(count, width)
    return jnp.sum(bits << jnp.arange(width, dtype=jnp.uint32),
                   axis=-1, dtype=jnp.uint32)


def _words_to_bytes(words: jax.Array, nbytes: int) -> jax.Array:
    b = jax.lax.bitcast_convert_type(words, jnp.uint8).reshape(-1)
    return b[:nbytes]


def _bytes_to_words(b: jax.Array, nwords: int) -> jax.Array:
    b = jnp.pad(b, (0, nwords * 4 - b.size))
    return jax.lax.bitcast_convert_type(b.reshape(nwords, 4), jnp.uint32)


def _pm1(x: jax.Array) -> jax.Array:
    """The 1-bit wire sign: +1 where x >= 0, else -1 (never 0)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# codec protocol
# ---------------------------------------------------------------------------


class Codec:
    """Encode a compressor's wire payload into framed bytes and back.

    Subclasses fill ``_section_bytes`` (static layout), ``_pack`` (payload ->
    per-section uint8 arrays), ``_unpack`` (sections -> canonical payload)
    and ``recon_tree`` (canonical payload -> server reconstruction).
    ``client_view`` returns the client-side dequantized reconstruction
    (and/or its (direction, scale) factorization) so EF in wire mode uses
    exactly what the server will apply.
    """

    kind: str = ""

    def __init__(self, cfg: CompressorConfig, params: PyTree,
                 policy: str = "fp32", *, strategy=None):
        if policy not in POLICY_DTYPES:
            raise ValueError(f"unknown dtype policy {policy!r}")
        self.cfg = cfg
        self.policy = policy
        # the method's CompressionStrategy — server reconstruction
        # (``recon_tree``) delegates to its ``server_decode`` so the Eq. 10
        # decode logic lives once, on the protocol object
        self.strategy = strategy if strategy is not None \
            else make_strategy(cfg)
        leaves, self.treedef = jax.tree_util.tree_flatten(params)
        self.shapes = [tuple(jnp.shape(l)) for l in leaves]
        self.sizes = [int(np.prod(s)) if len(s) else 1 for s in self.shapes]
        self.d = int(sum(self.sizes))
        # allocation-free params stand-in for shape-only reconstruction
        self.template = jax.tree_util.tree_unflatten(
            self.treedef,
            [jax.ShapeDtypeStruct(s, jnp.float32) for s in self.shapes])
        self.spec = frame.FrameSpec(self.kind, policy,
                                    tuple(self._section_bytes()))

    # -- static layout -----------------------------------------------------
    @property
    def nbytes(self) -> int:
        return self.spec.nbytes

    @property
    def header_bytes(self) -> int:
        return self.spec.header_bytes

    def _section_bytes(self):
        raise NotImplementedError

    # -- wire --------------------------------------------------------------
    def encode(self, wire, round_idx=0, client_idx=0) -> jax.Array:
        """wire payload -> (nbytes,) uint8 framed buffer (jit/vmap-safe)."""
        sections = self._pack(wire)
        for s, want in zip(sections, self.spec.section_bytes):
            assert s.dtype == jnp.uint8 and s.size == want, \
                (self.kind, s.shape, want)
        header = frame.encode_header(self.spec, round_idx, client_idx)
        return jnp.concatenate([header, *sections]) if sections else header

    def decode(self, buf: jax.Array):
        """(nbytes,) uint8 -> canonical payload (bit-exact round trip)."""
        parts = [buf[o:o + n] for o, n in
                 zip(self.spec.section_offsets, self.spec.section_bytes)]
        return self._unpack(parts)

    def _pack(self, wire):
        raise NotImplementedError

    def _unpack(self, sections):
        raise NotImplementedError

    # -- reconstruction ----------------------------------------------------
    def canonical(self, wire):
        """What ``decode(encode(wire))`` must reproduce, bit for bit —
        computed WITHOUT touching the byte stream (the round-trip oracle).
        Identity for lossless codecs; quantizing codecs apply their wire
        semantics (1-bit signs, dtype policy) here."""
        return wire

    def recon_tree(self, canon, params: PyTree) -> PyTree:
        """Server-side reconstruction from the decoded payload — the
        strategy's ``server_decode``, which is the one copy of each
        method's decode semantics."""
        return self.strategy.server_decode(canon, params)

    def check_round_wire(self) -> None:
        """Raise if this codec cannot host ``fl.round``'s wire mode (the
        round requires client EF to match the server decode exactly);
        lossless codecs and codecs with an exact ``client_view`` pass."""
        return None

    def client_view(self, out: TreeCompressed):
        """(recon, direction, scale) the client must use in wire mode.

        Defaults to the compressor's own (lossless codecs); quantizing
        codecs override so client EF matches the server's decode exactly.
        """
        return out.recon, out.direction, out.scale

    def _leaf_tree(self, leaves) -> PyTree:
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

CODECS: Dict[str, Callable[..., Codec]] = {}


def register_codec(cls):
    """Register a ``Codec`` subclass under its ``kind`` (duplicate kinds
    rejected — the third-party extension point, mirroring
    ``repro.core.strategy.register_strategy``). Third-party kinds are
    assigned an on-the-wire header id in the frame's extension range."""
    if not cls.kind:
        raise ValueError(
            f"codec class {cls.__name__} must set a non-empty `kind`")
    if cls.kind in CODECS:
        raise ValueError(f"codec kind {cls.kind!r} already registered "
                         f"(by {CODECS[cls.kind].__name__})")
    frame.register_kind_id(cls.kind)
    CODECS[cls.kind] = cls
    return cls


_register = register_codec          # back-compat alias


@_register
class IdentityCodec(Codec):
    """FedAvg: the raw f32 leaf stream, 4d payload bytes."""

    kind = "identity"

    def _section_bytes(self):
        return (4 * self.d,)

    def _pack(self, wire):
        leaves = jax.tree_util.tree_leaves(wire)
        return [jnp.concatenate([array_to_bytes(l) for l in leaves])]

    def _unpack(self, sections):
        vec = bytes_to_array(sections[0], (self.d,))
        leaves, off = [], 0
        for shape, n in zip(self.shapes, self.sizes):
            leaves.append(vec[off:off + n].reshape(shape))
            off += n
        return self._leaf_tree(leaves)

    def canonical(self, wire):
        # the wire stream is f32; decode hands back f32 leaves
        return jax.tree_util.tree_map(
            lambda l: jnp.asarray(l, jnp.float32), wire)


@_register
class TopkCodec(Codec):
    """DGC: per leaf, f32 values + indices at ceil(log2 n_leaf) bits."""

    kind = "topk"

    def _layout(self):
        for n in self.sizes:
            yield n, leaf_k(n, self.cfg.keep_ratio), index_width(n)

    def _section_bytes(self):
        out = []
        for _, k, w in self._layout():
            out += [4 * k, stream_bytes(k, w)]
        return out

    def _pack(self, wire):
        sections = []
        for (vals, idx), (_, k, w) in zip(wire, self._layout()):
            sections.append(array_to_bytes(vals))
            sections.append(pack_uint_stream(idx.astype(jnp.uint32), w))
        return sections

    def _unpack(self, sections):
        out = []
        for i, (_, k, w) in enumerate(self._layout()):
            vals = bytes_to_array(sections[2 * i], (k,))
            idx = unpack_uint_stream(sections[2 * i + 1], k, w)
            out.append((vals, idx.astype(jnp.int32)))
        return tuple(out)


@_register
class SignCodec(Codec):
    """signSGD: one packed sign bit per coordinate + one f32 scale per leaf.

    The sign stream covers the *concatenated* tree (ceil(d/8) bytes, byte-
    exact — no per-leaf padding), packed through the Pallas 32→1 kernel.
    """

    kind = "signsgd"

    def _section_bytes(self):
        return (-(-self.d // 8), 4 * len(self.sizes))

    def _pack(self, wire):
        u, scales = wire
        flatv = jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32)
             for l in jax.tree_util.tree_leaves(u)])
        words = bitpack.pack_signs(flatv)
        return [_words_to_bytes(words, -(-self.d // 8)),
                array_to_bytes(scales)]

    def _unpack(self, sections):
        words = _bytes_to_words(sections[0], -(-self.d // 32))
        pm1 = bitpack.unpack_signs(words, self.d)
        scales = bytes_to_array(sections[1], (len(self.sizes),))
        leaves, off = [], 0
        for i, (shape, n) in enumerate(zip(self.shapes, self.sizes)):
            leaves.append((scales[i] * pm1[off:off + n]).reshape(shape))
            off += n
        return self._leaf_tree(leaves)

    def canonical(self, wire):
        u, scales = wire
        leaves = [s * _pm1(l) for s, l
                  in zip(scales, jax.tree_util.tree_leaves(u))]
        return self._leaf_tree(
            [l.reshape(sh) for l, sh in zip(leaves, self.shapes)])

    def client_view(self, out):
        return self.canonical(out.wire), None, None


@_register
class StcCodec(Codec):
    """STC: per leaf, 1 sign bit per kept entry + packed indices + f32 mu.

    Same 1-bit sign semantics as ``SignCodec``: a kept value that is
    *exactly* zero (possible only when a leaf has fewer than k nonzeros)
    decodes to +mu where the float path reconstructs 0. ``bench_wire``
    measures the zero-kept count so a parity divergence is attributable.
    """

    kind = "stc"

    def _layout(self):
        for n in self.sizes:
            yield n, leaf_k(n, self.cfg.keep_ratio), index_width(n)

    def _section_bytes(self):
        out = []
        for _, k, w in self._layout():
            out += [stream_bytes(k, 1), stream_bytes(k, w), 4]
        return out

    def _pack(self, wire):
        sections = []
        for (sgn, idx, mu), (_, k, w) in zip(wire, self._layout()):
            sections.append(pack_uint_stream((sgn >= 0).astype(jnp.uint32), 1))
            sections.append(pack_uint_stream(idx.astype(jnp.uint32), w))
            sections.append(array_to_bytes(mu))
        return sections

    def _unpack(self, sections):
        out = []
        for i, (_, k, w) in enumerate(self._layout()):
            bits = unpack_uint_stream(sections[3 * i], k, 1)
            pm1 = bits.astype(jnp.float32) * 2.0 - 1.0
            idx = unpack_uint_stream(sections[3 * i + 1], k, w)
            mu = bytes_to_array(sections[3 * i + 2], ())
            out.append((pm1, idx.astype(jnp.int32), mu))
        return tuple(out)

    def canonical(self, wire):
        return tuple((_pm1(sgn), idx, mu) for sgn, idx, mu in wire)

    def client_view(self, out):
        return self.recon_tree(self.canonical(out.wire),
                               self.template), None, None


@_register
class ThreesfcCodec(Codec):
    """3SFC: the (D_syn, s) payload under a dtype policy; s always f32.

    ``recon_tree`` is the paper's decoder (Eq. 10): one backward of the
    global model on the decoded synthetic batch, scaled by s.
    """

    kind = "threesfc"

    def __init__(self, cfg, params, policy="fp32", *, strategy):
        syn_spec: SynSpec = strategy.syn_spec
        self.syn_spec = syn_spec
        lead = syn_spec.label_lead or syn_spec.x_shape[:1]
        if syn_spec.label_rank:
            self.y_shape = (*lead, syn_spec.label_rank)
            self.v_shape = (syn_spec.label_rank, syn_spec.num_classes)
        else:
            self.y_shape = (*lead, syn_spec.num_classes)
            self.v_shape = (0, 0)
        super().__init__(cfg, params, policy, strategy=strategy)

    def _section_bytes(self):
        item = POLICY_ITEMBYTES[self.policy]
        sizes = [int(np.prod(s)) for s in
                 (self.syn_spec.x_shape, self.y_shape, self.v_shape)]
        return [item * n for n in sizes] + [4]

    def _pack(self, wire):
        syn, s = wire
        dt = POLICY_DTYPES[self.policy]
        return [array_to_bytes(syn.x, dt), array_to_bytes(syn.y, dt),
                array_to_bytes(syn.y_rank, dt), array_to_bytes(s)]

    def _unpack(self, sections):
        dt = POLICY_DTYPES[self.policy]
        x = bytes_to_array(sections[0], self.syn_spec.x_shape, dt)
        y = bytes_to_array(sections[1], self.y_shape, dt)
        v = bytes_to_array(sections[2], self.v_shape, dt)
        s = bytes_to_array(sections[3], ())
        syn = SynData(x.astype(jnp.float32), y.astype(jnp.float32),
                      v.astype(jnp.float32))
        return syn, s

    def canonical(self, wire):
        syn, s = wire
        dt = POLICY_DTYPES[self.policy]
        return (SynData(*[jnp.asarray(a, dt).astype(jnp.float32)
                          for a in syn]),
                jnp.asarray(s, jnp.float32))

    def check_round_wire(self):
        if self.policy != "fp32":
            raise ValueError(
                "the round's wire mode requires the lossless fp32 policy "
                "for threesfc (client EF runs on the factored (gw, s)); "
                "lossy policies are a codec-level feature")

    def client_view(self, out):
        # EF runs on the factored (gw, s) — exact at fp32 policy (the only
        # policy the round's wire mode admits; see check_round_wire).
        return None, out.direction, out.scale


# ---------------------------------------------------------------------------
# construction helpers
# ---------------------------------------------------------------------------


def make_codec(cfg: CompressorConfig, params: PyTree, *,
               syn_spec: Optional[SynSpec] = None,
               syn_loss_fn=None, policy: Optional[str] = None) -> Codec:
    """Build the registered codec for ``cfg.kind`` over a params template.

    ``params`` may be real arrays or ``ShapeDtypeStruct``s — only shapes are
    read. Raises ``KeyError`` for kinds without a wire format (randk,
    fedsynth — see PAPERS.md; their budgets remain accounted-only).
    """
    if cfg.kind not in CODECS:
        raise KeyError(
            f"no wire codec registered for compressor kind {cfg.kind!r} "
            f"(have: {sorted(CODECS)})")
    strategy = make_strategy(cfg, loss_fn=syn_loss_fn, syn_spec=syn_spec)
    return strategy.wire_codec(params, policy=policy)


def wire_bytes(cfg: CompressorConfig, params: PyTree, *,
               syn_spec: Optional[SynSpec] = None,
               policy: Optional[str] = None) -> int:
    """Static total frame size (header + payload) for one uplink message."""
    return make_codec(cfg, params, syn_spec=syn_spec, policy=policy).nbytes
