"""repro.comm — the wire-format layer: framed bytes, not accounted floats.

``frame``  — versioned fixed-layout header; static sizes usable under jit.
``codec``  — per-compressor encode/decode between payloads and uint8 frames,
             registered per ``CompressorConfig.kind`` (``register_codec``).
``channel``— in-process transport moving only encoded buffers, with byte
             counters; ``FaultyChannel`` injects seeded transport faults
             (drop/truncate/bit-flip) for the fault harness.
"""
from repro.comm.channel import FaultyChannel, InProcessChannel, LinkStats
from repro.comm.codec import (CODECS, Codec, make_codec, register_codec,
                              wire_bytes)
from repro.comm.frame import (FrameError, FrameSpec, parse_header,
                              register_kind_id)

__all__ = ["CODECS", "Codec", "FaultyChannel", "FrameError", "FrameSpec",
           "InProcessChannel", "LinkStats", "make_codec", "parse_header",
           "register_codec", "register_kind_id", "wire_bytes"]
