"""repro.comm — the wire-format layer: framed bytes, not accounted floats.

``frame``  — versioned fixed-layout header; static sizes usable under jit.
``codec``  — per-compressor encode/decode between payloads and uint8 frames,
             registered per ``CompressorConfig.kind`` (``register_codec``).
``channel``— ``Channel`` transport interface + in-process transport moving
             only encoded buffers, with byte counters; ``FaultyChannel``
             injects seeded transport faults (drop/truncate/bit-flip) for
             the fault harness.
``transport`` — real length-prefixed socket transport (``SocketServer`` +
             worker-side ``ServerLink``) between a server process and N
             locally spawned client workers; deadlines, backoff retries,
             and heartbeat liveness map every wire fault onto the
             ``delivered=False`` branch of the fault model.
"""
from repro.comm.channel import (Channel, FaultyChannel, InProcessChannel,
                                LinkStats)
from repro.comm.codec import (CODECS, Codec, make_codec, register_codec,
                              wire_bytes)
from repro.comm.frame import (FrameError, FrameSpec, parse_header,
                              register_kind_id)
from repro.comm.transport import (ProtocolError, ServerLink, SocketServer,
                                  spawn_local_workers)

__all__ = ["CODECS", "Channel", "Codec", "FaultyChannel", "FrameError",
           "FrameSpec", "InProcessChannel", "LinkStats", "ProtocolError",
           "ServerLink", "SocketServer", "make_codec", "parse_header",
           "register_codec", "register_kind_id", "spawn_local_workers",
           "wire_bytes"]
