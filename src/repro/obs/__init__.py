"""repro.obs — structured observability: spans, meters, logs, endpoints.

``trace``  — low-overhead host-side span recorder (monotonic-clock spans
             tagged run/round/client/phase in a bounded ring buffer) with
             JSONL and Chrome/Perfetto trace-event export, plus the
             cross-process merge used to line worker timelines up against
             the server's round windows (heartbeat-derived clock offsets).
``meters`` — one registry of counters/gauges/histograms absorbing the
             stack's scattered accounting (LinkStats bytes, fault buckets,
             retry counts, heartbeat RTT/liveness) behind a point-in-time
             ``snapshot()`` that metrics files and HTTP endpoints render.
``http``   — a tiny threaded HTTP server exposing ``/healthz`` and
             ``/metrics`` (the registry snapshot as JSON).
``log``    — structured stderr logging with stable ``key=value`` context
             prefixes (``client``/``round``), so interleaved multi-process
             output stays attributable.

Everything here is HOST-side: spans wrap dispatch/transport/checkpoint
boundaries, never jitted computation (use ``launch/train.py --profile``
to capture the device timeline via ``jax.profiler``).
"""
from repro.obs.log import get_logger
from repro.obs.meters import (Counter, Gauge, Histogram, MetricsRegistry,
                              get_registry, set_registry)
from repro.obs.trace import (Span, Tracer, configure_tracer, get_tracer,
                             merge_traces, read_trace_jsonl, set_tracer,
                             write_chrome_trace)

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "Span",
           "Tracer", "configure_tracer", "get_logger", "get_registry",
           "get_tracer", "merge_traces", "read_trace_jsonl", "set_registry",
           "set_tracer", "write_chrome_trace"]
