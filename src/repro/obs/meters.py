"""A unified metrics registry: counters, gauges, histograms, pull sources.

The FL stack accumulates numbers in half a dozen places — ``LinkStats``
byte totals, ``FaultyChannel`` drop/corrupt buckets, ``RetryPolicy``
resend/give-up counts on the engine, heartbeat RTTs and liveness flips on
the transport.  Rather than rewrite those (their internal counters are
load-bearing for checkpoints and benches), the registry absorbs them two
ways:

- **Push instruments**: ``counter()``/``gauge()``/``histogram()`` return
  get-or-create instruments for code that wants to record directly
  (heartbeat RTT, liveness transitions, round wall times).
- **Pull sources**: ``register_source(name, fn)`` registers a zero-arg
  callable evaluated at ``snapshot()`` time — the transport registers a
  source that reads its live ``LinkStats`` ledger, so bytes shown by
  ``/metrics`` are always the billed truth, never a shadow copy.

``snapshot()`` is a plain JSON-able dict rendered identically by
``metrics.jsonl``, the ``/metrics`` HTTP endpoint, and ``trace_report``.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional


class Counter:
    """Monotonic accumulator."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def get(self) -> float:
        return self.value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self.value = value

    def get(self) -> Optional[float]:
        return self.value


class Histogram:
    """Bounded-reservoir histogram with on-demand quantiles.

    Keeps the most recent ``capacity`` observations plus exact running
    count/sum/min/max, so quantiles reflect recent behaviour while the
    aggregates stay lossless.
    """

    __slots__ = ("name", "capacity", "count", "total", "vmin", "vmax",
                 "_ring", "_lock")

    def __init__(self, name: str, capacity: int = 4096):
        self.name = name
        self.capacity = int(capacity)
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self._ring: List[float] = []
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = v if self.vmin is None else min(self.vmin, v)
            self.vmax = v if self.vmax is None else max(self.vmax, v)
            if len(self._ring) == self.capacity:
                self._ring.pop(0)
            self._ring.append(v)

    def quantile(self, q: float) -> Optional[float]:
        with self._lock:
            data = sorted(self._ring)
        if not data:
            return None
        idx = min(len(data) - 1, max(0, int(round(q * (len(data) - 1)))))
        return data[idx]

    def summary(self) -> Dict[str, Any]:
        return {
            "count": self.count, "sum": self.total,
            "min": self.vmin, "max": self.vmax,
            "mean": (self.total / self.count) if self.count else None,
            "p50": self.quantile(0.50), "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Get-or-create instrument registry plus pull-model sources."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._sources: Dict[str, Callable[[], Dict[str, Any]]] = {}
        self._lock = threading.Lock()
        self._t0 = time.monotonic()

    def counter(self, name: str) -> Counter:
        with self._lock:
            if name not in self._counters:
                self._counters[name] = Counter(name)
            return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            if name not in self._gauges:
                self._gauges[name] = Gauge(name)
            return self._gauges[name]

    def histogram(self, name: str, capacity: int = 4096) -> Histogram:
        with self._lock:
            if name not in self._histograms:
                self._histograms[name] = Histogram(name, capacity=capacity)
            return self._histograms[name]

    def register_source(self, name: str,
                        fn: Callable[[], Dict[str, Any]]) -> None:
        """Register a zero-arg callable polled at snapshot time.  The
        callable must return a JSON-able dict; exceptions are captured
        into the snapshot rather than propagated (a dead source must not
        take down ``/metrics``)."""
        with self._lock:
            self._sources[name] = fn

    def unregister_source(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time JSON-able view of every instrument and source."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
            sources = dict(self._sources)
        snap: Dict[str, Any] = {
            "uptime_s": time.monotonic() - self._t0,
            "counters": {n: c.get() for n, c in sorted(counters.items())},
            "gauges": {n: g.get() for n, g in sorted(gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(histograms.items())},
        }
        src_out: Dict[str, Any] = {}
        for name, fn in sorted(sources.items()):
            try:
                src_out[name] = fn()
            except Exception as e:  # noqa: BLE001 — surface, don't crash
                src_out[name] = {"error": f"{type(e).__name__}: {e}"}
        snap["sources"] = src_out
        return snap


_GLOBAL = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _GLOBAL


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _GLOBAL
    _GLOBAL = registry
    return registry
