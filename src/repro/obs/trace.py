"""Host-side span tracing with bounded memory and cross-process merge.

A :class:`Tracer` records *spans* (named intervals with tags) and *events*
(instantaneous points) on the host monotonic clock into a bounded ring
buffer.  It is designed for the round hot path:

- When disabled, ``span()`` returns a shared no-op context manager and
  ``event()`` returns immediately — no allocation, no clock read.
- When enabled, a span costs two ``time.monotonic_ns()`` calls and one
  deque append.  Nothing here ever touches a device array (a device read
  inside instrumentation would force a host sync and corrupt the very
  timing being measured).
- The ring is bounded (``capacity``); evictions are counted in
  ``dropped`` so truncation is visible, never silent.

Spans carry a ``proc`` label ("server", "client-3", ...) identifying the
recording process.  Workers drain their rings and piggyback the dicts on
``MSG_METRIC``; the server shifts them by a heartbeat-derived clock
offset (:func:`merge_traces`) so one file shows the server's deadline
windows against each worker's compute/encode/send timeline.

Export formats:

- JSONL: one span/event dict per line (``write_jsonl`` / ``read_trace_jsonl``).
- Chrome/Perfetto trace events (``write_chrome_trace``): load the file in
  ``chrome://tracing`` or https://ui.perfetto.dev — each ``proc`` becomes
  a named process row, spans become "X" complete events, events become
  "i" instants.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional


class Span:
    """An open span; close it via the context-manager protocol or ``end()``."""

    __slots__ = ("_tracer", "name", "tags", "t0", "t1")

    def __init__(self, tracer: "Tracer", name: str, tags: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.tags = tags
        self.t0 = tracer._clock()
        self.t1: Optional[int] = None

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()

    def end(self, **extra_tags: Any) -> None:
        if self.t1 is not None:
            return
        self.t1 = self._tracer._clock()
        if extra_tags:
            self.tags.update(extra_tags)
        self._tracer._append({
            "kind": "span", "name": self.name, "proc": self._tracer.proc,
            "t0": self.t0, "t1": self.t1, **self.tags,
        })


class _NoopSpan:
    """Shared disabled-path span: no clock reads, no allocation per use."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def end(self, **extra_tags: Any) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class Tracer:
    """Bounded ring buffer of span/event dicts on the monotonic clock."""

    def __init__(self, enabled: bool = True, proc: str = "main",
                 capacity: int = 65536,
                 clock: Callable[[], int] = time.monotonic_ns):
        self.enabled = enabled
        self.proc = proc
        self.capacity = int(capacity)
        self._clock = clock
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.dropped = 0

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **tags: Any):
        """Open a span; use as ``with tracer.span("phase", round=r): ...``."""
        if not self.enabled:
            return _NOOP_SPAN
        return Span(self, name, tags)

    def event(self, name: str, **tags: Any) -> None:
        """Record an instantaneous event."""
        if not self.enabled:
            return
        self._append({"kind": "event", "name": name, "proc": self.proc,
                      "t": self._clock(), **tags})

    def _append(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(rec)

    # -- draining / merging ------------------------------------------------

    def drain(self) -> List[Dict[str, Any]]:
        """Remove and return all buffered records (oldest first)."""
        with self._lock:
            out = list(self._ring)
            self._ring.clear()
        return out

    def to_dicts(self) -> List[Dict[str, Any]]:
        """Snapshot buffered records without clearing."""
        with self._lock:
            return list(self._ring)

    def extend_from_dicts(self, dicts: Iterable[Dict[str, Any]],
                          offset_ns: int = 0,
                          proc: Optional[str] = None) -> None:
        """Absorb records from another process, shifting timestamps by
        ``offset_ns`` (remote clock + offset == local clock)."""
        for d in dicts:
            rec = dict(d)
            if proc is not None:
                rec["proc"] = proc
            for k in ("t0", "t1", "t"):
                if rec.get(k) is not None:
                    rec[k] = int(rec[k]) + offset_ns
            self._append(rec)

    # -- export ------------------------------------------------------------

    def write_jsonl(self, path: str) -> int:
        """Write buffered records as JSONL; returns the record count."""
        recs = self.to_dicts()
        with open(path, "w") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")
        return len(recs)


def read_trace_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def merge_traces(server_records: Iterable[Dict[str, Any]],
                 worker_records: Dict[str, List[Dict[str, Any]]],
                 offsets_ns: Dict[str, int]) -> List[Dict[str, Any]]:
    """Merge worker record lists into the server timeline.

    ``worker_records`` maps proc label -> that worker's raw records (on its
    own monotonic clock); ``offsets_ns`` maps the same labels to the
    estimated ``server_clock - worker_clock`` offset.  Returns one list
    sorted by start time, all on the server clock.
    """
    merged: List[Dict[str, Any]] = [dict(r) for r in server_records]
    for proc, recs in worker_records.items():
        off = int(offsets_ns.get(proc, 0))
        for d in recs:
            rec = dict(d)
            rec["proc"] = proc
            for k in ("t0", "t1", "t"):
                if rec.get(k) is not None:
                    rec[k] = int(rec[k]) + off
            merged.append(rec)
    merged.sort(key=lambda r: r.get("t0", r.get("t", 0)))
    return merged


def write_chrome_trace(records: Iterable[Dict[str, Any]], path: str) -> int:
    """Export records as Chrome trace-event JSON (load in chrome://tracing
    or ui.perfetto.dev).  Timestamps are rebased to the earliest record so
    the viewer opens at t=0.  Returns the event count."""
    recs = list(records)
    starts = [r.get("t0", r.get("t")) for r in recs
              if r.get("t0", r.get("t")) is not None]
    base = min(starts) if starts else 0
    procs = sorted({r.get("proc", "main") for r in recs})
    pid_of = {p: i + 1 for i, p in enumerate(procs)}
    events: List[Dict[str, Any]] = []
    for p, pid in pid_of.items():
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": p}})
    reserved = {"kind", "name", "proc", "t0", "t1", "t"}
    for r in recs:
        pid = pid_of.get(r.get("proc", "main"), 0)
        args = {k: v for k, v in r.items() if k not in reserved}
        if r.get("kind") == "span" and r.get("t1") is not None:
            events.append({
                "ph": "X", "name": r["name"], "pid": pid, "tid": 0,
                "ts": (int(r["t0"]) - base) / 1e3,
                "dur": (int(r["t1"]) - int(r["t0"])) / 1e3,
                "args": args,
            })
        else:
            t = r.get("t", r.get("t0"))
            if t is None:
                continue
            events.append({"ph": "i", "name": r["name"], "pid": pid,
                           "tid": 0, "ts": (int(t) - base) / 1e3,
                           "s": "p", "args": args})
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)


# -- process-global tracer -------------------------------------------------

_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    global _GLOBAL
    _GLOBAL = tracer
    return tracer


def configure_tracer(enabled: bool, proc: str = "main",
                     capacity: int = 65536) -> Tracer:
    """Replace the process-global tracer; returns the new one."""
    return set_tracer(Tracer(enabled=enabled, proc=proc, capacity=capacity))
