"""Tiny threaded HTTP server exposing ``/healthz`` and ``/metrics``.

Serves the :mod:`repro.obs.meters` registry snapshot as JSON.  Stdlib
only (``http.server`` in a daemon thread), binds port 0 on request so
tests never collide, and shuts down cleanly via ``stop()``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.meters import MetricsRegistry, get_registry


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry  # set on the server class at start

    def _send(self, code: int, payload: dict) -> None:
        body = json.dumps(payload, indent=2).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 — BaseHTTPRequestHandler API
        path = self.path.split("?", 1)[0]
        try:
            if path == "/healthz":
                snap = self.server.registry.snapshot()  # type: ignore[attr-defined]
                self._send(200, {"status": "ok",
                                 "uptime_s": snap["uptime_s"]})
            elif path == "/metrics":
                snap = self.server.registry.snapshot()  # type: ignore[attr-defined]
                self._send(200, snap)
            else:
                self._send(404, {"error": f"no route {path}"})
        except Exception as e:  # noqa: BLE001 — endpoint must not crash server
            self._send(500, {"error": f"{type(e).__name__}: {e}"})

    def log_message(self, fmt, *args) -> None:  # silence per-request stderr
        pass


class ObsHTTPServer:
    """Background /healthz + /metrics server over a metrics registry."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry: Optional[MetricsRegistry] = None):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.registry = registry or get_registry()  # type: ignore[attr-defined]
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name=f"obs-http:{self.port}")
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)
