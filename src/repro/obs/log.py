"""Structured stderr logging with stable ``key=value`` context prefixes.

Multi-process runs interleave server and worker stderr; a bare line is
unattributable.  ``get_logger("worker", client=3)`` returns an adapter
that prefixes every line with ``[worker client=3]``; ``bind(round=12)``
derives a child with extra context, so the worker loop can rebind the
round number once per round and every subsequent line carries it.
"""
from __future__ import annotations

import logging
import sys
from typing import Any, Dict

_FORMAT = "%(asctime)s %(levelname).1s %(message)s"
_configured = False


def _ensure_handler() -> None:
    global _configured
    if _configured:
        return
    root = logging.getLogger("repro")
    if not root.handlers:
        h = logging.StreamHandler(sys.stderr)
        h.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        root.addHandler(h)
        root.setLevel(logging.INFO)
        root.propagate = False
    _configured = True


class ContextLogger(logging.LoggerAdapter):
    """LoggerAdapter whose extra dict renders as a ``[k=v ...]`` prefix."""

    def process(self, msg, kwargs):
        ctx = " ".join(f"{k}={v}" for k, v in self.extra.items()
                       if v is not None)
        return (f"[{ctx}] {msg}" if ctx else msg), kwargs

    def bind(self, **context: Any) -> "ContextLogger":
        merged: Dict[str, Any] = dict(self.extra)
        merged.update(context)
        return ContextLogger(self.logger, merged)


def get_logger(name: str, **context: Any) -> ContextLogger:
    """Structured logger under the ``repro`` namespace with bound context."""
    _ensure_handler()
    return ContextLogger(logging.getLogger(f"repro.{name}"), dict(context))
