from repro.optim.optimizers import OptState, adam, make_optimizer, momentum, sgd
