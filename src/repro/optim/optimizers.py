"""Minimal functional optimizers (optax-free).

``make_optimizer(name, lr, **kw)`` returns ``(init_fn, update_fn)``:
    state = init_fn(params)
    params, state = update_fn(params, grads, state)
All math is done in f32 and cast back to the param dtype (mixed-precision
friendly: bf16 params keep an f32 view only transiently).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree           # first moment (zeros tree for sgd)
    nu: PyTree           # second moment (zeros tree unless adam)


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr: float):
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), (), ())

    def update(params, grads, state):
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, OptState(state.step + 1, (), ())

    return init, update


def momentum(lr: float, beta: float = 0.9):
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), ())

    def update(params, grads, state):
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state.mu, grads)
        new = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mu)
        return new, OptState(state.step + 1, mu, ())

    return init, update


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        _zeros_like_f32(params), _zeros_like_f32(params))

    def update(params, grads, state):
        t = state.step + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m, v: (p.astype(jnp.float32)
                             - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)).astype(p.dtype),
            params, mu, nu)
        return new, OptState(t, mu, nu)

    return init, update


def make_optimizer(name: str, lr: float, **kw) -> Tuple[Callable, Callable]:
    return {"sgd": sgd, "momentum": momentum, "adam": adam}[name](lr, **kw)
