"""Device-resident multi-round FL engine: scanned rounds, on-device sampling,
donated EF state.

The seed drivers (``benchmarks/fl_harness.run_fl``, both ``launch/train.py``
paths) all ran the same Python loop: sample client batches on the host with
numpy, upload an ``(N, K, B, ...)`` tree every round, dispatch one jitted
round, then block on ≥2 device→host syncs (``float(m.loss)``,
``float(jnp.mean(m.cosine))``). This module replaces that loop with a single
device-resident program:

* the training set and the Dirichlet partition live on device
  (``device_pools`` pads the ragged per-client index lists to an ``(N, P)``
  pool matrix — padding is dead weight, never sampled, see the PRNG
  contract below);
* per-round batches are *gathered* inside the jitted computation
  (``vision_batcher`` / ``token_batcher``) — no host numpy, no per-round
  host→device transfer;
* ``RoundEngine`` wraps the round function in ``lax.scan`` over a whole
  eval block, so an L-round block costs ONE dispatch and ONE host sync
  (the stacked ``RoundMetrics`` fetch) instead of L dispatches + 2L syncs;
* the scan/jit donates the ``FLState`` argument, so the per-client N×d EF
  residual tree — the dominant HBM resident — is updated in place instead
  of being double-buffered across the dispatch boundary.

Sampling-gather PRNG contract
-----------------------------
The batch for (round r, client i) is fully determined by the engine seed::

    data_key = fold_in(PRNGKey(seed), 0)           # batch sampling stream
    round_key = fold_in(PRNGKey(seed), 1)          # compressor-key stream
    pos_i    = randint(fold_in(fold_in(data_key, r), i), (K, B), 0, size_i)
    batch_i  = gather(dataset, pools.index[i, pos_i])

``r`` is the *absolute* round counter carried in ``FLState.round`` — not the
position within a scan block. Folding on the absolute round (instead of
splitting a carried key) is what makes the stream independent of how rounds
are grouped into dispatches. The per-round compressor key is derived the
same way (``fold_in(round_key, r)``).

Why eval cadence = scan length
------------------------------
An eval is the one thing that genuinely needs the host: it reads
``state.params`` (or the caller formats/logs metrics), which forces a
device→host sync. So the scan should extend exactly to the next eval point
— any shorter wastes dispatches, any longer would compute past the params
the eval needs. ``RoundEngine.run`` therefore scans ``eval_every`` rounds
per dispatch (plus a final remainder block). By the PRNG contract above,
changing the eval cadence regroups the dispatches but does NOT change the
training trajectory — blocks [3] and [2, 1] produce bit-identical states
(tested in tests/test_engine.py::test_eval_cadence_invariance).

Donation safety: ``jit(..., donate_argnums=0)`` consumes the input state's
buffers — a donated ``FLState`` must never be touched after the dispatch.
``RoundEngine.init_state`` therefore deep-copies the params it is given
(the caller's model params survive the first donation), and every ``run*``
method returns the fresh state that replaces the consumed one.

Mesh placement contract
-----------------------
Pass ``shardings=make_fl_shardings(mesh)`` (see ``repro.fl.sharding``) to
run the engine on an explicit mesh. The contract, enforced end to end:

* ``init_state`` places the state before the first dispatch: params and the
  round counter replicated, the N×d EF residual tree sharded leading-axis
  over ``client_axes(mesh)`` — each device owns its clients' residuals.
* every scanned block is jitted with ``in_shardings``/``out_shardings`` set
  to that same ``FLState`` prefix tree, so (a) donation reuses the *sharded*
  buffers in place (the EF tree is never re-laid-out across a dispatch) and
  (b) the carried state can never silently gather to one device — the
  output sharding is pinned, not inferred.
* the per-round batch tree gathered by ``batch_fn`` is pinned to the client
  sharding inside the jit (``constrain_client_tree``) so GSPMD feeds each
  device exactly its clients' batches.
* block metrics are pinned replicated — they are O(N) scalars per round and
  the host fetch at the block boundary reads them without a device gather.

The round function must use the matching fan-out
(``make_fl_round(..., client_parallel='shard_map', mesh=mesh)``) for the
per-client region to stay collective-free; the vmap fan-out also runs
under these shardings (GSPMD partitions it) and is the bit-exactness
oracle (tests/test_shard_round.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.round import FLState, RoundMetrics, fl_init
from repro.fl.server import server_update
from repro.obs import get_registry, get_tracer

PyTree = Any
# batch_fn(data_key, round_idx) -> per-client stacked batch pytree (N, K, B, ...)
BatchFn = Callable[[jax.Array, jax.Array], PyTree]
RoundFn = Callable[[FLState, PyTree, jax.Array], Tuple[FLState, RoundMetrics]]

_DATA_FOLD = 0
_ROUND_FOLD = 1


class ClientPools(NamedTuple):
    """Padded on-device Dirichlet partition: ``index[i, :size[i]]`` are the
    dataset rows client ``i`` may sample; ``index[i, size[i]:]`` is padding
    (zeros) that the sampler never reads (positions are drawn < size[i])."""

    index: jax.Array                 # (N, P) int32
    size: jax.Array                  # (N,) int32


def device_pools(parts: Sequence[np.ndarray]) -> ClientPools:
    """Materialize a host-side partition (list of ragged index arrays, as
    produced by ``data.partition.dirichlet_partition``) as device pools.

    Zero-sample clients (an empty Dirichlet part — alpha small, N large)
    get ``size`` clamped to 1 over their all-zeros index row, i.e. they
    resample dataset row 0 every step: ``randint(maxval=0)`` is undefined
    (it silently returns garbage inside jit), so the clamp turns a
    degenerate part into a documented convention instead of corrupt
    sampling. Callers that want to exclude such clients outright should
    filter the partition before building pools."""
    cap = max(max(len(p) for p in parts), 1)
    index = np.zeros((len(parts), cap), np.int32)
    for i, p in enumerate(parts):
        index[i, : len(p)] = np.asarray(p, np.int32)
    size = np.array([max(len(p), 1) for p in parts], np.int32)
    return ClientPools(jnp.asarray(index), jnp.asarray(size))


def vision_batcher(train_x: np.ndarray, train_y: np.ndarray,
                   pools: ClientPools, local_steps: int,
                   local_batch: int) -> BatchFn:
    """Non-iid ``{"x", "y"}`` batches gathered from device-resident data."""
    x = jnp.asarray(train_x)
    y = jnp.asarray(train_y)
    num_clients = pools.index.shape[0]

    def batch_fn(data_key: jax.Array, round_idx: jax.Array) -> PyTree:
        kr = jax.random.fold_in(data_key, round_idx)

        def per_client(i):
            k = jax.random.fold_in(kr, i)
            pos = jax.random.randint(k, (local_steps, local_batch), 0,
                                     pools.size[i])
            return pools.index[i, pos]

        idx = jax.vmap(per_client)(jnp.arange(num_clients))
        return {"x": x[idx], "y": y[idx]}

    return batch_fn


def token_batcher(tokens: np.ndarray, num_clients: int, local_steps: int,
                  local_batch: int,
                  extras: Optional[Dict[str, Tuple[int, ...]]] = None) -> BatchFn:
    """IID ``{"tokens"}`` batches (the LM-smoke protocol) plus optional
    all-zero multimodal stubs: ``extras`` maps batch key -> trailing shape,
    materialized as ``(N, K, B, *shape)`` zeros inside the jit (free on
    device, vs. the seed loop uploading them every round)."""
    toks = jnp.asarray(tokens)
    n = toks.shape[0]
    extras = dict(extras or {})

    def batch_fn(data_key: jax.Array, round_idx: jax.Array) -> PyTree:
        kr = jax.random.fold_in(data_key, round_idx)

        def per_client(i):
            k = jax.random.fold_in(kr, i)
            return jax.random.randint(k, (local_steps, local_batch), 0, n)

        idx = jax.vmap(per_client)(jnp.arange(num_clients))
        batch = {"tokens": toks[idx]}
        for name, shape in extras.items():
            batch[name] = jnp.zeros(
                (num_clients, local_steps, local_batch, *shape), jnp.float32)
        return batch

    return batch_fn


@dataclasses.dataclass
class EngineStats:
    """Dispatch/sync accounting, the structural half of BENCH_round_engine."""

    dispatches: int = 0              # jitted computations launched
    host_syncs: int = 0              # blocking device->host reads
    rounds: int = 0

    def per_round(self) -> Dict[str, float]:
        r = max(self.rounds, 1)
        return {"dispatches_per_round": self.dispatches / r,
                "host_syncs_per_round": self.host_syncs / r}


class RunHistory(NamedTuple):
    metrics: RoundMetrics            # stacked over all rounds (host arrays)
    evals: List[Tuple[int, Any]]     # (round, eval_fn result) per eval point


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Transport give-up policy: how often a rejected/late uplink frame is
    re-requested before the server treats that client as DROPPED this
    round (the fault semantics of ``repro.fl.faults`` — the client's EF
    keeps the whole update, the server renormalizes over what arrived).

    Every retry is a re-send of the SAME frame and is billed by the
    channel like any other send — retransmission is never free, so a lossy
    link shows up in the per-round byte buckets, not just the fault
    counters.

    The timeout schedule generalizes the retry count to a live transport:
    attempt ``a`` waits ``recv_timeout_s * recv_backoff**a`` seconds
    (exponential backoff), capped at ``max_timeout_s`` — which the socket
    driver sets to the round deadline, since no single receive should
    outwait the round itself.
    """

    max_retries: int = 2
    recv_timeout_s: float = 2.0
    recv_backoff: float = 2.0
    max_timeout_s: float = 30.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.recv_timeout_s <= 0.0:
            raise ValueError(
                f"recv_timeout_s must be > 0, got {self.recv_timeout_s}")
        if self.recv_backoff < 1.0:
            raise ValueError(
                f"recv_backoff must be >= 1.0 (a shrinking retry window "
                f"races its own resends), got {self.recv_backoff}")
        if self.max_timeout_s < self.recv_timeout_s:
            raise ValueError(
                f"max_timeout_s ({self.max_timeout_s}) must be >= "
                f"recv_timeout_s ({self.recv_timeout_s})")

    def timeout(self, attempt: int) -> float:
        """Receive window for attempt ``attempt`` (0-based)."""
        return min(self.recv_timeout_s * self.recv_backoff ** attempt,
                   self.max_timeout_s)


class DeliveryReport(NamedTuple):
    """What ``RoundEngine.deliver`` got through the wire."""

    frames: List[Any]                # validated host frames; None = given up
    delivered: np.ndarray            # (N,) bool — the round's delivered mask
    retries: int                     # total re-sends across all clients


class RoundEngine:
    """Drives ``make_fl_round``-style round functions in eval-sized scans.

    ``run_block``/``run`` is the production path (one dispatch + one sync
    per block, donated state); ``run_loop`` is the per-round reference loop
    with the seed driver's dispatch/sync pattern but the *same* on-device
    sampling — the bit-exactness oracle for the scanned path.
    """

    def __init__(self, round_fn: RoundFn, batch_fn: BatchFn, *, seed: int = 0,
                 donate: bool = True, shardings=None):
        base = jax.random.PRNGKey(seed)
        self._data_key = jax.random.fold_in(base, _DATA_FOLD)
        self._round_key = jax.random.fold_in(base, _ROUND_FOLD)
        self._round_fn = round_fn
        self._batch_fn = batch_fn
        self.donate = donate
        # repro.fl.sharding.FLShardings | None — the mesh placement contract
        # (see module docstring); imported structurally to keep this module
        # importable without touching jax device state.
        self.shardings = shardings
        self._blocks: Dict[int, Callable] = {}
        self._loop_step = None
        self.stats = EngineStats()

    # -- state ------------------------------------------------------------
    def init_state(self, params: PyTree, num_clients: int,
                   strategy=None, *, staleness_max: int = 0) -> FLState:
        """``fl_init`` on a deep copy of ``params`` so donation of the
        engine state can never consume the caller's model tree. Pass the
        round's ``CompressionStrategy`` so its ``init_ef_state`` shapes the
        EF residual (zeros f32 otherwise — identical for every built-in),
        and ``staleness_max=run.staleness_max`` when the round function was
        built with a staleness buffer (the FLState structures must match).
        With a placement contract installed, the fresh state is placed on
        the mesh (params replicated, EF client-sharded) before the first
        dispatch."""
        owned = jax.tree_util.tree_map(jnp.copy, params)
        state = fl_init(owned, num_clients, strategy,
                        staleness_max=staleness_max)
        if self.shardings is not None:
            state = self.shardings.place_state(state)
        return state

    # -- transport delivery (host-side, the driver half of the fault model)
    @staticmethod
    def deliver(channel, frames, *,
                policy: RetryPolicy = RetryPolicy()) -> DeliveryReport:
        """Push per-client uplink frames through a (possibly faulty)
        channel with retry/give-up semantics.

        Each frame is sent via ``channel.send_up`` and validated with
        ``frame.parse_header``; a ``None`` delivery (the wire dropped it)
        or a typed ``FrameError`` (corrupt on arrival) triggers a re-send,
        up to ``policy.max_retries`` times. A client whose every attempt
        fails is marked undelivered — exactly the ``delivered=False``
        branch of the in-round fault model, so the driver can hand the
        mask to a faulted round (or just renormalize over the survivors).
        Retries are re-sends of the SAME frame and are billed by the
        channel like any other send (retransmission is not free).
        """
        from repro.comm.frame import FrameError, parse_header

        tracer = get_tracer()
        out: List[Any] = []
        delivered = np.zeros((len(frames),), bool)
        retries = 0
        with tracer.span("engine.deliver", clients=len(frames)) as sp:
            for i, buf in enumerate(frames):
                got = None
                for attempt in range(policy.max_retries + 1):
                    if attempt > 0:
                        retries += 1
                        tracer.event("retry.resend", client=i,
                                     attempt=attempt)
                    wire = channel.send_up(buf)
                    if wire is None:
                        continue
                    try:
                        parse_header(wire)
                    except FrameError:
                        continue
                    got = wire
                    break
                out.append(got)
                delivered[i] = got is not None
                if got is None:
                    tracer.event("retry.give_up", client=i,
                                 attempts=policy.max_retries)
            sp.end(delivered=int(delivered.sum()), retries=retries)
        get_registry().counter("engine.deliver.retries").inc(retries)
        return DeliveryReport(out, delivered, retries)

    # -- the round body (shared by scan and reference loop) ----------------
    def _round(self, state: FLState) -> Tuple[FLState, RoundMetrics]:
        batches = self._batch_fn(self._data_key, state.round)
        if self.shardings is not None:
            batches = self.shardings.constrain_client_tree(batches)
        key = jax.random.fold_in(self._round_key, state.round)
        return self._round_fn(state, batches, key)

    def _block(self, length: int) -> Callable:
        fn = self._blocks.get(length)
        if fn is None:
            def blk(state):
                return jax.lax.scan(lambda s, _: self._round(s), state, None,
                                    length=length)
            donate = (0,) if self.donate else ()
            if self.shardings is None:
                fn = jax.jit(blk, donate_argnums=donate)
            else:
                # pin input AND output state to the contract: donation then
                # reuses the sharded buffers in place, and the scanned EF
                # carry can never silently gather to one device.
                fn = jax.jit(
                    blk, donate_argnums=donate,
                    in_shardings=(self.shardings.state,),
                    out_shardings=(self.shardings.state,
                                   self.shardings.replicated))
            self._blocks[length] = fn
        return fn

    # -- scanned path ------------------------------------------------------
    def run_block(self, state: FLState,
                  length: int) -> Tuple[FLState, RoundMetrics]:
        """``length`` rounds in ONE dispatch; the input ``state`` is consumed
        (donated) — use only the returned state. The stacked metrics come
        back via a single ``device_get`` (the block's one host sync).

        Span tags use the engine's host-side round counter, never
        ``state.round`` — reading the device counter here would force an
        extra sync and corrupt the very dispatch/sync accounting this
        path is gated on."""
        tracer = get_tracer()
        r0 = self.stats.rounds
        with tracer.span("engine.dispatch", block=length, rounds_done=r0):
            state, ms = self._block(length)(state)
        self.stats.dispatches += 1
        with tracer.span("engine.sync", block=length, rounds_done=r0):
            ms = jax.device_get(ms)
        self.stats.host_syncs += 1
        self.stats.rounds += length
        return state, ms

    def run(self, state: FLState, num_rounds: int, *, eval_every: int = 0,
            eval_fn: Optional[Callable[[FLState, RoundMetrics, int], Any]] = None,
            ckpt_every: int = 0,
            ckpt_fn: Optional[Callable[[FLState, int], Any]] = None,
            ) -> Tuple[FLState, RunHistory]:
        """Blocks of ``eval_every`` rounds (plus a remainder block), with
        ``eval_fn(state, block_metrics, rounds_done)`` called at each eval
        boundary — the seed drivers' eval cadence ((r+1) % eval_every == 0,
        plus the final round). ``block_metrics`` is the just-fetched stacked
        ``RoundMetrics`` of the block that ended at the boundary, so
        eval-time logging costs no extra sync.

        ``ckpt_fn(state, absolute_round)`` fires whenever the *absolute*
        round counter (``FLState.round`` — a resumed state starts past 0)
        crosses a multiple of ``ckpt_every``; both cadences are anchored on
        the absolute counter, so a resumed run checkpoints and evals at the
        same rounds the uninterrupted run does. Scan blocks extend to the
        nearest upcoming boundary of either cadence — by the fold_in PRNG
        contract the extra block splits regroup dispatches without changing
        the trajectory (the eval-cadence-invariance property), which is
        exactly what makes checkpoint placement bitwise-free."""
        r0 = int(state.round)
        target = r0 + num_rounds

        def boundary(cur: int, every: int) -> int:
            return (cur // every + 1) * every if every > 0 else target

        chunks: List[RoundMetrics] = []
        evals: List[Tuple[int, Any]] = []
        cur = r0
        while cur < target:
            nxt = min(boundary(cur, eval_every), boundary(cur, ckpt_every),
                      target)
            state, ms = self.run_block(state, nxt - cur)
            cur = nxt
            chunks.append(ms)
            if eval_fn is not None and (
                    cur == target or (eval_every > 0 and cur % eval_every == 0)):
                evals.append((cur - r0, eval_fn(state, ms, cur - r0)))
            if ckpt_fn is not None and ckpt_every > 0 and cur % ckpt_every == 0:
                ckpt_fn(state, cur)
        if chunks:
            metrics = RoundMetrics(*[
                np.concatenate([np.atleast_1d(np.asarray(getattr(c, f)))
                                for c in chunks])
                for f in RoundMetrics._fields])
        else:                        # num_rounds == 0: empty, not None
            metrics = RoundMetrics(*[np.zeros((0,), np.float32)
                                     for _ in RoundMetrics._fields])
        return state, RunHistory(metrics, evals)

    # -- per-round reference loop -----------------------------------------
    def run_loop(self, state: FLState,
                 num_rounds: int) -> Tuple[FLState, RoundMetrics]:
        """Seed-driver dispatch pattern: one jit call per round, two blocking
        scalar syncs per round (loss, mean cosine) — but the same on-device
        sampling and round math as the scanned path, so the two are
        bit-exact. Never donates (the seed loop did not)."""
        if self._loop_step is None:
            self._loop_step = jax.jit(self._round)
        out: List[RoundMetrics] = []
        for _ in range(num_rounds):
            state, m = self._loop_step(state)
            self.stats.dispatches += 1
            float(m.loss)
            float(jnp.mean(m.cosine))
            self.stats.host_syncs += 2
            self.stats.rounds += 1
            # oracle record for the bit-exactness tests; by now the round is
            # fully computed, so this copy is instrumentation, not part of
            # the counted seed driver pattern
            out.append(jax.device_get(m))
        metrics = RoundMetrics(*[
            np.stack([np.asarray(getattr(m, f)) for m in out])
            for f in RoundMetrics._fields])
        return state, metrics


class LiveRoundLoop:
    """The server half of a live cross-process round over a transport.

    Where ``RoundEngine`` scans rounds inside one device program (clients
    are a vmap axis), ``LiveRoundLoop`` drives real client *processes*
    through a ``repro.comm.transport.SocketServer``: broadcast the params
    frame, ``collect`` the uplink under the round deadline with
    backoff/retries/liveness, ACK each worker its delivered verdict, and
    aggregate on the server.

    The server step mirrors the in-process faulted pipeline EXACTLY
    (``fl.round``'s codec decode -> recon -> masked mean x N/count ->
    ``server_update``), with every transport outcome — timeout, corrupt
    frame, dead worker — mapped onto the ``delivered=False`` mask. That is
    what makes the live loop bitwise-comparable to the in-process oracle
    on identical fault patterns (gated in ``benchmarks/bench_transport.py``):
    undelivered rows are zero placeholders whose decoded garbage the
    masked ``where`` never reads, exactly like the oracle's masked rows.

    ``participate_fn(round) -> (N,) bool`` drives partial participation
    (non-participants are told to sit the round out; their EF freezes —
    the ``participate=False`` branch). ``on_round(record, report)`` fires
    after every round with the history record + raw ``DeliveryReport``.
    """

    def __init__(self, server, strategy, codec, run, params, *,
                 policy: Optional[RetryPolicy] = None,
                 participate_fn=None, on_round=None):
        # lazy comm imports: fl never hard-depends on the wire layer
        from repro.comm.codec import make_codec
        from repro.configs.base import CompressorConfig

        self.server = server
        self.strategy = strategy
        self.codec = codec
        self.cfg = run
        self.policy = policy if policy is not None else run.retry_policy()
        self.participate_fn = participate_fn
        self.on_round = on_round
        self.params = jax.tree_util.tree_map(jnp.copy, params)
        self.history: List[Dict[str, Any]] = []
        N = run.fl.num_clients
        server_lr = run.fl.server_lr
        # the downlink broadcast is the raw params frame (identity codec);
        # compressing it too is the E-3SFC roadmap item, not this loop's
        self._down = make_codec(
            CompressorConfig(kind="identity", error_feedback=False), params)
        self._enc = jax.jit(
            lambda p, r: self._down.encode(p, round_idx=r))

        def step(p, bufs, delivered):
            # bitwise mirror of fl.round's faulted codec path at S=0,
            # weights=None: vmap decode -> recon -> mean(where) * N/count
            canon = jax.vmap(codec.decode)(bufs)
            recons = jax.vmap(lambda c: codec.recon_tree(c, p))(canon)
            cnt = jnp.sum(delivered.astype(jnp.float32))
            ratio = jnp.where(cnt > 0, N / cnt, 0.0)
            agg = jax.tree_util.tree_map(
                lambda x: jnp.mean(
                    jnp.where(delivered.reshape((-1,) + (1,) * (x.ndim - 1)),
                              x, 0), axis=0) * ratio,
                recons)
            return server_update(p, agg, server_lr)

        self._step = jax.jit(step)
        self._placeholder = np.zeros((codec.nbytes,), np.uint8)

    def run(self, num_rounds: int, *, deadline_s: Optional[float] = None,
            policy: Optional[RetryPolicy] = None, ckpt_every: int = 0,
            ckpt_fn=None):
        """Drive ``num_rounds`` live rounds; returns the final params.
        Per-round records (wall clock, delivered mask, retries, byte
        buckets, dead set, reported losses) accumulate in ``history``.
        ``deadline_s``/``policy`` override the loop's configuration for
        these rounds only — warm-up rounds (first-dispatch jit compilation
        happens inside the workers' round 0) want generous windows,
        measured straggle rounds tight ones.

        ``ckpt_fn(loop, round)`` fires at round boundaries where
        ``(round + 1) % ckpt_every == 0`` — round indices are absolute
        (``server.begin_round`` resumes numbering from a restored ledger),
        so a resumed loop checkpoints at the same rounds the uninterrupted
        one does. The driver's hook is expected to settle the server's EF
        bank (``wait_ef_bank``) before snapshotting."""
        N = self.cfg.fl.num_clients
        dl = self.cfg.round_deadline_s if deadline_s is None else deadline_s
        pol = self.policy if policy is None else policy
        tracer = get_tracer()
        meters = get_registry()
        for _ in range(num_rounds):
            r = self.server.begin_round()
            oh0 = (self.server.overhead_up, self.server.overhead_down)
            t0 = time.perf_counter()
            with tracer.span("round", round=r, deadline_s=dl) as round_sp:
                with tracer.span("round.encode", round=r,
                                 phase="encode") as enc_sp:
                    down = np.asarray(self._enc(self.params, jnp.uint32(r)))
                    enc_sp.end(bytes=int(down.nbytes))
                part = (np.ones((N,), bool) if self.participate_fn is None
                        else np.asarray(self.participate_fn(r), bool))
                with tracer.span("round.broadcast", round=r,
                                 phase="broadcast"):
                    self.server.broadcast_round(r, down, part)
                live = np.zeros((N,), bool)
                live[self.server.live_workers()] = True
                with tracer.span("round.collect", round=r, phase="collect",
                                 deadline_s=dl) as col_sp:
                    rep = self.server.collect(
                        r, part & live, policy=pol, deadline_s=dl)
                    col_sp.end(delivered=int(rep.delivered.sum()),
                               retries=rep.retries)
                with tracer.span("round.ack", round=r, phase="ack"):
                    self.server.send_acks(r, rep.delivered)
                with tracer.span("round.aggregate", round=r,
                                 phase="aggregate"):
                    bufs = np.stack(
                        [np.asarray(f, np.uint8) if f is not None
                         else self._placeholder for f in rep.frames])
                    self.params = self._step(self.params, jnp.asarray(bufs),
                                             jnp.asarray(rep.delivered))
                    jax.block_until_ready(self.params)
                dead = sorted(set(range(N))
                              - set(self.server.live_workers()))
                # one outcome tag per client per round: what the trace
                # analyzer attributes stragglers / drops / deaths from
                for cid in range(N):
                    if not part[cid]:
                        outcome = "sat_out"
                    elif rep.delivered[cid]:
                        outcome = "delivered"
                    elif cid in dead:
                        outcome = "dead"
                    else:
                        outcome = "undelivered"
                    tracer.event("round.outcome", round=r, client=cid,
                                 outcome=outcome)
                round_sp.end(delivered=int(rep.delivered.sum()),
                             retries=rep.retries)
            wall_s = time.perf_counter() - t0
            meters.counter("loop.rounds").inc()
            meters.gauge("loop.round").set(r)
            meters.histogram("loop.round_wall_s").observe(wall_s)
            rec = {"round": r,
                   "wall_s": wall_s,
                   "participate": part,
                   "delivered": rep.delivered.copy(),
                   "retries": rep.retries,
                   "bytes_up": self.server.uplink.per_round[-1],
                   "bytes_down": self.server.downlink.per_round[-1],
                   "overhead_up": self.server.overhead_up - oh0[0],
                   "overhead_down": self.server.overhead_down - oh0[1],
                   "dead": dead,
                   "losses": self.server.pop_metrics(r)}
            self.history.append(rec)
            if self.on_round is not None:
                self.on_round(rec, rep)
            if ckpt_fn is not None and ckpt_every > 0 \
                    and (r + 1) % ckpt_every == 0:
                ckpt_fn(self, r)
        return self.params
