"""One federated round, end to end, as a single jit/pjit-able pipeline.

``build_fl_round(loss_fn, strategy, run)`` composes THE round function from
three phases, each parameterized by the ``RunConfig`` and the
``CompressionStrategy`` (``repro.core.strategy``) instead of being one of
eight hand-written closure variants:

  1. **client phase** — every client runs K local SGD steps, then the
     strategy EF-compresses its accumulated update into a *message*:
     the reconstruction tree (float mode), the raw wire payload (fused
     mode) or a framed ``uint8`` codec buffer (codec mode). Per-client, no
     cross-client collectives.
  2. **transport boundary** — the client axis is fanned out either as a
     plain ``vmap`` (single-device reference semantics, the bit-exactness
     oracle) or as a ``jax.shard_map`` over ``client_axes(mesh)`` whose
     only communication is ONE tiled ``all_gather`` of the messages (the
     per-client region is HLO-gated collective-free under the
     ``CLIENT_SCOPE`` named scope).
  3. **server phase** — messages are decoded (codec mode) and aggregated:
     the default path averages per-client reconstructions (``fl.server``),
     while strategies declaring ``supports_fused_aggregate`` (3SFC) hand
     the *batched payloads* straight to ``strategy.server_aggregate`` —
     one replicated batched backward, no O(d) collective — so the fused
     decode is a strategy capability, not a special case here.

Fan-out notes (``run.client_parallel``)
---------------------------------------
* ``'vmap'``: single program; with a mesh attached, GSPMD partitions it.
* ``'shard_map'`` (requires ``run.mesh``): each device runs its *local*
  clients' ``local_train`` + encode; only the boundary communicates. The
  default path's gather is deliberately ``all_gather``-then-reduce instead
  of ``psum``: the all-reduce combiner order differs from a single-device
  axis reduction (measured ~1e-5 on 8 hosts), which would break the
  shard_map ≡ vmap oracle contract that keeps this pipeline testable. Per
  the HLO byte accounting both forms move the same O(d) operand bytes per
  device — a collective-order choice, not a bandwidth concession. The
  fused path's gather carries ONLY the tiny payloads (= the paper's
  compressed uplink, as on-mesh wire bytes).

Wire modes (``run.wire``)
-------------------------
* ``'float'``: messages are float trees; wire size is *accounted*
  (``payload_floats``, Eq. 1).
* ``'codec'`` (requires ``codec`` from ``repro.comm.make_codec``): each
  client serializes its payload into ONE framed ``uint8`` buffer inside
  the per-client region; only those buffers cross the boundary and the
  server decodes them before aggregating. ``RoundMetrics.wire_bytes_up``
  then reports the *measured* per-client uplink bytes. EF uses the codec's
  dequantized view, so client and server stay consistent; wherever the
  codec is lossless the round is bit-identical to float mode (gated by
  ``benchmarks/bench_wire.py``).

Metrics returned per round: mean local loss, per-client cosine compression
efficiency (paper Fig. 7), payload floats (paper Eq. 1 accounting), and the
measured uplink bytes (0 in float mode — nothing was serialized).

``make_fl_round`` is kept as a thin deprecated shim over
``build_fl_round`` for existing callers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FLConfig
from repro.configs.run import RunConfig
from repro.core import flat
from repro.core.strategy import CompressionStrategy, warn_deprecated_once
from repro.fl import faults as faults_lib
from repro.fl.client import local_train
from repro.fl.server import aggregate, server_update

PyTree = Any

# Named scope wrapping the per-client local-train + encode region; the
# collectives benchmark greps compiled-HLO metadata for this name to prove
# the region stays collective-free (tested in tests/test_hlo_analyzer.py).
CLIENT_SCOPE = "fl_client_local"


class FLState(NamedTuple):
    params: PyTree          # global model w^t
    ef: PyTree              # per-client EF residuals, leading axis N
    round: jax.Array
    # staleness ring buffer (repro.fl.faults): per params leaf a (S, *shape)
    # bank of weighted in-flight reconstructions + the (S,) arrived-weight
    # accumulator. None (an empty pytree node) whenever staleness_max == 0,
    # so zero-fault states keep the exact seed structure.
    buf: PyTree = None
    buf_w: Optional[jax.Array] = None


class RoundMetrics(NamedTuple):
    loss: jax.Array         # mean local training loss (participants only)
    cosine: jax.Array       # per-client compression efficiency (N,)
    payload_floats: jax.Array
    update_norm: jax.Array
    # measured per-client uplink bytes (wire='codec'); 0 in float mode
    wire_bytes_up: jax.Array = 0.0
    # total aggregation weight that arrived this round: N when healthy,
    # the renormalization denominator under faults (fresh + matured stale)
    arrivals: jax.Array = -1.0


def fl_init(params: PyTree, num_clients: int,
            strategy: Optional[CompressionStrategy] = None, *,
            staleness_max: int = 0) -> FLState:
    """Fresh round state; the EF residual comes from the strategy when one
    is given (zeros f32 mirroring params otherwise — the same default).
    ``staleness_max > 0`` attaches the zeroed staleness ring buffer."""
    if strategy is not None:
        ef1 = strategy.init_ef_state(params)
    else:
        ef1 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ef = jax.tree_util.tree_map(
        lambda e: jnp.broadcast_to(e, (num_clients, *e.shape)), ef1)
    buf, buf_w = faults_lib.init_stale_buffer(params, staleness_max)
    return FLState(params, ef, jnp.zeros((), jnp.int32), buf, buf_w)


def _check_codec(run: RunConfig, strategy: CompressionStrategy,
                 codec) -> None:
    """Validate the (wire, codec) pair for codec mode."""
    if run.wire == "float":
        return
    if codec is None:
        raise ValueError("wire='codec' requires a codec "
                         "(see repro.comm.make_codec)")
    if codec.kind != strategy.cfg.kind:
        raise ValueError(f"codec kind {codec.kind!r} does not match "
                         f"compressor kind {strategy.cfg.kind!r}")
    codec.check_round_wire()


def build_fl_round(
    loss_fn: Callable[[PyTree, Dict], jax.Array],
    strategy: CompressionStrategy,
    run: RunConfig,
    *,
    codec=None,
    fault_schedule_fn=None,
) -> Callable[[FLState, PyTree, jax.Array], Tuple[FLState, RoundMetrics]]:
    """THE round builder: one pipeline over (strategy × fan-out × wire).

    ``run.fused_decode`` requires ``strategy.supports_fused_aggregate``
    (§Perf beyond-paper optimization): the server aggregates straight from
    the gathered wire payloads — for 3SFC, since every ĝ_i is evaluated at
    the same w^t (Eq. 10),

        G(ĝ_1..ĝ_N) = ∇_w (1/N) Σ_i s_i F(D_syn,i, w^t),

    so the all_gather carries ONLY the tiny (D_syn, s) payloads and ONE
    replicated batched backward replaces the O(d) full-gradient collective.
    EF stays exact because each client updates its residual locally.

    ``run.has_faults`` switches in the masked fault pipeline
    (``repro.fl.faults``); ``fault_schedule_fn(round_idx, num_clients) ->
    FaultSchedule`` overrides the config-derived schedule and forces the
    masked pipeline even on a zero-fault config — the injection seam the
    fault harness uses to (a) prove the masked pipeline under a null
    schedule is bitwise the unfaulted round and (b) drive hand-written
    fault patterns in the EF-invariance tests. Injected schedules must
    respect ``run.staleness_max`` (delays > 0 need the ring buffer).
    """
    cfg: FLConfig = run.fl
    mesh: Optional[Mesh] = run.mesh
    axes = run.client_axes()
    fused = run.fused_decode
    faulted = run.has_faults or fault_schedule_fn is not None
    N = cfg.num_clients
    S = run.staleness_max
    if fused and not strategy.supports_fused_aggregate:
        raise ValueError(
            f"fused_decode requires a strategy with "
            f"supports_fused_aggregate; {strategy.cfg.kind!r} has none")
    if faulted and fused:
        if type(strategy).mask_payloads is CompressionStrategy.mask_payloads:
            raise ValueError(
                f"fused_decode under faults requires strategy "
                f"{strategy.cfg.kind!r} to implement mask_payloads "
                f"(weighting the batched wire payloads)")
    _check_codec(run, strategy, codec)
    # the fault stream is its own root key — fault patterns re-seed without
    # perturbing the data/compressor draws (fl.faults determinism contract)
    fault_key = jax.random.PRNGKey(run.fault_seed) if faulted else None

    # ---- client phase: local train + strategy encode ----------------------
    if run.wire == "codec":
        def encode(key_i, g, ef_i, params, cid, rnd):
            return strategy.wire_step(key_i, g, ef_i, params, codec=codec,
                                      round_idx=rnd, client_idx=cid)
    elif fused:
        def encode(key_i, g, ef_i, params, cid, rnd):
            return strategy.payload_step(key_i, g, ef_i, params)
    else:
        def encode(key_i, g, ef_i, params, cid, rnd):
            return strategy.step(key_i, g, ef_i, params)

    def client_core(global_params, ef_i, batches_i, key_i, cid, rnd):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=run.num_micro)
        msg, ef_new, metrics = encode(key_i, g, ef_i, global_params,
                                      cid, rnd)
        return g, msg, ef_new, loss, metrics

    if not faulted:
        def client_step(global_params, ef_i, batches_i, key_i, cid, rnd):
            _, msg, ef_new, loss, metrics = client_core(
                global_params, ef_i, batches_i, key_i, cid, rnd)
            return msg, ef_new, loss, metrics

        in_axes = (None, 0, 0, 0, 0, None)
    else:
        def client_step(global_params, ef_i, batches_i, key_i, cid, rnd,
                        part_i, deliv_i):
            g, msg, ef_new, loss, metrics = client_core(
                global_params, ef_i, batches_i, key_i, cid, rnd)
            # EF fault algebra (repro.fl.faults): a skipped client's
            # residual FREEZES; a dropped payload banks the whole
            # accumulated update u = g + e in the residual (nothing lost)
            # — with EF off there is no residual, the update is lost and
            # e stays whatever the strategy keeps it as. Pure per-client
            # `where` selects: no new collectives, bitwise inert when
            # part_i and deliv_i are both true.
            if strategy.cfg.error_feedback:
                ef_drop = strategy._accumulate(g, ef_i)
            else:
                ef_drop = ef_i
            ef_out = jax.tree_util.tree_map(
                lambda new, drop, old: jnp.where(
                    part_i, jnp.where(deliv_i, new, drop), old),
                ef_new, ef_drop, ef_i)
            return msg, ef_out, loss, metrics

        in_axes = (None, 0, 0, 0, 0, None, 0, 0)
    n_extra = 2 if faulted else 0

    # ---- transport boundary: the client fan-out ---------------------------
    if axes is None:
        def fanout(*args):
            return jax.vmap(client_step, in_axes=in_axes)(*args)
    else:
        def body(*args):
            with jax.named_scope(CLIENT_SCOPE):
                outs = jax.vmap(client_step, in_axes=in_axes)(*args)
            # ONE tiled all_gather of every output EXCEPT the
            # client-resident EF tree — the gathered operands are the wire
            # (recon trees, wire payloads or framed uint8 buffers). The
            # fault masks ride IN as client-sharded scalars (per-client
            # where-selects in the scope above), never adding a collective.
            gather = lambda x: jax.lax.all_gather(x, axes, tiled=True)
            return tuple(
                o if i == 1 else jax.tree_util.tree_map(gather, o)
                for i, o in enumerate(outs))

        fanout = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axes), P(axes), P(axes), P(axes), P())
            + (P(axes),) * n_extra,
            out_specs=tuple(P(axes) if i == 1 else P() for i in range(4)),
            check_rep=False,
        )

    def _replicate(x):
        # Explicit mesh plumbing for the vmap fused path: with no mesh the
        # constraint is a no-op by construction (single-process tests);
        # with one, the payloads are pinned replicated so the batched
        # backward runs on every device.
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))

    # ---- server phase: decode + aggregate + update + metrics --------------
    wire_bytes = codec.nbytes if run.wire == "codec" else 0.0

    def finish(state: FLState, agg, ef_new, loss, metrics, payload_floats,
               arrivals, buf, buf_w) -> Tuple[FLState, RoundMetrics]:
        new_params = server_update(state.params, agg, cfg.server_lr)
        ef_new = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), ef_new, state.ef)
        rm = RoundMetrics(
            loss=loss,
            cosine=metrics.cosine,
            payload_floats=payload_floats,
            update_norm=flat.tree_norm(agg),
            wire_bytes_up=jnp.float32(wire_bytes),
            arrivals=arrivals,
        )
        return FLState(new_params, ef_new, state.round + 1, buf, buf_w), rm

    def _mask_bcast(m, x):
        return m.reshape((-1,) + (1,) * (x.ndim - 1))

    def _faulted_aggregate(state: FLState, recons, sched, weights):
        """Masked/weighted aggregation + staleness-buffer turnover.

        Returns ``(agg, arrivals, buf, buf_w)``. The unweighted no-staleness
        branch is ``mean(where(mask, x, 0)) * (N/count)`` — count-correct
        renormalization that multiplies by *exactly* 1.0 under an
        all-healthy schedule, keeping the zero-fault round bitwise equal to
        the unfaulted pipeline (gated in benchmarks/bench_faults.py).
        """
        now = sched.arrives_now
        if S == 0 and weights is None:
            cnt = jnp.sum(now.astype(jnp.float32))
            ratio = jnp.where(cnt > 0, N / cnt, 0.0)
            agg = jax.tree_util.tree_map(
                lambda x: jnp.mean(jnp.where(_mask_bcast(now, x), x, 0),
                                   axis=0) * ratio,
                recons)
            return agg, cnt, state.buf, state.buf_w
        # generic path: staleness-weighted sum of fresh + matured payloads,
        # renormalized by the total arrived weight
        base_w = jnp.ones((N,), jnp.float32) if weights is None else weights
        w_now = jnp.where(now, sched.weight * base_w, 0.0)
        if S == 0:
            mature_w = jnp.float32(0.0)
            num = jax.tree_util.tree_map(
                lambda x: jnp.sum(_mask_bcast(w_now, x) * x, axis=0), recons)
            buf, buf_w = state.buf, state.buf_w
        else:
            if state.buf_w is None:
                raise ValueError(
                    "staleness_max > 0 requires an FLState carrying the "
                    "staleness buffer — init with fl_init(..., "
                    "staleness_max=run.staleness_max)")
            w_late = jnp.where(sched.arrives_late, sched.weight * base_w, 0.0)
            mature, mature_w, buf, buf_w = faults_lib.consume_and_bank(
                state.buf, state.buf_w, state.round, sched.delay, w_late,
                recons)
            num = jax.tree_util.tree_map(
                lambda x, m: jnp.sum(_mask_bcast(w_now, x) * x, axis=0) + m,
                recons, mature)
        den = jnp.sum(w_now) + mature_w
        inv = jnp.where(den > 0, 1.0 / den, 0.0)
        agg = jax.tree_util.tree_map(lambda x: x * inv, num)
        return agg, den, buf, buf_w

    def fl_round(state: FLState, client_batches: PyTree, key: jax.Array,
                 weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        cids = jnp.arange(cfg.num_clients, dtype=jnp.uint32)
        if faulted:
            if fault_schedule_fn is not None:
                sched = fault_schedule_fn(state.round, N)
            else:
                sched = faults_lib.fault_schedule(
                    fault_key, state.round, N,
                    participation_rate=run.participation_rate,
                    drop_rate=run.drop_rate,
                    straggler_rate=run.straggler_rate,
                    staleness_max=S)
            extra = (sched.participate, sched.delivered)
        else:
            sched = None
            extra = ()
        msgs, ef_new, losses, metrics = fanout(
            state.params, state.ef, client_batches, keys, cids, state.round,
            *extra)
        if faulted:
            # loss over participants only (mean × N/count: exact 1.0 when
            # everyone participates, same identity as the aggregate)
            cnt_p = jnp.sum(sched.participate.astype(jnp.float32))
            loss = jnp.mean(jnp.where(sched.participate, losses, 0.0)) * \
                jnp.where(cnt_p > 0, N / cnt_p, 0.0)
        else:
            loss = jnp.mean(losses)
        if fused:
            if axes is None:
                # vmap fan-out: the payloads are tiny -> pin replicated
                msgs = jax.tree_util.tree_map(_replicate, msgs)
            payloads = jax.vmap(codec.decode)(msgs) \
                if run.wire == "codec" else msgs
            # scalar, matching the default path's jnp.mean reduction
            pf = jnp.float32(strategy.payload_floats(state.params))
            if faulted:
                # fused faults: zero out undelivered payloads inside the
                # batched aggregate (S == 0 here by RunConfig validation),
                # then renormalize the mean over N to a mean over arrivals
                w = jnp.where(sched.arrives_now, jnp.float32(1.0),
                              jnp.float32(0.0))
                agg = strategy.server_aggregate(
                    state.params, strategy.mask_payloads(payloads, w))
                cnt = jnp.sum(w)
                agg = flat.tree_scale(
                    agg, jnp.where(cnt > 0, N / cnt, 0.0))
                return finish(state, agg, ef_new, loss, metrics, pf, cnt,
                              state.buf, state.buf_w)
            agg = strategy.server_aggregate(state.params, payloads)
            return finish(state, agg, ef_new, loss, metrics, pf,
                          jnp.float32(N), state.buf, state.buf_w)
        if run.wire == "codec":
            # (N, nbytes) uint8 -> per-client reconstruction trees
            canon = jax.vmap(codec.decode)(msgs)
            recons = jax.vmap(
                lambda c: codec.recon_tree(c, state.params))(canon)
        else:
            recons = msgs
        if faulted:
            agg, arrivals, buf, buf_w = _faulted_aggregate(
                state, recons, sched, weights)
            return finish(state, agg, ef_new, loss, metrics,
                          jnp.mean(metrics.payload_floats), arrivals,
                          buf, buf_w)
        # inputs are full (N, ...) arrays in client order on both fan-out
        # paths, so the reduction order — hence the result — is identical
        agg = aggregate(recons, weights)
        return finish(state, agg, ef_new, loss, metrics,
                      jnp.mean(metrics.payload_floats),
                      jnp.float32(N), state.buf, state.buf_w)

    return fl_round


# ---------------------------------------------------------------------------
# deprecated shim (PR 5): the old 10-knob factory over the new pipeline
# ---------------------------------------------------------------------------


def make_fl_round(
    loss_fn: Callable[[PyTree, Dict], jax.Array],
    compressor,
    cfg: FLConfig,
    *,
    num_micro: int = 1,
    fused_decode: bool = False,
    syn_loss_fn: Callable = None,
    syn_spec=None,
    client_parallel: str = "vmap",
    mesh: Optional[Mesh] = None,
    wire: str = "float",
    codec=None,
) -> Callable[[FLState, PyTree, jax.Array], Tuple[FLState, RoundMetrics]]:
    """Deprecated: build a ``RunConfig`` and call ``build_fl_round``.

    ``compressor`` may be a ``TreeCompressor`` (its strategy is used) or a
    ``CompressionStrategy`` directly. The legacy ``syn_loss_fn``/``syn_spec``
    pair is required with ``fused_decode`` for signature compatibility but
    the strategy's own hooks (identical by construction) do the work.
    """
    warn_deprecated_once(
        "make_fl_round",
        "repro.fl.round.build_fl_round(loss_fn, strategy, RunConfig(...))")
    if fused_decode:
        assert syn_loss_fn is not None and syn_spec is not None, \
            "fused_decode needs the 3SFC syn_loss_fn + syn_spec"
    strategy = getattr(compressor, "strategy", compressor)
    run = RunConfig(fl=cfg, client_parallel=client_parallel, wire=wire,
                    fused_decode=fused_decode, num_micro=num_micro,
                    mesh=mesh)
    return build_fl_round(loss_fn, strategy, run, codec=codec)


# convenience alias used in docs/examples
fl_round = make_fl_round
