"""One federated round, end to end, as a single jit/pjit-able function.

``make_fl_round(loss_fn, compressor, fl_cfg)`` closes over the model loss and
the compressor and returns ``fl_round(state, client_batches, key)``:

  1. every client runs K local SGD steps (vmapped over the client axis —
     on the production mesh the client axis is sharded over ('pod','data')),
  2. each client EF-compresses its accumulated update (3SFC encode / top-k /
     sign / ... — per-client, no cross-client collectives),
  3. the server aggregates reconstructions and updates the global model
     (paper Eq. 6). For 3SFC the reconstruction is, by Eq. 10, exactly what
     the server's decoder produces from (D_syn, s) — the exactness is a
     tested property (tests/test_threesfc.py::test_decode_matches_encoder).

Metrics returned per round: mean local loss, per-client cosine compression
efficiency (paper Fig. 7), payload floats (paper Eq. 1 accounting).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import flat
from repro.core.compressor import TreeCompressor
from repro.fl.client import local_train
from repro.fl.server import aggregate, server_update

PyTree = Any


class FLState(NamedTuple):
    params: PyTree          # global model w^t
    ef: PyTree              # per-client EF residuals, leading axis N
    round: jax.Array


class RoundMetrics(NamedTuple):
    loss: jax.Array         # mean local training loss
    cosine: jax.Array       # per-client compression efficiency (N,)
    payload_floats: jax.Array
    update_norm: jax.Array


def fl_init(params: PyTree, num_clients: int) -> FLState:
    ef1 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ef = jax.tree_util.tree_map(
        lambda e: jnp.broadcast_to(e, (num_clients, *e.shape)), ef1)
    return FLState(params, ef, jnp.zeros((), jnp.int32))


def make_fl_round(
    loss_fn: Callable[[PyTree, Dict], jax.Array],
    compressor: TreeCompressor,
    cfg: FLConfig,
    *,
    num_micro: int = 1,
    fused_decode: bool = False,
    syn_loss_fn: Callable = None,
    syn_spec=None,
) -> Callable[[FLState, PyTree, jax.Array], Tuple[FLState, RoundMetrics]]:
    """``fused_decode`` (3SFC only, §Perf beyond-paper optimization):

    The naive server path decodes per client (each recon is a FULL
    param-sized tree) and averages over the sharded client axis — an
    all-reduce of d floats, identical to FedAvg's collective bill. But since
    every ĝ_i is evaluated at the same w^t (Eq. 10),

        G(ĝ_1..ĝ_N) = ∇_w (1/N) Σ_i s_i F(D_syn,i, w^t),

    so the server can ALL-GATHER only the tiny (D_syn, s) payloads over the
    client axis (= the paper's compressed uplink, as wire bytes) and run ONE
    replicated batched backward. The full-gradient collective disappears;
    EF stays exact because each client computes its own recon locally.
    """

    def one_client(global_params, ef_i, batches_i, key_i):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        recon, ef_new, metrics = compressor.step(key_i, g, ef_i, global_params)
        return recon, ef_new, loss, metrics

    def fl_round(state: FLState, client_batches: PyTree, key: jax.Array,
                 weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        recons, ef_new, losses, metrics = jax.vmap(
            one_client, in_axes=(None, 0, 0, 0))(
            state.params, state.ef, client_batches, keys)
        agg = aggregate(recons, weights)
        new_params = server_update(state.params, agg, cfg.server_lr)
        ef_new = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), ef_new, state.ef)
        rm = RoundMetrics(
            loss=jnp.mean(losses),
            cosine=metrics.cosine,
            payload_floats=jnp.mean(metrics.payload_floats),
            update_norm=flat.tree_norm(agg),
        )
        return FLState(new_params, ef_new, state.round + 1), rm

    if not fused_decode:
        return fl_round

    assert syn_loss_fn is not None and syn_spec is not None, \
        "fused_decode needs the 3SFC syn_loss_fn + syn_spec"
    from jax.sharding import PartitionSpec as P
    from repro.core import threesfc
    from repro.kernels import ops

    ccfg = cfg.compressor

    def one_client_fused(global_params, ef_i, batches_i, key_i):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        u = flat.tree_add(g, ef_i) if ccfg.error_feedback else g
        syn0 = threesfc.init_syn(key_i, syn_spec)
        res = threesfc.encode(syn_loss_fn, global_params, u, syn0,
                              steps=ccfg.syn_steps, lr=ccfg.syn_lr,
                              lam=ccfg.l2_coef)
        # EF update is client-local (recon never crosses the network); the
        # fused e' = u − s·∇F stream means the recon tree is NEVER
        # materialized on this path — the server rebuilds it from (D_syn, s).
        ef_new = ops.tree_ef_update(u, res.gw, res.s) \
            if ccfg.error_feedback else ef_i
        return res.syn, res.s, ef_new, loss, res.cosine

    def _replicate(x):
        try:
            return jax.lax.with_sharding_constraint(
                x, P(*([None] * x.ndim)))
        except Exception:                      # no mesh context (tests)
            return x

    def fl_round_fused(state: FLState, client_batches: PyTree,
                       key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        syns, ss, ef_new, losses, cosines = jax.vmap(
            one_client_fused, in_axes=(None, 0, 0, 0))(
            state.params, state.ef, client_batches, keys)
        # the wire: all-gather ONLY the payloads (tiny) -> replicated
        syns = jax.tree_util.tree_map(_replicate, syns)
        ss = _replicate(ss)

        def total_loss(w):
            per = jax.vmap(lambda sy: syn_loss_fn(w, sy))(syns)   # (N,)
            return jnp.mean(jax.lax.stop_gradient(ss) * per)

        agg = jax.grad(total_loss)(state.params)                  # ONE backward
        new_params = server_update(state.params, agg, cfg.server_lr)
        ef_new = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), ef_new, state.ef)
        rm = RoundMetrics(
            loss=jnp.mean(losses),
            cosine=cosines,
            # scalar, matching the default path's jnp.mean reduction
            payload_floats=jnp.float32(syn_spec.floats + 1),
            update_norm=flat.tree_norm(agg),
        )
        return FLState(new_params, ef_new, state.round + 1), rm

    return fl_round_fused


# convenience alias used in docs/examples
fl_round = make_fl_round
