"""One federated round, end to end, as a single jit/pjit-able function.

``make_fl_round(loss_fn, compressor, fl_cfg)`` closes over the model loss and
the compressor and returns ``fl_round(state, client_batches, key)``:

  1. every client runs K local SGD steps (mapped over the client axis),
  2. each client EF-compresses its accumulated update (3SFC encode / top-k /
     sign / ... — per-client, no cross-client collectives),
  3. the server aggregates reconstructions and updates the global model
     (paper Eq. 6). For 3SFC the reconstruction is, by Eq. 10, exactly what
     the server's decoder produces from (D_syn, s) — the exactness is a
     tested property (tests/test_threesfc.py::test_decode_matches_encoder).

Client fan-out (``client_parallel``)
------------------------------------
* ``'vmap'`` (default): the client axis is a plain vmap — single-device
  reference semantics, and the bit-exactness oracle for the sharded path.
* ``'shard_map'`` (requires ``mesh``): each device runs its *local* clients'
  ``local_train`` + encode under ``jax.shard_map`` over ``client_axes(mesh)``
  with ZERO cross-client collectives in the per-client region (gated from
  the compiled HLO by ``benchmarks/bench_collectives.py`` via the
  ``CLIENT_SCOPE`` named scope). Only the shard_map *boundary* communicates:

  - default path: one tiled ``all_gather`` of the per-client reconstructions
    (the O(d)-per-device full-gradient collective — FedAvg's wire bill),
    then the server aggregate/update runs replicated with bitwise the same
    reduction order as the vmap oracle. An ``all_gather``-then-reduce is
    deliberately used instead of ``psum``: the CPU/TPU all-reduce combiner
    order differs from a single-device axis reduction (measured ~1e-5 on 8
    hosts), which would break the shard_map ≡ vmap oracle contract that
    keeps this refactor testable. Per the HLO byte accounting both forms
    move the same O(d) operand bytes per device — this is a collective-order
    choice, not a bandwidth concession.
  - fused 3SFC path: the ``all_gather`` carries ONLY the tiny ``(D_syn, s)``
    payload trees (= the paper's compressed uplink, as on-mesh wire bytes),
    and the single batched server backward runs replicated. The O(d)
    collective disappears entirely.

Wire modes (``wire``)
---------------------
* ``'float'`` (default): reconstructions cross the client/server boundary as
  float trees; wire size is *accounted* (``payload_floats``, Eq. 1).
* ``'codec'`` (requires ``codec`` from ``repro.comm.make_codec``): each
  client serializes its payload into ONE framed ``uint8`` buffer
  (``compressor.wire_step``) inside the per-client region; only those
  buffers cross the boundary (the shard_map path all-gathers the uint8
  frames instead of float trees) and the server decodes them before
  aggregating. ``RoundMetrics.wire_bytes_up`` then reports the *measured*
  per-client uplink bytes. EF uses the codec's dequantized view, so client
  and server stay consistent; wherever the codec is lossless the round is
  bit-identical to float mode (gated by ``benchmarks/bench_wire.py``).

Metrics returned per round: mean local loss, per-client cosine compression
efficiency (paper Fig. 7), payload floats (paper Eq. 1 accounting), and the
measured uplink bytes (0 in float mode — nothing was serialized).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FLConfig
from repro.core import flat
from repro.core.compressor import TreeCompressor
from repro.fl.client import local_train
from repro.fl.server import aggregate, server_update

PyTree = Any

# Named scope wrapping the per-client local-train + encode region; the
# collectives benchmark greps compiled-HLO metadata for this name to prove
# the region stays collective-free (tested in tests/test_hlo_analyzer.py).
CLIENT_SCOPE = "fl_client_local"


class FLState(NamedTuple):
    params: PyTree          # global model w^t
    ef: PyTree              # per-client EF residuals, leading axis N
    round: jax.Array


class RoundMetrics(NamedTuple):
    loss: jax.Array         # mean local training loss
    cosine: jax.Array       # per-client compression efficiency (N,)
    payload_floats: jax.Array
    update_norm: jax.Array
    # measured per-client uplink bytes (wire='codec'); 0 in float mode
    wire_bytes_up: jax.Array = 0.0


def fl_init(params: PyTree, num_clients: int) -> FLState:
    ef1 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ef = jax.tree_util.tree_map(
        lambda e: jnp.broadcast_to(e, (num_clients, *e.shape)), ef1)
    return FLState(params, ef, jnp.zeros((), jnp.int32))


def _check_fanout(cfg: FLConfig, client_parallel: str,
                  mesh: Optional[Mesh]) -> Optional[Tuple[str, ...]]:
    """Validate the (client_parallel, mesh) pair; returns the client axes
    for the shard_map path (None for vmap). The shard-count/divisibility
    policy is FLShardings' — one source of truth for the mesh contract
    (imported lazily: sharding.py imports this module at top level)."""
    if client_parallel not in ("vmap", "shard_map"):
        raise ValueError(
            f"client_parallel must be 'vmap' or 'shard_map', got "
            f"{client_parallel!r}")
    if client_parallel == "vmap":
        return None
    if mesh is None:
        raise ValueError("client_parallel='shard_map' requires an explicit "
                         "mesh (see repro.fl.sharding.make_fl_shardings)")
    from repro.fl.sharding import make_fl_shardings
    sh = make_fl_shardings(mesh)
    sh.check_divisible(cfg.num_clients)
    return sh.axes


def _check_wire(cfg: FLConfig, wire: str, codec) -> None:
    """Validate the (wire, codec) pair for codec mode."""
    if wire not in ("float", "codec"):
        raise ValueError(f"wire must be 'float' or 'codec', got {wire!r}")
    if wire == "float":
        return
    if codec is None:
        raise ValueError("wire='codec' requires a codec "
                         "(see repro.comm.make_codec)")
    if codec.kind != cfg.compressor.kind:
        raise ValueError(f"codec kind {codec.kind!r} does not match "
                         f"compressor kind {cfg.compressor.kind!r}")
    if cfg.compressor.kind == "threesfc" and codec.policy != "fp32":
        raise ValueError(
            "the round's wire mode requires the lossless fp32 policy for "
            "threesfc (client EF runs on the factored (gw, s)); lossy "
            "policies are a codec-level feature")


def make_fl_round(
    loss_fn: Callable[[PyTree, Dict], jax.Array],
    compressor: TreeCompressor,
    cfg: FLConfig,
    *,
    num_micro: int = 1,
    fused_decode: bool = False,
    syn_loss_fn: Callable = None,
    syn_spec=None,
    client_parallel: str = "vmap",
    mesh: Optional[Mesh] = None,
    wire: str = "float",
    codec=None,
) -> Callable[[FLState, PyTree, jax.Array], Tuple[FLState, RoundMetrics]]:
    """``fused_decode`` (3SFC only, §Perf beyond-paper optimization):

    The naive server path decodes per client (each recon is a FULL
    param-sized tree) and gathers it over the sharded client axis — an O(d)
    per-device collective, identical to FedAvg's bill. But since every ĝ_i
    is evaluated at the same w^t (Eq. 10),

        G(ĝ_1..ĝ_N) = ∇_w (1/N) Σ_i s_i F(D_syn,i, w^t),

    so the server can ALL-GATHER only the tiny (D_syn, s) payloads over the
    client axis (= the paper's compressed uplink, as wire bytes) and run ONE
    replicated batched backward. The full-gradient collective disappears;
    EF stays exact because each client computes its own recon locally.

    ``client_parallel='shard_map'`` + ``mesh`` turns either path into the
    explicitly sharded fan-out (see module docstring); ``mesh`` alone (with
    the default vmap fan-out) pins the fused path's replication constraint
    to that mesh instead of relying on an ambient mesh context.
    """
    axes = _check_fanout(cfg, client_parallel, mesh)
    _check_wire(cfg, wire, codec)

    def one_client(global_params, ef_i, batches_i, key_i):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        recon, ef_new, metrics = compressor.step(key_i, g, ef_i, global_params)
        return recon, ef_new, loss, metrics

    def _server_step(state: FLState, recons, ef_new, losses, metrics,
                     weights, wire_bytes=0.0) -> Tuple[FLState, RoundMetrics]:
        """Shared server half: aggregate + update + metrics packaging.
        Inputs are full (N, ...) arrays in client order on both fan-out
        paths, so the reduction order — hence the result — is identical."""
        agg = aggregate(recons, weights)
        new_params = server_update(state.params, agg, cfg.server_lr)
        ef_new = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), ef_new, state.ef)
        rm = RoundMetrics(
            loss=jnp.mean(losses),
            cosine=metrics.cosine,
            payload_floats=jnp.mean(metrics.payload_floats),
            update_norm=flat.tree_norm(agg),
            wire_bytes_up=jnp.float32(wire_bytes),
        )
        return FLState(new_params, ef_new, state.round + 1), rm

    def _shard_fanout(client_fn, *, ef_pos, n_out, extra_in_axes=(),
                      extra_specs=()):
        """The ONE shard_map fan-out all four sharded variants share: vmap
        the local clients inside the (HLO-gated) collective-free
        ``CLIENT_SCOPE``, then ONE tiled all_gather of every output EXCEPT
        the client-resident EF tree at ``ef_pos`` — the gathered operands
        are the wire (full recon trees, (D_syn, s) payloads, or framed
        uint8 buffers, depending on the variant)."""
        in_axes = (None, 0, 0, 0) + extra_in_axes

        def body(global_params, ef, batches, keys_, *extra):
            with jax.named_scope(CLIENT_SCOPE):
                outs = jax.vmap(client_fn, in_axes=in_axes)(
                    global_params, ef, batches, keys_, *extra)
            gather = lambda x: jax.lax.all_gather(x, axes, tiled=True)
            return tuple(
                o if i == ef_pos else jax.tree_util.tree_map(gather, o)
                for i, o in enumerate(outs))

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axes), P(axes), P(axes)) + extra_specs,
            out_specs=tuple(P(axes) if i == ef_pos else P()
                            for i in range(n_out)),
            check_rep=False,
        )

    def fl_round(state: FLState, client_batches: PyTree, key: jax.Array,
                 weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        recons, ef_new, losses, metrics = jax.vmap(
            one_client, in_axes=(None, 0, 0, 0))(
            state.params, state.ef, client_batches, keys)
        return _server_step(state, recons, ef_new, losses, metrics, weights)

    # ---- codec wire mode: only framed uint8 buffers cross the boundary ----

    def one_client_wire(global_params, ef_i, batches_i, key_i, cid, rnd):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        buf, ef_new, metrics = compressor.wire_step(
            key_i, g, ef_i, global_params, codec=codec,
            round_idx=rnd, client_idx=cid)
        return buf, ef_new, loss, metrics

    def _decode_recons(bufs, params):
        """(N, nbytes) uint8 -> per-client reconstruction trees (server)."""
        canon = jax.vmap(codec.decode)(bufs)
        return jax.vmap(lambda c: codec.recon_tree(c, params))(canon)

    def fl_round_wire(state: FLState, client_batches: PyTree, key: jax.Array,
                      weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        cids = jnp.arange(cfg.num_clients, dtype=jnp.uint32)
        bufs, ef_new, losses, metrics = jax.vmap(
            one_client_wire, in_axes=(None, 0, 0, 0, 0, None))(
            state.params, state.ef, client_batches, keys, cids, state.round)
        recons = _decode_recons(bufs, state.params)
        return _server_step(state, recons, ef_new, losses, metrics, weights,
                            wire_bytes=codec.nbytes)

    def fl_round_wire_shard(state: FLState, client_batches: PyTree,
                            key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        cids = jnp.arange(cfg.num_clients, dtype=jnp.uint32)
        # the wire: framed uint8 buffers only — N * codec.nbytes per round
        bufs, ef_new, losses, metrics = _shard_fanout(
            one_client_wire, ef_pos=1, n_out=4,
            extra_in_axes=(0, None), extra_specs=(P(axes), P()))(
            state.params, state.ef, client_batches, keys, cids, state.round)
        recons = _decode_recons(bufs, state.params)
        return _server_step(state, recons, ef_new, losses, metrics, weights,
                            wire_bytes=codec.nbytes)

    def fl_round_shard(state: FLState, client_batches: PyTree, key: jax.Array,
                       weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        # the wire: the gathered recons are O(d) per device — FedAvg's bill
        recons, ef_new, losses, metrics = _shard_fanout(
            one_client, ef_pos=1, n_out=4)(
            state.params, state.ef, client_batches, keys)
        return _server_step(state, recons, ef_new, losses, metrics, weights)

    if not fused_decode:
        if wire == "codec":
            return fl_round_wire if axes is None else fl_round_wire_shard
        return fl_round if axes is None else fl_round_shard

    assert syn_loss_fn is not None and syn_spec is not None, \
        "fused_decode needs the 3SFC syn_loss_fn + syn_spec"
    from repro.core import threesfc
    from repro.kernels import ops

    ccfg = cfg.compressor

    def one_client_fused(global_params, ef_i, batches_i, key_i):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        u = flat.tree_add(g, ef_i) if ccfg.error_feedback else g
        syn0 = threesfc.init_syn(key_i, syn_spec)
        res = threesfc.encode(syn_loss_fn, global_params, u, syn0,
                              steps=ccfg.syn_steps, lr=ccfg.syn_lr,
                              lam=ccfg.l2_coef)
        # EF update is client-local (recon never crosses the network); the
        # fused e' = u − s·∇F stream means the recon tree is NEVER
        # materialized on this path — the server rebuilds it from (D_syn, s).
        ef_new = ops.tree_ef_update(u, res.gw, res.s) \
            if ccfg.error_feedback else ef_i
        return res.syn, res.s, ef_new, loss, res.cosine

    def _replicate(x):
        # Explicit mesh plumbing: with no mesh the constraint is a no-op by
        # construction (single-process tests); with one, the payloads are
        # pinned replicated so the batched backward runs on every device.
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))

    def _fused_server_step(state, syns, ss, ef_new, losses, cosines,
                           wire_bytes=0.0):
        """Shared fused server half: ONE replicated batched backward over
        the gathered (D_syn, s) payloads (identical on both fan-out paths)."""
        def total_loss(w):
            per = jax.vmap(lambda sy: syn_loss_fn(w, sy))(syns)   # (N,)
            return jnp.mean(jax.lax.stop_gradient(ss) * per)

        agg = jax.grad(total_loss)(state.params)                  # ONE backward
        new_params = server_update(state.params, agg, cfg.server_lr)
        ef_new = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), ef_new, state.ef)
        rm = RoundMetrics(
            loss=jnp.mean(losses),
            cosine=cosines,
            # scalar, matching the default path's jnp.mean reduction
            payload_floats=jnp.float32(syn_spec.floats + 1),
            update_norm=flat.tree_norm(agg),
            wire_bytes_up=jnp.float32(wire_bytes),
        )
        return FLState(new_params, ef_new, state.round + 1), rm

    def fl_round_fused(state: FLState, client_batches: PyTree,
                       key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        syns, ss, ef_new, losses, cosines = jax.vmap(
            one_client_fused, in_axes=(None, 0, 0, 0))(
            state.params, state.ef, client_batches, keys)
        # the wire: the payloads are tiny -> replicated
        syns = jax.tree_util.tree_map(_replicate, syns)
        ss = _replicate(ss)
        return _fused_server_step(state, syns, ss, ef_new, losses, cosines)

    def fl_round_fused_shard(state: FLState, client_batches: PyTree,
                             key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        # the wire: all-gather ONLY the (D_syn, s) payloads — O(N·payload)
        # bytes, never the O(d) reconstruction trees
        syns, ss, ef_new, losses, cosines = _shard_fanout(
            one_client_fused, ef_pos=2, n_out=5)(
            state.params, state.ef, client_batches, keys)
        return _fused_server_step(state, syns, ss, ef_new, losses, cosines)

    # ---- fused + codec wire: the gathered payload IS the encoded frame ----

    def one_client_fused_wire(global_params, ef_i, batches_i, key_i, cid, rnd):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        u = flat.tree_add(g, ef_i) if ccfg.error_feedback else g
        syn0 = threesfc.init_syn(key_i, syn_spec)
        res = threesfc.encode(syn_loss_fn, global_params, u, syn0,
                              steps=ccfg.syn_steps, lr=ccfg.syn_lr,
                              lam=ccfg.l2_coef)
        buf = codec.encode((res.syn, res.s), round_idx=rnd, client_idx=cid)
        ef_new = ops.tree_ef_update(u, res.gw, res.s) \
            if ccfg.error_feedback else ef_i
        return buf, ef_new, loss, res.cosine

    def _decode_payloads(bufs):
        """(N, nbytes) uint8 -> batched (D_syn, s) for the server backward."""
        syns, ss = jax.vmap(codec.decode)(bufs)
        return syns, ss

    def fl_round_fused_wire(state: FLState, client_batches: PyTree,
                            key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        cids = jnp.arange(cfg.num_clients, dtype=jnp.uint32)
        bufs, ef_new, losses, cosines = jax.vmap(
            one_client_fused_wire, in_axes=(None, 0, 0, 0, 0, None))(
            state.params, state.ef, client_batches, keys, cids, state.round)
        syns, ss = _decode_payloads(_replicate(bufs))
        return _fused_server_step(state, syns, ss, ef_new, losses, cosines,
                                  wire_bytes=codec.nbytes)

    def fl_round_fused_wire_shard(state: FLState, client_batches: PyTree,
                                  key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        cids = jnp.arange(cfg.num_clients, dtype=jnp.uint32)
        # the wire: all-gather ONLY the framed (D_syn, s) bytes —
        # O(N·nbytes), the paper's compressed uplink as measured bytes
        bufs, ef_new, losses, cosines = _shard_fanout(
            one_client_fused_wire, ef_pos=1, n_out=4,
            extra_in_axes=(0, None), extra_specs=(P(axes), P()))(
            state.params, state.ef, client_batches, keys, cids, state.round)
        syns, ss = _decode_payloads(bufs)
        return _fused_server_step(state, syns, ss, ef_new, losses, cosines,
                                  wire_bytes=codec.nbytes)

    if wire == "codec":
        return fl_round_fused_wire if axes is None else fl_round_fused_wire_shard
    return fl_round_fused if axes is None else fl_round_fused_shard


# convenience alias used in docs/examples
fl_round = make_fl_round
