"""One federated round, end to end, as a single jit/pjit-able function.

``make_fl_round(loss_fn, compressor, fl_cfg)`` closes over the model loss and
the compressor and returns ``fl_round(state, client_batches, key)``:

  1. every client runs K local SGD steps (mapped over the client axis),
  2. each client EF-compresses its accumulated update (3SFC encode / top-k /
     sign / ... — per-client, no cross-client collectives),
  3. the server aggregates reconstructions and updates the global model
     (paper Eq. 6). For 3SFC the reconstruction is, by Eq. 10, exactly what
     the server's decoder produces from (D_syn, s) — the exactness is a
     tested property (tests/test_threesfc.py::test_decode_matches_encoder).

Client fan-out (``client_parallel``)
------------------------------------
* ``'vmap'`` (default): the client axis is a plain vmap — single-device
  reference semantics, and the bit-exactness oracle for the sharded path.
* ``'shard_map'`` (requires ``mesh``): each device runs its *local* clients'
  ``local_train`` + encode under ``jax.shard_map`` over ``client_axes(mesh)``
  with ZERO cross-client collectives in the per-client region (gated from
  the compiled HLO by ``benchmarks/bench_collectives.py`` via the
  ``CLIENT_SCOPE`` named scope). Only the shard_map *boundary* communicates:

  - default path: one tiled ``all_gather`` of the per-client reconstructions
    (the O(d)-per-device full-gradient collective — FedAvg's wire bill),
    then the server aggregate/update runs replicated with bitwise the same
    reduction order as the vmap oracle. An ``all_gather``-then-reduce is
    deliberately used instead of ``psum``: the CPU/TPU all-reduce combiner
    order differs from a single-device axis reduction (measured ~1e-5 on 8
    hosts), which would break the shard_map ≡ vmap oracle contract that
    keeps this refactor testable. Per the HLO byte accounting both forms
    move the same O(d) operand bytes per device — this is a collective-order
    choice, not a bandwidth concession.
  - fused 3SFC path: the ``all_gather`` carries ONLY the tiny ``(D_syn, s)``
    payload trees (= the paper's compressed uplink, as on-mesh wire bytes),
    and the single batched server backward runs replicated. The O(d)
    collective disappears entirely.

Metrics returned per round: mean local loss, per-client cosine compression
efficiency (paper Fig. 7), payload floats (paper Eq. 1 accounting).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FLConfig
from repro.core import flat
from repro.core.compressor import TreeCompressor
from repro.fl.client import local_train
from repro.fl.server import aggregate, server_update

PyTree = Any

# Named scope wrapping the per-client local-train + encode region; the
# collectives benchmark greps compiled-HLO metadata for this name to prove
# the region stays collective-free (tested in tests/test_hlo_analyzer.py).
CLIENT_SCOPE = "fl_client_local"


class FLState(NamedTuple):
    params: PyTree          # global model w^t
    ef: PyTree              # per-client EF residuals, leading axis N
    round: jax.Array


class RoundMetrics(NamedTuple):
    loss: jax.Array         # mean local training loss
    cosine: jax.Array       # per-client compression efficiency (N,)
    payload_floats: jax.Array
    update_norm: jax.Array


def fl_init(params: PyTree, num_clients: int) -> FLState:
    ef1 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ef = jax.tree_util.tree_map(
        lambda e: jnp.broadcast_to(e, (num_clients, *e.shape)), ef1)
    return FLState(params, ef, jnp.zeros((), jnp.int32))


def _check_fanout(cfg: FLConfig, client_parallel: str,
                  mesh: Optional[Mesh]) -> Optional[Tuple[str, ...]]:
    """Validate the (client_parallel, mesh) pair; returns the client axes
    for the shard_map path (None for vmap). The shard-count/divisibility
    policy is FLShardings' — one source of truth for the mesh contract
    (imported lazily: sharding.py imports this module at top level)."""
    if client_parallel not in ("vmap", "shard_map"):
        raise ValueError(
            f"client_parallel must be 'vmap' or 'shard_map', got "
            f"{client_parallel!r}")
    if client_parallel == "vmap":
        return None
    if mesh is None:
        raise ValueError("client_parallel='shard_map' requires an explicit "
                         "mesh (see repro.fl.sharding.make_fl_shardings)")
    from repro.fl.sharding import make_fl_shardings
    sh = make_fl_shardings(mesh)
    sh.check_divisible(cfg.num_clients)
    return sh.axes


def make_fl_round(
    loss_fn: Callable[[PyTree, Dict], jax.Array],
    compressor: TreeCompressor,
    cfg: FLConfig,
    *,
    num_micro: int = 1,
    fused_decode: bool = False,
    syn_loss_fn: Callable = None,
    syn_spec=None,
    client_parallel: str = "vmap",
    mesh: Optional[Mesh] = None,
) -> Callable[[FLState, PyTree, jax.Array], Tuple[FLState, RoundMetrics]]:
    """``fused_decode`` (3SFC only, §Perf beyond-paper optimization):

    The naive server path decodes per client (each recon is a FULL
    param-sized tree) and gathers it over the sharded client axis — an O(d)
    per-device collective, identical to FedAvg's bill. But since every ĝ_i
    is evaluated at the same w^t (Eq. 10),

        G(ĝ_1..ĝ_N) = ∇_w (1/N) Σ_i s_i F(D_syn,i, w^t),

    so the server can ALL-GATHER only the tiny (D_syn, s) payloads over the
    client axis (= the paper's compressed uplink, as wire bytes) and run ONE
    replicated batched backward. The full-gradient collective disappears;
    EF stays exact because each client computes its own recon locally.

    ``client_parallel='shard_map'`` + ``mesh`` turns either path into the
    explicitly sharded fan-out (see module docstring); ``mesh`` alone (with
    the default vmap fan-out) pins the fused path's replication constraint
    to that mesh instead of relying on an ambient mesh context.
    """
    axes = _check_fanout(cfg, client_parallel, mesh)

    def one_client(global_params, ef_i, batches_i, key_i):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        recon, ef_new, metrics = compressor.step(key_i, g, ef_i, global_params)
        return recon, ef_new, loss, metrics

    def _server_step(state: FLState, recons, ef_new, losses, metrics,
                     weights) -> Tuple[FLState, RoundMetrics]:
        """Shared server half: aggregate + update + metrics packaging.
        Inputs are full (N, ...) arrays in client order on both fan-out
        paths, so the reduction order — hence the result — is identical."""
        agg = aggregate(recons, weights)
        new_params = server_update(state.params, agg, cfg.server_lr)
        ef_new = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), ef_new, state.ef)
        rm = RoundMetrics(
            loss=jnp.mean(losses),
            cosine=metrics.cosine,
            payload_floats=jnp.mean(metrics.payload_floats),
            update_norm=flat.tree_norm(agg),
        )
        return FLState(new_params, ef_new, state.round + 1), rm

    def fl_round(state: FLState, client_batches: PyTree, key: jax.Array,
                 weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        recons, ef_new, losses, metrics = jax.vmap(
            one_client, in_axes=(None, 0, 0, 0))(
            state.params, state.ef, client_batches, keys)
        return _server_step(state, recons, ef_new, losses, metrics, weights)

    def fl_round_shard(state: FLState, client_batches: PyTree, key: jax.Array,
                       weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)

        def body(global_params, ef, batches, keys_):
            # per-client region: local clients only, NO collectives (gated)
            with jax.named_scope(CLIENT_SCOPE):
                recons, ef_new, losses, metrics = jax.vmap(
                    one_client, in_axes=(None, 0, 0, 0))(
                    global_params, ef, batches, keys_)
            # the wire: one tiled gather per tree reassembles client order
            gather = lambda x: jax.lax.all_gather(x, axes, tiled=True)
            recons = jax.tree_util.tree_map(gather, recons)
            losses = gather(losses)
            metrics = type(metrics)(*(gather(m) for m in metrics))
            return recons, ef_new, losses, metrics

        recons, ef_new, losses, metrics = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axes), P(axes), P(axes)),
            out_specs=(P(), P(axes), P(), P()),
            check_rep=False,
        )(state.params, state.ef, client_batches, keys)
        return _server_step(state, recons, ef_new, losses, metrics, weights)

    if not fused_decode:
        return fl_round if axes is None else fl_round_shard

    assert syn_loss_fn is not None and syn_spec is not None, \
        "fused_decode needs the 3SFC syn_loss_fn + syn_spec"
    from repro.core import threesfc
    from repro.kernels import ops

    ccfg = cfg.compressor

    def one_client_fused(global_params, ef_i, batches_i, key_i):
        g, loss = local_train(loss_fn, global_params, batches_i,
                              cfg.local_lr, num_micro=num_micro)
        u = flat.tree_add(g, ef_i) if ccfg.error_feedback else g
        syn0 = threesfc.init_syn(key_i, syn_spec)
        res = threesfc.encode(syn_loss_fn, global_params, u, syn0,
                              steps=ccfg.syn_steps, lr=ccfg.syn_lr,
                              lam=ccfg.l2_coef)
        # EF update is client-local (recon never crosses the network); the
        # fused e' = u − s·∇F stream means the recon tree is NEVER
        # materialized on this path — the server rebuilds it from (D_syn, s).
        ef_new = ops.tree_ef_update(u, res.gw, res.s) \
            if ccfg.error_feedback else ef_i
        return res.syn, res.s, ef_new, loss, res.cosine

    def _replicate(x):
        # Explicit mesh plumbing: with no mesh the constraint is a no-op by
        # construction (single-process tests); with one, the payloads are
        # pinned replicated so the batched backward runs on every device.
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))

    def _fused_server_step(state, syns, ss, ef_new, losses, cosines):
        """Shared fused server half: ONE replicated batched backward over
        the gathered (D_syn, s) payloads (identical on both fan-out paths)."""
        def total_loss(w):
            per = jax.vmap(lambda sy: syn_loss_fn(w, sy))(syns)   # (N,)
            return jnp.mean(jax.lax.stop_gradient(ss) * per)

        agg = jax.grad(total_loss)(state.params)                  # ONE backward
        new_params = server_update(state.params, agg, cfg.server_lr)
        ef_new = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), ef_new, state.ef)
        rm = RoundMetrics(
            loss=jnp.mean(losses),
            cosine=cosines,
            # scalar, matching the default path's jnp.mean reduction
            payload_floats=jnp.float32(syn_spec.floats + 1),
            update_norm=flat.tree_norm(agg),
        )
        return FLState(new_params, ef_new, state.round + 1), rm

    def fl_round_fused(state: FLState, client_batches: PyTree,
                       key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)
        syns, ss, ef_new, losses, cosines = jax.vmap(
            one_client_fused, in_axes=(None, 0, 0, 0))(
            state.params, state.ef, client_batches, keys)
        # the wire: the payloads are tiny -> replicated
        syns = jax.tree_util.tree_map(_replicate, syns)
        ss = _replicate(ss)
        return _fused_server_step(state, syns, ss, ef_new, losses, cosines)

    def fl_round_fused_shard(state: FLState, client_batches: PyTree,
                             key: jax.Array, weights: jax.Array = None):
        keys = jax.random.split(key, cfg.num_clients)

        def body(global_params, ef, batches, keys_):
            with jax.named_scope(CLIENT_SCOPE):
                syns, ss, ef_new, losses, cosines = jax.vmap(
                    one_client_fused, in_axes=(None, 0, 0, 0))(
                    global_params, ef, batches, keys_)
            # the wire: all-gather ONLY the (D_syn, s) payloads — O(N·payload)
            # bytes, never the O(d) reconstruction trees
            gather = lambda x: jax.lax.all_gather(x, axes, tiled=True)
            syns = jax.tree_util.tree_map(gather, syns)
            return syns, gather(ss), ef_new, gather(losses), gather(cosines)

        syns, ss, ef_new, losses, cosines = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axes), P(axes), P(axes)),
            out_specs=(P(), P(), P(axes), P(), P()),
            check_rep=False,
        )(state.params, state.ef, client_batches, keys)
        return _fused_server_step(state, syns, ss, ef_new, losses, cosines)

    return fl_round_fused if axes is None else fl_round_fused_shard


# convenience alias used in docs/examples
fl_round = make_fl_round
