"""Client-side local training: K SGD steps via ``lax.scan``.

``local_train`` consumes a stacked per-round batch pytree with leading axis
K (one entry per local step). Each local step optionally splits its batch
into ``microbatch`` gradient-accumulation slices (memory lever for the
production train_4k lowering — see DESIGN.md §3).

Returns ``g = w_global - w_local`` — the *accumulated update* with the
paper's sign convention (Eq. 3: the server SUBTRACTS the aggregate).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import flat

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jax.Array]], jax.Array]


def _grad_microbatched(loss_fn: LossFn, params: PyTree, batch: PyTree,
                       num_micro: int) -> Tuple[jax.Array, PyTree]:
    """value_and_grad, optionally accumulated over leading-dim slices."""
    if num_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def slice_batch(b, i):
        def f(x):
            mb = x.shape[0] // num_micro
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
        return jax.tree_util.tree_map(f, b)

    def body(carry, i):
        tot, acc = carry
        v, g = jax.value_and_grad(loss_fn)(params, slice_batch(batch, i))
        return (tot + v, flat.tree_add(acc, g)), None

    zero = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (tot, acc), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero), jnp.arange(num_micro))
    scale = 1.0 / num_micro
    return tot * scale, flat.tree_scale(acc, scale)


def local_train(
    loss_fn: LossFn,
    global_params: PyTree,
    batches: PyTree,                 # leading axis K
    lr: float,
    *,
    num_micro: int = 1,
) -> Tuple[PyTree, jax.Array]:
    """K local SGD steps from ``global_params``. Returns (g, mean_loss)."""

    def step(w, batch):
        v, grads = _grad_microbatched(loss_fn, w, batch, num_micro)
        w = jax.tree_util.tree_map(
            lambda p, gr: (p.astype(jnp.float32) - lr * gr.astype(jnp.float32)).astype(p.dtype),
            w, grads)
        return w, v

    w_local, losses = jax.lax.scan(step, global_params, batches)
    g = flat.tree_sub(global_params, w_local)          # w^t - w_i^t (paper sign)
    g = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), g)
    return g, jnp.mean(losses)
