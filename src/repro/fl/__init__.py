from repro.fl.client import local_train
from repro.fl.server import aggregate, server_update
from repro.fl.round import (FLState, build_fl_round, fl_init, fl_round,
                            make_fl_round)
from repro.fl.budget import matched_compressors, payload_budget
from repro.fl.engine import (ClientPools, DeliveryReport, EngineStats,
                             LiveRoundLoop, RetryPolicy, RoundEngine,
                             device_pools, token_batcher, vision_batcher)
from repro.fl.faults import (FaultSchedule, fault_schedule, null_schedule,
                             residual_mass_conserved)
from repro.fl.sharding import FLShardings, make_fl_shardings
