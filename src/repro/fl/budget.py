"""Shared payload-budget accounting and matched-compressor construction.

One source of truth for "the paper's five methods at the paper's budget
relations" — used by both the benchmark harness (``benchmarks/fl_harness``)
and the training driver (``repro.launch.train``), which previously re-derived
the same budgets independently (and drifted: the driver's copy silently
dropped ``local_batch``/``seed`` from its ``FLConfig``).

Budget math (paper Table 2 / Eq. 1): for MLP (199,210 params) the 3SFC
payload is 28·28·1 + 10 + 1 = 795 floats -> compression ratio 250.6x.
Competitor knobs derive from the same budget B: DGC keeps k = B/2 entries
(value + index per entry), STC/signSGD sit at their 32x quantization limit.

``measured_wire_bytes`` reports the same budgets as *serialized* sizes: the
``repro.comm`` codec's framed uint8 buffer, measured next to the accounted
floats wherever budgets are surfaced (``fl_harness``, ``bench_wire``).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import CompressorConfig
from repro.models.cnn import VisionSpec


def payload_budget(model_name: str, spec: VisionSpec, syn_batch: int = 1) -> float:
    """3SFC budget B for this (model, dataset): syn pixels + soft labels + s."""
    return float(syn_batch * (int(np.prod(spec.input_shape)) + spec.num_classes) + 1)


def matched_compressors(model_name: str, spec: VisionSpec, d: int,
                        syn_batch: int = 1) -> Dict[str, CompressorConfig]:
    """The paper's five methods at the paper's budget relations.

    Every returned kind is checked against the strategy registry
    (``repro.core.strategy``) so this table can never drift from what the
    runtime can actually dispatch."""
    from repro.core.strategy import strategy_kinds  # lazy: keep import-light

    B = payload_budget(model_name, spec, syn_batch)
    topk_ratio = max(B / 2.0, 1.0) / d          # 2k floats = B
    stc_ratio = (d / 33.0) / d                  # k + k/32 + 1 ~= d/32
    table = {
        "fedavg": CompressorConfig(kind="identity", error_feedback=False),
        "dgc": CompressorConfig(kind="topk", keep_ratio=topk_ratio),
        "signsgd": CompressorConfig(kind="signsgd"),
        "stc": CompressorConfig(kind="stc", keep_ratio=stc_ratio),
        # S=10 encoder iterations (Algorithm 1 line 7; "single-step" refers to
        # the single SIMULATION step, vs FedSynth's K-step unroll)
        "threesfc": CompressorConfig(kind="threesfc", syn_batch=syn_batch,
                                     syn_steps=10, syn_lr=0.1),
    }
    unknown = sorted({c.kind for c in table.values()} - set(strategy_kinds()))
    if unknown:
        raise ValueError(f"budget table names unregistered strategy kinds "
                         f"{unknown} (registered: {strategy_kinds()})")
    return table


def measured_wire_bytes(cfg: CompressorConfig, params, *,
                        syn_spec=None) -> Optional[float]:
    """Serialized uplink frame size (header included) for one client-round,
    or None for kinds without a registered wire codec (randk, fedsynth)."""
    from repro.comm.codec import wire_bytes    # lazy: keep budget import-light

    try:
        return float(wire_bytes(cfg, params, syn_spec=syn_spec))
    except KeyError:
        return None
