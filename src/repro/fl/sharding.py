"""Mesh placement for the FL round: where every FLState byte lives.

``make_fl_shardings(mesh)`` derives the one placement contract the round
engine, the round functions, and the launch drivers all share:

* ``params`` — replicated (``P()``): every device holds the global model
  w^t, so the per-client local-SGD/encode region needs no collective to
  read it and the server update runs replicated (identical on every
  device, no broadcast).
* ``client`` — leading axis sharded over ``client_axes(mesh)``: the
  dominant N×d EF residual tree, the ``ClientPools`` index/size arrays,
  the per-round ``(N, K, B, ...)`` batch trees, and the per-client PRNG
  keys all carry the client dimension first, so ONE leading-axis spec
  places all of them. Each device owns ``N / n_client_shards`` clients
  end to end — EF residuals never leave the device that updates them.
* ``scalar``/``replicated`` — ``P()`` for the round counter and metrics.

The specs are *prefix* pytrees in the jax sense: a single ``NamedSharding``
leaf applies to every array in the corresponding subtree, which is what
``jax.jit(in_shardings=...)``, ``shard_map`` specs, and the ``place_*``
helpers below all consume.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.fl.round import FLState
from repro.launch.mesh import client_axes

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLShardings:
    """NamedShardings for one mesh, derived once and threaded everywhere.

    ``state`` is an ``FLState``-shaped prefix tree (params replicated, EF
    client-sharded, round counter replicated) — pass it directly as
    ``jit``'s ``in_shardings``/``out_shardings`` entry for the state
    argument so donation reuses the *sharded* buffers in place.
    """

    mesh: Mesh
    axes: Tuple[str, ...]            # mesh axes carrying the client dim
    replicated: NamedSharding        # P(): params, metrics, scalars
    client: NamedSharding            # P(axes): leading-axis client sharding
    state: FLState                   # prefix tree for a whole FLState

    @property
    def client_shards(self) -> int:
        """How many ways the client axis is split (mesh axis size product)."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        n = 1
        for a in self.axes:
            n *= sizes[a]
        return n

    # ---- placement -------------------------------------------------------
    def place_state(self, state: FLState) -> FLState:
        """Explicitly place an FLState: params/round replicated, EF sharded
        on the client axis. Requires ``N % client_shards == 0``. The
        staleness ring buffer (when present) is replicated like the params
        it mirrors — it is server state, consumed by the replicated
        aggregate, and its leading axis is the S slots, not clients."""
        self.check_divisible(jax.tree_util.tree_leaves(state.ef)[0].shape[0])
        return FLState(
            params=jax.device_put(state.params, self.replicated),
            ef=jax.device_put(state.ef, self.client),
            round=jax.device_put(state.round, self.replicated),
            buf=(None if state.buf is None
                 else jax.device_put(state.buf, self.replicated)),
            buf_w=(None if state.buf_w is None
                   else jax.device_put(state.buf_w, self.replicated)),
        )

    def place_client_tree(self, tree: PyTree) -> PyTree:
        """Place any leading-axis-N pytree (ClientPools, stacked batches,
        per-client keys) shard-per-device on the client axis."""
        self.check_divisible(jax.tree_util.tree_leaves(tree)[0].shape[0])
        return jax.device_put(tree, self.client)

    # alias matching the ClientPools use site by name
    place_pools = place_client_tree

    def constrain_client_tree(self, tree: PyTree) -> PyTree:
        """In-jit version of ``place_client_tree``: pin a traced batch tree
        to the client sharding so GSPMD never round-trips it through one
        device between the gather and the shard_map fan-out."""
        return jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(x, self.client), tree)

    def check_divisible(self, num_clients: int) -> None:
        if num_clients % self.client_shards != 0:
            raise ValueError(
                f"num_clients={num_clients} is not divisible by the mesh's "
                f"{self.client_shards} client shard(s) (axes {self.axes} of "
                f"mesh {dict(zip(self.mesh.axis_names, self.mesh.devices.shape))}); "
                f"pad or regroup clients so each device owns a whole slice")


def make_fl_shardings(mesh: Mesh) -> FLShardings:
    """Derive the FL placement contract from a mesh (see module docstring)."""
    axes = client_axes(mesh)
    replicated = NamedSharding(mesh, P())
    client = NamedSharding(mesh, P(axes))
    return FLShardings(
        mesh=mesh,
        axes=axes,
        replicated=replicated,
        client=client,
        state=FLState(params=replicated, ef=client, round=replicated,
                      buf=replicated, buf_w=replicated),
    )
