"""Server-side aggregation G(·) and global-model update (paper Eq. 3/4/6)."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def aggregate(recons: PyTree, weights: Optional[jax.Array] = None) -> PyTree:
    """G over the leading client axis: arithmetic mean or |D_i|-weighted."""
    if weights is None:
        return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), recons)
    w = weights / jnp.sum(weights)

    def wmean(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(wb * x, axis=0)

    return jax.tree_util.tree_map(wmean, recons)


def server_update(global_params: PyTree, agg_update: PyTree,
                  server_lr: float = 1.0) -> PyTree:
    """w^{t+1} = w^t - lr * G(...). agg_update carries the paper's g sign."""
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) - server_lr * u.astype(jnp.float32)).astype(p.dtype),
        global_params, agg_update)
