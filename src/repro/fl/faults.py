"""Fault model for federated rounds: who shows up, what arrives, and when.

Production FL never sees the clean world the round pipeline assumes (every
round, all N clients report back on time with intact frames). This module
defines the repo's fault semantics as *data* — a deterministic per-round
``FaultSchedule`` of masks that stays inside the jitted/scanned round, so
the device-resident engine keeps its 1-dispatch/1-sync contract — and the
EF-correctness contract every fault pattern must satisfy.

Fault taxonomy (per client i, round t)
--------------------------------------
* **non-participation** (``participate[i] = False``): the client is not
  scheduled this round. It does not train, its EF residual is FROZEN
  (``e^{t+1} = e^t`` — no silent decay), its loss is excluded from the
  round mean, and it contributes nothing to the aggregate.
* **dropout mid-round** (``delivered[i] = False`` while participating):
  the client trained and encoded, but its payload never reached the
  server (crash, disconnect, corrupt frame the driver gave up on). The
  server renormalizes over the payloads it DID receive; the client keeps
  its whole accumulated update in the residual (``e^{t+1} = u^t = g + e^t``
  under error feedback), so nothing is silently lost.
* **straggler / staleness** (``delay[i] = k > 0``): the round-t payload
  arrives at round t+k (bounded by ``staleness_max``). The client's EF
  updates normally at t (the payload IS delivered, just late); the server
  banks the reconstruction in the ``FLState`` staleness ring buffer and
  folds it into the round-(t+k) aggregate with staleness weight
  ``1 / (1 + k)`` (fresh payloads weigh exactly 1.0), renormalizing by the
  total arrived weight.

EF residual-mass conservation
-----------------------------
The contract, provable per round for EVERY fault pattern: with error
feedback on, the client-side residual plus the payload the server will
(eventually) receive equals the accumulated update::

    participate=0:            e' = e,        delivered 0        (no update)
    delivered=0 (dropped):    e' = u,        delivered 0        u = g + e
    delay=k (straggler):      e' = u - r,    delivered r at t+k
    healthy:                  e' = u - r,    delivered r at t

Summing either side: no term of ``u`` is ever silently destroyed — faults
move mass between the residual and the wire, never out of the system.
``residual_mass_conserved`` checks the identity on concrete trees;
``tests/test_faults.py`` drives it across strategies and fault patterns.

Determinism contract
--------------------
``fault_schedule`` derives every mask from
``fold_in(PRNGKey(fault_seed), round)`` — the same absolute-round fold_in
convention as the engine's sampling streams — so the schedule for round t
is a pure function of ``(fault_seed, t)``: independent of eval-block
grouping (cadence invariance), of the fan-out, and of any other stream
(the fault key never touches the data/compressor keys).

The zero-fault schedule (participation rate 1, drop rate 0, staleness 0)
is *bitwise* inert: every mask it produces is all-true/all-zero, every
weight exactly 1.0, and the masked round pipeline reduces to the unfaulted
one bit-for-bit (gated in ``benchmarks/bench_faults.py``).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import flat

PyTree = Any

# fold offset for the fault stream: PRNGKey(fault_seed) is a stream of its
# own (the engine folds its data/compressor streams from PRNGKey(fl.seed)),
# so fault patterns can be re-seeded without perturbing training draws.
FAULT_FOLD = 2


class FaultSchedule(NamedTuple):
    """One round's fault pattern over N clients — pure arrays, jit-resident.

    ``participate``/``delivered`` are (N,) bool; ``delay`` is (N,) int32 in
    ``[0, staleness_max]`` (0 for everyone when staleness is off); ``weight``
    is the (N,) f32 staleness aggregation weight ``1/(1+delay)`` — exactly
    1.0 wherever ``delay == 0``, so a zero-fault schedule multiplies
    nothing by anything but 1.0.
    """

    participate: jax.Array
    delivered: jax.Array
    delay: jax.Array
    weight: jax.Array

    @property
    def arrives_now(self) -> jax.Array:
        """(N,) bool: payload delivered this round with zero delay."""
        return self.participate & self.delivered & (self.delay == 0)

    @property
    def arrives_late(self) -> jax.Array:
        """(N,) bool: payload delivered, but banked for a future round."""
        return self.participate & self.delivered & (self.delay > 0)


def staleness_weight(delay: jax.Array) -> jax.Array:
    """Aggregation weight of a payload ``delay`` rounds late: 1/(1+delay).

    Exactly 1.0 at delay 0 (the IEEE-exact identity the zero-fault bitwise
    gate relies on); monotonically discounts staler payloads, the standard
    polynomial staleness function of async FL.
    """
    return 1.0 / (1.0 + delay.astype(jnp.float32))


def fault_schedule(fault_key: jax.Array, round_idx: jax.Array,
                   num_clients: int, *, participation_rate: float = 1.0,
                   drop_rate: float = 0.0, straggler_rate: float = 0.0,
                   staleness_max: int = 0) -> FaultSchedule:
    """The round's ``FaultSchedule``, a pure function of (key, round).

    All draws come from ``fold_in(fault_key, round_idx)`` split four ways
    (participation, dropout, straggling, delay), so the pattern depends on
    the absolute round counter only — same seed ⇒ same schedule regardless
    of how rounds are grouped into scan blocks.

    Rate edge cases are exact, not approximate: ``uniform() < 1.0`` is
    always true (uniform draws live in [0, 1)) and ``uniform() < 0.0``
    never, so rate-1 participation and rate-0 dropout/straggling produce
    all-true/all-false masks bitwise, with no special-casing.
    """
    k = jax.random.fold_in(fault_key, round_idx)
    kp, kd, ks, kl = jax.random.split(k, 4)
    n = (num_clients,)
    participate = jax.random.uniform(kp, n) < participation_rate
    delivered = ~(jax.random.uniform(kd, n) < drop_rate)
    if staleness_max > 0:
        straggle = jax.random.uniform(ks, n) < straggler_rate
        delay = jnp.where(
            straggle,
            jax.random.randint(kl, n, 1, staleness_max + 1), 0
        ).astype(jnp.int32)
    else:
        delay = jnp.zeros(n, jnp.int32)
    return FaultSchedule(participate, delivered, delay,
                         staleness_weight(delay))


def null_schedule(num_clients: int) -> FaultSchedule:
    """The all-healthy schedule: everyone participates, everything arrives
    on time with weight exactly 1.0."""
    n = (num_clients,)
    return FaultSchedule(jnp.ones(n, bool), jnp.ones(n, bool),
                         jnp.zeros(n, jnp.int32), jnp.ones(n, jnp.float32))


# ---------------------------------------------------------------------------
# staleness ring buffer (server-side FLState extension)
# ---------------------------------------------------------------------------


def init_stale_buffer(params: PyTree, staleness_max: int):
    """Zeroed ring buffer for payloads in flight: per params leaf a
    ``(S, *shape)`` f32 bank (slot j holds the weighted sum of
    reconstructions maturing at rounds ≡ j mod S) plus the matching (S,)
    arrived-weight accumulator. Returns ``(None, None)`` when staleness is
    off so the zero-fault ``FLState`` keeps its exact seed structure."""
    if staleness_max <= 0:
        return None, None
    buf = jax.tree_util.tree_map(
        lambda p: jnp.zeros((staleness_max, *p.shape), jnp.float32), params)
    return buf, jnp.zeros((staleness_max,), jnp.float32)


def consume_and_bank(buf: PyTree, buf_w: jax.Array, round_idx: jax.Array,
                     delay: jax.Array, w_late: jax.Array, recons: PyTree):
    """One round of ring-buffer turnover.

    ``w_late`` is the (N,) banking weight of each client's payload —
    nonzero only for payloads arriving late (staleness weight, optionally
    times a caller aggregation weight). Returns ``(mature, mature_w,
    new_buf, new_buf_w)``: the weighted-sum tree + weight maturing THIS
    round (slot ``t mod S``), and the buffer with that slot recycled and
    every late payload banked at slot ``(t + delay) mod S``.
    Consume-then-bank ordering makes ``delay == S`` land in the just-freed
    slot (arrives at exactly t+S, the bound). On-time payloads carry
    ``w_late == 0`` into the consumed slot — an exact no-op — so the
    scatter needs no gating.
    """
    S = buf_w.shape[0]
    slot = jnp.mod(round_idx, S)
    mature = jax.tree_util.tree_map(lambda b: b[slot], buf)
    mature_w = buf_w[slot]
    target = jnp.mod(round_idx + delay, S)                         # (N,)

    def bank(b, r):
        wb = w_late.reshape((-1,) + (1,) * (r.ndim - 1))
        return b.at[slot].set(0.0).at[target].add(
            wb * r.astype(jnp.float32))

    new_buf = jax.tree_util.tree_map(bank, buf, recons)
    new_buf_w = buf_w.at[slot].set(0.0).at[target].add(w_late)
    return mature, mature_w, new_buf, new_buf_w


def pending_mass(buf_w: Optional[jax.Array]) -> jax.Array:
    """Total staleness weight still in flight (0 when staleness is off) —
    the bench's observability hook for 'how much update is in the air'."""
    if buf_w is None:
        return jnp.zeros((), jnp.float32)
    return jnp.sum(buf_w)


# ---------------------------------------------------------------------------
# the EF-correctness oracle (host-side, test/bench surface)
# ---------------------------------------------------------------------------


def residual_mass_conserved(u: PyTree, e_new: PyTree, delivered_payload: PyTree,
                            *, atol: float = 0.0) -> bool:
    """Check the per-round conservation identity  e' + delivered == u.

    ``delivered_payload`` is the reconstruction the server will (eventually)
    receive from this client — the zero tree for a dropped payload. Exact
    by construction for the frozen/dropped branches (pure ``where``
    selects); the healthy/straggler branch is ``u - r + r``, conserving up
    to one f32 rounding of the subtraction — pass a small ``atol`` there.
    """
    diff = flat.tree_sub(u, flat.tree_add(e_new, delivered_payload))
    worst = max((float(jnp.max(jnp.abs(l))) if l.size else 0.0
                 for l in jax.tree_util.tree_leaves(diff)), default=0.0)
    return worst <= atol
