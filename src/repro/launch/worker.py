"""Client worker process: the other end of the socket transport.

``python -m repro.launch.worker --connect host:port --client-id i`` dials
the ``repro.comm.transport.SocketServer`` at ``host:port``, introduces
itself (HELLO), rebuilds the *entire* client-side computation from the
server's SETUP blob — model, synthetic dataset, Dirichlet partition,
strategy, codec, PRNG streams — and then serves rounds until STOP.

Determinism contract (the socket-vs-oracle bitwise gate rests on this)
----------------------------------------------------------------------
The worker recomputes exactly what the in-process oracle's client ``i``
computes, from nothing but the SETUP blob and its client id:

* model params come from ``model.init(PRNGKey(run.fl.seed))`` — but the
  round's *global* params are always the server's ROUND broadcast
  (identity-codec framed, lossless), so server and workers agree bit for
  bit even after faulted rounds;
* the batch for (round r, client i) follows the engine PRNG contract
  (``repro.fl.engine``): ``pos = randint(fold_in(fold_in(data_key, r), i),
  (K, B), 0, size_i)`` over the device-resident pools — the gather indices
  are integer math, identical at any fan-out width;
* the compressor key is ``split(fold_in(round_key, r), N)[i]`` — the same
  element of the same split the oracle's vmap consumes;
* the client step runs as a width-1 ``jax.vmap`` over the SAME
  ``client_step`` body as ``fl.round``'s fan-out (local_train ->
  ``strategy.wire_step``), with the batch gather inside the same jit.

EF commit protocol
------------------
The worker holds its EF residual locally and *defers* the commit until the
server's ACK for the round arrives: ACK(delivered=1) commits the
strategy's post-compression residual (``e' = u - r``), ACK(delivered=0)
banks the whole accumulated update (``e' = u = g + e``) — byte-for-byte
the fault algebra of ``repro.fl.faults``, which is what makes residual-
mass conservation hold over a real wire. A round that is still un-acked
when the next ROUND arrives is committed as undelivered (conservative: the
server has necessarily moved on without this client's frame). MSG_EF_REQ
dumps the committed residual as a flat f32 leaf stream — the observability
hook the conservation gates read.

Every commit is also *pushed* to the server (MSG_EF_PUSH, tagged with the
committed round): the server's EF bank then always holds this client's
last-committed residual, which is the only state the worker process owns.
That bank is the recovery source for elastic membership — when this
process is killed and a replacement connects, the server re-syncs it with
MSG_EF_SYNC and the residual continues bitwise from where it died
(``VisionClientCompute.install_ef``).

A non-participating round (ROUND flags bit 0 clear) is sat out entirely:
no compute, no frame, EF frozen — the ``participate=False`` branch.

Induced straggle: the SETUP blob may carry ``straggle[cid] = seconds``;
the worker then sleeps that long each round between computing and sending
its frame (the heartbeat thread keeps ticking, so a straggler is *alive*,
just late — the server's deadline, not the straggler's nap, bounds the
round).
"""
from __future__ import annotations

import argparse
import json
import struct
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.transport import (FLAG_PARTICIPATE, MSG_ACK, MSG_EF_DUMP,
                                  MSG_EF_PUSH, MSG_EF_REQ, MSG_EF_SYNC,
                                  MSG_FRAME, MSG_METRIC, MSG_RESEND,
                                  MSG_ROUND, MSG_SETUP, MSG_STOP, ServerLink)
from repro.obs import configure_tracer, get_logger, get_tracer

PyTree = Any

# pre-SETUP heartbeat period: the worker must look alive from the moment it
# connects (jit compilation of the client step can take seconds), before it
# knows the configured heartbeat_s
_BOOT_HEARTBEAT_S = 0.2


def vision_setup(run, *, model: str, spec, train_size: int,
                 straggle: Optional[Dict[int, float]] = None,
                 trace: bool = False) -> Dict:
    """The SETUP blob for a vision run — everything a worker needs to
    rebuild the client computation, JSON-serializable. One construction
    shared by the training CLI, the transport bench and the tests so the
    blob's schema cannot drift between drivers. ``trace=True`` turns on
    the worker-side span recorder (spans ride back on MSG_METRIC)."""
    return {
        "kind": "vision",
        "model": model,
        "spec": [spec.name, list(spec.input_shape), int(spec.num_classes)],
        "train_size": int(train_size),
        "run": run.to_json(),
        "straggle": {str(k): float(v) for k, v in (straggle or {}).items()},
        "trace": bool(trace),
    }


class VisionClientCompute:
    """Client ``i``'s half of the vision round, rebuilt from a SETUP blob.

    Holds the local EF residual (leading axis 1, mirroring the oracle's
    per-client row) plus the deferred-commit slot the ACK protocol fills.
    """

    def __init__(self, setup: Dict, client_id: int):
        from repro.configs.run import RunConfig
        from repro.configs.base import CompressorConfig
        from repro.comm.codec import make_codec
        from repro.core.strategy import make_strategy
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_class_image_dataset
        from repro.fl.client import local_train
        from repro.fl.engine import device_pools
        from repro.models.build import vision_syn_spec
        from repro.models.cnn import VisionSpec, make_paper_model

        run = RunConfig.from_json(setup["run"])
        cfg = run.fl
        spec = VisionSpec(setup["spec"][0], tuple(setup["spec"][1]),
                          int(setup["spec"][2]))
        model = make_paper_model(setup["model"], spec)
        params = model.init(jax.random.PRNGKey(cfg.seed))
        comp = cfg.compressor
        strategy = make_strategy(comp, loss_fn=model.syn_loss,
                                 syn_spec=vision_syn_spec(spec, comp),
                                 local_lr=cfg.local_lr)
        codec = strategy.wire_codec(params, policy=run.wire_policy)

        key = jax.random.PRNGKey(cfg.seed)
        train = make_class_image_dataset(key, setup["train_size"],
                                         spec.input_shape, spec.num_classes)
        parts = dirichlet_partition(train.y, cfg.num_clients,
                                    alpha=cfg.dirichlet_alpha, seed=cfg.seed,
                                    min_per_client=cfg.local_batch)
        pools = device_pools(parts)
        x = jnp.asarray(train.x)
        y = jnp.asarray(train.y)

        base = jax.random.PRNGKey(cfg.seed)
        data_key = jax.random.fold_in(base, 0)    # engine _DATA_FOLD
        round_key = jax.random.fold_in(base, 1)   # engine _ROUND_FOLD

        self.run = run
        self.codec = codec
        i = int(client_id)
        N = cfg.num_clients
        K, B = cfg.local_steps, cfg.local_batch
        loss_fn = model.loss

        # width-1 row of the oracle's per-client state
        self.ef = jax.tree_util.tree_map(
            lambda e: e[None], strategy.init_ef_state(params))
        self._pending: Optional[Dict] = None

        # the downlink params frame is identity-coded (lossless f32)
        self._down = make_codec(
            CompressorConfig(kind="identity", error_feedback=False), params)
        self._dec = jax.jit(
            lambda buf: self._down.recon_tree(self._down.decode(buf), params))

        def client_step(global_params, ef_i, batches_i, key_i, cid, rnd):
            # the oracle's client body verbatim (fl.round client phase)
            g, loss = local_train(loss_fn, global_params, batches_i,
                                  cfg.local_lr, num_micro=run.num_micro)
            msg, ef_new, _ = strategy.wire_step(
                key_i, g, ef_i, global_params, codec=codec,
                round_idx=rnd, client_idx=cid)
            ef_drop = strategy._accumulate(g, ef_i) \
                if comp.error_feedback else ef_i
            return msg, ef_new, ef_drop, loss

        def step(p, ef, r):
            # batch gather inside the jit, per the engine PRNG contract
            kr = jax.random.fold_in(data_key, r)
            k = jax.random.fold_in(kr, i)
            pos = jax.random.randint(k, (K, B), 0, pools.size[i])
            idx = pools.index[i, pos]
            batches = {"x": x[idx][None], "y": y[idx][None]}
            keys = jax.random.split(
                jax.random.fold_in(round_key, r), N)[i:i + 1]
            cids = jnp.arange(N, dtype=jnp.uint32)[i:i + 1]
            return jax.vmap(client_step, in_axes=(None, 0, 0, 0, 0, None))(
                p, ef, batches, keys, cids, r)

        self._step = jax.jit(step)

    def decode_params(self, frame_bytes: bytes) -> PyTree:
        return self._dec(jnp.asarray(np.frombuffer(frame_bytes, np.uint8)))

    def compute(self, params: PyTree, round_idx: int):
        """Run client ``i``'s round ``round_idx``; stages the two EF
        branches for the deferred ACK commit. Returns (frame bytes, loss)."""
        msg, ef_new, ef_drop, loss = self._step(
            params, self.ef, jnp.int32(round_idx))
        self._pending = {"round": round_idx, "ef_new": ef_new,
                         "ef_drop": ef_drop}
        return np.asarray(msg[0], np.uint8).tobytes(), float(loss[0])

    def pending_round(self) -> Optional[int]:
        return None if self._pending is None else self._pending["round"]

    def commit(self, delivered: bool) -> None:
        """Resolve the staged round: the strategy residual on delivery, the
        whole banked update on drop (fault algebra of ``repro.fl.faults``),
        cast back to the carried EF dtype exactly like the oracle's
        ``finish``."""
        if self._pending is None:
            return
        src = self._pending["ef_new" if delivered else "ef_drop"]
        self.ef = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), src, self.ef)
        self._pending = None

    def ef_bytes(self) -> bytes:
        """Committed EF residual as the flat f32 leaf stream MSG_EF_DUMP
        and MSG_EF_PUSH carry (tree_leaves order, matching any host-side
        flattening of the oracle's EF row)."""
        return np.concatenate(
            [np.asarray(l[0], np.float32).ravel()
             for l in jax.tree_util.tree_leaves(self.ef)]).tobytes()

    def install_ef(self, stream: bytes) -> None:
        """Install a server-synced residual (flat f32 leaf stream, the
        MSG_EF_SYNC body) — the rejoin path: a restarted worker process
        lost its residual with its life, and the server's EF bank is the
        recovery source. Clears any staged round (it predates the sync)."""
        flat = np.frombuffer(stream, np.float32)
        leaves, treedef = jax.tree_util.tree_flatten(self.ef)
        total = sum(int(l.size) for l in leaves)
        if flat.size != total:
            raise ValueError(
                f"EF sync stream carries {flat.size} floats, this client's "
                f"residual has {total}")
        out, off = [], 0
        for l in leaves:
            n = int(l.size)
            out.append(jnp.asarray(flat[off:off + n].reshape(l.shape),
                                   dtype=l.dtype))
            off += n
        self.ef = jax.tree_util.tree_unflatten(treedef, out)
        self._pending = None


def build_compute(setup: Dict, client_id: int):
    if setup.get("kind") != "vision":
        raise ValueError(
            f"worker only knows how to rebuild 'vision' runs, got "
            f"{setup.get('kind')!r}")
    return VisionClientCompute(setup, client_id)


def _serve(link: ServerLink, compute, client_id: int,
           straggle_s: float, log=None) -> None:
    """The worker's message loop: ROUND -> compute/frame/metric, RESEND ->
    re-send the cached frame, ACK -> commit the EF branch, EF_REQ -> dump,
    STOP -> exit. Single-threaded on purpose (besides the heartbeat): the
    protocol is strictly ordered per connection, so there is nothing to
    race.

    When the process tracer is enabled (SETUP ``trace``), the round's
    decode/compute/straggle spans are drained and piggybacked on the
    MSG_METRIC body — they reach the server in-band, on this worker's own
    clock, for offset-shifted merge into the server trace."""
    if log is None:
        log = get_logger("worker", client=client_id)
    tracer = get_tracer()
    last_frame: Optional[bytes] = None
    last_round = -1

    def commit_and_push(delivered: bool) -> None:
        # resolve the staged round, then push the committed residual so the
        # server's EF bank tracks this client's last commit (the rejoin /
        # resume recovery source)
        staged = compute.pending_round()
        if staged is None:
            return
        compute.commit(delivered=delivered)
        stream = compute.ef_bytes()
        link.send(MSG_EF_PUSH, struct.pack("<I", staged) + stream)
        tracer.event("ef_push", round=staged, bytes=len(stream),
                     delivered=delivered)

    while True:
        mtype, body = link.recv()
        if mtype == MSG_STOP:
            log.info("stop received, exiting")
            return
        if mtype == MSG_ROUND:
            rnd, flags = struct.unpack_from("<IB", body)
            rlog = log.bind(round=rnd)
            # a still-staged previous round means the server moved on
            # without acking us — it necessarily gave up on our frame
            commit_and_push(delivered=False)
            if not flags & FLAG_PARTICIPATE:
                last_frame, last_round = None, rnd
                rlog.debug("sitting round out")
                continue                     # sit the round out; EF frozen
            with tracer.span("worker.decode", round=rnd, phase="decode",
                             bytes=len(body) - 5):
                params = compute.decode_params(body[5:])
            with tracer.span("worker.compute", round=rnd, phase="compute"):
                frame, loss = compute.compute(params, rnd)
            if straggle_s > 0:
                with tracer.span("worker.straggle", round=rnd,
                                 phase="straggle", sleep_s=straggle_s):
                    time.sleep(straggle_s)   # alive (heartbeats), just late
            payload = struct.pack("<If", rnd, loss)
            spans = tracer.drain()
            if spans:
                payload += json.dumps(spans).encode("utf-8")
            link.send(MSG_METRIC, payload)
            with tracer.span("worker.send", round=rnd, phase="send",
                             bytes=len(frame)):
                link.send(MSG_FRAME, frame)
            last_frame, last_round = frame, rnd
            rlog.debug("served: loss=%.4f frame=%dB", loss, len(frame))
        elif mtype == MSG_RESEND:
            (rnd,) = struct.unpack("<I", body)
            if last_frame is not None and rnd == last_round:
                tracer.event("worker.resend", round=rnd,
                             bytes=len(last_frame))
                link.send(MSG_FRAME, last_frame)
                log.bind(round=rnd).info("re-sent frame (%dB)",
                                         len(last_frame))
        elif mtype == MSG_ACK:
            rnd, delivered = struct.unpack("<IB", body)
            if compute.pending_round() == rnd:
                commit_and_push(delivered=bool(delivered))
        elif mtype == MSG_EF_REQ:
            link.send(MSG_EF_DUMP, compute.ef_bytes())
        elif mtype == MSG_EF_SYNC:
            # server-held residual (rejoin/resume): install and continue
            # from exactly where the previous incarnation committed
            compute.install_ef(body[4:])
            tracer.event("ef_sync", bytes=len(body) - 4)
            log.info("EF residual re-synced from server (%dB)",
                     len(body) - 4)
        # unknown/duplicate control messages are ignored: the server owns
        # the protocol version, the worker just serves what it understands


def run_worker(address, client_id: int) -> None:
    log = get_logger("worker", client=client_id)
    link = ServerLink.connect(tuple(address), client_id)
    log.info("connected to %s:%s", *tuple(address))
    # look alive immediately — SETUP parsing and jit compilation happen
    # before the configured heartbeat is known
    link.start_heartbeat(_BOOT_HEARTBEAT_S)
    try:
        setup = None
        while setup is None:
            mtype, body = link.recv()
            if mtype == MSG_STOP:
                return
            if mtype == MSG_SETUP:
                setup = json.loads(body.decode("utf-8"))
        if setup.get("trace"):
            configure_tracer(True, proc=f"client-{client_id}")
        t0 = time.monotonic()
        compute = build_compute(setup, client_id)
        log.info("computation rebuilt in %.1fs", time.monotonic() - t0)
        hb = compute.run.heartbeat_s
        if hb < _BOOT_HEARTBEAT_S:
            link.start_heartbeat(hb)         # beat faster than configured
        straggle_s = float(setup.get("straggle", {}).get(str(client_id), 0.0))
        if straggle_s > 0:
            log.info("induced straggle: %.2fs per round", straggle_s)
        _serve(link, compute, client_id, straggle_s, log=log)
    except (ConnectionError, OSError):
        log.info("server connection lost, exiting")
    finally:
        link.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--client-id", type=int, required=True, dest="client_id")
    args = ap.parse_args(argv)
    host, port = args.connect.rsplit(":", 1)
    run_worker((host, int(port)), args.client_id)


if __name__ == "__main__":
    main()
